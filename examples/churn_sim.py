"""DGRO self-repair under a correlated regional failure.

A FABRIC fleet loses an entire site at t=5s; SWIM detection confirms the
crashes, the churn engine tombstones the victims, ring repairs stitch the
survivors, and DGRO's ring-selection repair (Algorithm 3 over the live
fleet) restores a low-diameter overlay — all on incrementally-maintained
distances.  Chord replays the same trace for contrast.

    PYTHONPATH=src python examples/churn_sim.py
"""
import numpy as np

from repro.dynamics import ChordPolicy, ChurnEngine, DGROPolicy
from repro.dynamics.scenarios import regional_failure


def main():
    trace = regional_failure(n0=51, site=0, t_fail=5_000.0, seed=1)
    victims = sorted({e.node for e in trace.events})
    print(f"== regional failure: site 0 of a {trace.n0}-host FABRIC fleet ==")
    print(f"victims (slots at site 0): {victims}")
    print(f"trace is replayable JSON ({len(trace.to_json())} bytes)\n")

    for policy in (DGROPolicy(adapt_every=2), ChordPolicy()):
        eng = ChurnEngine(trace, policy, seed=0, detect_failures=True)
        res = eng.run(sample_exact=True)
        print(f"-- {policy.name} --")
        print("   t(ms)  event      live  diameter(ms)")
        for s in res.samples:
            print(f"{s.time:8.0f}  {s.event:<9s}  {s.n_live:4d}  "
                  f"{s.diameter:8.1f}")
        st = res.stats
        print(f"final (exact) diameter: {res.final_diameter:.1f}ms | "
              f"relaxations={st['relaxations']} rebuilds={st['rebuilds']}"
              + (f" ring-adaptations={st['adaptations']}"
                 if "adaptations" in st else ""))
        assert eng.inc.n_live == trace.n0 - len(victims)
        assert np.isfinite(res.final_diameter)
        print()


if __name__ == "__main__":
    main()
