"""Manual data-parallel training with int8-compressed gradient all-reduce
over the DGRO ring (8 simulated hosts) — the distributed-optimization demo.

Must set the device-count flag before jax imports, so this example is its
own process:

    PYTHONPATH=src python examples/compressed_dp.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np                      # noqa: E402
import jax                              # noqa: E402
import jax.numpy as jnp                 # noqa: E402
from repro.compat import make_mesh, shard_map  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_arch      # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticLM  # noqa: E402
from repro.models import model as Mdl   # noqa: E402
from repro.train.collectives import compressed_grad_allreduce  # noqa: E402
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: E402
from repro.train.train_step import cross_entropy  # noqa: E402


def main():
    n_hosts = 8
    mesh = make_mesh((n_hosts,), ("data",))
    cfg = get_arch("musicgen-large").smoke()
    params = Mdl.init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=2e-3)
    opt = adamw_init(params)
    err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=n_hosts * 2))

    def local_loss(p, batch):
        logits, _ = Mdl.forward(cfg, p, batch["tokens"], mode="train")
        loss, _ = cross_entropy(logits, batch["labels"])
        return loss

    def dp_step(p, opt, err, batch):
        """Runs per-host: local grads -> int8 ring all-reduce + error
        feedback -> identical AdamW update on every host."""
        loss, grads = jax.value_and_grad(local_loss)(p, batch)
        grads, new_err = compressed_grad_allreduce(grads, "data", err)
        new_p, new_opt, gnorm = adamw_update(opt_cfg, grads, opt, p)
        return new_p, new_opt, new_err, jax.lax.pmean(loss, "data"), gnorm

    step = shard_map(
        dp_step, mesh=mesh,
        in_specs=(P(), P(), P(), P("data")),
        out_specs=(P(), P(), P(), P(), P()),
        check_vma=False)
    step = jax.jit(step, donate_argnums=(0, 1, 2))

    print(f"== compressed DP: {n_hosts} hosts, int8 ring all-reduce ==")
    for i in range(12):
        raw = data.batch(i)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt, err, loss, gnorm = step(params, opt, err, batch)
        if i % 2 == 0:
            print(f"step {i:3d} loss {float(loss):7.4f} "
                  f"gnorm {float(gnorm):6.3f}")
    print("[example] OK: trained with 4x-compressed DCN gradient traffic")


if __name__ == "__main__":
    main()
