"""Overlay-as-a-service quickstart: boot, stream churn, query, recover.

Boots the :mod:`repro.service` control plane in-process, streams a
churn+drift trace through the versioned /v1 HTTP API, queries the live
overlay while a background re-optimization is in flight, snapshots, and
restores the state from disk — the full daemon lifecycle in one script.

    PYTHONPATH=src python examples/service_quickstart.py
"""
import tempfile

from repro.dynamics.scenarios import Trace, churn_with_drift
from repro.service import ServiceClient, ServiceServer, ServiceState

N0 = 32


def main():
    trace = churn_with_drift(n0=N0, dist="bitnode", seed=2,
                             join_rate=1.5e-3, leave_rate=1.5e-3)
    events = sorted(trace.events, key=lambda e: e.time)[:40]
    snapdir = tempfile.mkdtemp(prefix="dgro-quickstart-")

    world = Trace(n0=N0, capacity=trace.capacity, dist="bitnode", seed=2,
                  events=[], name="quickstart")
    state = ServiceState.fresh(world, policy="dgro", snapshot_dir=snapdir)
    server = ServiceServer(state, reopt_every=16, reopt_eps=0.45).start()
    print(f"== serving the /v1 control plane at {server.url} ==")

    client = ServiceClient(server.url)
    client.wait_ready()
    d0 = client.diameter()
    print(f"boot: {d0['n_live']} live nodes, diameter {d0['diameter']:.1f}ms")

    print(f"\nstreaming {len(events)} churn+drift events ...")
    for i in range(0, len(events), 8):
        res = client.post_events(events[i:i + 8])
        st = client.stats()
        print(f"  t={res['clock']:7.0f}ms  live={res['n_live']:3d}  "
              f"distances={st['distances_are']:<11s}  "
              f"reopts={st['reopts_completed']}")

    client.reoptimize()                       # async; queries keep answering
    nodes = client.adjacency()["nodes"]
    route = client.route(nodes[0], nodes[-1])
    print(f"\nroute {route['src']} -> {route['dst']}: "
          f"{route['distance']:.1f}ms ({route['bound']} bound), "
          f"path {route['path']}")

    snap = client.snapshot()
    print(f"snapshot #{snap['seq']} committed -> {snap['path']}")
    server.stop(final_snapshot=True)       # drains the re-optimizer first
    d1 = state.diameter(exact=True)
    print(f"stopped; exact diameter was {d1['diameter']:.1f}ms "
          f"(version {d1['version']})")

    restored = ServiceState.restore(snapdir)
    d2 = restored.diameter(exact=True)
    print(f"restored from {snapdir}: diameter {d2['diameter']:.1f}ms, "
          f"{d2['n_live']} live — matches: "
          f"{abs(d2['diameter'] - d1['diameter']) < 1e-4}")


if __name__ == "__main__":
    main()
