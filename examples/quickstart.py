"""Quickstart: the DGRO pipeline on a realistic latency matrix in ~30s.

    PYTHONPATH=src python examples/quickstart.py

Builds a FABRIC-style 64-node fleet through the ``repro.overlay`` API:
compares ring constructions (random / nearest / DGRO-adaptive), runs the
gossip latency measurement (Alg. 3) and the rho-based selection (§V), and
shows the parallel construction (Alg. 4).
"""
import numpy as np

from repro import overlay
from repro.core.construction import nearest_ring, random_ring
from repro.core.selection import (clustering_ratio, measure_latency_stats,
                                  select_ring_kind)
from repro.core.topology import make_latency


def main():
    n, k = 64, 3
    w = make_latency("fabric", n, seed=0)
    rng = np.random.default_rng(0)

    print(f"== DGRO quickstart: {n} nodes, FABRIC latencies, K={k} rings ==")

    ov_rand = overlay.build("random", w, overlay.RandomRingsConfig(k=k),
                            rng=rng)
    ov_near = overlay.Overlay.from_rings(
        w, [nearest_ring(w, 0)] + [random_ring(rng, n) for _ in range(k - 1)])
    print(f"random K-ring diameter          : {ov_rand.diameter():7.1f} ms")
    print(f"nearest+random K-ring diameter  : {ov_near.diameter():7.1f} ms")

    # --- Algorithm 3: gossip latency measurement + rho selection (§V) ---
    stats = measure_latency_stats(w, ov_rand.adjacency, seed=0)
    rho = clustering_ratio(stats)
    kind = select_ring_kind(rho)
    print(f"measured: L_local={stats.l_local:.1f} L_global={stats.l_global:.1f} "
          f"L_min={stats.l_min:.1f} -> rho={rho:.2f} -> add {kind!r} ring")

    ov_dgro = overlay.build("dgro", w, overlay.DGROConfig(k=k), rng=rng)
    print(f"DGRO adaptive ({ov_dgro.num_rings} rho-selected rings)      : "
          f"{ov_dgro.diameter():7.1f} ms "
          f"({(1 - ov_dgro.diameter() / ov_rand.diameter()) * 100:.0f}% "
          f"better than random)")

    # --- Algorithm 4: parallel construction ---
    print("\nparallel construction (Alg. 4):")
    for m in (1, 4, 16):
        ov_p = overlay.build("parallel", w, overlay.ParallelConfig(m=m),
                             seed=0)
        print(f"  {m:3d} partitions -> single-ring diameter "
              f"{ov_p.diameter():7.1f} ms ({n // m} sequential steps)")

    # overlays snapshot/restore as JSON (benchmark artifacts, trace replays)
    restored = overlay.Overlay.from_json(ov_dgro.to_json())
    assert restored.equals(ov_dgro)
    print(f"\noverlay JSON round-trip OK ({len(ov_dgro.to_json())} bytes, "
          f"policy={restored.policy!r}, degree stats {restored.degree_stats()})")


if __name__ == "__main__":
    main()
