"""Quickstart: the DGRO pipeline on a realistic latency matrix in ~30s.

    PYTHONPATH=src python examples/quickstart.py

Builds a FABRIC-style 64-node fleet, compares ring constructions (random /
nearest / DGRO-adaptive), runs the gossip latency measurement (Alg. 3) and
the rho-based selection (§V), and shows the parallel construction (Alg. 4).
"""
import numpy as np

from repro.core.construction import k_rings, nearest_ring, random_ring
from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.core.parallel import parallel_ring
from repro.core.selection import (clustering_ratio, measure_latency_stats,
                                  select_ring_kind)
from repro.core.topology import make_latency


def main():
    n, k = 64, 3
    w = make_latency("fabric", n, seed=0)
    rng = np.random.default_rng(0)

    print(f"== DGRO quickstart: {n} nodes, FABRIC latencies, K={k} rings ==")

    d_rand = diameter_scipy(adjacency_from_rings(
        w, [random_ring(rng, n) for _ in range(k)]))
    d_near = diameter_scipy(adjacency_from_rings(
        w, [nearest_ring(w, 0) for _ in range(1)]
        + [random_ring(rng, n) for _ in range(k - 1)]))
    print(f"random K-ring diameter          : {d_rand:7.1f} ms")
    print(f"nearest+random K-ring diameter  : {d_near:7.1f} ms")

    # --- Algorithm 3: gossip latency measurement + rho selection (§V) ---
    probe = adjacency_from_rings(w, k_rings(w, k, "random", rng))
    stats = measure_latency_stats(w, probe, seed=0)
    rho = clustering_ratio(stats)
    kind = select_ring_kind(rho)
    print(f"measured: L_local={stats.l_local:.1f} L_global={stats.l_global:.1f} "
          f"L_min={stats.l_min:.1f} -> rho={rho:.2f} -> add {kind!r} ring")

    best_d, best_m = np.inf, None
    for m in range(k + 1):
        d = diameter_scipy(adjacency_from_rings(
            w, k_rings(w, k, f"mixed:{m}", rng)))
        if d < best_d:
            best_d, best_m = d, m
    print(f"DGRO adaptive ({best_m} random + {k - best_m} nearest rings) : "
          f"{best_d:7.1f} ms "
          f"({(1 - best_d / d_rand) * 100:.0f}% better than random)")

    # --- Algorithm 4: parallel construction ---
    print("\nparallel construction (Alg. 4):")
    for m in (1, 4, 16):
        perm = parallel_ring(w, m, seed=0)
        d = diameter_scipy(adjacency_from_rings(w, [perm]))
        print(f"  {m:3d} partitions -> single-ring diameter {d:7.1f} ms "
              f"({n // m} sequential steps)")


if __name__ == "__main__":
    main()
