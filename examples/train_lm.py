"""End-to-end training driver: a ~10M-param granite-family LM for a few
hundred steps on CPU, with a checkpoint/restart mid-run (fault-tolerance
demo).  The identical entrypoint trains the FULL configs on the production
mesh (see repro.launch.train / repro.launch.dryrun).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Scaling note: --params-100m switches to a ~100M config (same code path);
at CPU speeds that is hours, on a single TPU host it is minutes.
"""
import argparse
import dataclasses
import sys
import tempfile

import repro.configs as configs
from repro.configs import get_arch


def run_train(arch: str, steps: int, ckpt_dir: str, resume: bool):
    from repro.launch import train as T
    sys.argv = ["train", "--arch", arch, "--steps", str(steps),
                "--batch", "8", "--seq", "128", "--lr", "1e-3",
                "--ckpt-dir", ckpt_dir, "--ckpt-every", "20"]
    if resume:
        sys.argv.append("--resume")
    return T.main()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--params-100m", action="store_true")
    args = ap.parse_args()

    base = get_arch("granite-8b")
    if args.params_100m:
        cfg = dataclasses.replace(
            base, name="granite-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32768)
    else:
        cfg = dataclasses.replace(
            base, name="granite-10m", n_layers=4, d_model=256, n_heads=8,
            n_kv_heads=4, head_dim=32, d_ff=1024, vocab=4096)
    configs.ARCHS[cfg.name] = cfg     # register the example config

    with tempfile.TemporaryDirectory() as ckpt_dir:
        losses1 = run_train(cfg.name, args.steps // 2, ckpt_dir, resume=False)
        print("\n=== simulated preemption: restarting from checkpoint ===\n")
        losses2 = run_train(cfg.name, args.steps, ckpt_dir, resume=True)

    assert losses2[-1] < losses1[0], "loss must improve end-to-end"
    print(f"\n[example] OK: loss {losses1[0]:.3f} -> {losses2[-1]:.3f} "
          f"across a checkpoint/restart boundary")


if __name__ == "__main__":
    main()
