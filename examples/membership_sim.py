"""Membership-plane simulation: DGRO ring vs random ring for failure
detection and dissemination, plus straggler demotion and elastic rescale.

    PYTHONPATH=src python examples/membership_sim.py
"""
import numpy as np

from repro.core.construction import nearest_ring, random_ring
from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.core.topology import make_latency
from repro.membership.elastic import HostState, plan_rescale, update_ewma
from repro.membership.gossip import disseminate, simulate_failure_detection


def main():
    n = 96
    w = make_latency("bitnode", n, seed=1)
    rng = np.random.default_rng(0)

    overlays = {
        "random ring (Chord-style)": adjacency_from_rings(
            w, [random_ring(rng, n), random_ring(rng, n)]),
        "DGRO ring (nearest+random)": adjacency_from_rings(
            w, [nearest_ring(w, 0), random_ring(rng, n)]),
    }
    print(f"== membership plane over {n} geo-distributed hosts ==")
    for name, adj in overlays.items():
        d = diameter_scipy(adj)
        t_diss = np.mean([disseminate(adj, w, s, seed=s)[0] for s in range(6)])
        det = simulate_failure_detection(adj, w, failed=7)
        print(f"{name:28s} diameter={d:7.1f}ms  dissemination={t_diss:7.1f}ms  "
              f"failure: suspect@{det.t_first_suspect:.0f}ms "
              f"everyone-knows@{det.t_all_know:.0f}ms")

    # --- straggler + elastic rescale ---
    print("\n== elastic rescale after failure + straggler demotion ==")
    hosts = [HostState(i) for i in range(32)]
    hosts[5].alive = False                       # crashed
    for _ in range(20):
        update_ewma(hosts[11], 250.0)            # persistent straggler
        for h in hosts:
            if h.host_id != 11 and h.alive:
                update_ewma(h, np.random.default_rng(h.host_id).normal(10, 1))
    plan = plan_rescale(make_latency("fabric", 32, seed=3), hosts,
                        model_hosts=4, old_world=32)
    print(f"survivors={len(plan.hosts)} mesh(pods,data,model)={plan.mesh_shape} "
          f"ring={plan.ring_kind} rho={plan.rho:.2f}")
    print(f"step-time factor ~{plan.expected_step_time_factor:.2f}x; "
          f"shard remap sample: {dict(list(plan.shard_remap.items())[:4])}")
    assert 5 not in plan.hosts and 11 not in plan.hosts


if __name__ == "__main__":
    main()
