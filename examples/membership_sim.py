"""Membership-plane simulation: DGRO ring vs random ring for failure
detection and dissemination, plus engine-driven elastic rescale — a crash
and a straggler flow through the churn engine (SWIM confirmation, overlay
repair, straggler demotion) and the surviving fleet feeds the rescale plan.

    PYTHONPATH=src python examples/membership_sim.py
"""
import numpy as np

from repro import overlay
from repro.core.construction import nearest_ring, random_ring
from repro.core.topology import make_latency
from repro.dynamics import ChurnEngine, DGROPolicy, Event, Trace
from repro.membership.elastic import plan_rescale_from_engine
from repro.membership.gossip import disseminate, simulate_failure_detection


def main():
    n = 96
    w = make_latency("bitnode", n, seed=1)
    rng = np.random.default_rng(0)

    overlays = {
        "random ring (Chord-style)": overlay.build(
            "random", w, overlay.RandomRingsConfig(k=2), rng=rng),
        "DGRO ring (nearest+random)": overlay.Overlay.from_rings(
            w, [nearest_ring(w, 0), random_ring(rng, n)], policy="dgro"),
    }
    print(f"== membership plane over {n} geo-distributed hosts ==")
    for name, ov in overlays.items():
        adj = ov.adjacency
        t_diss = np.mean([disseminate(adj, w, s, seed=s)[0] for s in range(6)])
        det = simulate_failure_detection(adj, w, failed=7)
        print(f"{name:28s} diameter={ov.diameter():7.1f}ms  "
              f"dissemination={t_diss:7.1f}ms  "
              f"failure: suspect@{det.t_first_suspect:.0f}ms "
              f"everyone-knows@{det.t_all_know:.0f}ms")

    # --- churn engine: crash + straggler -> demotion -> elastic rescale ---
    print("\n== engine-driven rescale after failure + straggler demotion ==")
    events = [
        Event(time=1_000.0, kind="fail", node=5),                 # crash
        Event(time=3_000.0, kind="straggler", node=11, factor=25.0),
    ]
    trace = Trace(n0=32, capacity=32, dist="fabric", seed=3,
                  events=events, name="rescale_demo")
    engine = ChurnEngine(trace, DGROPolicy(), seed=0, detect_failures=True)
    res = engine.run(sample_exact=True)
    for s in res.samples:
        print(f"t={s.time:7.0f}ms  {s.event:<9s} live={s.n_live:2d}  "
              f"diameter={s.diameter:7.1f}ms")
    print(f"overlay after churn: exact diameter {res.final_diameter:.1f}ms "
          f"({res.stats['relaxations']} relaxations, "
          f"{res.stats['rebuilds']} rebuilds)")

    plan = plan_rescale_from_engine(engine, model_hosts=4, old_world=32)
    print(f"survivors={len(plan.hosts)} mesh(pods,data,model)={plan.mesh_shape} "
          f"ring={plan.ring_kind} rho={plan.rho:.2f}")
    print(f"step-time factor ~{plan.expected_step_time_factor:.2f}x; "
          f"shard remap sample: {dict(list(plan.shard_remap.items())[:4])}")
    assert 5 not in plan.hosts and 11 not in plan.hosts


if __name__ == "__main__":
    main()
