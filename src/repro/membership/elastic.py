"""Elastic scaling + straggler mitigation on top of the membership plane.

On a confirmed membership change (failure, join, or straggler demotion) the
fleet computes a RESCALE PLAN:

  1. re-run DGRO ring selection over the surviving hosts' latency matrix
     (the paper's §V adaptive selection — random vs nearest ring by rho);
  2. choose the largest valid mesh (pod, data, model) that the survivors
     support, preferring to shrink the data axis (model-parallel groups must
     stay intact so checkpoint shards stay host-local);
  3. emit a checkpoint-shard remap: which host reads which shard range.

Straggler policy: hosts whose heartbeat-latency EWMA exceeds
``straggler_factor`` x fleet median are demoted — treated as failed for mesh
membership (they can still serve traffic) — the classic tail-latency
mitigation of Dean & Barroso, driven here by the paper's own gossip
measurements (Alg. 3's L_local samples double as heartbeat RTTs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import overlay as overlay_api
from repro.core.selection import (clustering_ratio, measure_latency_stats,
                                  select_ring_kind)


@dataclasses.dataclass
class HostState:
    host_id: int
    alive: bool = True
    ewma_ms: float = 1.0


@dataclasses.dataclass
class RescalePlan:
    hosts: List[int]                  # surviving hosts, DGRO ring order
    mesh_shape: Tuple[int, ...]       # (pods, data, model) in hosts
    ring_kind: str                    # ring chosen by rho selection
    rho: float
    shard_remap: Dict[int, int]       # old shard id -> new owner host
    expected_step_time_factor: float  # ~ new_world/old_world compute scaling


def update_ewma(state: HostState, sample_ms: float, alpha: float = 0.2):
    state.ewma_ms = (1 - alpha) * state.ewma_ms + alpha * sample_ms


def detect_stragglers(hosts: Sequence[HostState],
                      factor: float = 3.0) -> List[int]:
    alive = [h for h in hosts if h.alive]
    med = float(np.median([h.ewma_ms for h in alive])) if alive else 1.0
    return [h.host_id for h in alive if h.ewma_ms > factor * med]


def _largest_mesh(n_hosts: int, model_hosts: int) -> Tuple[int, int, int]:
    """(pods, data, model) host-level factorization: keep model groups whole,
    then the largest power-of-two data axis, pods = what remains."""
    usable = (n_hosts // model_hosts) * model_hosts
    groups = usable // model_hosts
    data = 1 << int(np.floor(np.log2(max(groups, 1))))
    return (groups // data if data else 1, data, model_hosts)


def plan_rescale(
    w: np.ndarray,
    hosts: Sequence[HostState],
    *,
    model_hosts: int = 1,
    old_world: Optional[int] = None,
    straggler_factor: float = 3.0,
    seed: int = 0,
) -> RescalePlan:
    """Compute the post-event mesh + ring + shard remap."""
    stragglers = set(detect_stragglers(hosts, straggler_factor))
    members = [h.host_id for h in hosts if h.alive and h.host_id not in stragglers]
    if not members:
        raise RuntimeError("no live hosts")
    sub = w[np.ix_(members, members)]

    # paper §V: measure rho on a probe (random-ring) overlay and pick the
    # ring kind; both rings come from the overlay builder registry
    rng = np.random.default_rng(seed)
    probe = overlay_api.build("random", sub,
                              overlay_api.RandomRingsConfig(k=1), rng=rng)
    stats = measure_latency_stats(sub, probe.adjacency, seed=seed)
    rho = clustering_ratio(stats)
    kind = select_ring_kind(rho)
    if kind == "nearest":
        chosen = overlay_api.build(
            "nearest", sub, overlay_api.NearestRingsConfig(k=1), rng=rng)
        ring = chosen.rings[0]
    elif kind == "random":
        ring = probe.rings[0]
    else:
        ring = probe.rings[0]
        kind = "keep-random"
    ordered = [members[i] for i in ring]

    pods, data, model = _largest_mesh(len(ordered), model_hosts)
    world = pods * data * model
    ordered = ordered[:world]
    remap = {i: ordered[i % len(ordered)] for i in range(old_world or world)}
    factor = (old_world / world) if old_world else 1.0
    return RescalePlan(hosts=ordered, mesh_shape=(pods, data, model),
                       ring_kind=kind, rho=rho, shard_remap=remap,
                       expected_step_time_factor=factor)


def plan_rescale_from_engine(
    engine,
    *,
    model_hosts: int = 1,
    old_world: Optional[int] = None,
    straggler_factor: Optional[float] = None,
    seed: int = 0,
) -> RescalePlan:
    """Rescale plan driven by a ``repro.dynamics.ChurnEngine``'s live state.

    The engine's alive mask and per-node latency factors (its straggler
    view, updated by Straggler events) replace the hand-maintained
    ``HostState`` list: after replaying a churn trace, the surviving fleet
    and its current latency matrix feed directly into ``plan_rescale``.
    ``straggler_factor`` defaults to the engine's own demotion threshold so
    the plan agrees with the replay about who counts as a straggler."""
    if straggler_factor is None:
        straggler_factor = engine.straggler_factor
    return plan_rescale(engine.w, engine.host_states(),
                        model_hosts=model_hosts, old_world=old_world,
                        straggler_factor=straggler_factor, seed=seed)
