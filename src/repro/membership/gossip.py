"""Gossip membership plane over the DGRO ring (SWIM-style), in simulation.

This is the paper's *application*: membership dissemination latency is
bounded by the overlay DIAMETER, which DGRO minimizes.  The simulator is a
discrete-event model over a latency matrix (the same matrices the paper
evaluates) and provides:

* SWIM probe/suspect/confirm failure detection over the DGRO overlay;
* push gossip dissemination with per-edge latency = w(u, v);
* measured dissemination latency (time until X% of members know an event),
  which tests assert is monotone in the overlay diameter;
* hooks used by the elastic layer: on confirmed failure the fleet re-runs
  DGRO over the survivors (see ``repro.membership.elastic``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.diameter import INF


@dataclasses.dataclass
class GossipEvent:
    time: float
    dst: int
    kind: str           # "update" | "probe" | "ack"
    payload: Tuple


def neighbours(adj: np.ndarray, u: int) -> np.ndarray:
    return np.flatnonzero((adj[u] > 0) & (adj[u] < float(INF) / 2))


def disseminate(
    adj: np.ndarray,
    w: np.ndarray,
    source: int,
    *,
    fanout: int = 2,
    proc_delay: float = 1.0,
    seed: int = 0,
    coverage: float = 1.0,
) -> Tuple[float, np.ndarray]:
    """Push-gossip a single update from ``source`` until ``coverage`` of
    nodes have it.  Each node, on first receipt, forwards to all ring
    neighbours plus ``fanout`` random peers after ``proc_delay`` ms.

    Returns (time until coverage reached, per-node receive times).
    """
    n = adj.shape[0]
    rng = np.random.default_rng(seed)
    recv = np.full(n, np.inf)
    recv[source] = 0.0
    heap: List[Tuple[float, int]] = [(0.0, source)]
    covered = 1
    target = int(np.ceil(coverage * n))
    t_cov = 0.0
    while heap and covered < target:
        t, u = heapq.heappop(heap)
        if t > recv[u]:
            continue
        targets = list(neighbours(adj, u))
        extra = rng.choice(n, size=min(fanout, n), replace=False)
        targets.extend(int(e) for e in extra if e != u)
        for v in targets:
            t_arr = t + proc_delay + float(w[u, v])
            if t_arr < recv[v]:
                first = np.isinf(recv[v])
                recv[v] = t_arr
                heapq.heappush(heap, (t_arr, v))
        covered = int(np.sum(np.isfinite(recv)))
        if covered >= target:
            t_cov = float(np.sort(recv[np.isfinite(recv)])[target - 1])
    if covered < target:
        return float("inf"), recv
    return t_cov, recv


@dataclasses.dataclass
class SwimConfig:
    probe_period: float = 100.0       # ms between probes
    probe_timeout: float = 50.0       # direct-probe timeout
    indirect_k: int = 3               # SWIM indirect probes
    suspect_timeout: float = 300.0    # suspect -> confirm


@dataclasses.dataclass
class DetectionResult:
    t_failed: float
    t_first_suspect: float
    t_confirmed: float
    t_all_know: float                 # dissemination complete


def simulate_failure_detection(
    adj: np.ndarray,
    w: np.ndarray,
    failed: int,
    cfg: SwimConfig = SwimConfig(),
    seed: int = 0,
) -> DetectionResult:
    """One failure: node ``failed`` dies at t=0; SWIM probes detect it, the
    confirmation gossips over the overlay.  Event-driven approximation:
    detection by the first ring neighbour whose probe window hits, then
    dissemination via ``disseminate`` from the detector."""
    rng = np.random.default_rng(seed)
    n = adj.shape[0]
    nbrs = neighbours(adj, failed)
    if len(nbrs) == 0:
        nbrs = np.array([(failed + 1) % n])
    # each neighbour probes the failed node at a random phase of its period
    phases = rng.uniform(0, cfg.probe_period, size=len(nbrs))
    rtt = 2.0 * w[failed, nbrs]
    # direct probe fails (timeout), then indirect probes also fail
    detect_times = phases + cfg.probe_timeout + cfg.probe_timeout
    first = int(np.argmin(detect_times))
    t_suspect = float(detect_times[first])
    detector = int(nbrs[first])
    t_confirm = t_suspect + cfg.suspect_timeout
    t_diss, _ = disseminate(adj, w, detector, seed=seed, coverage=0.99)
    return DetectionResult(
        t_failed=0.0,
        t_first_suspect=t_suspect,
        t_confirmed=t_confirm,
        t_all_know=t_confirm + t_diss,
    )
