"""Gossip membership plane over the DGRO ring (SWIM-style), in simulation.

This is the paper's *application*: membership dissemination latency is
bounded by the overlay DIAMETER, which DGRO minimizes.  The simulator is a
discrete-event model over a latency matrix (the same matrices the paper
evaluates) and provides:

* SWIM probe/suspect/confirm failure detection over the DGRO overlay;
* push gossip dissemination with per-edge latency = w(u, v);
* measured dissemination latency (time until X% of members know an event),
  which tests assert is monotone in the overlay diameter;
* hooks used by the elastic layer: on confirmed failure the fleet re-runs
  DGRO over the survivors (see ``repro.membership.elastic``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.diameter import is_edge, neighbour_lists


@dataclasses.dataclass
class GossipEvent:
    time: float
    dst: int
    kind: str           # "update" | "probe" | "ack"
    payload: Tuple


def neighbours(adj: np.ndarray, u: int) -> np.ndarray:
    return np.flatnonzero(is_edge(adj[u]))


def disseminate(
    adj: np.ndarray,
    w: np.ndarray,
    source: int,
    *,
    fanout: int = 2,
    proc_delay: float = 1.0,
    seed: int = 0,
    coverage: float = 1.0,
) -> Tuple[float, np.ndarray]:
    """Push-gossip a single update from ``source`` until ``coverage`` of
    nodes have it.  Each node, on first receipt, forwards to all ring
    neighbours plus ``fanout`` random peers after ``proc_delay`` ms.

    Returns (time until coverage reached, per-node receive times).
    """
    n = adj.shape[0]
    rng = np.random.default_rng(seed)
    neigh = neighbour_lists(adj)
    recv = np.full(n, np.inf)
    recv[source] = 0.0
    heap: List[Tuple[float, int]] = [(0.0, source)]
    covered = 1
    target = int(np.ceil(coverage * n))
    t_cov = 0.0
    while heap and covered < target:
        t, u = heapq.heappop(heap)
        if t > recv[u]:
            continue
        targets = list(neigh[u])
        extra = rng.choice(n, size=min(fanout, n), replace=False)
        targets.extend(int(e) for e in extra if e != u)
        for v in targets:
            t_arr = t + proc_delay + float(w[u, v])
            if t_arr < recv[v]:
                first = np.isinf(recv[v])
                recv[v] = t_arr
                heapq.heappush(heap, (t_arr, v))
        covered = int(np.sum(np.isfinite(recv)))
        if covered >= target:
            t_cov = float(np.sort(recv[np.isfinite(recv)])[target - 1])
    if covered < target:
        return float("inf"), recv
    return t_cov, recv


@dataclasses.dataclass
class SwimConfig:
    probe_period: float = 100.0       # ms between probes
    probe_timeout: float = 50.0       # direct-probe timeout
    indirect_k: int = 3               # SWIM indirect probes
    suspect_timeout: float = 300.0    # suspect -> confirm


@dataclasses.dataclass
class DetectionResult:
    t_failed: float
    t_first_suspect: float
    t_confirmed: float
    t_all_know: float                 # dissemination complete


def _swim_detection(adj: np.ndarray, failed: int, cfg: SwimConfig,
                    rng: np.random.Generator) -> Tuple[float, int]:
    """SWIM probe detection alone: (suspect time, detector node).

    Each ring neighbour probes the dead node at a random phase of its
    period; the direct probe times out, then the indirect probes do too."""
    n = adj.shape[0]
    nbrs = neighbours(adj, failed)
    if len(nbrs) == 0:
        nbrs = np.array([(failed + 1) % n])
    phases = rng.uniform(0, cfg.probe_period, size=len(nbrs))
    detect_times = phases + cfg.probe_timeout + cfg.probe_timeout
    first = int(np.argmin(detect_times))
    return float(detect_times[first]), int(nbrs[first])


def simulate_failure_detection(
    adj: np.ndarray,
    w: np.ndarray,
    failed: int,
    cfg: SwimConfig = SwimConfig(),
    seed: int = 0,
) -> DetectionResult:
    """One failure: node ``failed`` dies at t=0; SWIM probes detect it, the
    confirmation gossips over the overlay.  Event-driven approximation:
    detection by the first ring neighbour whose probe window hits, then
    dissemination via ``disseminate`` from the detector."""
    rng = np.random.default_rng(seed)
    t_suspect, detector = _swim_detection(adj, failed, cfg, rng)
    t_confirm = t_suspect + cfg.suspect_timeout
    t_diss, _ = disseminate(adj, w, detector, seed=seed, coverage=0.99)
    return DetectionResult(
        t_failed=0.0,
        t_first_suspect=t_suspect,
        t_confirmed=t_confirm,
        t_all_know=t_confirm + t_diss,
    )


def confirmed_leave_time(
    adj: np.ndarray,
    failed: int,
    t_fail: float = 0.0,
    cfg: SwimConfig = SwimConfig(),
    seed: int = 0,
) -> float:
    """Absolute time at which a crash at ``t_fail`` becomes an actionable
    membership change: SWIM probe detection + suspect->confirm timeout.

    This is the bridge into ``repro.dynamics``: the churn engine turns a
    Fail event into a Leave event scheduled at this time, so the overlay
    keeps routing through the dead node until the gossip plane has actually
    confirmed the failure.  Only detection is simulated — the dissemination
    sweep of ``simulate_failure_detection`` (which this rng-matches) feeds
    ``t_all_know``, a quantity the confirmation time never uses."""
    rng = np.random.default_rng(seed)
    t_suspect, _ = _swim_detection(adj, failed, cfg, rng)
    return t_fail + t_suspect + cfg.suspect_timeout
