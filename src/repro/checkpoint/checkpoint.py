"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
             manifest.json      (tree structure, shapes, dtypes, step, meta)
             shard_<h>.npz      (this host's param shards, one per host)
             COMMITTED          (written last: atomic-commit marker)

* **Atomic**: everything is written into ``step_<N>.tmp`` and renamed;
  readers ignore directories without the COMMITTED marker, so a job killed
  mid-save can never restore a torn checkpoint.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread — the train loop never blocks on disk.
* **Elastic**: ``restore`` takes the CURRENT device layout (any mesh) and
  ``device_put``s each leaf with the new sharding — restarts may change pod
  count/mesh shape freely (multi-host: each host loads every shard file it
  needs; here single-process hosts one file).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np

import jax

PyTree = Any

_MARKER = "COMMITTED"


def _to_numpy(v) -> np.ndarray:
    arr = np.asarray(v)
    # npz can't round-trip ml_dtypes (bf16/f8): store as fp32 (lossless
    # upcast); restore() casts back to the template dtype.
    if arr.dtype.kind not in "biufc":
        arr = np.asarray(jax.numpy.asarray(v).astype(jax.numpy.float32))
    elif arr.dtype == np.dtype("float16"):
        pass
    elif str(arr.dtype) in ("bfloat16",):
        arr = arr.astype(np.float32)
    return arr


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {jax.tree_util.keystr(path): leaf for path, leaf in leaves}
    return keyed, jax.tree.structure(tree)


def save(directory: str, step: int, tree: PyTree, *,
         meta: Optional[Dict] = None, host_id: int = 0) -> str:
    """Synchronous sharded save.  Returns the committed directory."""
    keyed, _ = _flatten(tree)
    host_arrays = {k: _to_numpy(v) for k, v in keyed.items()}

    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **host_arrays)
    manifest = {
        "step": step,
        "meta": meta or {},
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in host_arrays.items()},
        "n_hosts": 1,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Background-thread checkpointing; at most one save in flight."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_committed: Optional[str] = None

    def save_async(self, step: int, tree: PyTree, meta: Optional[Dict] = None):
        self.wait()
        # snapshot to host memory NOW (device buffers may be donated later)
        keyed, _ = _flatten(tree)
        snapshot = {k: _to_numpy(v) for k, v in keyed.items()}

        def work():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"), **snapshot)
            manifest = {
                "step": step, "meta": meta or {},
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in snapshot.items()},
                "n_hosts": 1,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, _MARKER), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self.last_committed = final
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(latest_steps(self.directory))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        path = os.path.join(directory, name)
        if (name.startswith("step_") and not name.endswith(".tmp")
                and os.path.exists(os.path.join(path, _MARKER))):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def restore(directory: str, template: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> tuple[PyTree, int]:
    """Restore into the structure of ``template``.

    ``shardings``: optional pytree of jax.sharding.Sharding matching the
    template — leaves are device_put with the NEW sharding, enabling elastic
    restarts onto a different mesh.  Returns (tree, step).
    """
    steps = latest_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoint under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))

    keyed, _ = _flatten(template)
    missing = [k for k in keyed if k not in data]
    if missing:
        raise ValueError(f"checkpoint at step {step} missing leaves: {missing[:5]}")

    shard_map_ = None
    if shardings is not None:
        shard_keyed, _ = _flatten(shardings)
        shard_map_ = shard_keyed

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for pth, leaf in leaves_with_path:
        k = jax.tree_util.keystr(pth)
        arr = data[k]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {k}: ckpt {arr.shape} vs "
                             f"template {leaf.shape}")
        cast = jax.numpy.asarray(arr).astype(leaf.dtype)
        if shard_map_ is not None and k in shard_map_:
            new_leaves.append(jax.device_put(cast, shard_map_[k]))
        else:
            new_leaves.append(jax.device_put(cast))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
