"""Minimal optax-free optimizer stack (pure pytree transforms).

Provides AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule.  Used by both the DGRO Q-network (repro.core) and the
LM training substrate (repro.train.train_step).  Optimizer moments are stored
in fp32 regardless of parameter dtype; state is a pytree with the same
structure as params, so pjit shards it with the params (ZeRO-style extra
sharding is applied in train_step via explicit out_shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "warmup_cosine", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None


class AdamWState(NamedTuple):
    step: jnp.ndarray   # ()
    mu: PyTree          # first moment, fp32
    nu: PyTree          # second moment, fp32


def adamw_init(params: PyTree, moment_dtype=jnp.float32) -> AdamWState:
    """``moment_dtype``: fp32 default; bf16 halves optimizer HBM for the
    largest archs (llama4-maverick on a single pod) at some update noise —
    the MaxText-style trade, see DESIGN.md §8."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak_lr * jnp.where(step < warmup, warm, cos)
    return sched


def adamw_update(
    cfg: AdamWConfig,
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
) -> Tuple[PyTree, AdamWState, jnp.ndarray]:
    """One AdamW step.  Returns (new_params, new_state, grad_norm)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)

    step = state.step + 1
    lr = cfg.schedule(step) if cfg.schedule is not None else jnp.asarray(cfg.lr)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        mdt = m.dtype
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm
