"""Training step: masked cross-entropy + AdamW, microbatch gradient
accumulation, remat, MoE aux loss — all pjit-compatible.

Label convention: ``labels < 0`` positions (padding, vision-patch positions,
doc boundaries) are excluded from the loss.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as Mdl
from repro.models.sharding import shard
from .optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    remat: bool = True
    microbatches: int = 1          # gradient-accumulation steps
    aux_weight: float = 0.01       # MoE load-balancing loss weight
    z_weight: float = 1e-4         # z-loss (logit norm regularizer)
    ce_chunk: int = 0              # >0: chunked CE — never materializes the
                                   # full (B,S,V) logits (S-chunks of this
                                   # size; chunk fwd is rematerialized in bwd)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_weight: float = 0.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked token-mean CE.  logits (B,S,V) any dtype; labels (B,S) int,
    negatives masked.  Returns (loss, n_tokens).

    Sharding note: logits arrive VOCAB-SHARDED over the model axis.  The
    gold logit is picked with an iota==label comparison + reduction (partial
    per shard, small (B,S) all-reduce) — a ``take_along_axis`` here would
    all-gather the full logits (tens of GB/device at 262k vocab)."""
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    logits32 = logits.astype(jnp.float32)
    # stable logsumexp over the (sharded) vocab axis: reductions only
    m = jax.lax.stop_gradient(jnp.max(logits32, axis=-1))
    lse = m + jnp.log(jnp.sum(jnp.exp(logits32 - m[..., None]), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == lab[..., None], logits32, 0.0),
                   axis=-1)
    nll = (lse - gold) * mask
    n = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / n
    if z_weight:
        loss = loss + z_weight * jnp.sum(jnp.square(lse) * mask) / n
    return loss, n


def chunked_cross_entropy(x: jnp.ndarray, head: jnp.ndarray,
                          labels: jnp.ndarray, chunk: int,
                          z_weight: float = 0.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """CE without materializing (B,S,V) logits: scan over S-chunks, each
    chunk's logits rematerialized in the backward (jax.checkpoint).  Peak
    extra memory = one (B,chunk,V) block."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    xc = x.reshape(b, nc, chunk, d).swapaxes(0, 1)       # (nc, B, chunk, d)
    lc = labels.reshape(b, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def one(xb, lb):
        logits = xb @ head
        mask = (lb >= 0).astype(jnp.float32)
        lab = jnp.maximum(lb, 0)
        lg32 = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(lg32, axis=-1))
        lse = m + jnp.log(jnp.sum(jnp.exp(lg32 - m[..., None]), axis=-1))
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(jnp.where(iota == lab[..., None], lg32, 0.0), axis=-1)
        nll = jnp.sum((lse - gold) * mask)
        zl = jnp.sum(jnp.square(lse) * mask)
        return nll, zl, mask.sum()

    def body(carry, inp):
        nll, zl, n = carry
        xb, lb = inp
        a, b_, c = one(xb, lb)
        return (nll + a, zl + b_, n + c), None

    (nll, zl, n), _ = jax.lax.scan(body, (0.0, 0.0, 0.0), (xc, lc))
    n = jnp.maximum(n, 1.0)
    loss = nll / n
    if z_weight:
        loss = loss + z_weight * zl / n
    return loss, n


def loss_fn(cfg: ArchConfig, tc: TrainConfig, params: PyTree,
            batch: Dict[str, jnp.ndarray], mesh=None,
            data_axes=("data",)) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    labels = batch["labels"]
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        np_ = batch["vision_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (np_,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)  # patches: no loss

    if tc.ce_chunk:
        x, head, aux = Mdl.forward(
            cfg, params, batch["tokens"], mode="train_hidden",
            vision_embeds=batch.get("vision_embeds"), mesh=mesh,
            data_axes=data_axes, remat=tc.remat)
        ce, n_tok = chunked_cross_entropy(x, head, labels, tc.ce_chunk,
                                          tc.z_weight)
    else:
        logits, aux = Mdl.forward(
            cfg, params, batch["tokens"], mode="train",
            vision_embeds=batch.get("vision_embeds"), mesh=mesh,
            data_axes=data_axes, remat=tc.remat)
        ce, n_tok = cross_entropy(logits, labels, tc.z_weight)
    total = ce + tc.aux_weight * aux
    return total, {"ce": ce, "aux": aux, "n_tok": n_tok}


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: AdamWState

    def tree_flatten(self):  # pragma: no cover - registered below
        return (self.params, self.opt), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, kids: TrainState(params=kids[0], opt=kids[1]),
)


def init_state(cfg: ArchConfig, key, dtype=jnp.float32) -> TrainState:
    params = Mdl.init_params(cfg, key, dtype)
    return TrainState(params=params, opt=adamw_init(params))


def train_step(cfg: ArchConfig, tc: TrainConfig, state: TrainState,
               batch: Dict[str, jnp.ndarray], mesh=None,
               data_axes=("data",),
               grad_shardings=None,
               grad_transform=None) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
    """One optimizer step.  With tc.microbatches > 1, the global batch is
    split on the batch axis and gradients are accumulated with a lax.scan —
    the standard memory/throughput trade (and the unit XLA's latency-hiding
    scheduler overlaps the gradient all-reduce against).

    ``grad_shardings``: optional pytree of Shardings (same structure as
    params).  Pinning grads to the params' sharding forces the partitioner
    to emit the grad dots in param layout — without it, the embed/lm_head
    grad dot may pick the activation layout and all-gather full-vocab
    dlogits (tens of GB/device)."""

    def constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(cfg, tc, p, b, mesh, data_axes), has_aux=True)

    if tc.microbatches <= 1:
        (loss, metrics), grads = grad_fn(state.params, batch)
        grads = constrain(grads)
    else:
        m = tc.microbatches
        b = batch["tokens"].shape[0]
        assert b % m == 0, (b, m)

        def split(x):
            return x.reshape(m, b // m, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_body(carry, mb):
            g_acc, l_acc = carry
            (l, met), g = grad_fn(state.params, mb)
            g_acc = jax.tree.map(jnp.add, g_acc, constrain(g))
            return (g_acc, l_acc + l), met

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            state.params)
        (grads, loss), mets = jax.lax.scan(acc_body, (zero, 0.0), micro)
        grads = jax.tree.map(lambda g: g / m, grads)
        loss = loss / m
        metrics = jax.tree.map(lambda x: x[-1], mets)

    if grad_transform is not None:
        # e.g. int8 ring all-reduce over the pod axis (repro.train.pod_compress)
        grads = grad_transform(grads)
    new_params, new_opt, gnorm = adamw_update(
        tc.optimizer, grads, state.opt, state.params)
    metrics = dict(metrics)
    metrics.update(loss=loss, grad_norm=gnorm)
    return TrainState(params=new_params, opt=new_opt), metrics
