"""Distributed-optimization collectives: int8-compressed ring all-reduce
with error feedback, over the DGRO-ordered ring.

The DCN-level gradient all-reduce is a RING reduce-scatter + all-gather over
``ppermute``; the ring ORDER is the mesh's device order along the data axis
— which ``repro.launch.mesh`` builds from the DGRO ring optimization (the
paper's technique applied to the collective plane, DESIGN.md §2/§5).

Compression: per-chunk symmetric int8 quantization (scale = max|x|/127),
4x less DCN traffic than fp32 (2x vs bf16).  Quantization error is returned
so the caller can apply error feedback (add the residual into the next
step's gradient) — keeping convergence unbiased in expectation.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import axis_size, shard_map

PyTree = Any


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _ring_allreduce_1d(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Ring all-reduce (reduce-scatter + all-gather) of a flat fp32 vector
    with int8-compressed hops.  x must divide by the axis size."""
    n = axis_size(axis)
    i = jax.lax.axis_index(axis)
    chunks = x.reshape(n, -1)
    fwd = [(j, (j + 1) % n) for j in range(n)]

    # --- reduce-scatter: after n-1 hops, rank i holds the full sum of
    # chunk (i+1) mod n ---
    def rs_body(step, acc):
        # each rank sends the chunk it currently accumulates for (i - step)
        send_idx = (i - step) % n
        q, s = _quantize(acc[send_idx])
        q_r = jax.lax.ppermute(q, axis, fwd)
        s_r = jax.lax.ppermute(s, axis, fwd)
        recv_idx = (i - step - 1) % n
        return acc.at[recv_idx].add(q_r.astype(jnp.float32) * s_r)

    acc = jax.lax.fori_loop(0, n - 1, rs_body, chunks)

    # --- all-gather: quantize each completed chunk ONCE and circulate the
    # quantized payload unchanged, so every rank dequantizes identical bits
    # (re-quantizing per hop would make DP ranks diverge) ---
    own_idx = (i + 1) % n
    q0, s0 = _quantize(acc[own_idx])
    out_q = jnp.zeros((n,) + q0.shape, jnp.int8).at[own_idx].set(q0)
    out_s = jnp.zeros((n,), jnp.float32).at[own_idx].set(s0)

    def ag_body(step, carry):
        out_q, out_s, q, s = carry
        q = jax.lax.ppermute(q, axis, fwd)
        s = jax.lax.ppermute(s, axis, fwd)
        idx = (i - step) % n          # chunk id that arrives at this step
        return (out_q.at[idx].set(q), out_s.at[idx].set(s), q, s)

    out_q, out_s, _, _ = jax.lax.fori_loop(0, n - 1, ag_body,
                                           (out_q, out_s, q0, s0))
    out = out_q.astype(jnp.float32) * out_s[:, None]
    return out.reshape(x.shape)


def ring_allreduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """int8-compressed ring all-reduce — call INSIDE shard_map.  ``x`` is a
    per-shard fp32 array of identical shape on every shard; returns the sum.
    """
    n = axis_size(axis)
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = _ring_allreduce_1d(flat, axis)
    if pad:
        out = out[:-pad]
    return out.reshape(x.shape).astype(x.dtype)


def compressed_grad_allreduce(grads: PyTree, axis: str = "data",
                              error_fb: PyTree | None = None,
                              ) -> Tuple[PyTree, PyTree]:
    """Mean-all-reduce per-shard gradients with int8 compression + error
    feedback — call INSIDE shard_map (manual-DP step; see
    examples/compressed_dp.py and tests/test_collectives.py).

    Returns (reduced_grads, new_error_feedback): the residual the local
    quantization dropped this step, to be added to next step's grads.
    """
    n = axis_size(axis)
    if error_fb is not None:
        grads = jax.tree.map(lambda g, e: g + e.astype(g.dtype), grads, error_fb)

    def reduce_one(g):
        return ring_allreduce(g, axis) / n

    mean = jax.tree.map(reduce_one, grads)

    def residual(g):
        q, s = _quantize(g.astype(jnp.float32))
        return g.astype(jnp.float32) - q.astype(jnp.float32) * s

    new_err = jax.tree.map(residual, grads)
    return mean, new_err
