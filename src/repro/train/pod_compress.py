"""Cross-pod gradient compression: manual DP over the ``pod`` axis with the
int8 ring all-reduce, auto-SPMD within each pod.

The multi-pod baseline lets the partitioner all-reduce gradients over
("pod", "data") in one fused collective — the pod hop crosses DCN at full
width.  This variant makes the pod axis MANUAL (``shard_map`` with
``axis_names={"pod"}``): each pod runs the standard train step body
(microbatching, remat, ZeRO grad shardings — all inherited from
``train_step``) over its half of the batch, and the pod-level reduction is
the paper-adjacent piece: an int8-quantized RING reduce over ``ppermute``
along the DGRO-ordered pod ring (repro.train.collectives), 4x less DCN
traffic than fp32.

Trades: quantization noise (bounded by max|g|/254, optionally
error-fed-back) for a 4x cut of the slowest link's traffic.  §Perf
hillclimb C measures the collective-term delta from the compiled HLO.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from .collectives import compressed_grad_allreduce
from .train_step import TrainConfig, TrainState, train_step

PyTree = Any


def pod_compressed_train_step(
    cfg: ArchConfig,
    tc: TrainConfig,
    mesh: Mesh,
    state_shapes: TrainState,
    batch_shapes: Dict[str, Any],
    pod_axis: str = "pod",
    inner_data_axes: Tuple[str, ...] = ("data",),
    grad_shardings=None,
):
    """Builds the hybrid step fn.  In partial-manual shard_map the specs
    mention ONLY the manual axis: params/opt replicate across pods (P()),
    the batch splits its leading dim over pods, and the within-pod
    data/model sharding flows through the auto axes."""

    def transform(grads):
        mean, _err = compressed_grad_allreduce(grads, pod_axis)
        return mean

    def body(state: TrainState, batch: Dict[str, jnp.ndarray]):
        new_state, metrics = train_step(
            cfg, tc, state, batch, mesh=mesh, data_axes=inner_data_axes,
            grad_shardings=grad_shardings, grad_transform=transform)
        metrics["loss"] = jax.lax.pmean(metrics["loss"], pod_axis)
        return new_state, metrics

    pods = mesh.shape[pod_axis]
    state_specs = jax.tree.map(lambda _: P(), state_shapes)

    def batch_spec(leaf):
        if leaf.shape and leaf.shape[0] % pods == 0 and leaf.shape[0] >= pods:
            return P(pod_axis, *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    batch_specs_tree = jax.tree.map(batch_spec, batch_shapes)
    metric_specs = {"loss": P(), "ce": P(), "aux": P(), "n_tok": P(),
                    "grad_norm": P()}

    return shard_map(
        body, mesh=mesh,
        in_specs=(state_specs, batch_specs_tree),
        out_specs=(state_specs, metric_specs),
        axis_names={pod_axis},          # pod manual; data/model stay auto
        check_vma=False,
    )
