"""Trip-count-aware HLO cost walk.

XLA's ``cost_analysis()`` counts a while-loop body ONCE, but our step
functions put everything interesting inside loops (lax.scan over layer
blocks, microbatches, attention KV chunks).  This module re-derives the
roofline inputs from the compiled HLO text with loop multipliers:

  * parse the module into computations and instructions;
  * infer each while loop's trip count from its condition computation
    (compare(iv, constant(N), LT) pattern emitted by lax.scan/fori_loop);
  * walk the call graph (entry -> fusions/calls/conditionals/whiles) with
    multipliers, accumulating
      - dot FLOPs:        2 * |result| * (contracted extent)     [MXU work]
      - naive HBM bytes:  operand + result bytes per instruction  [upper-ish
                           bound; intra-fusion reuse not modelled]
      - collective bytes: ring-model transfer per op (analysis.py)
  * conditionals take the max across branches (decode cells guard rolling
    cache writes with conditionals).

Elementwise FLOPs are not counted (dots dominate every cell); transcendental
cost is folded into the bytes term via its operands.  The walk is validated
against unrolled-vs-scanned reference programs in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from .analysis import _DTYPE_BYTES, Collective, parse_collectives

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_KNOWN_TRIP = re.compile(r'known_trip_count.+?"n":"(\d+)"')
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<type>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>[\w\-]+)\((?P<args>[^)]*)\)(?P<attrs>.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r"constant\((\d+)\)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    args: List[str]
    attrs: str
    line: str


def _split_args(s: str) -> List[str]:
    """Split an operand list on top-level commas only (shape dims and layout
    braces contain commas too: ``f32[64,64]{1,0} %x, f32[64]{0} %y``)."""
    out, cur, depth = [], [], 0
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def parse_module(hlo: str) -> Tuple[Dict[str, List[Instr]], Dict[str, str], str]:
    """Returns (computations, name->type map, entry computation name)."""
    comps: Dict[str, List[Instr]] = {}
    types: Dict[str, str] = {}
    entry = ""
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):            # computation header / brace
            stripped = line.strip()
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HEADER.match(stripped)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
                    if stripped.startswith("ENTRY"):
                        entry = cur
            elif stripped == "}":
                cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        # older XLA text prints operand types inline ("f32[64,64]{1,0} %x");
        # newer prints bare names ("%x").  Take the last token as the name
        # and harvest any inline type into the name->type map.
        args = []
        for a in _split_args(m.group("args")):
            a = a.strip()
            if not a:
                continue
            toks = a.split()
            name = toks[-1].lstrip("%")
            args.append(name)
            if len(toks) > 1:
                inline_type = " ".join(toks[:-1])
                if _SHAPE.search(inline_type):
                    types.setdefault(name, inline_type)
        ins = Instr(name=m.group("name"), type_str=m.group("type"),
                    op=m.group("op"), args=args, attrs=m.group("attrs"),
                    line=line)
        comps[cur].append(ins)
        types[ins.name] = ins.type_str
        # parameters also carry types: "%p = f32[..] parameter(0)"
    return comps, types, entry


def _called(attrs: str, key: str) -> List[str]:
    # e.g. calls=%fused_computation.12 | body=%region_0.1 | condition=%r.2
    out = []
    for m in re.finditer(key + r"=%?([\w.\-]+)", attrs):
        out.append(m.group(1))
    return out


def _trip_count(cond_comp: List[Instr]) -> int:
    """lax loops compare the induction variable against constant(N), LT."""
    consts = {}
    for ins in cond_comp:
        if ins.op == "constant":
            m = _TRIP.search(ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    for ins in cond_comp:
        if ins.op == "compare" and "direction=LT" in ins.attrs:
            for a in ins.args:
                if a in consts:
                    return max(1, consts[a])
    # fallback: any constant in the condition
    if consts:
        return max(1, max(consts.values()))
    return 1


def _dot_flops(ins: Instr, types: Dict[str, str]) -> float:
    out = _shape_dims(ins.type_str)
    if out is None:
        return 0.0
    result_elems = float(np.prod(out[1])) if out[1] else 1.0
    lhs = ins.args[0] if ins.args else None
    k = 1.0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if lhs and lhs in types and m:
        ldims = _shape_dims(types[lhs])
        if ldims:
            for d in m.group(1).split(","):
                if d and int(d) < len(ldims[1]):
                    k *= ldims[1][int(d)]
    return 2.0 * result_elems * k


def _instr_bytes(ins: Instr, types: Dict[str, str]) -> float:
    b = float(_shape_bytes(ins.type_str))
    for a in ins.args:
        if a in types:
            b += _shape_bytes(types[a])
    return b


@dataclasses.dataclass
class WalkCosts:
    dot_flops: float = 0.0
    naive_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1


_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")


def walk(hlo: str) -> WalkCosts:
    comps, types, entry = parse_module(hlo)
    costs = WalkCosts()
    memo_lines: Dict[str, List[str]] = {}

    def comp_collectives(name: str) -> List[Collective]:
        lines = memo_lines.setdefault(
            name, [i.line for i in comps.get(name, [])])
        return parse_collectives("\n".join(lines))

    visited_stack = []

    def visit(comp: str, mult: float, in_fusion: bool = False):
        """in_fusion: inside a fusion computation HBM traffic is the call
        site's operands/result, not the internal elementwise chain — bytes
        are only accumulated for scheduled (non-fusion) computations."""
        if comp not in comps or comp in visited_stack:
            return
        visited_stack.append(comp)
        for c in comp_collectives(comp):
            costs.collective_bytes += mult * c.transfer_bytes
            costs.collective_by_op[c.op] = costs.collective_by_op.get(
                c.op, 0.0) + mult * c.transfer_bytes
        for ins in comps[comp]:
            if ins.op == "dot":
                costs.dot_flops += mult * _dot_flops(ins, types)
            if not in_fusion and ins.op not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional"):
                costs.naive_bytes += mult * _instr_bytes(ins, types)
            if ins.op == "while":
                conds = _called(ins.attrs, "condition")
                bodies = _called(ins.attrs, "body")
                kt = _KNOWN_TRIP.search(ins.attrs)   # XLA's own annotation
                if kt:
                    trip = max(1, int(kt.group(1)))
                else:
                    trip = _trip_count(comps.get(conds[0], [])) if conds else 1
                costs.n_while += 1
                costs.max_trip = max(costs.max_trip, trip)
                for b in bodies:
                    visit(b, mult * trip, in_fusion)
            elif ins.op in ("fusion",):
                for c in _called(ins.attrs, "calls"):
                    visit(c, mult, True)
            elif ins.op in ("call", "async-start"):
                for c in _called(ins.attrs, "calls"):
                    visit(c, mult, in_fusion)
            elif ins.op == "conditional":
                branches = (_called(ins.attrs, "true_computation")
                            + _called(ins.attrs, "false_computation")
                            + _called(ins.attrs, "branch_computations"))
                for br in branches:   # branches are tiny here; count each
                    visit(br, mult, in_fusion)
        visited_stack.pop()

    visit(entry, 1.0)
    return costs
