"""Roofline-term derivation from compiled dry-run artifacts (TPU v5e model).

    compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory_s     = HLO_bytes_per_device / HBM_BW
    collective_s = collective_bytes_per_device / ICI_BW

``cost_analysis()`` on an SPMD-partitioned executable reports the PER-DEVICE
program, so the terms need no further division by chip count.  Collective
bytes are parsed from the compiled HLO text: every (possibly async-start)
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute,
with standard ring-transfer factors applied per op kind and group size.

MODEL_FLOPS = 6*N*D (N = active params, D = tokens per step) is the "useful
work" cross-check: MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat recompute,
masked-attention waste and dispatch overhead.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

# --- TPU v5e hardware model (per chip) -------------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (conservative single-link)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<res>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


@dataclasses.dataclass
class Collective:
    op: str
    result_bytes: int
    group_size: int
    transfer_bytes: float    # ring-model bytes sent per device


def _shape_bytes(tok: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(tok):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total = max(total, n * _DTYPE_BYTES[dtype])
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collectives(hlo_text: str, default_group: int = 1) -> List[Collective]:
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        res_bytes = _shape_bytes(m.group("res"))
        g = _group_size(line, default_group)
        if g <= 1 and op != "collective-permute":
            continue
        if op == "all-reduce":
            xfer = 2.0 * res_bytes * (g - 1) / max(g, 1)
        elif op == "all-gather":
            # result holds the gathered value; each device sends its shard
            xfer = res_bytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            # result is the scattered shard; input = result * g
            xfer = res_bytes * (g - 1)
        elif op == "all-to-all":
            xfer = res_bytes * (g - 1) / max(g, 1)
        else:  # collective-permute
            xfer = float(res_bytes)
        out.append(Collective(op, res_bytes, g, xfer))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float               # per device
    hbm_bytes: float           # per device
    collective_bytes: float    # per device (ring-model transferred)
    collective_raw_bytes: float  # naive sum of collective operand sizes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    n_collectives: int
    by_op: Dict[str, float]

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_from(cost: Dict, hlo_text: str) -> Roofline:
    if isinstance(cost, (list, tuple)):       # jax 0.4.x: list of one dict
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives(hlo_text)
    cbytes = sum(c.transfer_bytes for c in colls)
    craw = sum(c.result_bytes for c in colls)
    by_op: Dict[str, float] = {}
    for c in colls:
        by_op[c.op] = by_op.get(c.op, 0.0) + c.transfer_bytes
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    collective_s = cbytes / ICI_BW
    dom = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return Roofline(flops=flops, hbm_bytes=hbm, collective_bytes=cbytes,
                    collective_raw_bytes=craw, compute_s=compute_s,
                    memory_s=memory_s, collective_s=collective_s,
                    dominant=dom, n_collectives=len(colls), by_op=by_op)


def model_flops(cfg, n_tokens: int, n_active_params: int) -> float:
    """6 * N_active * D (the standard training-FLOPs estimate; for inference
    steps callers pass the per-step token count)."""
    return 6.0 * n_active_params * n_tokens


def active_param_count(cfg, params_shapes) -> int:
    """Active params per token: total minus the non-routed share of experts."""
    import jax

    total = sum(int(l.size) for l in jax.tree.leaves(params_shapes))
    if cfg.n_experts == 0:
        return total
    leaves, _ = (jax.tree_util.tree_flatten_with_path(params_shapes))
    moe_params = sum(
        int(l.size) for p, l in leaves
        if "moe" in jax.tree_util.keystr(p)
        and re.search(r"w_(gate|up|down)", jax.tree_util.keystr(p)))
    active = total - moe_params + int(moe_params * cfg.top_k / cfg.n_experts)
    return active
