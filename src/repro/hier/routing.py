"""Three-leg greedy routing over a hierarchical overlay.

A node in cluster ``a`` reaches a node in cluster ``b`` the way the
topology is wired: greedy within ``a`` to the cluster head, greedy over
the head ring to ``b``'s head, greedy within ``b`` to the destination
(intra-cluster pairs route in one local leg).  Every leg reuses the
packed-neighbour-table router from :mod:`repro.routing.greedy` — the
batched variant groups legs per cluster so a (P, 2) pair batch costs one
device call per touched cluster plus one for the head ring, and the
single-pair host variant (served by ``/v1/route``) applies the identical
float32 next-hop rule per leg.

Observability: delivered routes record per-level hop counts into the
pre-registered ``repro_hier_route_hops{level="local"|"head"}`` histogram
(:mod:`repro.obs`), and request outcomes land in the shared
``repro_route_requests_total`` counter under policy ``"hier-<policy>"``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import HIER_ROUTE_HOPS
from repro.routing.greedy import (RouteResult, ring_distance_keys,
                                  route_pairs, route_single_host)
from repro.routing.metrics import ROUTE_REQUESTS

__all__ = ["HierRouteResult", "route_pairs_hier", "route_single_hier"]


@dataclasses.dataclass(frozen=True)
class HierRouteResult:
    """Per-pair outcome of one batched hierarchical routing call.

    Mirrors :class:`repro.routing.greedy.RouteResult` (same field
    semantics) with the hop count split by level: ``hops = hops_local +
    hops_head``.  ``optimum`` is the exact hierarchical shortest-path
    latency (:meth:`HierarchicalOverlay.distance_bound_pairs`), so
    ``stretch`` prices the greedy walk against the true optimum of this
    topology.
    """

    pairs: np.ndarray        # (P, 2) intp global src/dst
    hops: np.ndarray         # (P,) int32 total
    hops_local: np.ndarray   # (P,) int32 intra-cluster hops
    hops_head: np.ndarray    # (P,) int32 head-ring hops
    latency: np.ndarray      # (P,) float32
    success: np.ndarray      # (P,) bool
    failed: np.ndarray       # (P,) bool dead-ended on some leg
    optimum: np.ndarray      # (P,) float32
    stretch: np.ndarray      # (P,) float32; NaN unless delivered

    @property
    def n_pairs(self) -> int:
        return int(self.pairs.shape[0])


def _leg_route(ov, pairs: np.ndarray, policy: str,
               hop_budget: Optional[int]) -> RouteResult:
    """One leg on one flat overlay (cluster or head ring)."""
    ring = np.asarray(ov.rings[0]) if ov.rings else None
    return route_pairs(ov.adjacency, ov.distances(), pairs, policy=policy,
                       ring=ring, hop_budget=hop_budget)


def _merge_leg(rows: np.ndarray, res: RouteResult, hops: np.ndarray,
               lat: np.ndarray, success: np.ndarray,
               failed: np.ndarray) -> None:
    hops[rows] += res.hops
    lat[rows] += res.latency
    success[rows] &= res.success
    failed[rows] |= res.failed


def _record_batch(policy: str, success: np.ndarray, failed: np.ndarray,
                  hops_local: np.ndarray, hops_head: np.ndarray) -> None:
    label = f"hier-{policy}"
    n_ok = int(success.sum())
    n_dead = int(failed.sum())
    n_exhausted = success.size - n_ok - n_dead
    for outcome, count in (("delivered", n_ok), ("dead_end", n_dead),
                           ("exhausted", n_exhausted)):
        if count:
            ROUTE_REQUESTS.labels(policy=label, outcome=outcome).inc(count)
    local = HIER_ROUTE_HOPS.labels(level="local")
    head = HIER_ROUTE_HOPS.labels(level="head")
    for h in hops_local[success]:
        local.observe(int(h))
    for h in hops_head[success & (hops_head > 0)]:
        head.observe(int(h))


def route_pairs_hier(hov, pairs: np.ndarray, *, policy: str = "latency",
                     hop_budget: Optional[int] = None) -> HierRouteResult:
    """Route a (P, 2) batch of GLOBAL-id pairs over the hierarchy.

    Legs are grouped per cluster (and one head-ring batch), so the device
    call count is bounded by the number of touched clusters, not P.
    ``hop_budget`` applies per leg (default: the leg overlay's own N).
    """
    pairs = np.asarray(pairs, np.intp).reshape(-1, 2)
    p = pairs.shape[0]
    src, dst = pairs[:, 0], pairs[:, 1]
    a = hov.assignment[src]
    b = hov.assignment[dst]
    lsrc, ldst = hov._local[src], hov._local[dst]
    hl = hov._local[hov.heads]

    hops_local = np.zeros(p, np.int32)
    hops_head = np.zeros(p, np.int32)
    lat = np.zeros(p, np.float32)
    success = np.ones(p, bool)
    failed = np.zeros(p, bool)

    inter = a != b
    # leg 1 + intra leg: grouped by source cluster.  Intra pairs aim at
    # their destination; inter pairs aim at the source cluster's head.
    for c in np.unique(a):
        rows = np.flatnonzero(a == c)
        tgt = np.where(inter[rows], hl[c], ldst[rows])
        res = _leg_route(hov.clusters[c],
                         np.stack([lsrc[rows], tgt], axis=1), policy,
                         hop_budget)
        _merge_leg(rows, res, hops_local, lat, success, failed)
    # leg 2: one batch on the head ring (cluster-id node space)
    rows = np.flatnonzero(inter)
    if rows.size:
        res = _leg_route(hov.head_overlay,
                         np.stack([a[rows], b[rows]], axis=1), policy,
                         hop_budget)
        hops_head[rows] += res.hops
        lat[rows] += res.latency
        success[rows] &= res.success
        failed[rows] |= res.failed
        # leg 3: grouped by destination cluster, head -> dst
        for c in np.unique(b[rows]):
            sub = rows[b[rows] == c]
            res = _leg_route(hov.clusters[c],
                             np.stack([np.full(sub.size, hl[c], np.intp),
                                       ldst[sub]], axis=1), policy,
                             hop_budget)
            _merge_leg(sub, res, hops_local, lat, success, failed)

    optimum, _ = hov.distance_bound_pairs(src, dst)
    optimum = optimum.astype(np.float32)
    stretch = np.full(p, np.nan, np.float32)
    pos = success & (optimum > 0)
    stretch[pos] = lat[pos] / optimum[pos]
    stretch[success & (optimum == 0)] = 1.0
    _record_batch(policy, success, failed, hops_local, hops_head)
    return HierRouteResult(pairs=pairs, hops=hops_local + hops_head,
                           hops_local=hops_local, hops_head=hops_head,
                           latency=lat, success=success, failed=failed,
                           optimum=optimum, stretch=stretch)


def _leg_single(ov, src_local: int, dst_local: int, policy: str,
                hop_budget: Optional[int]
                ) -> Tuple[List[int], float, int, str]:
    if policy == "ring" and ov.rings:
        key = ring_distance_keys(np.asarray(ov.rings[0]),
                                 np.asarray([dst_local]))[0]
    else:
        key = ov.distances()[:, dst_local]
    return route_single_host(ov.adjacency, key, src_local, dst_local,
                             policy=policy, hop_budget=hop_budget)


def route_single_hier(hov, src: int, dst: int, *, policy: str = "latency",
                      hop_budget: Optional[int] = None
                      ) -> Tuple[List[int], float, Dict[str, int], str]:
    """Route ONE pair on the host, returning the GLOBAL-id path.

    Returns ``(path, latency, hops_by_level, outcome)`` where
    ``hops_by_level`` has ``"local"`` / ``"head"`` keys and outcome is
    ``"delivered"`` / ``"dead_end"`` / ``"exhausted"`` (first failing leg
    wins).  Metrics are recorded per call, matching the batched variant.
    """
    src, dst = int(src), int(dst)
    a, b = hov.cluster_of(src), hov.cluster_of(dst)
    hl = hov._local[hov.heads]
    legs: List[Tuple[str, object, int, int, np.ndarray]] = []
    if a == b:
        legs.append(("local", hov.clusters[a], hov.local_id(src),
                     hov.local_id(dst), hov.members[a]))
    else:
        legs.append(("local", hov.clusters[a], hov.local_id(src),
                     int(hl[a]), hov.members[a]))
        legs.append(("head", hov.head_overlay, a, b, hov.heads))
        legs.append(("local", hov.clusters[b], int(hl[b]),
                     hov.local_id(dst), hov.members[b]))
    path: List[int] = []
    lat = 0.0
    hops = {"local": 0, "head": 0}
    outcome = "delivered"
    for level, ov, s, d, to_global in legs:
        leg_path, leg_lat, leg_hops, outcome = _leg_single(
            ov, s, d, policy, hop_budget)
        glob = [int(to_global[u]) for u in leg_path]
        path.extend(glob if not path else glob[1:])
        lat += leg_lat
        hops[level] += leg_hops
        if outcome != "delivered":
            break
    ROUTE_REQUESTS.labels(policy=f"hier-{policy}", outcome=outcome).inc()
    if outcome == "delivered":
        HIER_ROUTE_HOPS.labels(level="local").observe(hops["local"])
        if hops["head"]:
            HIER_ROUTE_HOPS.labels(level="head").observe(hops["head"])
    return path, float(lat), hops, outcome
