"""Two-level hierarchical overlays (paper §VI, composed construction).

The flat :class:`~repro.overlay.Overlay` carries a dense (N, N) latency
matrix and dense APSP caches — O(N^2) memory caps it around N=4096.  This
module composes the paper's partitioned construction into a two-level
hierarchy that reaches N=10^5-10^6:

* nodes are partitioned into latency clusters (recursive farthest-point
  splitting over a lazy :class:`~repro.hier.geo.LatencyModel` — never a
  dense matrix);
* each cluster gets a flat cluster-local :class:`Overlay` whose rings are
  built by the device-batched engine (``core.construction
  .nearest_rings_batched``): all clusters in a chunk build their k rings in
  ONE fused jit call over an INF-padded (M·k, P, P) block stack;
* each cluster elects a **head** (latency medoid), and a DGRO ring overlay
  is built over the heads.

Heads are each cluster's only gateway, which makes the two-level distance
composition *exact for the hierarchical topology*: for u in cluster a and
v in cluster b != a,

    d(u, v) = d_a(u, h_a) + D_head(a, b) + d_b(h_b, v)

(any excursion into a third cluster's interior enters and leaves through
the same head, a non-negative cycle).  :meth:`HierarchicalOverlay
.diameter_bound` therefore stamps ``"exact"`` when it evaluates full
cluster APSPs, and ``"upper"`` for the cheap eccentricity composition
``max_{a,b} ecc_a + D_head(a, b) + ecc_b`` (a == b included: 2·ecc bounds
the intra-cluster diameter) that needs only one Dijkstra per cluster.

:class:`HierarchicalOverlay` satisfies the :class:`repro.overlay.Topology`
protocol; the ``"dgro-hier"`` registry builder returns one from a dense
latency matrix, and :func:`build_hier` accepts any lazy latency model for
the large-N path.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro import serde
from repro.core.construction import default_num_rings, nearest_rings_batched
from repro.core.diameter import INF, is_edge
from repro.overlay import Overlay, register
from repro.overlay import build as build_overlay
from .geo import (DenseLatency, LatencyModel, SubsetLatency, as_latency,
                  latency_from_spec)

__all__ = ["HierConfig", "HierarchicalOverlay", "build_hier",
           "assign_latency_clusters", "default_cluster_size"]


def default_cluster_size(n: int) -> int:
    """Target cluster size: sqrt(N) balances the two levels (cluster state
    and head ring are then both ~sqrt(N)), capped at 512 so a cluster's
    dense (P, P) state stays small at any N.  The balance matters: a cap
    far below sqrt(N) pushes all the nodes into the head ring, whose
    guided DGRO build is the O(M^2)-and-up term."""
    return int(min(512, max(8, math.ceil(math.sqrt(max(n, 1))))))


@dataclasses.dataclass(frozen=True)
class HierConfig:
    """Config for the ``"dgro-hier"`` builder / :func:`build_hier`.

    ``cluster_size=0`` / ``k_local=0`` pick :func:`default_cluster_size` and
    ``ceil(log2 cluster_size)`` rings (the paper's per-node degree budget)
    respectively.  ``head_policy`` names any registered *flat* builder for
    the ring over cluster heads.  ``chunk`` bounds how many clusters share
    one fused device build (memory/compile-shape knob, not semantics).
    """

    cluster_size: int = 0
    k_local: int = 0
    head_policy: str = "dgro"
    chunk: int = 64


# ---------------------------------------------------------------------------
# latency clustering (lazy model, O(N * M) time, O(N) memory)
# ---------------------------------------------------------------------------

def _split_group(lat: LatencyModel, mem: np.ndarray, k: int,
                 rng: np.random.Generator) -> List[np.ndarray]:
    """Partition ``mem`` into ``k`` groups by nearest of k farthest-point
    seeds (distances asked from the lazy model one column at a time)."""
    seeds = [int(mem[rng.integers(mem.size)])]
    near = lat.block(mem, seeds[:1])[:, 0].astype(np.float64)
    assign = np.zeros(mem.size, np.int64)
    for c in range(1, k):
        s = int(mem[np.argmax(near)])
        seeds.append(s)
        d = lat.block(mem, [s])[:, 0].astype(np.float64)
        closer = d < near
        near[closer] = d[closer]
        assign[closer] = c
    groups = [mem[assign == c] for c in range(k)]
    groups = [g for g in groups if g.size]
    if len(groups) == 1 and k > 1:
        # degenerate metric (e.g. co-located nodes): chop by distance rank
        order = mem[np.argsort(near, kind="stable")]
        groups = [g for g in np.array_split(order, k) if g.size]
    return groups


def _merge_small_leaves(lat: LatencyModel, leaves: List[np.ndarray],
                        target: int, cap: int) -> List[np.ndarray]:
    """Fold leaves below ``target // 2`` into their nearest neighbour leaf
    (by representative latency) while the union stays under ``cap``.

    Nearest-seed splitting is uneven under skewed node density — seeds in
    sparse regions capture few nodes — and every undersized leaf becomes a
    head-ring node, inflating the level whose guided build is the
    expensive one.  This greedy pass restores the ~sqrt(N) balance.
    """
    floor = max(2, target // 2)
    reps = np.array([int(g[g.size // 2]) for g in leaves], np.intp)
    sizes = np.array([g.size for g in leaves], np.int64)
    alive = np.ones(len(leaves), bool)
    groups: List[np.ndarray] = list(leaves)
    while alive.sum() > 1:
        small = np.flatnonzero(alive & (sizes < floor))
        if not small.size:
            break
        i = int(small[np.argmin(sizes[small])])
        cand = np.flatnonzero(alive & (sizes + sizes[i] <= cap))
        cand = cand[cand != i]
        if not cand.size:      # nothing can absorb it without bursting cap
            alive[i] = False   # keep as-is, stop reconsidering it
            continue
        d = lat.pairs(np.full(cand.size, reps[i], np.intp), reps[cand])
        j = int(cand[np.argmin(d)])
        groups[j] = np.sort(np.concatenate([groups[j], groups[i]]))
        sizes[j] += sizes[i]
        alive[i] = False
        groups[i] = np.zeros(0, np.intp)
        sizes[i] = 0
    return [g for g in groups if g.size]


def assign_latency_clusters(lat: LatencyModel, target: int,
                            rng: np.random.Generator) -> np.ndarray:
    """(N,) cluster assignment with every cluster below ~1.5x ``target``.

    Recursive farthest-point splitting: any group above the cap is split
    ``ceil(size / target)``-ways (at most 64 per round) by nearest-seed.
    Unlike one global farthest-point pass, this stays balanced under skewed
    node density (a metro site with 10^4 co-located nodes still ends up in
    ~``size / target`` clusters).  Clusters are numbered by their smallest
    member id, so the labelling is stable and members are sorted.
    """
    if target < 2:
        raise ValueError(f"target cluster size must be >= 2, got {target}")
    cap = max(3, int(1.5 * target))
    queue: List[np.ndarray] = [np.arange(lat.n)]
    leaves: List[np.ndarray] = []
    while queue:
        mem = queue.pop()
        if mem.size <= cap:
            leaves.append(mem)
            continue
        k = min(64, math.ceil(mem.size / target))
        queue.extend(_split_group(lat, mem, k, rng))
    leaves = _merge_small_leaves(lat, leaves, target, cap)
    leaves.sort(key=lambda g: int(g[0]))
    assignment = np.empty(lat.n, np.int32)
    for c, mem in enumerate(leaves):
        assignment[mem] = c
    return assignment


# ---------------------------------------------------------------------------
# fused cluster-local ring construction
# ---------------------------------------------------------------------------

def _round_up(x: int, mult: int = 16) -> int:
    return ((x + mult - 1) // mult) * mult


def _build_cluster_overlays(lat: LatencyModel, members: List[np.ndarray],
                            k_local: int, rng: np.random.Generator,
                            chunk: int) -> Tuple[List[Overlay], List[int]]:
    """Cluster-local overlays + head election, via fused device builds.

    Clusters are sorted by size into chunks; each chunk pads its latency
    blocks to one (P, P) shape (INF sentinel keeps pad nodes unreachable
    until the real nodes are exhausted) and builds all ``len(chunk) *
    k_local`` nearest rings in one ``nearest_rings_batched`` call —
    distinct random starts make the k rings of a cluster distinct.
    """
    m = len(members)
    overlays: List[Optional[Overlay]] = [None] * m
    heads: List[int] = [0] * m
    order = sorted(range(m), key=lambda c: members[c].size)
    # chunk is additionally capped so a chunk's padded block stack stays
    # under ~256 MB of float32 whatever the cluster sizes are
    budget = 1 << 26
    lo = 0
    while lo < m:
        hi = lo + 1
        while (hi < m and hi - lo < chunk
               and (hi - lo + 1) * k_local
               * _round_up(members[order[hi]].size) ** 2 <= budget):
            hi += 1
        cs = order[lo:hi]
        lo = hi
        pad = _round_up(max(members[c].size for c in cs))
        blocks = np.full((len(cs) * k_local, pad, pad), float(INF), np.float32)
        starts = np.zeros(len(cs) * k_local, np.int32)
        w_blocks = []
        for i, c in enumerate(cs):
            mem = members[c]
            wb = lat.block(mem, mem)
            w_blocks.append(wb)
            blocks[i * k_local:(i + 1) * k_local, :mem.size, :mem.size] = wb
            if mem.size >= k_local:
                starts[i * k_local:(i + 1) * k_local] = rng.choice(
                    mem.size, size=k_local, replace=False)
            else:
                starts[i * k_local:(i + 1) * k_local] = rng.integers(
                    0, mem.size, size=k_local)
        perms = np.asarray(nearest_rings_batched(jnp.asarray(blocks),
                                                 jnp.asarray(starts)))
        for i, c in enumerate(cs):
            size = members[c].size
            rings = [perms[i * k_local + j][:size].astype(np.intp)
                     for j in range(k_local)]
            overlays[c] = Overlay.from_rings(w_blocks[i], rings,
                                             policy="dgro-hier-local")
            heads[c] = int(members[c][np.argmin(w_blocks[i].sum(axis=1))])
    return overlays, heads    # type: ignore[return-value]


def _build_head_overlay(w_heads: np.ndarray, head_policy: str,
                        rng: np.random.Generator) -> Overlay:
    m = w_heads.shape[0]
    if m < 4:
        # too small for the guided builders: a single ring IS the topology
        return Overlay.from_rings(w_heads, [np.arange(m)], policy=head_policy)
    return build_overlay(head_policy, w_heads, rng=rng)


# ---------------------------------------------------------------------------
# the hierarchical overlay
# ---------------------------------------------------------------------------

@dataclasses.dataclass(eq=False)
class HierarchicalOverlay:
    """Two-level topology: cluster-local overlays + a head ring.

    Satisfies the :class:`repro.overlay.Topology` protocol.  Node ids are
    global (``range(n)``); cluster ``c``'s local node order is its sorted
    member ids (derived from ``assignment``, so serialization only carries
    the assignment vector).  ``heads[c]`` is the global id of cluster
    ``c``'s gateway; ``head_overlay`` is a flat overlay whose node ``c`` is
    cluster ``c``'s head.
    """

    lat: LatencyModel
    assignment: np.ndarray
    clusters: Tuple[Overlay, ...]
    heads: np.ndarray
    head_overlay: Overlay
    head_policy: str = "dgro"
    policy: str = "dgro-hier"

    def __post_init__(self):
        self.assignment = np.asarray(self.assignment, np.int32)
        self.heads = np.asarray(self.heads, np.intp)
        self.clusters = tuple(self.clusters)
        m = len(self.clusters)
        if self.assignment.ndim != 1 or self.assignment.size != self.lat.n:
            raise ValueError(
                f"assignment must be ({self.lat.n},), got "
                f"{self.assignment.shape}")
        if self.heads.shape != (m,) or self.head_overlay.n != m:
            raise ValueError(
                f"need one head per cluster: {m} clusters, "
                f"{self.heads.size} heads, head overlay n={self.head_overlay.n}")
        self.members: Tuple[np.ndarray, ...] = tuple(
            np.flatnonzero(self.assignment == c) for c in range(m))
        self._local = np.zeros(self.n, np.intp)
        for c, mem in enumerate(self.members):
            if mem.size != self.clusters[c].n:
                raise ValueError(
                    f"cluster {c} overlay has n={self.clusters[c].n} but "
                    f"{mem.size} assigned members")
            if mem.size == 0:
                raise ValueError(f"cluster {c} is empty")
            self._local[mem] = np.arange(mem.size)
            if self.assignment[self.heads[c]] != c:
                raise ValueError(
                    f"head {int(self.heads[c])} of cluster {c} is assigned "
                    f"to cluster {int(self.assignment[self.heads[c]])}")
        self._cache: Dict[str, object] = {}

    # -- basic shape ------------------------------------------------------

    @property
    def n(self) -> int:
        return self.assignment.size

    @property
    def n_clusters(self) -> int:
        return len(self.clusters)

    def cluster_of(self, u: int) -> int:
        return int(self.assignment[int(u)])

    def local_id(self, u: int) -> int:
        return int(self._local[int(u)])

    def cluster_sizes(self) -> np.ndarray:
        return np.array([mem.size for mem in self.members], np.int64)

    def edge_list(self) -> np.ndarray:
        """(E, 2) unique undirected global edges (u < v): the union of every
        cluster's edges and the head overlay's edges."""
        if "edge_list" not in self._cache:
            parts = [mem[ov.edge_list()]
                     for mem, ov in zip(self.members, self.clusters)]
            he = self.head_overlay.edge_list()
            if he.size:
                parts.append(self.heads[he])
            e = np.concatenate(parts, axis=0) if parts else \
                np.zeros((0, 2), np.intp)
            e = e[e[:, 0] != e[:, 1]]          # 1-node cluster self-loops
            e = np.sort(e, axis=1)
            self._cache["edge_list"] = np.unique(e, axis=0)
        return self._cache["edge_list"]

    # -- distance / diameter bounds (topology protocol) -------------------

    def _head_local(self) -> np.ndarray:
        return self._local[self.heads]

    def distance_bound(self, u: int, v: int) -> Tuple[float, str]:
        """Exact hierarchical shortest-path latency.

        Heads are the only inter-cluster gateways, so the three-leg
        composition is exact for this topology (see module docstring).
        """
        u, v = int(u), int(v)
        a, b = self.cluster_of(u), self.cluster_of(v)
        lu, lv = self.local_id(u), self.local_id(v)
        if a == b:
            return float(self.clusters[a].distances()[lu, lv]), "exact"
        hl = self._head_local()
        da = float(self.clusters[a].distances()[lu, hl[a]])
        db = float(self.clusters[b].distances()[hl[b], lv])
        dh = float(self.head_overlay.distances()[a, b])
        return da + dh + db, "exact"

    def distance_bound_pairs(self, us, vs) -> Tuple[np.ndarray, str]:
        """Vectorized :meth:`distance_bound` over aligned id arrays."""
        us = np.asarray(us, np.intp)
        vs = np.asarray(vs, np.intp)
        a, b = self.assignment[us], self.assignment[vs]
        lu, lv = self._local[us], self._local[vs]
        hl = self._head_local()
        dh = self.head_overlay.distances()
        out = np.empty(us.shape, np.float64)
        for i in range(us.size):
            ca, cb = int(a[i]), int(b[i])
            if ca == cb:
                out[i] = self.clusters[ca].distances()[lu[i], lv[i]]
            else:
                out[i] = (self.clusters[ca].distances()[lu[i], hl[ca]]
                          + dh[ca, cb]
                          + self.clusters[cb].distances()[hl[cb], lv[i]])
        return out, "exact"

    def _head_eccentricities(self, exact: bool) -> np.ndarray:
        """Per-cluster max distance from the head to any member.

        ``exact=True`` reads the (cached) full cluster APSPs; otherwise one
        sparse Dijkstra per cluster — O(E log P), no (P, P) cache.
        """
        key = "ecc_exact" if exact else "ecc"
        if key not in self._cache:
            hl = self._head_local()
            ecc = np.empty(self.n_clusters, np.float64)
            if exact:
                for c, ov in enumerate(self.clusters):
                    ecc[c] = ov.distances()[hl[c]].max()
            else:
                from scipy.sparse import csr_matrix
                from scipy.sparse.csgraph import dijkstra
                for c, ov in enumerate(self.clusters):
                    adj = np.asarray(ov.adjacency, np.float64)
                    sp = csr_matrix(np.where(np.asarray(is_edge(adj)),
                                             adj, 0.0))
                    d = dijkstra(sp, directed=False, indices=int(hl[c]))
                    ecc[c] = d[np.isfinite(d)].max()
            self._cache[key] = ecc
        return self._cache[key]

    def diameter_bound(self, method: str = "auto") -> Tuple[float, str]:
        """Hierarchical diameter: exact or a cheap upper bound.

        * ``"exact"`` — full cluster APSPs: max over per-cluster diameters
          and the head-composed cross terms ``ecc_a + D_head(a, b) + ecc_b``
          (a != b).  Exact for this topology; stamp ``"exact"``.
        * ``"ecc"`` — one Dijkstra per cluster: max over ``ecc_a +
          D_head(a, b) + ecc_b`` including a == b (2·ecc bounds each
          intra-cluster diameter).  Never an underestimate; stamp
          ``"upper"``.
        * ``"auto"`` — ``"exact"`` up to N = 4096 (where caching every
          cluster APSP is trivially cheap), else ``"ecc"``.
        """
        if method == "auto":
            method = "exact" if self.n <= 4096 else "ecc"
        if method not in ("exact", "ecc"):
            raise ValueError(f"unknown diameter method {method!r}")
        key = f"diameter_{method}"
        if key not in self._cache:
            dh = self.head_overlay.distances().astype(np.float64)
            if method == "exact":
                ecc = self._head_eccentricities(exact=True)
                cross = ecc[:, None] + dh + ecc[None, :]
                np.fill_diagonal(cross, -np.inf)
                intra = max(ov.diameter() for ov in self.clusters)
                value = float(max(intra, cross.max())) \
                    if self.n_clusters > 1 else float(intra)
                self._cache[key] = (value, "exact")
            else:
                ecc = self._head_eccentricities(exact=False)
                cross = ecc[:, None] + dh + ecc[None, :]
                self._cache[key] = (float(cross.max()), "upper")
        return self._cache[key]

    # -- materialization (small-N verification only) ----------------------

    def materialize(self) -> Overlay:
        """Flatten to a dense global :class:`Overlay` (exact-APSP oracle
        for tests/benchmarks).  Refuses above N=4096 — the whole point of
        the hierarchy is that the dense form does not fit there."""
        if self.n > 4096:
            raise ValueError(
                f"refusing to materialize n={self.n} > 4096 as a dense "
                f"Overlay; use distance_bound / diameter_bound instead")
        from repro.core.diameter import adjacency_from_edges
        w = self.lat.dense()
        adj = adjacency_from_edges(w, self.edge_list())
        return Overlay.from_adjacency(w, adj, policy=self.policy)

    # -- subset (churn) ---------------------------------------------------

    def subset(self, alive) -> "HierarchicalOverlay":
        """Restrict to live nodes, reindexing to ``range(n_live)``.

        Per-cluster subsetting reuses :meth:`Overlay.subset`; emptied
        clusters are dropped, dead heads are re-elected (latency medoid of
        the survivors), and the head ring is rebuilt with ``head_policy``
        whenever the head set changed.  The latency model becomes a lazy
        :class:`~repro.hier.geo.SubsetLatency` view — nothing dense is
        materialized.
        """
        alive = np.asarray(alive)
        if alive.dtype == bool:
            if alive.shape != (self.n,):
                raise ValueError(
                    f"boolean subset mask must have shape ({self.n},), got "
                    f"{alive.shape}")
            idx = np.flatnonzero(alive)
        else:
            idx = np.unique(np.asarray(alive, np.intp).ravel())
            if idx.size and (idx[0] < 0 or idx[-1] >= self.n):
                raise ValueError(
                    f"subset indices must lie in [0, {self.n})")
        if idx.size == 0:
            raise ValueError("subset() needs at least one live node")
        keep = np.zeros(self.n, bool)
        keep[idx] = True
        remap = np.full(self.n, -1, np.intp)
        remap[idx] = np.arange(idx.size)

        new_clusters: List[Overlay] = []
        new_heads_old: List[int] = []        # global ids in OLD numbering
        new_assign = np.empty(idx.size, np.int32)
        heads_changed = False
        for c, mem in enumerate(self.members):
            live_local = np.flatnonzero(keep[mem])
            if live_local.size == 0:
                heads_changed = True
                continue
            sub = self.clusters[c].subset(live_local)
            live_global = mem[live_local]
            if keep[self.heads[c]]:
                head = int(self.heads[c])
            else:
                heads_changed = True
                head = int(live_global[np.argmin(sub.w.sum(axis=1))])
            new_assign[remap[live_global]] = len(new_clusters)
            new_clusters.append(sub)
            new_heads_old.append(head)
        if len(new_clusters) != self.n_clusters:
            heads_changed = True
        heads_old = np.asarray(new_heads_old, np.intp)
        if heads_changed:
            w_heads = self.lat.block(heads_old, heads_old)
            head_overlay = _build_head_overlay(
                w_heads, self.head_policy, np.random.default_rng(0))
        else:
            head_overlay = self.head_overlay
        return HierarchicalOverlay(
            lat=SubsetLatency(self.lat, idx), assignment=new_assign,
            clusters=tuple(new_clusters), heads=remap[heads_old],
            head_overlay=head_overlay, head_policy=self.head_policy,
            policy=self.policy)

    # -- serialization (schema 2) -----------------------------------------

    def to_json(self) -> str:
        """Schema-2 snapshot (``"kind": "hier_overlay"``).

        Members/local ordering are derived from ``assignment`` on load, so
        the payload carries assignment + heads + the latency spec + nested
        flat-overlay payloads (each schema 1, as written by
        :meth:`Overlay.to_json`).
        """
        return serde.dumps({
            "kind": "hier_overlay",
            "policy": self.policy,
            "head_policy": self.head_policy,
            "n": self.n,
            "assignment": [int(c) for c in self.assignment],
            "heads": [int(h) for h in self.heads],
            "latency": self.lat.to_spec(),
            "clusters": [json.loads(ov.to_json()) for ov in self.clusters],
            "head_overlay": json.loads(self.head_overlay.to_json()),
        }, schema=serde.HIER_SCHEMA, indent=None)

    @classmethod
    def from_json(cls, s: str) -> "HierarchicalOverlay":
        d = serde.loads(s, what="HierarchicalOverlay JSON")
        if serde.payload_schema(d) != serde.HIER_SCHEMA \
                or d.get("kind") != "hier_overlay":
            raise ValueError(
                "payload is not a schema-2 hierarchical overlay; flat "
                "Overlay payloads load with repro.overlay.Overlay.from_json "
                "or repro.overlay.from_topology_json")
        return cls(
            lat=latency_from_spec(d["latency"]),
            assignment=np.asarray(d["assignment"], np.int32),
            clusters=tuple(Overlay.from_json(json.dumps(p))
                           for p in d["clusters"]),
            heads=np.asarray(d["heads"], np.intp),
            head_overlay=Overlay.from_json(json.dumps(d["head_overlay"])),
            head_policy=d["head_policy"],
            policy=d["policy"])

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "HierarchicalOverlay":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- misc -------------------------------------------------------------

    def equals(self, other: "HierarchicalOverlay") -> bool:
        return (self.policy == other.policy
                and self.head_policy == other.head_policy
                and np.array_equal(self.assignment, other.assignment)
                and np.array_equal(self.heads, other.heads)
                and self.head_overlay.equals(other.head_overlay)
                and len(self.clusters) == len(other.clusters)
                and all(a.equals(b)
                        for a, b in zip(self.clusters, other.clusters)))

    def __repr__(self) -> str:
        sizes = self.cluster_sizes()
        return (f"HierarchicalOverlay(policy={self.policy!r}, n={self.n}, "
                f"clusters={self.n_clusters}, "
                f"cluster_size=[{int(sizes.min())}..{int(sizes.max())}], "
                f"head_policy={self.head_policy!r})")


# ---------------------------------------------------------------------------
# construction
# ---------------------------------------------------------------------------

def build_hier(lat, cfg: Optional[HierConfig] = None, *,
               rng: Optional[np.random.Generator] = None,
               seed: int = 0) -> HierarchicalOverlay:
    """Build a two-level hierarchical overlay over any latency source.

    ``lat`` is a :class:`~repro.hier.geo.LatencyModel` or a dense matrix
    (coerced).  This is the large-N entry point — with a lazy model the
    build never allocates anything bigger than one cluster chunk's padded
    block stack and the (M, M) head matrix.
    """
    lat = as_latency(lat)
    cfg = cfg or HierConfig()
    rng = rng if rng is not None else np.random.default_rng(seed)
    n = lat.n
    target = cfg.cluster_size or default_cluster_size(n)
    k_local = cfg.k_local or default_num_rings(min(target, n))
    assignment = assign_latency_clusters(lat, target, rng)
    m = int(assignment.max()) + 1
    members = [np.flatnonzero(assignment == c) for c in range(m)]
    clusters, heads = _build_cluster_overlays(lat, members, k_local, rng,
                                              max(1, cfg.chunk))
    heads_arr = np.asarray(heads, np.intp)
    w_heads = lat.block(heads_arr, heads_arr)
    head_overlay = _build_head_overlay(w_heads, cfg.head_policy, rng)
    return HierarchicalOverlay(
        lat=lat, assignment=assignment, clusters=tuple(clusters),
        heads=heads_arr, head_overlay=head_overlay,
        head_policy=cfg.head_policy, policy="dgro-hier")


@register("dgro-hier", config=HierConfig, kind="hier")
def _build_dgro_hier(w: np.ndarray, cfg: HierConfig,
                     rng: np.random.Generator) -> HierarchicalOverlay:
    """Registry builder: dense latency matrix in, hierarchy out.  Large-N
    callers with a lazy latency model use :func:`build_hier` directly."""
    return build_hier(DenseLatency(np.asarray(w, np.float32)), cfg, rng=rng)
