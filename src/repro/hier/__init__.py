"""repro.hier — hierarchical overlays for N = 10^5..10^6 fleets.

The flat :class:`repro.overlay.Overlay` holds the full (N, N) latency
matrix: 40 GB of float32 at N = 10^5, and DGRO construction is
O(N^2 log N).  Following the paper's §VI composition argument (parallel
partition construction composes into two-level hierarchies), this
package partitions the fleet into latency-coherent clusters, builds a
cluster-local flat DGRO overlay per partition — ALL clusters in one
fused device batch via ``nearest_rings_batched`` — and a DGRO head ring
over one representative ("head") per cluster.  Memory and construction
cost drop to O(sum_c P_c^2 + M^2).

Layout:

  geo      — lazy latency models (``LatencyModel``): block-on-demand
             synthetic geography so N = 10^5 never materializes (N, N)
  core     — clustering, fused construction, ``HierarchicalOverlay``
             (the second :class:`repro.overlay.Topology` implementation;
             schema-2 serde), the ``"dgro-hier"`` registry builder
  routing  — three-leg greedy routing (cluster -> head ring -> cluster)
             reusing the packed-neighbour-table router per level
  engine   — ``HierChurnEngine``: cluster-local incremental maintenance;
             the head ring is touched only on head death / drain /
             split / merge

Distance/diameter bounds keep the stack-wide contract: stamped
``"exact"`` or ``"lower"`` (``"upper"`` for diameter estimates), never
silently approximate.  Importing this package registers the
``"dgro-hier"`` builder with :mod:`repro.overlay` (the registry also
lazy-imports it on first use).
"""
from .core import (HierConfig, HierarchicalOverlay,  # noqa: F401
                   assign_latency_clusters, build_hier,
                   default_cluster_size)
from .engine import HierChurnEngine  # noqa: F401
from .geo import (DenseLatency, LatencyModel, SubsetLatency,  # noqa: F401
                  SyntheticGeo, as_latency, latency_from_spec,
                  synthetic_geo)
from .routing import (HierRouteResult, route_pairs_hier,  # noqa: F401
                      route_single_hier)

__all__ = [
    "HierConfig", "HierarchicalOverlay", "build_hier",
    "assign_latency_clusters", "default_cluster_size",
    "HierChurnEngine",
    "LatencyModel", "DenseLatency", "SyntheticGeo", "SubsetLatency",
    "synthetic_geo", "as_latency", "latency_from_spec",
    "HierRouteResult", "route_pairs_hier", "route_single_hier",
]
