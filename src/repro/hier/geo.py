"""Lazy latency models — the (N, N) matrix N=10^5 cannot afford.

A flat N=10^5 float32 latency matrix is 40 GB; the hierarchical builder
never materializes it.  Instead it consumes a :class:`LatencyModel`: an
object that answers ``block(rows, cols)`` — the (R, C) latency submatrix
between two id sets — on demand.  Two implementations:

* :class:`DenseLatency` — wraps an existing (N, N) matrix (the small/mid-N
  path; every ``core.topology`` distribution and every :class:`Trace`
  world goes through this, so hierarchical and flat builds see identical
  numbers);
* :class:`SyntheticGeo` — the large-N synthetic-geo world: ``sites``
  random ground stations, nodes multinomially assigned with local
  coordinate jitter, latency = great-circle distance at 2/3 c + router
  overhead + both endpoints' processing latency (the same physical model
  as ``core.topology.fabric_latency``, minus the fixed 17-site table).
  O(N) state — coordinates and per-node processing times — and any block
  is computed vectorized on demand.

Both serialize to a small spec dict (``to_spec`` / :func:`latency_from_spec`)
so a :class:`~repro.hier.HierarchicalOverlay` snapshot can restore its
world: dense specs embed the matrix, synthetic-geo specs embed only
``(n, sites, seed)`` and regenerate deterministically.
"""
from __future__ import annotations

from typing import Dict, Sequence, Union

import numpy as np

__all__ = ["LatencyModel", "DenseLatency", "SyntheticGeo", "SubsetLatency",
           "synthetic_geo", "as_latency", "latency_from_spec"]

# one-way propagation: great-circle km at 0.66 c, plus router/queuing
# overhead — identical constants to core.topology._greatcircle_ms
_KM_PER_MS = 0.66 * 299.79
_ROUTER_MS = 2.0


class LatencyModel:
    """Protocol-ish base: latency lookups over node-id sets, no (N, N)."""

    @property
    def n(self) -> int:
        raise NotImplementedError

    def block(self, rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
        """(R, C) float32 latency submatrix (0 where the same node)."""
        raise NotImplementedError

    def pairs(self, us: np.ndarray, vs: np.ndarray) -> np.ndarray:
        """Elementwise latency for aligned id vectors."""
        us = np.asarray(us, np.intp)
        vs = np.asarray(vs, np.intp)
        out = np.empty(us.shape, np.float32)
        for i, (u, v) in enumerate(zip(us, vs)):
            out[i] = self.block(np.array([u]), np.array([v]))[0, 0]
        return out

    def dense(self) -> np.ndarray:
        """The full (N, N) matrix — small-N convenience only."""
        ids = np.arange(self.n)
        return self.block(ids, ids)

    def to_spec(self) -> Dict:
        raise NotImplementedError


class DenseLatency(LatencyModel):
    """A plain (N, N) matrix behind the lazy-block interface."""

    def __init__(self, w: np.ndarray):
        w = np.asarray(w, np.float32)
        if w.ndim != 2 or w.shape[0] != w.shape[1]:
            raise ValueError(f"w must be square, got shape {w.shape}")
        self.w = w

    @property
    def n(self) -> int:
        return self.w.shape[0]

    def block(self, rows, cols) -> np.ndarray:
        return self.w[np.ix_(np.asarray(rows, np.intp),
                             np.asarray(cols, np.intp))]

    def pairs(self, us, vs) -> np.ndarray:
        return self.w[np.asarray(us, np.intp), np.asarray(vs, np.intp)]

    def dense(self) -> np.ndarray:
        return self.w

    def to_spec(self) -> Dict:
        return {"kind": "dense",
                "w": [[float(x) for x in row] for row in self.w]}


class SyntheticGeo(LatencyModel):
    """Synthetic-geo world: O(N) coordinates, lazy great-circle blocks."""

    def __init__(self, n: int, *, sites: int = 64, seed: int = 0):
        if n < 1 or sites < 1:
            raise ValueError(f"need n >= 1 and sites >= 1, got {n}, {sites}")
        self._n = int(n)
        self.sites = int(sites)
        self.seed = int(seed)
        rng = np.random.default_rng(seed)
        # ground stations over the populated latitude band; node density per
        # site is Dirichlet-skewed (a few metros, many small sites)
        site_lon = rng.uniform(-180.0, 180.0, size=sites)
        site_lat = rng.uniform(-50.0, 65.0, size=sites)
        weights = rng.dirichlet(np.full(sites, 1.5))
        self.site_of = rng.choice(sites, size=n, p=weights).astype(np.int32)
        jitter = rng.normal(0.0, 1.5, size=(n, 2))
        self.coords = np.stack([site_lon[self.site_of] + jitter[:, 0],
                                site_lat[self.site_of] + jitter[:, 1]],
                               axis=1)
        self.proc_ms = np.clip(rng.normal(5.0, 1.0, size=n),
                               0.1, None).astype(np.float32)

    @property
    def n(self) -> int:
        return self._n

    def block(self, rows, cols) -> np.ndarray:
        rows = np.asarray(rows, np.intp)
        cols = np.asarray(cols, np.intp)
        a, b = self.coords[rows], self.coords[cols]
        lon_a, lat_a = np.radians(a[:, 0])[:, None], np.radians(a[:, 1])[:, None]
        lon_b, lat_b = np.radians(b[:, 0])[None, :], np.radians(b[:, 1])[None, :]
        cosd = (np.sin(lat_a) * np.sin(lat_b)
                + np.cos(lat_a) * np.cos(lat_b) * np.cos(lon_a - lon_b))
        km = 6371.0 * np.arccos(np.clip(cosd, -1.0, 1.0))
        ms = (km / _KM_PER_MS + _ROUTER_MS
              + self.proc_ms[rows][:, None] + self.proc_ms[cols][None, :])
        ms[rows[:, None] == cols[None, :]] = 0.0
        return ms.astype(np.float32)

    def pairs(self, us, vs) -> np.ndarray:
        us = np.asarray(us, np.intp)
        vs = np.asarray(vs, np.intp)
        a, b = self.coords[us], self.coords[vs]
        lon_a, lat_a = np.radians(a[:, 0]), np.radians(a[:, 1])
        lon_b, lat_b = np.radians(b[:, 0]), np.radians(b[:, 1])
        cosd = (np.sin(lat_a) * np.sin(lat_b)
                + np.cos(lat_a) * np.cos(lat_b) * np.cos(lon_a - lon_b))
        km = 6371.0 * np.arccos(np.clip(cosd, -1.0, 1.0))
        ms = km / _KM_PER_MS + _ROUTER_MS + self.proc_ms[us] + self.proc_ms[vs]
        return np.where(us == vs, 0.0, ms).astype(np.float32)

    def to_spec(self) -> Dict:
        return {"kind": "synthetic-geo", "n": self._n, "sites": self.sites,
                "seed": self.seed}


class SubsetLatency(LatencyModel):
    """A reindexed view onto another model: new id ``i`` = base id ``ids[i]``.

    Produced by ``HierarchicalOverlay.subset`` so the surviving topology
    keeps lazy latency access without materializing anything.
    """

    def __init__(self, base: "LatencyModel", ids):
        self.base = base
        self.ids = np.asarray(ids, np.intp)
        if self.ids.size and (self.ids.min() < 0 or self.ids.max() >= base.n):
            raise ValueError(
                f"subset ids must lie in [0, {base.n}), got range "
                f"[{self.ids.min()}, {self.ids.max()}]")

    @property
    def n(self) -> int:
        return self.ids.size

    def block(self, rows, cols) -> np.ndarray:
        return self.base.block(self.ids[np.asarray(rows, np.intp)],
                               self.ids[np.asarray(cols, np.intp)])

    def pairs(self, us, vs) -> np.ndarray:
        return self.base.pairs(self.ids[np.asarray(us, np.intp)],
                               self.ids[np.asarray(vs, np.intp)])

    def to_spec(self) -> Dict:
        return {"kind": "subset", "ids": [int(i) for i in self.ids],
                "base": self.base.to_spec()}


def synthetic_geo(n: int, *, sites: int = 64, seed: int = 0) -> SyntheticGeo:
    """The fig21 large-N world (deterministic in ``seed``)."""
    return SyntheticGeo(n, sites=sites, seed=seed)


def as_latency(x: Union[LatencyModel, np.ndarray, Sequence]) -> LatencyModel:
    """Coerce a dense matrix to :class:`DenseLatency`; pass models through."""
    if isinstance(x, LatencyModel):
        return x
    return DenseLatency(np.asarray(x, np.float32))


def latency_from_spec(d: Dict) -> LatencyModel:
    """Inverse of ``to_spec`` (snapshot restore)."""
    kind = d.get("kind")
    if kind == "dense":
        return DenseLatency(np.asarray(d["w"], np.float32))
    if kind == "synthetic-geo":
        return SyntheticGeo(int(d["n"]), sites=int(d["sites"]),
                            seed=int(d["seed"]))
    if kind == "subset":
        return SubsetLatency(latency_from_spec(d["base"]), d["ids"])
    raise ValueError(f"unknown latency spec kind {kind!r}")
