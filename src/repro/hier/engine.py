"""Churn maintenance for hierarchical overlays.

:class:`HierChurnEngine` is the two-level counterpart of
:class:`repro.dynamics.engine.ChurnEngine`: node-level events (join /
leave / fail / latency_drift / straggler) dispatch to the OWNING cluster's
:class:`~repro.dynamics.incremental.IncrementalDistances` state —
cluster-local O(P^2) repairs instead of global O(N^2) — and the head ring
is only touched when a head dies (re-election), a cluster drains or
revives, or a ``cluster_split`` / ``cluster_merge`` event reorganizes the
partition.  Every capacity slot is pre-assigned to a cluster at
construction (the assignment covers the FULL trace capacity), so a join
needs no global work: it splices into its home cluster's live members.

Bound semantics match the flat engine's contract: each maintained
distance matrix (per cluster, and the head graph) is exact or an
elementwise LOWER bound between deletion-triggered rebuilds, and the
composed :meth:`diameter` is therefore itself exact-or-lower —
``diameter(exact=True)`` refreshes every level first.

Deliberate simplifications vs the flat engine (documented, not hidden):
failures are applied as immediate confirmed leaves (no SWIM confirmation
delay at the hierarchy level, so :attr:`pending_confirmations` is always
0), and straggler events re-weight the victim's links without the elastic
demotion pass.

Observability: the engine keeps the pre-registered ``repro_hier_clusters``
and ``repro_hier_headring_diameter`` gauges (``repro.obs``) current, and
counts every applied event in ``repro_engine_events_total{kind}`` — the
same series the flat engine uses, now covering the cluster kinds too.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.construction import default_num_rings, k_rings
from repro.core.diameter import (INF, adjacency_from_edges, is_edge,
                                 ring_edges)
from repro.dynamics.engine import RunResult, TrajectorySample
from repro.dynamics.incremental import IncrementalDistances
from repro.dynamics.scenarios import EVENT_KINDS, Event, N_FABRIC_SITES, Trace
from repro.obs import HIER_CLUSTERS, HIER_HEADRING_DIAMETER, HIER_ROUTE_HOPS
from repro.obs import REGISTRY
from repro.routing.greedy import route_single_host
from repro.routing.metrics import ROUTE_REQUESTS

from .core import HierConfig, default_cluster_size, assign_latency_clusters
from .geo import DenseLatency, LatencyModel, as_latency

__all__ = ["HierChurnEngine"]

_HALF_INF = float(INF) / 2

# same process-global series as the flat engine (idempotent re-register)
_EVENT_KIND = {
    k: REGISTRY.counter("repro_engine_events_total",
                        "churn events applied, by kind",
                        labels=("kind",)).labels(kind=k)
    for k in EVENT_KINDS}


@dataclasses.dataclass
class _ClusterState:
    """One cluster's maintained state: its capacity slots (sorted global
    ids, fixed between reorgs) and the incremental APSP over them."""

    slots: np.ndarray              # sorted global slot ids
    inc: IncrementalDistances      # local (slots.size,) indexing
    head: int                      # global id of the head, -1 if drained

    @property
    def live_slots(self) -> np.ndarray:
        return self.slots[self.inc.alive]

    @property
    def head_local(self) -> int:
        return int(np.searchsorted(self.slots, self.head))


class HierChurnEngine:
    """Replay/ingest churn against a cluster-partitioned overlay."""

    def __init__(self, trace: Trace, cfg: Optional[HierConfig] = None, *,
                 lat: Optional[LatencyModel] = None,
                 rebuild_threshold: int = 8, seed: int = 0):
        """``lat`` overrides ``trace.latency()`` with a lazy latency model
        (required above N ~ 10^4, where the dense matrix stops fitting)."""
        self.trace = trace
        self.cfg = cfg or HierConfig()
        self.rng = np.random.default_rng(seed)
        self.rebuild_threshold = int(rebuild_threshold)
        self.lat = as_latency(lat) if lat is not None \
            else DenseLatency(trace.latency())
        c = trace.capacity
        if self.lat.n != c:
            raise ValueError(f"latency model covers {self.lat.n} slots but "
                             f"the trace has capacity {c}")
        self.latency_factor = np.ones(c, np.float32)
        self.drift_scale = np.ones(c, np.float32)
        alive = np.zeros(c, bool)
        alive[:trace.n0] = True

        target = self.cfg.cluster_size or default_cluster_size(c)
        # pre-assign EVERY capacity slot (dead ones too): a later join
        # already knows its home cluster
        self._slot_cluster = assign_latency_clusters(
            self.lat, target, self.rng).astype(np.int64)
        self._next_cluster = int(self._slot_cluster.max()) + 1
        self.states: Dict[int, _ClusterState] = {}
        for cid in range(self._next_cluster):
            slots = np.flatnonzero(self._slot_cluster == cid)
            self._adopt(cid, self._make_state(slots, alive[slots]))
        self.head_inc: IncrementalDistances = None  # type: ignore
        self._rebuild_head_graph()

        self.reorg_stats = {"splits": 0, "merges": 0, "head_rebuilds": 0}
        self._ran = False
        self.clock = 0.0
        self.events_processed = 0
        self.inc = _HierIncView(self)      # flat-engine-shaped facade

    # -- construction helpers ---------------------------------------------

    def _scaled_block(self, slots: np.ndarray) -> np.ndarray:
        f = (self.latency_factor * self.drift_scale)[slots]
        w = self.lat.block(slots, slots) * f[:, None] * f[None, :]
        np.fill_diagonal(w, 0.0)
        return w.astype(np.float32)

    def _make_state(self, slots: np.ndarray,
                    alive: np.ndarray) -> _ClusterState:
        """Fresh cluster state: nearest rings over the LIVE members, dead
        pre-assigned slots kept as tombstoned capacity."""
        slots = np.asarray(slots, np.intp)
        alive = np.asarray(alive, bool)
        w = self._scaled_block(slots)
        live_local = np.flatnonzero(alive)
        edges = np.zeros((0, 2), np.intp)
        if live_local.size >= 2:
            wl = w[np.ix_(live_local, live_local)]
            k = min(live_local.size - 1,
                    default_num_rings(live_local.size)) or 1
            perms = k_rings(wl, k, "nearest", rng=self.rng)
            edges = live_local[np.concatenate(
                [ring_edges(p) for p in perms], axis=0)]
        inc = IncrementalDistances(w, adjacency_from_edges(w, edges), alive,
                                   rebuild_threshold=self.rebuild_threshold)
        head = int(slots[live_local[np.argmin(
            w[np.ix_(live_local, live_local)].sum(axis=1))]]) \
            if live_local.size else -1
        return _ClusterState(slots=slots, inc=inc, head=head)

    def _adopt(self, cid: int, state: _ClusterState) -> None:
        self.states[cid] = state
        self._slot_cluster[state.slots] = cid

    def _rebuild_head_graph(
            self, edges: Optional[np.ndarray] = None) -> None:
        """Rebuild the ring over cluster heads (cluster-id node space).

        Cheap by design — the head graph has one node per cluster — so any
        head-set change (death, drain, revive, split, merge) just rebuilds
        it exactly rather than patching it incrementally.  ``edges``
        overrides the freshly-built nearest rings with an explicit
        cluster-id edge list (snapshot restore).
        """
        cap = self._next_cluster
        active = sorted(c for c, s in self.states.items() if s.head >= 0)
        heads = np.array([self.states[c].head for c in active], np.intp)
        w = np.full((cap, cap), float(INF), np.float32)
        np.fill_diagonal(w, 0.0)
        alive = np.zeros(cap, bool)
        if len(active) >= 1:
            act = np.asarray(active, np.intp)
            alive[act] = True
            f = (self.latency_factor * self.drift_scale)[heads]
            wh = (self.lat.block(heads, heads)
                  * f[:, None] * f[None, :]).astype(np.float32)
            np.fill_diagonal(wh, 0.0)
            w[np.ix_(act, act)] = wh
            if edges is None:
                edges = np.zeros((0, 2), np.intp)
                if len(active) >= 2:
                    k = min(len(active) - 1,
                            default_num_rings(len(active))) or 1
                    perms = k_rings(wh, k, "nearest", rng=self.rng)
                    edges = act[np.concatenate(
                        [ring_edges(p) for p in perms], axis=0)]
        else:
            edges = np.zeros((0, 2), np.intp)
        self.head_inc = IncrementalDistances(
            w, adjacency_from_edges(w, edges), alive,
            rebuild_threshold=self.rebuild_threshold)
        if hasattr(self, "reorg_stats"):
            self.reorg_stats["head_rebuilds"] += 1
        HIER_CLUSTERS.set(float(len(active)))
        HIER_HEADRING_DIAMETER.set(
            float(self.head_inc.diameter()) if len(active) > 1 else 0.0)

    # -- restore (repro.service snapshots) --------------------------------

    @classmethod
    def restore(cls, trace: Trace, cfg: Optional[HierConfig] = None, *,
                slot_cluster: np.ndarray, alive: np.ndarray,
                edges: np.ndarray, heads: Dict[int, int],
                latency_factor: np.ndarray, drift_scale: np.ndarray,
                lat: Optional[LatencyModel] = None,
                clock: float = 0.0, events_processed: int = 0,
                rebuild_threshold: int = 8, seed: int = 0
                ) -> "HierChurnEngine":
        """Rebuild an engine from snapshotted state: the slot->cluster map,
        the live mask, the GLOBAL intra-cluster edge list, and each
        cluster's head.  Distances are recomputed exactly from the restored
        adjacency (no staleness survives a restore); the head ring is
        rebuilt over the restored heads."""
        eng = cls.__new__(cls)
        eng.trace = trace
        eng.cfg = cfg or HierConfig()
        eng.rng = np.random.default_rng(seed)
        eng.rebuild_threshold = int(rebuild_threshold)
        eng.lat = as_latency(lat) if lat is not None \
            else DenseLatency(trace.latency())
        eng.latency_factor = np.asarray(latency_factor, np.float32).copy()
        eng.drift_scale = np.asarray(drift_scale, np.float32).copy()
        eng._slot_cluster = np.asarray(slot_cluster, np.int64).copy()
        eng._next_cluster = int(eng._slot_cluster.max()) + 1
        alive = np.asarray(alive, bool)
        edges = np.asarray(edges, np.intp).reshape(-1, 2)
        eng.states = {}
        for cid in sorted(set(int(c) for c in eng._slot_cluster if c >= 0)):
            slots = np.flatnonzero(eng._slot_cluster == cid)
            w = eng._scaled_block(slots)
            mine = edges[(eng._slot_cluster[edges[:, 0]] == cid)
                         & (eng._slot_cluster[edges[:, 1]] == cid)]
            local = np.searchsorted(slots, mine)
            inc = IncrementalDistances(
                w, adjacency_from_edges(w, local), alive[slots],
                rebuild_threshold=eng.rebuild_threshold)
            eng.states[cid] = _ClusterState(
                slots=slots, inc=inc, head=int(heads.get(cid, -1)))
        eng.head_inc = None  # type: ignore
        eng.reorg_stats = {"splits": 0, "merges": 0, "head_rebuilds": 0}
        # cross-cluster edges in the snapshot ARE the head ring (including
        # any reopt-added head edges): restore them verbatim
        cross = edges[eng._slot_cluster[edges[:, 0]]
                      != eng._slot_cluster[edges[:, 1]]]
        eng._rebuild_head_graph(
            edges=eng._slot_cluster[cross].astype(np.intp)
            if cross.size else None)
        eng.reorg_stats["head_rebuilds"] = 0
        eng._ran = False
        eng.clock = float(clock)
        eng.events_processed = int(events_processed)
        eng.inc = _HierIncView(eng)
        return eng

    # -- conveniences -----------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.trace.capacity

    @property
    def alive(self) -> np.ndarray:
        out = np.zeros(self.capacity, bool)
        for s in self.states.values():
            out[s.live_slots] = True
        return out

    def live_ids(self) -> np.ndarray:
        return np.flatnonzero(self.alive)

    @property
    def n_live(self) -> int:
        return sum(s.inc.n_live for s in self.states.values())

    @property
    def n_clusters(self) -> int:
        """Active (non-drained) clusters."""
        return sum(1 for s in self.states.values() if s.head >= 0)

    @property
    def pending_confirmations(self) -> int:
        """Always 0: hierarchy-level failures apply as immediate confirmed
        leaves (no SWIM confirmation delay — documented simplification)."""
        return 0

    def cluster_of(self, u: int) -> int:
        return int(self._slot_cluster[int(u)])

    def edge_list(self) -> np.ndarray:
        """(E, 2) unique live GLOBAL edges: cluster-local plus head-ring
        edges (head-ring edges mapped through each cluster's head)."""
        parts = []
        for s in self.states.values():
            mask = np.asarray(is_edge(s.inc.adj))
            e = np.argwhere(np.triu(mask, 1))
            if e.size:
                parts.append(s.slots[e])
        hmask = np.asarray(is_edge(self.head_inc.adj))
        he = np.argwhere(np.triu(hmask, 1))
        if he.size:
            head_of = np.full(self._next_cluster, -1, np.intp)
            for cid, s in self.states.items():
                head_of[cid] = s.head
            parts.append(head_of[he])
        if not parts:
            return np.zeros((0, 2), np.intp)
        e = np.sort(np.concatenate(parts, axis=0), axis=1)
        return np.unique(e, axis=0)

    def weighted_edges(self) -> Tuple[np.ndarray, np.ndarray]:
        """(edges, weights): :meth:`edge_list` plus each edge's CURRENT
        maintained weight (cluster adjacency for intra edges, head-graph
        adjacency for head-ring edges)."""
        edges = self.edge_list()
        wts = np.empty(edges.shape[0], np.float32)
        for i, (u, v) in enumerate(edges):
            a, b = self.cluster_of(int(u)), self.cluster_of(int(v))
            if a == b:
                s = self.states[a]
                lu = int(np.searchsorted(s.slots, u))
                lv = int(np.searchsorted(s.slots, v))
                wts[i] = s.inc.adj[lu, lv]
            else:
                wts[i] = self.head_inc.adj[a, b]
        return edges, wts

    def distance_bound(self, u: int, v: int) -> Tuple[float, str]:
        """Maintained hierarchical distance and its staleness stamp:
        ``"exact"`` when no deletions are pending anywhere, else a provable
        ``"lower"`` bound (same contract the flat service serves)."""
        u, v = int(u), int(v)
        a, b = self.cluster_of(u), self.cluster_of(v)
        sa, sb = self.states[a], self.states[b]
        lu = int(np.searchsorted(sa.slots, u))
        lv = int(np.searchsorted(sb.slots, v))
        stamp = "exact" if self.pending_deletions == 0 else "lower"
        if a == b:
            return float(sa.inc.distances[lu, lv]), stamp
        d = (float(sa.inc.distances[lu, sa.head_local])
             + float(self.head_inc.distances[a, b])
             + float(sb.inc.distances[sb.head_local, lv]))
        return d, stamp

    def stats(self) -> Dict[str, int]:
        """Aggregated maintenance counters over every level, plus the
        reorganization counts."""
        agg = {"relaxations": 0, "joins": 0, "leaves": 0, "rebuilds": 0,
               "events": 0}
        for s in self.states.values():
            for k in agg:
                agg[k] += s.inc.stats[k]
        for k in agg:
            agg[k] += self.head_inc.stats[k]
        agg.update(self.reorg_stats)
        return agg

    @property
    def pending_deletions(self) -> int:
        return (sum(s.inc.pending_deletions for s in self.states.values())
                + self.head_inc.pending_deletions)

    # -- diameter (composed, exact-or-lower) ------------------------------

    def refresh(self) -> None:
        for s in self.states.values():
            s.inc.refresh()
        self.head_inc.refresh()

    def diameter(self, exact: bool = False) -> float:
        """Composed hierarchical diameter over the LIVE fleet.

        ``max(max_c diam_c, max_{a != b} ecc_a + D_head(a, b) + ecc_b)``
        from the maintained matrices: exact when nothing is stale at any
        level, otherwise a lower bound (monotone composition of
        elementwise lower bounds).  ``exact=True`` refreshes first.
        """
        if exact:
            self.refresh()
        active = [c for c, s in self.states.items()
                  if s.head >= 0 and s.inc.n_live > 0]
        if not active:
            return 0.0
        intra = 0.0
        ecc = {}
        for c in active:
            s = self.states[c]
            intra = max(intra, s.inc.diameter())
            row = s.inc.distances[s.head_local][s.inc.alive]
            row = row[row < _HALF_INF]
            ecc[c] = float(row.max()) if row.size else 0.0
        if len(active) < 2:
            return float(intra)
        dh = self.head_inc.distances
        best = intra
        for i, a in enumerate(active):
            for b in active[i + 1:]:
                d = float(dh[a, b])
                if d < _HALF_INF:
                    best = max(best, ecc[a] + d + ecc[b])
        return float(best)

    # -- event handlers ---------------------------------------------------

    def _elect_head(self, cid: int) -> None:
        """Re-elect ``cid``'s head (min summed latency over live members)
        and rebuild the head ring."""
        s = self.states[cid]
        live = np.flatnonzero(s.inc.alive)
        if live.size == 0:
            s.head = -1
        else:
            wl = s.inc.w[np.ix_(live, live)]
            s.head = int(s.slots[live[np.argmin(wl.sum(axis=1))]])
        self._rebuild_head_graph()

    def _handle_join(self, u: int) -> None:
        cid = self.cluster_of(u)
        s = self.states[cid]
        local = int(np.searchsorted(s.slots, u))
        if s.inc.alive[local]:
            return
        live = np.flatnonzero(s.inc.alive)
        if live.size:
            k = min(live.size, default_num_rings(max(s.inc.n_live + 1, 2)))
            order = np.argsort(s.inc.w[local, live], kind="stable")[:k]
            s.inc.join(local, sorted(int(live[i]) for i in order))
        else:
            s.inc.join(local, [])
        if s.head < 0:                 # revived a drained cluster
            self._elect_head(cid)

    def _handle_leave(self, u: int) -> None:
        cid = self.cluster_of(u)
        s = self.states[cid]
        local = int(np.searchsorted(s.slots, u))
        if not s.inc.alive[local]:
            return
        was_head = s.head == u
        nbrs = np.flatnonzero(is_edge(s.inc.adj[local]))
        s.inc.leave(local)
        # stitch: reconnect the departed node's neighbours pairwise so the
        # cluster stays connected (same repair shape as the flat policies)
        nbrs = [int(v) for v in nbrs if s.inc.alive[v]]
        for a, b in zip(nbrs, nbrs[1:]):
            s.inc.add_edge(a, b)
        if was_head or s.inc.n_live == 0:
            self._elect_head(cid)

    def _handle_drift(self, factor: float, region: int) -> None:
        """Same per-node drift semantics as the flat engine (FABRIC site =
        slot id mod ``N_FABRIC_SITES``), applied only to the clusters that
        actually contain affected nodes."""
        site_of = np.arange(self.capacity) % N_FABRIC_SITES
        hit = site_of == region if region >= 0 else np.ones(
            self.capacity, bool)
        self.drift_scale = np.where(
            hit, np.float32(np.sqrt(factor)), self.drift_scale)
        for cid, s in self.states.items():
            if hit[s.slots].any():
                s.inc.apply_latency_matrix(self._scaled_block(s.slots))
        self._rebuild_head_graph()     # head-pair latencies moved too

    def _handle_straggler(self, u: int, factor: float) -> None:
        self.latency_factor[u] *= np.float32(factor)
        cid = self.cluster_of(u)
        s = self.states[cid]
        new_w = self._scaled_block(s.slots)
        s.inc.w = new_w.copy()
        local = int(np.searchsorted(s.slots, u))
        if s.inc.alive[local]:
            for v in np.flatnonzero(is_edge(s.inc.adj[local])):
                s.inc.set_latency(local, int(v), float(new_w[local, v]))
        if s.head == u:
            self._rebuild_head_graph()   # the head's uplink latencies moved

    def _handle_split(self, cid: int) -> None:
        """Split cluster ``cid`` by its farthest live pair (2-medoid): each
        live member follows the nearer pole; pre-assigned dead slots stay
        with ``cid``.  No-op (but counted) below 4 live members."""
        if cid not in self.states:
            raise ValueError(f"cluster_split of unknown cluster {cid}")
        s = self.states[cid]
        live = np.flatnonzero(s.inc.alive)
        if live.size < 4:
            return
        wl = s.inc.w[np.ix_(live, live)]
        a = int(np.argmax(wl.sum(axis=1)))
        b = int(np.argmax(wl[a]))
        to_b = wl[b] < wl[a]
        if not to_b.any() or to_b.all():
            return
        keep_slots = np.sort(np.concatenate(
            [s.slots[~s.inc.alive], s.slots[live[~to_b]]]))
        move_slots = np.sort(s.slots[live[to_b]])
        alive_mask = self.alive
        new_cid = self._next_cluster
        self._next_cluster += 1
        self._adopt(cid, self._make_state(keep_slots, alive_mask[keep_slots]))
        self._adopt(new_cid,
                    self._make_state(move_slots, alive_mask[move_slots]))
        self.reorg_stats["splits"] += 1
        self._rebuild_head_graph()

    def _handle_merge(self, cid: int, peer: int) -> None:
        """Absorb cluster ``peer`` into ``cid``: union the slot sets,
        rebuild one cluster state, retire ``peer``'s id."""
        if cid not in self.states or peer not in self.states:
            raise ValueError(
                f"cluster_merge of unknown cluster pair ({cid}, {peer}); "
                f"known clusters: {sorted(self.states)}")
        if cid == peer:
            raise ValueError(f"cluster_merge needs distinct clusters, "
                             f"got {cid} twice")
        union = np.sort(np.concatenate(
            [self.states[cid].slots, self.states[peer].slots]))
        alive_mask = self.alive
        del self.states[peer]
        self._adopt(cid, self._make_state(union, alive_mask[union]))
        self.reorg_stats["merges"] += 1
        self._rebuild_head_graph()

    # -- dispatch / ingest (flat-engine-compatible surface) ---------------

    def _dispatch(self, t: float, e: Event) -> None:
        if e.kind == "join":
            self._handle_join(e.node)
        elif e.kind in ("leave", "fail"):
            # fail == immediate confirmed leave (no SWIM delay at this level)
            self._handle_leave(e.node)
        elif e.kind == "latency_drift":
            self._handle_drift(e.factor, e.region)
        elif e.kind == "straggler":
            self._handle_straggler(e.node, e.factor)
        elif e.kind == "cluster_split":
            self._handle_split(e.node)
        elif e.kind == "cluster_merge":
            self._handle_merge(e.node, e.peer)
        else:
            raise ValueError(f"unknown event kind {e.kind!r}")
        _EVENT_KIND[e.kind].inc()
        self.clock = max(self.clock, t)
        self.events_processed += 1

    def process(self, event: Event) -> int:
        """Apply one externally-arriving event NOW (control-plane path).
        Events must arrive in nondecreasing time order, matching the flat
        engine's ingest contract."""
        if event.time < self.clock:
            raise ValueError(
                f"event at t={event.time} arrived after the clock advanced "
                f"to t={self.clock}; the control plane ingests events in "
                f"nondecreasing time order")
        self._dispatch(event.time, event)
        return 1

    def flush(self, until: float = float("inf")) -> int:
        """Nothing is ever scheduled (failures confirm immediately)."""
        return 0

    def run(self, record: bool = True,
            sample_exact: bool = False) -> RunResult:
        """Replay the trace, sampling the composed diameter per event."""
        if self._ran:
            raise RuntimeError(
                "HierChurnEngine.run() consumed its trace against mutated "
                "state; construct a fresh engine to replay")
        self._ran = True
        samples: List[TrajectorySample] = []
        if record:
            samples.append(TrajectorySample(
                0.0, "init", self.n_live, self.diameter(exact=sample_exact)))
        for e in sorted(self.trace.events, key=lambda e: e.time):
            self._dispatch(e.time, e)
            if record:
                samples.append(TrajectorySample(
                    e.time, e.kind, self.n_live,
                    self.diameter(exact=sample_exact)))
        final = self.diameter(exact=True)
        return RunResult(policy="dgro-hier", trace=self.trace.name,
                         samples=samples, final_diameter=final,
                         stats=self.stats())

    # -- routing (repro.service /v1/route) --------------------------------

    def route(self, src: int, dst: int, *, policy: str = "latency",
              hop_budget: Optional[int] = None
              ) -> Tuple[List[int], float, Dict[str, int], str]:
        """Three-leg host route over the MAINTAINED state (same exact-or-
        lower-bound keys the flat service serves).  Returns ``(global
        path, latency, hops_by_level, outcome)``."""
        src, dst = int(src), int(dst)
        a, b = self.cluster_of(src), self.cluster_of(dst)
        sa, sb = self.states[a], self.states[b]
        legs: List[Tuple[str, IncrementalDistances, int, int, np.ndarray]]
        if a == b:
            legs = [("local", sa.inc, int(np.searchsorted(sa.slots, src)),
                     int(np.searchsorted(sa.slots, dst)), sa.slots)]
        else:
            head_of = np.full(self._next_cluster, -1, np.intp)
            for cid, s in self.states.items():
                head_of[cid] = s.head
            legs = [
                ("local", sa.inc, int(np.searchsorted(sa.slots, src)),
                 sa.head_local, sa.slots),
                ("head", self.head_inc, a, b, head_of),
                ("local", sb.inc, sb.head_local,
                 int(np.searchsorted(sb.slots, dst)), sb.slots),
            ]
        path: List[int] = []
        lat = 0.0
        hops = {"local": 0, "head": 0}
        outcome = "delivered"
        for level, inc, s, d, to_global in legs:
            leg_path, leg_lat, leg_hops, outcome = route_single_host(
                inc.adj, inc.distances[:, d], s, d, policy=policy,
                hop_budget=hop_budget)
            glob = [int(to_global[u]) for u in leg_path]
            path.extend(glob if not path else glob[1:])
            lat += leg_lat
            hops[level] += leg_hops
            if outcome != "delivered":
                break
        ROUTE_REQUESTS.labels(policy=f"hier-{policy}",
                              outcome=outcome).inc()
        if outcome == "delivered":
            HIER_ROUTE_HOPS.labels(level="local").observe(hops["local"])
            if hops["head"]:
                HIER_ROUTE_HOPS.labels(level="head").observe(hops["head"])
        return path, float(lat), hops, outcome


class _HierIncView:
    """Flat-engine-shaped read facade (``engine.inc``) so the service's
    staleness/liveness gauges and stats bind to either engine unchanged."""

    def __init__(self, eng: HierChurnEngine):
        self._eng = eng

    @property
    def pending_deletions(self) -> int:
        return self._eng.pending_deletions

    @property
    def n_live(self) -> int:
        return self._eng.n_live

    @property
    def capacity(self) -> int:
        return self._eng.capacity

    def live_ids(self) -> np.ndarray:
        return self._eng.live_ids()

    @property
    def stats(self) -> Dict[str, int]:
        return self._eng.stats()

    def diameter(self, exact: bool = False) -> float:
        return self._eng.diameter(exact=exact)

    def refresh(self) -> None:
        self._eng.refresh()
