"""Versioned JSON payloads — the repo's serialization contract.

Every durable JSON surface (``Overlay.to_json``, ``Trace.to_json``, the
``repro.service`` API envelopes and its checkpoint snapshots) carries a
``"schema"`` field so readers can refuse payloads from a *future* writer
instead of mis-parsing them.  The rules:

* writers stamp ``"schema": SCHEMA_VERSION`` (currently 1);
* readers accept any schema ``<= SCHEMA_VERSION`` — including payloads
  with NO schema field at all (everything serialized before this module
  existed is schema-1 by definition);
* readers reject unknown *future* schemas with a :class:`SchemaError`
  naming both versions, so a v1 daemon fed a v2 snapshot fails loudly at
  the boundary rather than deep inside array parsing.

``dumps``/``check_schema`` are deliberately tiny — the point is that every
surface shares ONE version constant and ONE rejection message, not that
serialization itself is abstracted away.
"""
from __future__ import annotations

import json
from typing import Any, Dict

__all__ = ["SCHEMA_VERSION", "SchemaError", "check_schema", "dumps", "loads"]

SCHEMA_VERSION = 1


class SchemaError(ValueError):
    """Payload written by a newer (unknown) schema than this reader."""


def check_schema(d: Dict[str, Any], what: str = "payload") -> Dict[str, Any]:
    """Validate ``d``'s schema field and return ``d``.

    Version-absent payloads are legacy schema-1; anything newer than
    :data:`SCHEMA_VERSION` raises :class:`SchemaError`.
    """
    v = d.get("schema", 1)
    if not isinstance(v, int) or v < 1:
        raise SchemaError(f"{what} has malformed schema field {v!r}")
    if v > SCHEMA_VERSION:
        raise SchemaError(
            f"{what} uses schema {v}, but this reader only understands "
            f"<= {SCHEMA_VERSION}; upgrade the reader (or re-export the "
            f"payload from the older writer)")
    return d


def dumps(d: Dict[str, Any], **kw) -> str:
    """``json.dumps`` with the current schema stamped in."""
    kw.setdefault("sort_keys", True)
    return json.dumps({**d, "schema": SCHEMA_VERSION}, **kw)


def loads(s: str, what: str = "payload") -> Dict[str, Any]:
    """``json.loads`` + :func:`check_schema`."""
    d = json.loads(s)
    if not isinstance(d, dict):
        raise SchemaError(f"{what} must be a JSON object, got {type(d).__name__}")
    return check_schema(d, what)
