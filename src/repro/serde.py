"""Versioned JSON payloads — the repo's serialization contract.

Every durable JSON surface (``Overlay.to_json``, ``Trace.to_json``, the
``repro.service`` API envelopes and its checkpoint snapshots) carries a
``"schema"`` field so readers can refuse payloads from a *future* writer
instead of mis-parsing them.  The rules:

* flat writers stamp ``"schema": SCHEMA_VERSION`` (currently 1) — every
  payload shape that existed before hierarchical overlays keeps emitting
  byte-identical schema-1 JSON;
* hierarchical payloads (``HierarchicalOverlay.to_json``, the service's
  hierarchical snapshots) stamp ``"schema": HIER_SCHEMA`` (2) via
  ``dumps(d, schema=HIER_SCHEMA)``;
* readers accept any schema ``<= MAX_SCHEMA`` — including payloads with
  NO schema field at all (everything serialized before this module
  existed is schema-1 by definition);
* readers reject unknown *future* schemas with a :class:`SchemaError`
  naming both versions, so a daemon fed a v3 snapshot fails loudly at
  the boundary rather than deep inside array parsing.

``dumps``/``check_schema`` are deliberately tiny — the point is that every
surface shares ONE version constant and ONE rejection message, not that
serialization itself is abstracted away.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

__all__ = ["SCHEMA_VERSION", "HIER_SCHEMA", "MAX_SCHEMA", "SchemaError",
           "check_schema", "payload_schema", "dumps", "loads"]

SCHEMA_VERSION = 1      # flat payloads: unchanged, byte-for-byte
HIER_SCHEMA = 2         # hierarchical-overlay payloads
MAX_SCHEMA = 2          # newest schema this reader understands


class SchemaError(ValueError):
    """Payload written by a newer (unknown) schema than this reader."""


def payload_schema(d: Dict[str, Any]) -> int:
    """The schema version a parsed payload was written under (absent = 1)."""
    v = d.get("schema", 1)
    if not isinstance(v, int) or v < 1:
        raise SchemaError(f"payload has malformed schema field {v!r}")
    return v


def check_schema(d: Dict[str, Any], what: str = "payload") -> Dict[str, Any]:
    """Validate ``d``'s schema field and return ``d``.

    Version-absent payloads are legacy schema-1; anything newer than
    :data:`MAX_SCHEMA` raises :class:`SchemaError`.
    """
    v = d.get("schema", 1)
    if not isinstance(v, int) or v < 1:
        raise SchemaError(f"{what} has malformed schema field {v!r}")
    if v > MAX_SCHEMA:
        raise SchemaError(
            f"{what} uses schema {v}, but this reader only understands "
            f"<= {MAX_SCHEMA}; upgrade the reader (or re-export the "
            f"payload from the older writer)")
    return d


def dumps(d: Dict[str, Any], *, schema: Optional[int] = None, **kw) -> str:
    """``json.dumps`` with a schema stamped in (default: flat schema 1)."""
    v = SCHEMA_VERSION if schema is None else int(schema)
    if not 1 <= v <= MAX_SCHEMA:
        raise SchemaError(f"cannot write unknown schema {v}")
    kw.setdefault("sort_keys", True)
    return json.dumps({**d, "schema": v}, **kw)


def loads(s: str, what: str = "payload") -> Dict[str, Any]:
    """``json.loads`` + :func:`check_schema`."""
    d = json.loads(s)
    if not isinstance(d, dict):
        raise SchemaError(f"{what} must be a JSON object, got {type(d).__name__}")
    return check_schema(d, what)
