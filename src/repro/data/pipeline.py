"""Deterministic synthetic LM data pipeline with per-host sharding.

Produces packed (tokens, labels) batches: documents with lognormal lengths
are concatenated with EOS separators; labels are next-token targets with -1
at padding and document boundaries.  Determinism: batch ``i`` of host ``h``
is a pure function of (seed, i, h) — a restarted job resumes bit-identically
from the step counter alone (no iterator state in checkpoints), and a
re-sharded (elastic) job stays deterministic per global batch index.

The "dataset" is a seeded token-level Markov sampler — enough structure that
cross-entropy drops measurably during the example runs (unlike uniform
noise), with zero external data dependencies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

EOS = 0


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: float = 256.0
    n_hosts: int = 1
    host_id: int = 0


def _markov_row(rng: np.random.Generator, vocab: int, branch: int = 8):
    """Per-state successor table: each token has `branch` likely successors."""
    return rng.integers(1, vocab, size=branch)


class SyntheticLM:
    """Markov-chain token stream, packed into fixed-length sequences."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        base = np.random.default_rng(cfg.seed)
        # a small transition table shared by all hosts (the "corpus")
        self.branch = 8
        self.table = base.integers(
            1, cfg.vocab, size=(min(cfg.vocab, 4096), self.branch))

    def _sample_doc(self, rng: np.random.Generator) -> np.ndarray:
        n = max(2, int(rng.lognormal(np.log(self.cfg.mean_doc_len), 0.6)))
        out = np.empty(n, np.int32)
        tok = int(rng.integers(1, self.cfg.vocab))
        for i in range(n):
            out[i] = tok
            row = self.table[tok % self.table.shape[0]]
            tok = int(row[rng.integers(0, self.branch)]) if rng.random() > 0.1 \
                else int(rng.integers(1, self.cfg.vocab))
        return out

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        """Global batch ``index`` — this host's shard of it."""
        cfg = self.cfg
        s = cfg.seq_len
        toks = np.zeros((self.local_batch, s + 1), np.int32)
        for b in range(self.local_batch):
            gi = index * cfg.global_batch + cfg.host_id * self.local_batch + b
            rng = np.random.default_rng((cfg.seed, 1, gi))
            pos = 0
            while pos < s + 1:
                doc = self._sample_doc(rng)
                take = min(len(doc), s + 1 - pos)
                toks[b, pos:pos + take] = doc[:take]
                pos += take
                if pos < s + 1:
                    toks[b, pos] = EOS
                    pos += 1
        tokens = toks[:, :-1]
        labels = toks[:, 1:].astype(np.int32)
        labels = np.where(tokens == EOS, -1, labels)   # no loss across docs
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1
