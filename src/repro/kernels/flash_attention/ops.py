"""jit'd public wrapper for flash attention (padding + backend dispatch).

``flash_attention`` pads (Tq, Tk, D) to tile multiples, invokes the Pallas
kernel (compiled on TPU, interpret-mode on CPU) and slices the result.  The
model stack calls ``repro.models.layers.attention`` which dispatches between
this kernel and the jnp oracle based on backend — the math is identical
(validated in tests/test_kernels.py across shape/dtype/window sweeps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(
    q: jnp.ndarray,                 # (B, Hq, Tq, D)
    k: jnp.ndarray,                 # (B, Hkv, Tk, D)
    v: jnp.ndarray,                 # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    pq = (-tq) % bq
    pk = (-tk) % bk
    pd = (-d) % 128
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, pd)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, pd)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, pd)))

    # the kernel masks kpos >= padded seq via its seq_k closure: pass true len
    # by re-masking padded keys — zero-padded K rows yield s=0 which must be
    # excluded, so we set seq_k to the true tk inside the kernel call.
    out = _call_kernel(qp, kp, vp, causal=causal, window=window, scale=scale,
                       bq=bq, bk=bk, interpret=interpret, true_tk=tk)
    return out[:, :, :tq, :d]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret", "true_tk"))
def _call_kernel(qp, kp, vp, *, causal, window, scale, bq, bk, interpret,
                 true_tk):
    import functools as ft

    from jax.experimental import pallas as pl  # noqa: F401
    from . import kernel as K

    b, hq, tq, d = qp.shape
    _, hkv, tk, _ = kp.shape
    num_kb = tk // bk
    grid = (b, hq, tq // bq, num_kb)
    kern = ft.partial(K._flash_kernel, scale=scale, causal=causal,
                      window=window, bq=bq, bk=bk, seq_k=true_tk,
                      num_kb=num_kb)

    def kv_head(h):
        return h * hkv // hq

    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, qi, ki: (b_, kv_head(h), ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, qi, ki: (b_, kv_head(h), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq, d), qp.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)


__all__ = ["flash_attention", "attention_ref", "flash_attention_pallas"]
