"""Pallas TPU kernel: flash attention (causal / sliding-window, GQA-aware).

Online-softmax attention over tiled KV panels — the framework's dominant
compute hot spot for prefill/training.  TPU-native design notes:

  * grid (B, Hq, Tq/bq, Tk/bk); the KV panel index is the LAST grid dim, so
    the TPU revisiting rule keeps the (bq, d) accumulator and the (bq,)
    running max/sum resident in VMEM scratch across panels.
  * GQA is handled in the BlockSpec index_map — query head h reads KV head
    h * n_kv // n_q — so KV is never materialized per-query-head in HBM
    (a torch-style `repeat_interleave` would multiply KV HBM traffic by the
    group size; on TPU we only re-read the same KV tile, which hits VMEM).
  * q/k tiles are (bq, d) and (bk, d) with d padded to a lane multiple of
    128; s = q @ k^T runs on the MXU in fp32; masks are computed from
    absolute positions so causal+window+padding all fold into one select.
  * fully-masked panels (beyond the causal frontier or outside the sliding
    window) are skipped with pl.when — for long_500k-style shapes with a
    1024-token window this skips ~Tk/window of all panels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, seq_k: int, num_kb: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * bq
    k_lo = ki * bk

    # panel-level skip: entirely above the causal diagonal or left of window
    live = jnp.bool_(True)
    if causal:
        live = live & (k_lo <= q_lo + bq - 1)
    if window is not None:
        live = live & (k_lo + bk - 1 >= q_lo - (window - 1))

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < seq_k                              # KV padding
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                      # exp(NEG_INF-m) underflow guard
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == num_kb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)               # fully-masked rows
        o_ref[0, 0, :, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention_pallas(
    q: jnp.ndarray,                 # (B, Hq, Tq, D)
    k: jnp.ndarray,                 # (B, Hkv, Tk, D)
    v: jnp.ndarray,                 # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled flash attention.  Tq/Tk must be padded to bq/bk multiples and D
    to a 128 multiple by the caller (``ops.flash_attention``).  ``seq_k`` for
    masking is carried via static closure over the padded shape; callers pass
    the true KV length through ops."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    assert tq % bq == 0 and tk % bk == 0 and d % 128 == 0, (q.shape, k.shape)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    num_kb = tk // bk
    grid = (b, hq, tq // bq, num_kb)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, seq_k=tk, num_kb=num_kb)

    def kv_head(h):
        return h * hkv // hq

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, qi, ki: (b_, kv_head(h), ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, qi, ki: (b_, kv_head(h), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max  m_i
            pltpu.VMEM((bq,), jnp.float32),      # running sum  l_i
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
