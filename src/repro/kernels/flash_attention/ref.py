"""Pure-jnp oracle for flash attention (dense softmax, fp32)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,                 # (B, Hq, Tq, D)
    k: jnp.ndarray,                 # (B, Hkv, Tk, D)
    v: jnp.ndarray,                 # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Dense masked attention.  ``q_offset`` places the query block at
    absolute positions [q_offset, q_offset+Tq) against KV positions
    [0, Tk) — used for decode (Tq=1, q_offset=cache_len-1)."""
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0
    groups = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    kr = jnp.repeat(k, groups, axis=1)
    vr = jnp.repeat(v, groups, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale

    qpos = q_offset + jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = jnp.where(mask[None, None], p, 0.0)
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
