"""Pallas TPU kernel: tiled min-plus (tropical) matrix product.

C[i, j] = min_k A[i, k] + B[k, j]

This is the inner step of the min-plus-squaring APSP used by
``repro.core.diameter`` — the paper's diameter computation is the hot spot of
both the Q-learning reward loop and the GA baseline.  Min-plus has no
multiply-accumulate, so it maps to the VPU (not the MXU); the tiling is
therefore chosen for VMEM residency and 8x128 vector-lane alignment rather
than for MXU 128x128 systolic shape:

  * grid (M/bm, N/bn, K/bk), K innermost so the output block stays resident
    in VMEM across the K panels (revisiting rule on TPU: last grid dim is
    sequential minor-most).
  * each (bm, bk) x (bk, bn) panel is reduced in CHUNK=8 slabs: a
    (bm, 8, bn) broadcast-add + min keeps the temporary under 0.5 MiB
    (bm=bn=128) while amortizing loop overhead over full 8x128 vregs.
  * VMEM per step: A tile 64 KiB + B tile 64 KiB + C tile 64 KiB fp32
    (+ double buffering) — far below the ~16 MiB/core budget, leaving room
    for the pipeline to prefetch the next K panel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 1e9
_CHUNK = 8


def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)

    def body(c, acc):
        a_slab = jax.lax.dynamic_slice_in_dim(a, c * _CHUNK, _CHUNK, axis=1)
        b_slab = jax.lax.dynamic_slice_in_dim(b, c * _CHUNK, _CHUNK, axis=0)
        cand = a_slab[:, :, None] + b_slab[None, :, :]       # (bm, CHUNK, bn)
        return jnp.minimum(acc, jnp.min(cand, axis=1))

    o_ref[...] = jax.lax.fori_loop(0, bk // _CHUNK, body, o_ref[...])


def _minplus_kernel_batched(a_ref, b_ref, o_ref, *, bk: int):
    """Batched variant: leading grid axis walks the batch; block shapes carry
    a unit batch dim that is squeezed before the slab reduction."""
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    a = a_ref[0]  # (bm, bk)
    b = b_ref[0]  # (bk, bn)

    def body(c, acc):
        a_slab = jax.lax.dynamic_slice_in_dim(a, c * _CHUNK, _CHUNK, axis=1)
        b_slab = jax.lax.dynamic_slice_in_dim(b, c * _CHUNK, _CHUNK, axis=0)
        cand = a_slab[:, :, None] + b_slab[None, :, :]       # (bm, CHUNK, bn)
        return jnp.minimum(acc, jnp.min(cand, axis=1))

    o_ref[0] = jax.lax.fori_loop(0, bk // _CHUNK, body, o_ref[0])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_pallas_batched(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched tiled min-plus: ``(B, M, K) x (B, K, N) -> (B, M, N)``.

    The batch axis is the OUTERMOST grid dimension, so each batch element's
    output tiles are finished before the next element starts and the
    per-step VMEM footprint is identical to the unbatched kernel (the
    batch never touches VMEM as a whole).
    """
    bsz, m, k = a.shape
    bsz2, k2, n = b.shape
    assert bsz == bsz2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    assert bk % _CHUNK == 0, bk

    grid = (bsz, m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_minplus_kernel_batched, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda bb, i, j, kk: (bb, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda bb, i, j, kk: (bb, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda bb, i, j, kk: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


# ---------------------------------------------------------------------------
# blocked Floyd-Warshall APSP (the tiled engine behind batcheval "tiled")
# ---------------------------------------------------------------------------
#
# Per diagonal block k, three kernels over the same (T, T) block grid as
# ``ref.apsp_tiled_ref`` (which is the bit-exact CPU twin — min over floats
# is exact, so the 8-slab reductions here regroup the rank-1 candidate sets
# of the ref without changing a single bit):
#
#   1. ``_fw_diag_kernel``    — close the diagonal tile in VMEM (rank-1 FW,
#      sequential over T pivots: each pivot depends on the previous).
#   2. ``_panel_*_kernel``    — min(p, diag ⊗ p) / min(p, p ⊗ diag) for the
#      row/column panels, 1D grid over the panel's (T, T) blocks.
#   3. ``_outer_kernel``      — min(d, colp ⊗ rowp) over the FULL 2D
#      (N/T, N/T) block grid; each grid step reads one stationary output
#      tile plus one panel tile from each operand (K = T, single panel).
#
# VMEM per step at T=256 fp32: 3-4 tiles of 256 KiB + the (T, 8, T) slab
# temporary — ~1.3 MiB, far under the ~16 MiB/core budget, so the pipeline
# can double-buffer the next tile while the VPU reduces the current one.


def _fw_diag_kernel(d_ref, o_ref):
    """Rank-1 Floyd-Warshall closure of one (T, T) tile, fully in VMEM."""
    def body(k, d):
        row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)     # (1, T)
        col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)     # (T, 1)
        return jnp.minimum(d, col + row)

    o_ref[...] = jax.lax.fori_loop(0, d_ref.shape[0], body, d_ref[...])


def _slab_minplus(acc, a, b):
    """min(acc, a ⊗ b) by CHUNK-slab reduction; a is (M, T), b is (T, N)."""
    def body(c, acc):
        a_slab = jax.lax.dynamic_slice_in_dim(a, c * _CHUNK, _CHUNK, axis=1)
        b_slab = jax.lax.dynamic_slice_in_dim(b, c * _CHUNK, _CHUNK, axis=0)
        cand = a_slab[:, :, None] + b_slab[None, :, :]      # (M, CHUNK, N)
        return jnp.minimum(acc, jnp.min(cand, axis=1))

    return jax.lax.fori_loop(0, a.shape[1] // _CHUNK, body, acc)


def _panel_left_kernel(diag_ref, p_ref, o_ref):
    """One (T, T) block of the row panel: o = min(p, diag ⊗ p)."""
    p = p_ref[...]
    o_ref[...] = _slab_minplus(p, diag_ref[...], p)


def _panel_right_kernel(p_ref, diag_ref, o_ref):
    """One (T, T) block of the column panel: o = min(p, p ⊗ diag)."""
    p = p_ref[...]
    o_ref[...] = _slab_minplus(p, p, diag_ref[...])


def _outer_kernel(d_ref, colp_ref, rowp_ref, o_ref):
    """One (T, T) output tile: o = min(d, colp_tile ⊗ rowp_tile)."""
    o_ref[...] = _slab_minplus(d_ref[...], colp_ref[...], rowp_ref[...])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def apsp_tiled_pallas(d: jnp.ndarray, tile: int = 256,
                      interpret: bool = False) -> jnp.ndarray:
    """Blocked Floyd-Warshall APSP over a (N/T, N/T) Pallas block grid.

    ``d`` is one (N, N) adjacency (0 diag, INF non-edges) with N divisible
    by ``tile`` and ``tile`` divisible by 8 (``ops.apsp_tiled`` pads).
    Keeps dtype (fp32 or bf16).  Bit-identical to ``ref.apsp_tiled_ref``
    on the same padded input — the module docstring above explains why.
    """
    n = d.shape[0]
    assert d.ndim == 2 and d.shape[1] == n, d.shape
    assert n % tile == 0, (n, tile)
    assert tile % _CHUNK == 0, tile
    nb = n // tile
    dt = d.dtype

    def _call(kernel, grid, in_specs, out_specs, out_shape):
        return pl.pallas_call(
            kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
            out_shape=jax.ShapeDtypeStruct(out_shape, dt),
            interpret=interpret)

    t = tile
    fw_diag = _call(
        _fw_diag_kernel, (1,),
        [pl.BlockSpec((t, t), lambda i: (0, 0))],
        pl.BlockSpec((t, t), lambda i: (0, 0)), (t, t))
    panel_left = _call(
        _panel_left_kernel, (nb,),
        [pl.BlockSpec((t, t), lambda j: (0, 0)),
         pl.BlockSpec((t, t), lambda j: (0, j))],
        pl.BlockSpec((t, t), lambda j: (0, j)), (t, n))
    panel_right = _call(
        _panel_right_kernel, (nb,),
        [pl.BlockSpec((t, t), lambda i: (i, 0)),
         pl.BlockSpec((t, t), lambda i: (0, 0))],
        pl.BlockSpec((t, t), lambda i: (i, 0)), (n, t))
    outer = _call(
        _outer_kernel, (nb, nb),
        [pl.BlockSpec((t, t), lambda i, j: (i, j)),
         pl.BlockSpec((t, t), lambda i, j: (i, 0)),
         pl.BlockSpec((t, t), lambda i, j: (0, j))],
        pl.BlockSpec((t, t), lambda i, j: (i, j)), (n, n))

    def kblock(kb, d):
        o = kb * t
        diag = fw_diag(jax.lax.dynamic_slice(d, (o, o), (t, t)))
        rowp = jax.lax.dynamic_update_slice(
            jax.lax.dynamic_slice(d, (o, 0), (t, n)), diag, (0, o))
        rowp = panel_left(diag, rowp)
        colp = jax.lax.dynamic_update_slice(
            jax.lax.dynamic_slice(d, (0, o), (n, t)), diag, (o, 0))
        colp = panel_right(colp, diag)
        d = jax.lax.dynamic_update_slice(d, rowp, (o, 0))
        d = jax.lax.dynamic_update_slice(d, colp, (0, o))
        return outer(d, colp, rowp)

    return jax.lax.fori_loop(0, nb, kblock, d)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled min-plus product.  Inputs must be fp32 with dims divisible by
    the block sizes (``ops.minplus`` handles padding)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    assert bk % _CHUNK == 0, bk

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
