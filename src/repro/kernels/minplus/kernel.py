"""Pallas TPU kernel: tiled min-plus (tropical) matrix product.

C[i, j] = min_k A[i, k] + B[k, j]

This is the inner step of the min-plus-squaring APSP used by
``repro.core.diameter`` — the paper's diameter computation is the hot spot of
both the Q-learning reward loop and the GA baseline.  Min-plus has no
multiply-accumulate, so it maps to the VPU (not the MXU); the tiling is
therefore chosen for VMEM residency and 8x128 vector-lane alignment rather
than for MXU 128x128 systolic shape:

  * grid (M/bm, N/bn, K/bk), K innermost so the output block stays resident
    in VMEM across the K panels (revisiting rule on TPU: last grid dim is
    sequential minor-most).
  * each (bm, bk) x (bk, bn) panel is reduced in CHUNK=8 slabs: a
    (bm, 8, bn) broadcast-add + min keeps the temporary under 0.5 MiB
    (bm=bn=128) while amortizing loop overhead over full 8x128 vregs.
  * VMEM per step: A tile 64 KiB + B tile 64 KiB + C tile 64 KiB fp32
    (+ double buffering) — far below the ~16 MiB/core budget, leaving room
    for the pipeline to prefetch the next K panel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF = 1e9
_CHUNK = 8


def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    a = a_ref[...]  # (bm, bk)
    b = b_ref[...]  # (bk, bn)

    def body(c, acc):
        a_slab = jax.lax.dynamic_slice_in_dim(a, c * _CHUNK, _CHUNK, axis=1)
        b_slab = jax.lax.dynamic_slice_in_dim(b, c * _CHUNK, _CHUNK, axis=0)
        cand = a_slab[:, :, None] + b_slab[None, :, :]       # (bm, CHUNK, bn)
        return jnp.minimum(acc, jnp.min(cand, axis=1))

    o_ref[...] = jax.lax.fori_loop(0, bk // _CHUNK, body, o_ref[...])


def _minplus_kernel_batched(a_ref, b_ref, o_ref, *, bk: int):
    """Batched variant: leading grid axis walks the batch; block shapes carry
    a unit batch dim that is squeezed before the slab reduction."""
    k_step = pl.program_id(3)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    a = a_ref[0]  # (bm, bk)
    b = b_ref[0]  # (bk, bn)

    def body(c, acc):
        a_slab = jax.lax.dynamic_slice_in_dim(a, c * _CHUNK, _CHUNK, axis=1)
        b_slab = jax.lax.dynamic_slice_in_dim(b, c * _CHUNK, _CHUNK, axis=0)
        cand = a_slab[:, :, None] + b_slab[None, :, :]       # (bm, CHUNK, bn)
        return jnp.minimum(acc, jnp.min(cand, axis=1))

    o_ref[0] = jax.lax.fori_loop(0, bk // _CHUNK, body, o_ref[0])


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_pallas_batched(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Batched tiled min-plus: ``(B, M, K) x (B, K, N) -> (B, M, N)``.

    The batch axis is the OUTERMOST grid dimension, so each batch element's
    output tiles are finished before the next element starts and the
    per-step VMEM footprint is identical to the unbatched kernel (the
    batch never touches VMEM as a whole).
    """
    bsz, m, k = a.shape
    bsz2, k2, n = b.shape
    assert bsz == bsz2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    assert bk % _CHUNK == 0, bk

    grid = (bsz, m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_minplus_kernel_batched, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda bb, i, j, kk: (bb, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda bb, i, j, kk: (bb, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda bb, i, j, kk: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_pallas(
    a: jnp.ndarray,
    b: jnp.ndarray,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tiled min-plus product.  Inputs must be fp32 with dims divisible by
    the block sizes (``ops.minplus`` handles padding)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape, bm, bn, bk)
    assert bk % _CHUNK == 0, bk

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
