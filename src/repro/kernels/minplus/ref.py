"""Pure-jnp oracles for the min-plus kernels (unbatched, batched, tiled).

``apsp_tiled_ref`` is the CPU twin of the Pallas blocked Floyd-Warshall in
``kernel.apsp_tiled_pallas``: it sequences the SAME three per-k-block
phases over the SAME (tile, tile) block grid, so CPU CI exercises the
kernel's block logic bit-for-bit (min over floats is exact, so any
regrouping of the same candidate set — the kernel's 8-slab reduction vs
the rank-1 loops here — produces identical bits).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[i, j] = min_k A[i, k] + B[k, j] (dense broadcast)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def minplus_batched_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[b, i, j] = min_k A[b, i, k] + B[b, k, j] (vmapped dense broadcast)."""
    return jax.vmap(minplus_ref)(a, b)


# ---------------------------------------------------------------------------
# blocked Floyd-Warshall (the tiled APSP fallback)
# ---------------------------------------------------------------------------

def fw_tile_ref(d: jnp.ndarray, *, symmetric: bool = False) -> jnp.ndarray:
    """Transitive closure of one (T, T) tile by rank-1 Floyd-Warshall.

    ``symmetric`` reads only the contiguous pivot row — bitwise equal to
    the general form on symmetric tiles (FW preserves symmetry exactly:
    the two update terms commute under +).
    """
    def body(k, d):
        row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)     # (1, T)
        col = row.T if symmetric else \
            jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)       # (T, 1)
        return jnp.minimum(d, col + row)

    return jax.lax.fori_loop(0, d.shape[0], body, d, unroll=4)


def _panel_update(p: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                  *, unroll: int = 8) -> jnp.ndarray:
    """``min(p, a ⊗ b)`` with the product taken against FROZEN a, b.

    Freezing matters: updating the operand mid-loop would admit ulp-level
    double-relaxation candidates the Pallas kernel (which reduces against
    the unmodified block) never sees, breaking bit parity.
    """
    def body(k, acc):
        col = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=1)     # (M, 1)
        row = jax.lax.dynamic_slice_in_dim(b, k, 1, axis=0)     # (1, N)
        return jnp.minimum(acc, col + row)

    return jax.lax.fori_loop(0, a.shape[1], body, p, unroll=unroll)


def apsp_tiled_ref(d: jnp.ndarray, tile: int, *,
                   symmetric: bool = False) -> jnp.ndarray:
    """Blocked Floyd-Warshall APSP over a (tile, tile) block grid.

    For each diagonal block k (three phases, the classic blocked FW):

    1. close the (T, T) diagonal tile (rank-1 FW);
    2. relax the k-th row panel against the closed diagonal
       (``min(rowp, diag ⊗ rowp)``) and the column panel symmetrically;
    3. rank-1 outer update of the WHOLE matrix against the fresh panels
       (``min(d, colp ⊗ rowp)``) — the panels themselves are included
       (their extra candidates are valid path lengths, so the update is a
       no-op there up to fp rounding), which keeps the update a uniform
       2D block grid exactly like the Pallas kernel's.

    ``symmetric`` derives the column panel as ``rowp.T`` — bitwise equal
    to the general form on symmetric inputs, at 2/3 of the panel work.
    Requires ``d.shape[0] % tile == 0`` (callers pad with INF).
    """
    n = d.shape[0]
    assert n % tile == 0, (n, tile)
    nb = n // tile

    def kblock(kb, d):
        o = kb * tile
        diag = fw_tile_ref(jax.lax.dynamic_slice(d, (o, o), (tile, tile)),
                           symmetric=symmetric)
        rowp = jax.lax.dynamic_update_slice(
            jax.lax.dynamic_slice(d, (o, 0), (tile, n)), diag, (0, o))
        rowp = _panel_update(rowp, diag, rowp)
        if symmetric:
            colp = rowp.T
        else:
            colp = jax.lax.dynamic_update_slice(
                jax.lax.dynamic_slice(d, (0, o), (n, tile)), diag, (o, 0))
            colp = _panel_update(colp, colp, diag)
        d = jax.lax.dynamic_update_slice(d, rowp, (o, 0))
        d = jax.lax.dynamic_update_slice(d, colp, (0, o))
        return _panel_update(d, colp, rowp)

    return jax.lax.fori_loop(0, nb, kblock, d)
