"""Pure-jnp oracle for the min-plus kernel."""
from __future__ import annotations

import jax.numpy as jnp


def minplus_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[i, j] = min_k A[i, k] + B[k, j] (dense broadcast)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)
