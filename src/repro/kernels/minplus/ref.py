"""Pure-jnp oracles for the min-plus kernel (unbatched and batched)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[i, j] = min_k A[i, k] + B[k, j] (dense broadcast)."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def minplus_batched_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C[b, i, j] = min_k A[b, i, k] + B[b, k, j] (vmapped dense broadcast)."""
    return jax.vmap(minplus_ref)(a, b)
