"""jit'd public wrappers for the min-plus kernels (padding + dispatch).

On TPU the Pallas kernels run compiled; on CPU (this container) they run in
interpret mode for correctness validation, and callers that need speed use
the jnp oracles (``repro.core.batcheval`` picks per backend).

Blocks are chosen ADAPTIVELY from the operand shape: a 20-node product pads
to 24 (the next 8-multiple), not to 128 — padding with +INF is semantically
neutral (padded k entries contribute INF + x and never win the min; padded
rows/cols are sliced off), but an 128-block pad at N=20 was 40x wasted
work.  On TPU, shapes >= 128 keep the 128 lane-aligned block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (INF, _CHUNK, apsp_tiled_pallas, minplus_pallas,
                     minplus_pallas_batched)
from .ref import apsp_tiled_ref, minplus_batched_ref, minplus_ref


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def _auto_block(*dims: int) -> int:
    """Smallest 8-multiple covering the largest dim, capped at 128 (the
    TPU lane-aligned tile; larger shapes are gridded over 128-blocks)."""
    return min(128, _ceil_to(max(max(dims), _CHUNK), _CHUNK))


def default_tile(n: int, cap: int = 256) -> int:
    """Tile for the blocked-FW APSP: the smallest 8-multiple tiling N in
    ``ceil(N / cap)`` blocks, so padding waste stays under one 8-row slab
    per block row instead of rounding N all the way up to a cap multiple
    (N=300 tiles as 2 x 152, not 2 x 256)."""
    nb = max(1, -(-n // cap))
    return _ceil_to(max(-(-n // nb), _CHUNK), _CHUNK)


def _pad_to(x: jnp.ndarray, mult: int, fill: float) -> jnp.ndarray:
    *lead, m, n = x.shape
    pm = (-m) % mult
    pn = (-n) % mult
    if pm == 0 and pn == 0:
        return x
    pad = [(0, 0)] * len(lead) + [(0, pm), (0, pn)]
    return jnp.pad(x, pad, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def minplus(a: jnp.ndarray, b: jnp.ndarray, block: int | None = None,
            interpret: bool | None = None) -> jnp.ndarray:
    """Min-plus product with INF padding to (adaptive) block multiples."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = a.shape[0], b.shape[1]
    if block is None:
        block = _auto_block(m, a.shape[1], n)
    a32 = _pad_to(a.astype(jnp.float32), block, INF)
    b32 = _pad_to(b.astype(jnp.float32), block, INF)
    out = minplus_pallas(a32, b32, bm=block, bn=block, bk=block,
                         interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("block", "force_kernel"))
def minplus_batched(a: jnp.ndarray, b: jnp.ndarray, block: int | None = None,
                    force_kernel: bool = False) -> jnp.ndarray:
    """Batched min-plus product ``(B, M, K) x (B, K, N) -> (B, M, N)``.

    Backend dispatch: on TPU the Pallas kernel runs compiled with the batch
    as the outermost grid axis; everywhere else the vmapped jnp oracle is
    used (the interpret-mode kernel is far too slow for bulk evaluation —
    ``force_kernel`` exists so tests can still exercise the kernel path).
    """
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_kernel):
        return minplus_batched_ref(a, b)
    m, n = a.shape[1], b.shape[2]
    if block is None:
        block = _auto_block(m, a.shape[2], n)
    a32 = _pad_to(a.astype(jnp.float32), block, INF)
    b32 = _pad_to(b.astype(jnp.float32), block, INF)
    out = minplus_pallas_batched(a32, b32, bm=block, bn=block, bk=block,
                                 interpret=not on_tpu)
    return out[:, :m, :n]


@functools.partial(jax.jit, static_argnames=("tile", "force_kernel",
                                             "interpret", "symmetric"))
def apsp_tiled(d: jnp.ndarray, tile: int | None = None, *,
               force_kernel: bool = False, interpret: bool | None = None,
               symmetric: bool = False) -> jnp.ndarray:
    """Blocked Floyd-Warshall APSP of one (N, N) adjacency, memory-bounded.

    Pads N to a ``tile`` multiple with INF (padded nodes are unreachable
    and sliced off), then runs the (N/T, N/T) block-grid engine: the Pallas
    kernel on TPU (or under ``force_kernel``, interpret mode off-TPU), the
    bit-identical jnp twin ``ref.apsp_tiled_ref`` otherwise.  Keeps the
    input dtype (fp32 or bf16).  ``symmetric`` enables the ref's
    column-panel-as-transpose shortcut — bitwise-safe for the undirected
    overlays this repo builds; pass ``False`` for directed inputs.
    """
    n = d.shape[-1]
    assert d.ndim == 2 and d.shape[0] == n, d.shape
    if tile is None:
        tile = default_tile(n)
    on_tpu = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = not on_tpu
    dp = _pad_to(d, tile, INF)
    if on_tpu or force_kernel:
        out = apsp_tiled_pallas(dp, tile=tile, interpret=interpret)
    else:
        out = apsp_tiled_ref(dp, tile, symmetric=symmetric)
    return out[:n, :n]


__all__ = ["minplus", "minplus_batched", "minplus_ref", "minplus_batched_ref",
           "apsp_tiled", "default_tile"]
