"""jit'd public wrapper for the min-plus kernel (padding + backend dispatch).

On TPU the Pallas kernel runs compiled; on CPU (this container) it runs in
interpret mode for correctness validation, and callers that need speed use
the jnp oracle (``repro.core.diameter`` defaults to the oracle on CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import INF, minplus_pallas, minplus_pallas_batched
from .ref import minplus_batched_ref, minplus_ref


def _pad_to(x: jnp.ndarray, mult: int, fill: float) -> jnp.ndarray:
    *lead, m, n = x.shape
    pm = (-m) % mult
    pn = (-n) % mult
    if pm == 0 and pn == 0:
        return x
    pad = [(0, 0)] * len(lead) + [(0, pm), (0, pn)]
    return jnp.pad(x, pad, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def minplus(a: jnp.ndarray, b: jnp.ndarray, block: int = 128,
            interpret: bool | None = None) -> jnp.ndarray:
    """Min-plus product with INF padding to block multiples.

    Padding with +INF is semantically neutral: padded k entries contribute
    INF + x >= INF and never win the min; padded rows/cols are sliced off.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = a.shape[0], b.shape[1]
    a32 = _pad_to(a.astype(jnp.float32), block, INF)
    b32 = _pad_to(b.astype(jnp.float32), block, INF)
    out = minplus_pallas(a32, b32, bm=block, bn=block, bk=block,
                         interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("block", "force_kernel"))
def minplus_batched(a: jnp.ndarray, b: jnp.ndarray, block: int = 128,
                    force_kernel: bool = False) -> jnp.ndarray:
    """Batched min-plus product ``(B, M, K) x (B, K, N) -> (B, M, N)``.

    Backend dispatch: on TPU the Pallas kernel runs compiled with the batch
    as the outermost grid axis; everywhere else the vmapped jnp oracle is
    used (the interpret-mode kernel is far too slow for bulk evaluation —
    ``force_kernel`` exists so tests can still exercise the kernel path).
    """
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_kernel):
        return minplus_batched_ref(a, b)
    m, n = a.shape[1], b.shape[2]
    a32 = _pad_to(a.astype(jnp.float32), block, INF)
    b32 = _pad_to(b.astype(jnp.float32), block, INF)
    out = minplus_pallas_batched(a32, b32, bm=block, bn=block, bk=block,
                                 interpret=not on_tpu)
    return out[:, :m, :n]


__all__ = ["minplus", "minplus_batched", "minplus_ref", "minplus_batched_ref"]
