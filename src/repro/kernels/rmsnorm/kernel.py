"""Pallas TPU kernel: fused RMSNorm (forward).

Every layer runs 2-4 RMSNorms over the residual stream; unfused, XLA emits
square -> mean -> rsqrt -> mul -> mul as separate HBM round-trips on some
shapes.  The kernel tiles rows into VMEM blocks, computes the row moment in
fp32 on the VPU and applies the scale in one pass — one HBM read + one
write per element.

Tiling: grid over row blocks of ``bm`` rows; the full feature dim d stays
resident (d ≤ 8192 bf16 = 16 KiB/row — far under VMEM with bm=256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # (bm, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    scale = 1.0 + s_ref[...].astype(jnp.float32)    # (d,)
    o_ref[...] = (x * inv * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def rmsnorm_pallas(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
                   bm: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (rows, d); scale: (d,).  Rows must divide by bm (ops pads)."""
    rows, d = x.shape
    assert rows % bm == 0, (rows, bm)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
