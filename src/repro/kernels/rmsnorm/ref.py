"""Pure-jnp oracle for the fused RMSNorm kernel (mirrors models.layers)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)
