"""jit'd public wrapper for the fused RMSNorm kernel (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rmsnorm_pallas
from .ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, *, eps: float = 1e-6,
            interpret: bool | None = None) -> jnp.ndarray:
    """Fused RMSNorm over the last dim; leading dims flattened to rows."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    flat = x.reshape(rows, d)
    bm = min(256, rows)
    pad = (-rows) % bm
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    out = rmsnorm_pallas(flat, scale, eps=eps, bm=bm, interpret=interpret)
    if pad:
        out = out[:rows]
    return out.reshape(shape)


__all__ = ["rmsnorm", "rmsnorm_ref"]
