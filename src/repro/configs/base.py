"""Architecture configuration schema + shape registry.

Every assigned architecture is a frozen ``ArchConfig``; the four input-shape
cells (train_4k / prefill_32k / decode_32k / long_500k) are ``ShapeConfig``s.
``smoke()`` derives a reduced same-family config for CPU tests; the FULL
configs are only ever lowered via ShapeDtypeStructs (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False           # qwen1.5
    qk_norm: bool = False            # gemma3
    mlp_kind: str = "swiglu"         # swiglu | gelu (musicgen)
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- local/global attention (gemma3) ---
    sliding_window: Optional[int] = None   # window for local layers
    global_period: int = 0                 # every Nth layer is global (0 = all global)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_period: int = 1               # MoE every Nth layer (llama4: 2)
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- SSM (mamba1/mamba2) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64            # mamba2 heads
    ssm_kind: str = ""                # "mamba1" | "mamba2"
    # --- hybrid (zamba2) ---
    shared_attn_period: int = 0       # every Nth block runs the shared attn block
    # --- multimodal stub frontend ---
    frontend: Optional[str] = None    # None | "audio" | "vision"
    n_patches: int = 256              # vision stub: patch positions per sample
    # --- training ---
    max_seq: int = 131_072

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, self.d_model // 16)

    def is_global_layer(self, i: int) -> bool:
        if self.global_period <= 0 or self.sliding_window is None:
            return True
        return (i + 1) % self.global_period == 0

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return (i + 1) % self.moe_period == 0

    def is_attn_block(self, i: int) -> bool:
        """hybrid (zamba2): every shared_attn_period-th block appends the
        shared attention block after the mamba block."""
        if self.shared_attn_period <= 0:
            return False
        return (i + 1) % self.shared_attn_period == 0

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = max(self.global_period, self.moe_period if self.n_experts else 1,
                     self.shared_attn_period, 1)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2 * period, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // max(self.n_heads, 1)),
            head_dim=16,
            d_ff=128,
            d_ff_expert=64 if self.n_experts else 0,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            vocab=256,
            sliding_window=16 if self.sliding_window else None,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_kind == "mamba2" else self.ssm_head_dim,
            n_patches=8,
            max_seq=256,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention: run for SSM/hybrid and for
    sliding-window archs (gemma3 — only every-6th layer keeps a full-length
    cache); skip for pure full-attention archs (see DESIGN.md)."""
    if shape.name == "long_500k":
        subquadratic = (arch.family in ("ssm", "hybrid")
                        or arch.sliding_window is not None)
        if not subquadratic:
            return False, "skipped: pure full-attention arch at 524k context"
    return True, ""
