"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab=262144,
    qk_norm=True,
    sliding_window=1024,
    global_period=6,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq=131_072,
)
