"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

Backbone only: the ViT frontend is a stub — input_specs() provides
precomputed patch embeddings that are prepended to the token embeddings."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1_000_000.0,
    frontend="vision",
    n_patches=256,
    max_seq=131_072,
)
