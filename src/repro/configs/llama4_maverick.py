"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
expert d_ff=8192 vocab=202048, MoE 128e top-1 — early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

MoE every OTHER layer (moe_period=2, 24 MoE layers): all-layer MoE at these
dims would be ~775B params, contradicting the 400B name; interleaved MoE +
dense d_ff 16384 + shared expert reproduces ~400B total / ~17B active
(DESIGN.md §6).  Early fusion: optional vision embeddings are fused into the
token stream by the stub frontend."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,                 # dense-layer / shared-expert width
    d_ff_expert=8192,
    n_experts=128,
    top_k=1,
    moe_period=2,
    shared_expert=True,
    vocab=202048,
    rope_theta=500_000.0,
    frontend="vision",          # early fusion (stub patch embeddings)
    n_patches=256,
    max_seq=131_072,
)
