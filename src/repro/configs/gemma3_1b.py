"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144 — 5:1 local:global sliding window, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    qk_norm=True,
    sliding_window=512,
    global_period=6,           # every 6th layer global => 5:1 local:global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq=131_072,
)
