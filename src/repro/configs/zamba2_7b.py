"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64 — Mamba2 blocks + shared attention block
[arXiv:2411.15242; unverified].

81 Mamba2 blocks; every 6th block is followed by the SHARED transformer
block (one set of attention+MLP weights reused at each invocation — the
Zamba trick).  d_ff applies to the shared block's MLP."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_kind="mamba2",
    ssm_head_dim=64,
    shared_attn_period=6,
    max_seq=1_048_576,
)
