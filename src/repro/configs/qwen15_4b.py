"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].

Note: the assignment's hf tag names the 0.5B checkpoint but the listed dims
are Qwen1.5-4B; we implement the listed dims (see DESIGN.md §6)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    max_seq=32_768,
)
