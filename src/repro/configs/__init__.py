"""Assigned-architecture registry: ``get_arch(name)`` / ``--arch <id>``."""
from __future__ import annotations

from .base import SHAPES, ArchConfig, ShapeConfig, shape_applicable
from .qwen15_4b import CONFIG as _qwen
from .gemma3_1b import CONFIG as _g1
from .granite_8b import CONFIG as _granite
from .gemma3_27b import CONFIG as _g27
from .falcon_mamba_7b import CONFIG as _mamba
from .musicgen_large import CONFIG as _musicgen
from .moonshot_v1_16b import CONFIG as _moonshot
from .llama4_maverick import CONFIG as _llama4
from .pixtral_12b import CONFIG as _pixtral
from .zamba2_7b import CONFIG as _zamba

ARCHS = {c.name: c for c in [
    _qwen, _g1, _granite, _g27, _mamba, _musicgen, _moonshot, _llama4,
    _pixtral, _zamba,
]}


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "get_arch",
           "shape_applicable"]
