"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only: the EnCodec frontend is a stub — input_specs() provides the
token stream directly (one codebook stream; the 4-codebook delay pattern is
a data-layout concern, not a backbone concern).  MLP is plain GELU (the
original is a standard transformer, not SwiGLU)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab=2048,
    mlp_kind="gelu",
    frontend="audio",
    max_seq=32_768,
)
