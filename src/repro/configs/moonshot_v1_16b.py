"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight
[hf:moonshotai/Moonlight-16B-A3B; hf].

Every layer is MoE (64 experts, top-6) with a shared expert sized 2x1408
(Moonlight uses 2 shared experts of 1408).  The assignment's 48L at these
dims totals ~27B params (the hf checkpoint has 27 layers); we implement the
assigned 48L (DESIGN.md §6)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=2816,                  # shared-expert width (2 x 1408)
    d_ff_expert=1408,
    n_experts=64,
    top_k=6,
    moe_period=1,
    shared_expert=True,
    vocab=163840,
    rope_theta=50_000.0,
    max_seq=8_192,
)
