"""Churn scenario library + replayable trace format.

A :class:`Trace` is plain data — timestamped events over capacity-slot node
ids plus the latency-distribution spec — so a benchmark run is exactly
reproducible from its JSON serialization, and the SAME trace can drive every
overlay policy (traces name *who* joins/leaves/fails, policies decide *how*
the overlay reacts).

Scenarios (all deterministic in ``seed``):

* ``poisson_churn``     — memoryless background join/leave churn;
* ``flash_crowd``       — a burst of joins inside a short window (fleet
                          onboarding, auto-scaling step);
* ``regional_failure``  — every node of one FABRIC site fails at once
                          (correlated regional outage; sites follow the
                          round-robin assignment of
                          ``topology.fabric_latency``);
* ``diurnal_drift``     — sinusoidal global latency scaling (daily WAN
                          congestion cycle);
* ``straggler_storm``   — a handful of nodes degrade sharply (tail-latency
                          incidents a la Dean & Barroso).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import numpy as np

from repro import serde
from repro.core.topology import N_FABRIC_SITES, make_latency

__all__ = ["Event", "Trace", "poisson_churn", "flash_crowd",
           "regional_failure", "diurnal_drift", "straggler_storm",
           "merge_traces", "churn_with_drift", "cluster_split_merge",
           "SCENARIOS"]

#: the five node-level kinds every engine handles, plus the two
#: cluster-level kinds only hierarchical engines accept (the flat
#: ``ChurnEngine`` raises a descriptive error on them)
EVENT_KINDS = ("join", "leave", "fail", "latency_drift", "straggler",
               "cluster_split", "cluster_merge")

_NODE_KINDS = EVENT_KINDS[:5]


@dataclasses.dataclass(frozen=True)
class Event:
    """One timestamped churn event (times in ms, node ids are slot indices).

    ``factor`` scales latencies for drift/straggler events; ``region``
    restricts a drift to one FABRIC site (-1 = global).  For the
    cluster-level kinds ``node`` holds the CLUSTER id (``cluster_split``
    splits it in two; ``cluster_merge`` absorbs cluster ``peer`` into it).
    ``peer`` is only serialized for cluster events, so node-level trace
    JSON is byte-identical to the pre-hierarchy format.
    """
    time: float
    kind: str
    node: int = -1
    factor: float = 1.0
    region: int = -1
    peer: int = -1

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown event kind {self.kind!r}; options {EVENT_KINDS}")
        if self.kind != "latency_drift" and self.node < 0:
            raise ValueError(
                f"{self.kind} event needs a node id >= 0, got {self.node} "
                f"(negative ids would silently index from the end)")
        if self.region != -1 and not 0 <= self.region < N_FABRIC_SITES:
            raise ValueError(
                f"region must be -1 (global) or a FABRIC site in "
                f"[0, {N_FABRIC_SITES}), got {self.region}")
        if self.kind == "cluster_merge":
            if self.peer < 0 or self.peer == self.node:
                raise ValueError(
                    f"cluster_merge needs a peer cluster id >= 0 distinct "
                    f"from node, got node={self.node} peer={self.peer}")
        elif self.peer != -1:
            raise ValueError(
                f"peer is only meaningful for cluster_merge events, got "
                f"peer={self.peer} on a {self.kind!r} event")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.kind in _NODE_KINDS:
            d.pop("peer")       # node-level JSON stays byte-identical
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Event":
        return cls(**d)


@dataclasses.dataclass
class Trace:
    """A replayable churn trace: initial fleet + capacity + event stream."""
    n0: int                 # initially-live nodes: slots [0, n0)
    capacity: int           # total slots (joins activate n0, n0+1, ...)
    dist: str               # latency distribution name (core.topology)
    seed: int               # latency-matrix seed
    events: List[Event]
    name: str = "trace"

    def __post_init__(self):
        bad = [e for e in self.events if e.node >= self.capacity]
        if bad:
            raise ValueError(
                f"events reference slots >= capacity {self.capacity}: "
                f"{bad[:3]}")

    def latency(self) -> np.ndarray:
        """The (capacity, capacity) base latency matrix this trace runs on."""
        return make_latency(self.dist, self.capacity, seed=self.seed)

    def to_json(self) -> str:
        return serde.dumps({
            "name": self.name, "n0": self.n0, "capacity": self.capacity,
            "dist": self.dist, "seed": self.seed,
            "events": [e.to_dict() for e in self.events],
        }, indent=None)

    @classmethod
    def from_json(cls, s: str) -> "Trace":
        d = serde.loads(s, what="Trace JSON")
        return cls(n0=d["n0"], capacity=d["capacity"], dist=d["dist"],
                   seed=d["seed"], name=d.get("name", "trace"),
                   events=[Event.from_dict(e) for e in d["events"]])

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------

def poisson_churn(n0: int = 40, dist: str = "bitnode", seed: int = 0, *,
                  horizon: float = 30_000.0, join_rate: float = 0.4e-3,
                  leave_rate: float = 0.4e-3, min_live: int = 8) -> Trace:
    """Memoryless background churn: joins/leaves as independent Poisson
    processes (rates in events/ms)."""
    rng = np.random.default_rng(seed + 1)
    live = list(range(n0))
    next_id = n0
    t = 0.0
    events: List[Event] = []
    total = join_rate + leave_rate
    while True:
        t += float(rng.exponential(1.0 / total))
        if t >= horizon:
            break
        if rng.random() < join_rate / total:
            events.append(Event(time=t, kind="join", node=next_id))
            live.append(next_id)
            next_id += 1
        elif len(live) > min_live:
            u = live.pop(int(rng.integers(len(live))))
            events.append(Event(time=t, kind="leave", node=u))
    return Trace(n0=n0, capacity=next_id, dist=dist, seed=seed,
                 events=events, name="poisson_churn")


def flash_crowd(n0: int = 32, dist: str = "bitnode", seed: int = 0, *,
                burst: int = 24, t0: float = 5_000.0,
                window: float = 2_000.0) -> Trace:
    """A join burst: ``burst`` nodes arrive within ``window`` ms of ``t0``."""
    rng = np.random.default_rng(seed + 1)
    times = np.sort(rng.uniform(t0, t0 + window, size=burst))
    events = [Event(time=float(t), kind="join", node=n0 + i)
              for i, t in enumerate(times)]
    return Trace(n0=n0, capacity=n0 + burst, dist=dist, seed=seed,
                 events=events, name="flash_crowd")


def regional_failure(n0: int = 51, dist: str = "fabric", seed: int = 0, *,
                     site: int = 0, t_fail: float = 5_000.0,
                     jitter: float = 50.0) -> Trace:
    """Correlated outage: every live node at one FABRIC site crashes at
    ~``t_fail`` (small per-node jitter models the power/link cascade)."""
    rng = np.random.default_rng(seed + 1)
    victims = [u for u in range(n0) if u % N_FABRIC_SITES == site]
    assert len(victims) < n0, "regional failure would kill the whole fleet"
    events = [Event(time=float(t_fail + rng.uniform(0, jitter)), kind="fail",
                    node=u) for u in victims]
    events.sort(key=lambda e: e.time)
    return Trace(n0=n0, capacity=n0, dist=dist, seed=seed,
                 events=events, name="regional_failure")


def diurnal_drift(n0: int = 40, dist: str = "bitnode", seed: int = 0, *,
                  period: float = 24_000.0, steps: int = 6,
                  amplitude: float = 0.4) -> Trace:
    """Sinusoidal global latency drift sampled at ``steps`` points per
    period: factor(t) = 1 + amplitude * sin(2 pi t / period)."""
    assert 0 <= amplitude < 1.0, amplitude
    events = [
        Event(time=(k + 1) * period / steps, kind="latency_drift",
              factor=float(1.0 + amplitude
                           * np.sin(2 * np.pi * (k + 1) / steps)))
        for k in range(steps)
    ]
    return Trace(n0=n0, capacity=n0, dist=dist, seed=seed,
                 events=events, name="diurnal_drift")


def straggler_storm(n0: int = 40, dist: str = "gaussian", seed: int = 0, *,
                    k: int = 3, factor: float = 6.0, t0: float = 4_000.0,
                    gap: float = 1_500.0) -> Trace:
    """``k`` distinct nodes degrade by ``factor`` x, one every ``gap`` ms."""
    rng = np.random.default_rng(seed + 1)
    victims = rng.choice(n0, size=min(k, n0), replace=False)
    events = [Event(time=t0 + i * gap, kind="straggler", node=int(u),
                    factor=factor) for i, u in enumerate(victims)]
    return Trace(n0=n0, capacity=n0, dist=dist, seed=seed,
                 events=events, name="straggler_storm")


def cluster_split_merge(n0: int = 96, dist: str = "fabric", seed: int = 0, *,
                        cluster: int = 0, peer: int = 1,
                        t_split: float = 4_000.0, t_merge: float = 12_000.0,
                        churn_rate: float = 0.2e-3,
                        horizon: float = 16_000.0) -> Trace:
    """Hierarchical reorganization under background churn: cluster
    ``cluster`` splits in two, then later absorbs cluster ``peer``, while
    Poisson join/leave churn keeps arriving.  Only hierarchical engines
    accept the cluster events; the flat engine rejects this trace with a
    descriptive error."""
    churn = poisson_churn(n0, dist, seed, horizon=horizon,
                          join_rate=churn_rate, leave_rate=churn_rate)
    reorg = Trace(n0=n0, capacity=n0, dist=dist, seed=seed, events=[
        Event(time=t_split, kind="cluster_split", node=cluster),
        Event(time=t_merge, kind="cluster_merge", node=cluster, peer=peer),
    ], name="cluster_reorg")
    return merge_traces(churn, reorg, name="cluster_split_merge")


def merge_traces(*traces: Trace, name: str | None = None) -> Trace:
    """Superimpose traces that share a latency world (n0/dist/seed must
    agree): events are merged in time order, capacity is the max.  This is
    how compound workloads (e.g. churn + drift) are assembled without a
    bespoke generator per combination."""
    if not traces:
        raise ValueError("merge_traces needs at least one trace")
    first = traces[0]
    for t in traces[1:]:
        if (t.n0, t.dist, t.seed) != (first.n0, first.dist, first.seed):
            raise ValueError(
                f"traces disagree on the latency world: "
                f"{(t.n0, t.dist, t.seed)} vs {(first.n0, first.dist, first.seed)}")
    events = sorted((e for t in traces for e in t.events), key=lambda e: e.time)
    return Trace(n0=first.n0, capacity=max(t.capacity for t in traces),
                 dist=first.dist, seed=first.seed, events=events,
                 name=name or "+".join(t.name for t in traces))


def churn_with_drift(n0: int = 40, dist: str = "bitnode", seed: int = 0, *,
                     horizon: float = 30_000.0, join_rate: float = 0.4e-3,
                     leave_rate: float = 0.4e-3, drift_steps: int = 6,
                     amplitude: float = 0.3) -> Trace:
    """The service benchmark's compound workload: memoryless join/leave
    churn superimposed on a diurnal latency cycle — membership changes keep
    arriving while every link's weight is drifting underneath them."""
    churn = poisson_churn(n0, dist, seed, horizon=horizon,
                          join_rate=join_rate, leave_rate=leave_rate)
    drift = diurnal_drift(n0, dist, seed, period=horizon,
                          steps=drift_steps, amplitude=amplitude)
    return merge_traces(churn, drift, name="churn_with_drift")


SCENARIOS: Dict[str, Callable[..., Trace]] = {
    "poisson_churn": poisson_churn,
    "flash_crowd": flash_crowd,
    "regional_failure": regional_failure,
    "diurnal_drift": diurnal_drift,
    "straggler_storm": straggler_storm,
    "churn_with_drift": churn_with_drift,
    "cluster_split_merge": cluster_split_merge,
}
