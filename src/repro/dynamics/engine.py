"""Discrete-event churn engine over the incremental distance state.

A :class:`ChurnEngine` replays a :class:`Trace` (timestamped Join / Leave /
Fail / LatencyDrift / Straggler events — see ``dynamics.scenarios``) against
an overlay maintained by an :class:`OverlayPolicy` (DGRO, Chord, RAPID or
Perigee rules) on top of :class:`~repro.dynamics.incremental.IncrementalDistances`.

Policies are thin adapters over the ``repro.overlay`` builder registry:
initial construction resolves through ``overlay.build(policy.builder, ...)``
(so the Chord / RAPID / Perigee construction rules live in exactly one
place), and only the *dynamic* rules — ring splices, stitch repairs,
join-time fingers / nearest-neighbour edges (via the registry's shared edge
helpers), and DGRO's periodic ``selection.adapt`` self-repair — live here.

Membership-plane wiring (the paper's application layer):

* **Fail -> Leave**: a crash is not actionable until SWIM detects and
  confirms it — ``detect_failures=True`` asks
  ``repro.membership.gossip.confirmed_leave_time`` for the confirmation
  delay and schedules the Leave then; until confirmation the dead node is
  still routed through (the honest stale view).
* **Straggler demotion**: Straggler events inflate a node's latencies; the
  DGRO policy demotes nodes flagged by
  ``repro.membership.elastic.detect_stragglers`` (treated as Leave for the
  overlay, exactly like the elastic layer's mesh rule).
* **DGRO self-repair**: after every ``adapt_every`` confirmed membership
  changes the DGRO policy runs ``repro.core.selection.adapt`` over the live
  fleet's overlay; the winning ring's edges are applied as incremental
  relaxations, so the distance matrix never needs a from-scratch rebuild
  for repair.

Traces are plain data and replay deterministically: engine randomness comes
from one ``numpy`` Generator seeded at construction.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Tuple

import numpy as np

from repro import overlay as overlay_api
from repro.core import selection
from repro.obs import REGISTRY
from repro.core.construction import default_num_rings
from repro.core.diameter import adjacency_from_edges, is_edge
from repro.membership.elastic import HostState, detect_stragglers
from repro.membership.gossip import SwimConfig, confirmed_leave_time

from .incremental import IncrementalDistances
from .scenarios import EVENT_KINDS, Event, N_FABRIC_SITES, Trace

__all__ = [
    "TrajectorySample",
    "RunResult",
    "OverlayPolicy",
    "RingOverlayPolicy",
    "DGROPolicy",
    "ChordPolicy",
    "RapidPolicy",
    "PerigeePolicy",
    "POLICIES",
    "ChurnEngine",
]

Edge = Tuple[int, int]

# one series per trace event kind, shared by every engine in the process —
# scrapers diff before/after; children are pre-resolved so the per-event
# cost is one dict lookup + one guarded increment
_ENGINE_EVENTS = REGISTRY.counter(
    "repro_engine_events_total", "churn events applied, by kind",
    labels=("kind",))
_EVENT_KIND = {k: _ENGINE_EVENTS.labels(kind=k) for k in EVENT_KINDS}


@dataclasses.dataclass(frozen=True, eq=False)
class TrajectorySample:
    time: float
    event: str
    n_live: int
    diameter: float
    stretch: float = float("nan")    # routing-probe mean stretch (NaN = off)

    def __eq__(self, other):
        # NaN-tolerant: two replays with the probe off (stretch NaN) must
        # still compare equal sample-for-sample
        if not isinstance(other, TrajectorySample):
            return NotImplemented
        a, b = dataclasses.astuple(self), dataclasses.astuple(other)
        return all(x == y or (x != x and y != y) for x, y in zip(a, b))

    __hash__ = None


@dataclasses.dataclass
class RunResult:
    policy: str
    trace: str
    samples: List[TrajectorySample]
    final_diameter: float            # exact (post-refresh)
    stats: Dict[str, int]

    @property
    def mean_diameter(self) -> float:
        if not self.samples:           # run(record=False) keeps no samples
            return float("nan")
        return float(np.mean([s.diameter for s in self.samples]))

    @property
    def peak_diameter(self) -> float:
        if not self.samples:
            return float("nan")
        return float(np.max([s.diameter for s in self.samples]))

    @property
    def mean_stretch(self) -> float:
        """Mean over the probed samples' routing stretch (NaN when the run
        had ``route_probe=0`` or no probe ever delivered a pair)."""
        vals = [s.stretch for s in self.samples if np.isfinite(s.stretch)]
        return float(np.mean(vals)) if vals else float("nan")


# ---------------------------------------------------------------------------
# overlay policies
# ---------------------------------------------------------------------------

class OverlayPolicy:
    """How a protocol builds its overlay and reacts to membership changes.

    All node ids are *global* capacity-slot indices.  Policies only ever ADD
    edges between live nodes on join/repair — removals happen exclusively
    through tombstoning the departed node, which keeps every repair an exact
    incremental relaxation.
    """

    name = "base"
    demotes_stragglers = False

    def build(self, w: np.ndarray, live: np.ndarray,
              rng: np.random.Generator) -> List[Edge]:
        raise NotImplementedError

    def attach(self, w: np.ndarray, live: np.ndarray,
               rng: np.random.Generator, u: int) -> List[Edge]:
        raise NotImplementedError

    def detach(self, u: int, rng: np.random.Generator) -> List[Edge]:
        raise NotImplementedError

    def maybe_adapt(self, engine: "ChurnEngine") -> None:
        return None


class RingOverlayPolicy(OverlayPolicy):
    """Union-of-K-rings overlays with splice joins and stitch repairs.

    Construction is NOT implemented here: ``build()`` resolves ``builder``
    through the ``repro.overlay`` registry over the live sub-fleet's latency
    block and adopts the resulting :class:`~repro.overlay.Overlay`'s rings
    (re-indexed to global slot ids) and edge set.  The built overlay is kept
    on ``initial_overlay`` so traces can snapshot it (``to_json``).

    ``rings`` holds cyclic node-id lists.  A join splices the new node into
    each ring next to a chosen anchor ("random" position, or the "nearest"
    live ring member by latency); the anchor's old successor edge is kept —
    the overlay stays a supergraph of its rings, matching how neighbour
    tables grow before pruning.  A leave removes the node from each ring and
    stitches predecessor to successor.
    """

    name = "rings"
    builder = "rapid"                # registry name resolved by build()
    splice = "random"

    def __init__(self, k_rings: int | None = None):
        self.k_rings = k_rings
        self.rings: List[List[int]] = []
        self.initial_overlay = None

    def _build_config(self, n: int):
        """Registry config for a fresh build over ``n`` live nodes."""
        return overlay_api.RapidConfig(k=self.k_rings)

    def build(self, w, live, rng) -> List[Edge]:
        live = np.asarray(live)
        ov = overlay_api.build(self.builder, w[np.ix_(live, live)],
                               self._build_config(len(live)), rng=rng)
        self.initial_overlay = ov
        self.rings = [[int(live[i]) for i in ring] for ring in ov.rings]
        return [(int(live[a]), int(live[b])) for a, b in ov.edge_list()]

    def _splice(self, ring: List[int], w, rng, u: int) -> List[Edge]:
        if not ring:                 # fleet fully drained: joiner re-seeds it
            ring.append(u)
            return []
        if self.splice == "nearest":
            anchor = min(range(len(ring)), key=lambda i: w[u, ring[i]])
        else:
            anchor = int(rng.integers(len(ring)))
        succ = ring[(anchor + 1) % len(ring)]
        pred = ring[anchor]
        ring.insert(anchor + 1, u)
        return [(pred, u), (u, succ)]

    def attach(self, w, live, rng, u) -> List[Edge]:
        return [e for ring in self.rings for e in self._splice(ring, w, rng, u)]

    def detach(self, u, rng) -> List[Edge]:
        repairs: List[Edge] = []
        for ring in self.rings:
            if u not in ring:
                continue
            i = ring.index(u)
            pred, succ = ring[i - 1], ring[(i + 1) % len(ring)]
            ring.remove(u)
            if pred != succ and pred != u and succ != u:
                repairs.append((pred, succ))
        return repairs


class DGROPolicy(RingOverlayPolicy):
    """DGRO: rho-adaptive ring construction (the registry's ``"dgro"``
    builder), latency-aware splices, and periodic Algorithm-3 ring-selection
    repair applied as incremental relaxations."""

    name = "dgro"
    builder = "dgro"
    splice = "nearest"
    demotes_stragglers = True

    def __init__(self, k_rings: int | None = 2, adapt_every: int = 8):
        super().__init__(k_rings)
        self.adapt_every = adapt_every
        self._changes_since_adapt = 0
        self.adaptations = 0

    def _build_config(self, n: int):
        return overlay_api.DGROConfig(k=self.k_rings)

    def build(self, w, live, rng) -> List[Edge]:
        # reset adaptation state so a policy instance reused across engines
        # starts its cadence and stats fresh (build() already resets rings)
        self._changes_since_adapt = 0
        self.adaptations = 0
        return super().build(w, live, rng)

    def maybe_adapt(self, engine: "ChurnEngine") -> None:
        self._changes_since_adapt += 1
        if self._changes_since_adapt < self.adapt_every:
            return
        live = engine.inc.live_ids()
        if len(live) < 4:
            return                  # keep the pending count; adapt once viable
        self._changes_since_adapt = 0
        wl = engine.w[np.ix_(live, live)]
        adjl = engine.inc.adj[np.ix_(live, live)]
        seed = int(engine.rng.integers(2**31))
        # fold_weights: the engine keeps adj == w at edges, but external
        # drivers may have added custom-weight links via inc.add_edge
        live_ov = overlay_api.Overlay.from_adjacency(wl, adjl, policy="dgro",
                                                     fold_weights=True)
        new_ov, kind, _rho = selection.adapt(live_ov, seed=seed)
        if kind == "keep":
            return
        self.adaptations += 1
        added = np.argwhere(np.triu(new_ov.adjacency < adjl, 1))
        for i, j in added:
            engine.inc.add_edge(int(live[i]), int(live[j]),
                                float(new_ov.adjacency[i, j]))


class ChordPolicy(RingOverlayPolicy):
    """Chord: one identifier-space ring plus power-of-two finger edges.

    Joins splice at a random identifier position and add the joiner's own
    fingers (``overlay.chord_finger_edges`` — the same rule the registry
    builder uses); other nodes' fingers are repaired lazily (dead targets
    vanish with the tombstone), which is how Chord's periodic fixups behave
    between stabilization rounds.
    """

    name = "chord"
    builder = "chord"
    splice = "random"

    def __init__(self):
        super().__init__(k_rings=1)

    def _build_config(self, n: int):
        return overlay_api.ChordConfig()

    def attach(self, w, live, rng, u) -> List[Edge]:
        edges = super().attach(w, live, rng, u)
        ring = self.rings[0]
        edges.extend(overlay_api.chord_finger_edges(ring, ring.index(u)))
        return edges


class RapidPolicy(RingOverlayPolicy):
    """RAPID: K independent consistent-hash (random) rings."""

    name = "rapid"
    builder = "rapid"
    splice = "random"

    def __init__(self, k_rings: int | None = None):
        super().__init__(k_rings)


class PerigeePolicy(RingOverlayPolicy):
    """Perigee: per-node d lowest-latency neighbours + one connectivity ring.

    Joins add the joiner's nearest-neighbour edges with the registry
    builder's own rule (``overlay.nearest_neighbour_edges``).
    """

    name = "perigee"
    builder = "perigee"
    splice = "random"

    def __init__(self, degree: int | None = None):
        super().__init__(k_rings=1)
        self.degree = degree

    def _build_config(self, n: int):
        return overlay_api.PerigeeConfig(degree=self.degree)

    def attach(self, w, live, rng, u) -> List[Edge]:
        edges = super().attach(w, live, rng, u)
        d = self.degree or default_num_rings(len(live))
        edges.extend(overlay_api.nearest_neighbour_edges(
            w, np.asarray(live), u, d))
        return edges


POLICIES = {
    "dgro": DGROPolicy,
    "chord": ChordPolicy,
    "rapid": RapidPolicy,
    "perigee": PerigeePolicy,
}


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ChurnEngine:
    """Replay a churn trace against a policy-maintained overlay."""

    def __init__(self, trace: Trace, policy: OverlayPolicy, *,
                 rebuild_threshold: int = 8, mode: str = "incremental",
                 detect_failures: bool = False,
                 swim: SwimConfig | None = None,
                 straggler_factor: float = 3.0, seed: int = 0,
                 route_probe: int = 0, route_pairs: int = 64,
                 route_policy: str = "latency"):
        self.trace = trace
        self.policy = policy
        self.rng = np.random.default_rng(seed)
        self.swim = swim or SwimConfig()
        self.detect_failures = detect_failures
        self.straggler_factor = straggler_factor
        # routing probe: every route_probe-th recorded sample also greedy-
        # routes route_pairs seeded uniform pairs over the live overlay and
        # records the mean stretch (0 = off; see probe_stretch())
        self.route_probe = int(route_probe)
        self.route_pairs = int(route_pairs)
        self.route_policy = route_policy

        self.w_base = trace.latency()
        c = trace.capacity
        self.latency_factor = np.ones(c, np.float32)   # straggler inflation
        self.drift_scale = np.ones(c, np.float32)      # per-node drift factor
        alive = np.zeros(c, bool)
        alive[:trace.n0] = True

        w = self.w_base.copy()
        adj = adjacency_from_edges(
            w, policy.build(w, np.flatnonzero(alive), self.rng))
        self.inc = IncrementalDistances(
            w, adj, alive, rebuild_threshold=rebuild_threshold, mode=mode)
        self._seq = 0
        self._ran = False
        self._pending_failed: set[int] = set()
        # scheduled-but-not-yet-due events (SWIM leave confirmations) for the
        # live-ingest path; run() keeps its own single heap
        self._pending: List[Tuple[float, int, Event]] = []
        self.clock = 0.0          # time of the last processed event
        self.events_processed = 0

    @classmethod
    def restore(cls, trace: Trace, policy: OverlayPolicy, *,
                w: np.ndarray, adj: np.ndarray, alive: np.ndarray,
                latency_factor: np.ndarray, drift_scale: np.ndarray,
                clock: float = 0.0, events_processed: int = 0,
                rebuild_threshold: int = 8, mode: str = "incremental",
                detect_failures: bool = False, swim: SwimConfig | None = None,
                straggler_factor: float = 3.0, seed: int = 0) -> "ChurnEngine":
        """Rebuild an engine from externally-snapshotted state (crash
        recovery, ``repro.service``).

        The policy is adopted as-is — the caller must have restored its ring
        membership (``policy.rings``) to match ``adj`` — and the distance
        matrix is recomputed exactly from the restored adjacency, so a
        restored engine never inherits staleness from before the crash.
        Unconfirmed failures are NOT restored: a crash loses in-flight SWIM
        confirmations, and the victims simply fail again on re-detection
        (the honest outcome for a restarted observer).
        """
        eng = cls.__new__(cls)
        eng.trace = trace
        eng.policy = policy
        eng.rng = np.random.default_rng(seed)
        eng.swim = swim or SwimConfig()
        eng.detect_failures = detect_failures
        eng.straggler_factor = straggler_factor
        eng.w_base = trace.latency()
        c = trace.capacity
        assert np.asarray(w).shape == (c, c), (np.asarray(w).shape, c)
        eng.latency_factor = np.asarray(latency_factor, np.float32).copy()
        eng.drift_scale = np.asarray(drift_scale, np.float32).copy()
        eng.inc = IncrementalDistances(
            np.asarray(w, np.float32), np.asarray(adj, np.float32),
            np.asarray(alive, bool), rebuild_threshold=rebuild_threshold,
            mode=mode)
        eng._seq = 0
        eng._ran = False
        eng._pending_failed = set()
        eng._pending = []
        eng.clock = float(clock)
        eng.events_processed = int(events_processed)
        eng.route_probe = 0
        eng.route_pairs = 64
        eng.route_policy = "latency"
        return eng

    # -- conveniences -----------------------------------------------------

    @property
    def w(self) -> np.ndarray:
        return self.inc.w

    @property
    def alive(self) -> np.ndarray:
        return self.inc.alive

    def live_ids(self) -> np.ndarray:
        return self.inc.live_ids()

    @property
    def initial_overlay(self):
        """The :class:`~repro.overlay.Overlay` the policy built at t=0 over
        the initial live fleet (local node indexing), or ``None`` for
        policies that bypass the registry.  ``to_json()`` it next to the
        trace to snapshot exactly what a replay started from."""
        return getattr(self.policy, "initial_overlay", None)

    def probe_stretch(self, n_pairs: int | None = None,
                      policy: str | None = None) -> float:
        """Greedy-route a seeded uniform pair batch over the LIVE overlay
        and return the mean routing stretch over delivered pairs.

        The probe is a read-only measurement: exact live-block APSP (never
        the maintenance lower bound — a probe must not charge the router
        for the engine's bounded staleness), ``repro.routing``'s batched
        device router, pairs seeded by ``events_processed`` so replays
        probe identical traffic.  NaN when fewer than 2 nodes are live or
        nothing was delivered.  ``policy="ring"`` routes on the policy's
        first ring (live members only); the default latency policy needs
        no ring embedding.
        """
        import jax.numpy as jnp

        from repro import routing
        from repro.core.batcheval import batched_apsp

        n_pairs = self.route_pairs if n_pairs is None else int(n_pairs)
        policy = self.route_policy if policy is None else policy
        live = self.live_ids()
        m = len(live)
        if m < 2 or n_pairs < 1:
            return float("nan")
        adjl = np.asarray(self.inc.adj, np.float32)[np.ix_(live, live)]
        dist = np.asarray(batched_apsp(jnp.asarray(adjl)[None])[0])
        ring = None
        if policy == "ring":
            pos = {int(g): i for i, g in enumerate(live)}
            rings = getattr(self.policy, "rings", None) or [[]]
            ring = np.asarray([pos[g] for g in rings[0] if g in pos],
                              np.int64)
            if ring.size < 2:
                return float("nan")
        res = routing.route_pairs(
            adjl, dist, routing.sample_pairs(
                m, n_pairs, "uniform", seed=self.events_processed),
            policy=policy, ring=ring, hop_budget=m)
        ok = res.success & np.isfinite(res.stretch)
        return float(res.stretch[ok].mean()) if ok.any() else float("nan")

    def host_states(self) -> List[HostState]:
        """Per-slot membership view for the elastic layer (``plan_rescale``):
        EWMA latency stands in for heartbeat RTT via the straggler factor."""
        return [HostState(i, alive=bool(self.alive[i]),
                          ewma_ms=float(self.latency_factor[i]))
                for i in range(self.inc.capacity)]

    # -- event handlers ---------------------------------------------------

    def _push(self, heap, t: float, event: Event) -> None:
        self._seq += 1
        heapq.heappush(heap, (t, self._seq, event))

    def _confirmed_leave(self, u: int) -> None:
        if not self.alive[u]:
            return
        repairs = self.policy.detach(u, self.rng)
        self.inc.leave(u)
        self._pending_failed.discard(u)
        for a, b in repairs:
            if self.alive[a] and self.alive[b]:
                self.inc.add_edge(a, b)
        self.policy.maybe_adapt(self)

    def _handle_join(self, u: int) -> None:
        if self.alive[u]:
            return
        live = self.live_ids()
        edges = self.policy.attach(self.w, live, self.rng, u)
        nbrs = set()
        for a, b in edges:
            if u not in (a, b):
                raise ValueError(
                    f"attach() must return edges incident to the joiner "
                    f"{u}; got {(a, b)}")
            nbrs.add(b if a == u else a)
        nbrs.discard(u)
        self.inc.join(u, sorted(nbrs))
        self.policy.maybe_adapt(self)

    def _handle_fail(self, heap, t: float, u: int) -> None:
        if not self.alive[u] or u in self._pending_failed:
            return
        if not self.detect_failures:
            self._confirmed_leave(u)
            return
        # crashed-but-unconfirmed peers cannot probe or relay: the SWIM
        # detection runs on the live view minus the other pending victims
        live = self.live_ids()
        obs = live[~np.isin(live, list(self._pending_failed))]
        pos = int(np.searchsorted(obs, u))
        t_conf = confirmed_leave_time(
            self.inc.adj[np.ix_(obs, obs)], pos, t_fail=t, cfg=self.swim,
            seed=int(self.rng.integers(2**31)))
        self._pending_failed.add(u)
        self._push(heap, t_conf, Event(time=t_conf, kind="leave", node=u))

    def _scaled_w(self) -> np.ndarray:
        f = self.latency_factor * self.drift_scale
        w = self.w_base * f[:, None] * f[None, :]
        np.fill_diagonal(w, 0.0)
        return w.astype(np.float32)

    def _handle_drift(self, factor: float, region: int) -> None:
        """Latency drift via per-NODE factors: each hit node gets the
        absolute factor ``sqrt(factor)``, and a link scales by the product
        of its endpoints' factors.  Globally (``region < 0``) every link
        scales by exactly ``factor``; for a regional event only the hit
        FABRIC site's intra-site links get the full ``factor`` while
        cross-site links get ``sqrt(factor)`` (one congested endpoint).
        Factors don't compound across events (each drift event overwrites
        the hit nodes' values) and persist through straggler rescales."""
        site_of = np.arange(self.inc.capacity) % N_FABRIC_SITES
        hit = site_of == region if region >= 0 else np.ones(
            self.inc.capacity, bool)
        self.drift_scale = np.where(
            hit, np.float32(np.sqrt(factor)), self.drift_scale)
        self.inc.apply_latency_matrix(self._scaled_w())

    def _handle_straggler(self, u: int, factor: float) -> None:
        self.latency_factor[u] *= np.float32(factor)
        # demote BEFORE re-weighting: detection only needs latency_factor,
        # and demoted nodes' inflated rows then never enter the rebuild
        if self.policy.demotes_stragglers:
            live_hosts = [h for h in self.host_states() if h.alive]
            for sid in detect_stragglers(live_hosts, self.straggler_factor):
                if self.inc.n_live > 3:
                    self._confirmed_leave(sid)
        new_w = self._scaled_w()
        self.inc.w = new_w                  # bulk latency bookkeeping
        if self.alive[u]:
            # only u's incident edges changed weight: route them through
            # set_latency (relax on decrease, bounded staleness on increase)
            # instead of a full apply_latency_matrix rebuild
            for v in np.flatnonzero(is_edge(self.inc.adj[u])):
                self.inc.set_latency(u, int(v), float(new_w[u, v]))
        # demoted: only the tombstoned node's rows changed — nothing to do

    # -- event dispatch (shared by run() replay and live ingest) ----------

    def _dispatch(self, heap, t: float, e: Event) -> None:
        """Apply one due event; SWIM leave confirmations scheduled by a fail
        go into ``heap`` (run()'s replay heap, or ``self._pending`` for the
        live-ingest path)."""
        if e.kind == "join":
            self._handle_join(e.node)
        elif e.kind == "leave":
            self._confirmed_leave(e.node)
        elif e.kind == "fail":
            self._handle_fail(heap, t, e.node)
        elif e.kind == "latency_drift":
            self._handle_drift(e.factor, e.region)
        elif e.kind == "straggler":
            self._handle_straggler(e.node, e.factor)
        elif e.kind in ("cluster_split", "cluster_merge"):
            raise ValueError(
                f"{e.kind} events need a hierarchical engine "
                f"(repro.hier.HierChurnEngine); the flat ChurnEngine has "
                f"no cluster structure to reorganize")
        else:
            raise ValueError(f"unknown event kind {e.kind!r}")
        _EVENT_KIND[e.kind].inc()
        self.clock = max(self.clock, t)
        self.events_processed += 1

    # -- live ingest (repro.service) --------------------------------------

    def process(self, event: Event) -> int:
        """Apply one externally-arriving event NOW (the control-plane path:
        the event stream is open-ended, so there is no trace heap).

        Scheduled SWIM confirmations that came due strictly before
        ``event.time`` are drained first — identical ordering to run()'s
        single heap, where a trace event at the same timestamp pops before
        the later-pushed confirmation.  Returns the number of events applied
        (1 + drained confirmations).  Events must arrive in nondecreasing
        time order; a stale timestamp raises ``ValueError`` (the service
        maps it to HTTP 409).
        """
        if event.time < self.clock:
            raise ValueError(
                f"event at t={event.time} arrived after the clock advanced "
                f"to t={self.clock}; the control plane ingests events in "
                f"nondecreasing time order")
        n = self._drain_pending(event.time)
        self._dispatch(self._pending, event.time, event)
        return n + 1

    def flush(self, until: float = float("inf")) -> int:
        """Drain scheduled confirmations due at or before ``until`` (all of
        them by default).  Returns the number applied."""
        return self._drain_pending(until, strict=False)

    def _drain_pending(self, until: float, strict: bool = True) -> int:
        n = 0
        while self._pending and (self._pending[0][0] < until or
                                 (not strict and self._pending[0][0] <= until)):
            t, _, e = heapq.heappop(self._pending)
            self._dispatch(self._pending, t, e)
            n += 1
        return n

    @property
    def pending_confirmations(self) -> int:
        """Failures detected but not yet SWIM-confirmed (live-ingest path)."""
        return len(self._pending)

    # -- main loop --------------------------------------------------------

    def run(self, record: bool = True,
            sample_exact: bool = False) -> RunResult:
        """Replay the trace.  ``sample_exact`` refreshes pending deletions
        before every recorded sample so trajectories report true diameters
        rather than the maintenance lower bound — use it when comparing
        policies (the sampling rebuilds then also land in stats)."""
        if self._ran:
            raise RuntimeError(
                "ChurnEngine.run() consumed its trace against mutated state; "
                "construct a fresh engine to replay")
        self._ran = True
        heap: List[Tuple[float, int, Event]] = []
        for e in sorted(self.trace.events, key=lambda e: e.time):
            self._push(heap, e.time, e)
        samples: List[TrajectorySample] = []
        probe = (lambda: self.probe_stretch()) if self.route_probe else \
            (lambda: float("nan"))
        if record:
            samples.append(TrajectorySample(
                0.0, "init", self.inc.n_live,
                self.inc.diameter(exact=sample_exact), probe()))
        while heap:
            t, _, e = heapq.heappop(heap)
            self._dispatch(heap, t, e)
            if record:
                due = (self.route_probe
                       and self.events_processed % self.route_probe == 0)
                samples.append(TrajectorySample(
                    t, e.kind, self.inc.n_live,
                    self.inc.diameter(exact=sample_exact),
                    probe() if due else float("nan")))
        stats = dict(self.inc.stats)     # churn cost only: snapshot before
        final = self.inc.diameter(exact=True)  # ... the exactness refresh
        if isinstance(self.policy, DGROPolicy):
            stats["adaptations"] = self.policy.adaptations
        return RunResult(policy=self.policy.name, trace=self.trace.name,
                         samples=samples, final_diameter=final, stats=stats)
