"""repro.dynamics — churn-driven dynamic overlay engine.

Submodules:
  incremental — exact O(N^2) APSP repair under edge inserts / node joins,
                tombstone + threshold-rebuild under deletions, batched
                replica variants (one device call for B scenarios)
  scenarios   — replayable churn traces (JSON) + the scenario library
                (poisson churn, flash crowd, regional failure, diurnal
                drift, straggler storm)
  engine      — discrete-event replay of a trace against an overlay policy
                (DGRO / Chord / RAPID / Perigee) with SWIM failure
                confirmation and DGRO ring-selection self-repair
"""
from . import engine, incremental, scenarios  # noqa: F401
from .engine import (ChordPolicy, ChurnEngine, DGROPolicy, PerigeePolicy,  # noqa: F401
                     POLICIES, RapidPolicy, RunResult)
from .incremental import IncrementalDistances  # noqa: F401
from .scenarios import SCENARIOS, Event, Trace  # noqa: F401
