"""Incremental APSP / diameter maintenance under churn.

The static stack (``repro.core.diameter`` / ``repro.core.batcheval``)
recomputes all-pairs distances from scratch — O(N^3) per overlay.  Under
churn most events touch one node or one edge, and the distance matrix can
be repaired far cheaper:

* **edge insert** (and any latency *decrease*): the O(N^2) relaxation
  ``D' = min(D, D[:,u] + w_uv + D[v,:], D[:,v] + w_uv + D[u,:])`` is exact —
  with positive weights a new shortest path crosses the new edge at most
  once.
* **node join**: activate a tombstoned capacity slot, compute the new row
  by one min-plus vector step over the attach edges, then relax all pairs
  through the new node — O(N^2) total, exact for the same reason.
* **node leave** (and any latency *increase*): distances can only grow,
  which a relaxation cannot express.  The node is tombstoned (isolated in
  the adjacency, its distance row/col set to INF) and a bounded staleness
  counter is incremented; when accumulated deletions exceed
  ``rebuild_threshold`` a full batched rebuild runs through
  ``repro.core.batcheval``.  Between rebuilds the matrix is a *lower
  bound*: stale entries may still use paths through departed nodes, so
  ``D_stale <= D_true`` elementwise — ``refresh()`` restores exactness on
  demand.

All device math is jit'd with static shapes: the state is allocated at a
fixed ``capacity`` and dead slots are isolated singletons, which the
largest-connected-component diameter rule (paper §IV-C) ignores.  The
``*_batched`` variants advance B independent scenario replicas in one
device call (vmap over the batch axis — the same grid-over-batch shape as
``kernels.minplus.minplus_batched``; the relax itself is a broadcast
min-add, so no Pallas tile is needed) and ``relax_edge_stream_batched``
folds a whole (T, B) insert trace into a single ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import batcheval
from repro.core.diameter import (INF, is_edge, largest_cc_diameter,
                                 relax_edge_update)
from repro.obs import jit_span

__all__ = [
    "relax_edge",
    "relax_edges_batched",
    "relax_edge_stream_batched",
    "join_node",
    "join_nodes_batched",
    "tombstone",
    "tombstones_batched",
    "IncrementalDistances",
]


# ---------------------------------------------------------------------------
# jit'd pure updates (single replica + vmapped batch variants)
# ---------------------------------------------------------------------------

# Exact O(N^2) edge-insert repair.  The primitive itself lives in
# ``core.diameter`` so the DQN rollout engine (``core.rollout``) can reuse it
# as its in-scan reward update without a core -> dynamics dependency.
_relax_edge_impl = relax_edge_update


def _join_node_impl(dist: jnp.ndarray, row: jnp.ndarray,
                    u: jnp.ndarray) -> jnp.ndarray:
    """Activate node ``u`` (previously isolated) with one-hop weights ``row``
    (INF where no attach edge).  Exact: a shortest path visits u at most
    once, so u's row is one min-plus vector step over exact old distances
    and every other pair improves only via ``d(i,u) + d(u,j)``."""
    du = jnp.min(row[:, None] + dist, axis=0)      # d(u, j) over attach edges
    du = du.at[u].set(0.0)
    dist = dist.at[u, :].set(jnp.minimum(dist[u, :], du))
    dist = dist.at[:, u].set(jnp.minimum(dist[:, u], du))
    return jnp.minimum(dist, du[:, None] + du[None, :])


def _tombstone_impl(dist: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Isolate node ``u`` in the distance matrix (INF row/col, 0 self)."""
    c = dist.shape[0]
    iso = jnp.full((c,), INF, dist.dtype).at[u].set(0.0)
    return dist.at[u, :].set(iso).at[:, u].set(iso)


relax_edge = jax.jit(_relax_edge_impl)
join_node = jax.jit(_join_node_impl)
tombstone = jax.jit(_tombstone_impl)

# batched: (B, C, C) distance stacks advanced in one device call
relax_edges_batched = jax.jit(jax.vmap(_relax_edge_impl))
join_nodes_batched = jax.jit(jax.vmap(_join_node_impl))
tombstones_batched = jax.jit(jax.vmap(_tombstone_impl))


@jax.jit
def relax_edge_stream_batched(dists: jnp.ndarray, us: jnp.ndarray,
                              vs: jnp.ndarray, ws: jnp.ndarray) -> jnp.ndarray:
    """Apply a (T, B) stream of edge inserts to (B, C, C) replicas in ONE
    device call: ``lax.scan`` over time, vmap over the batch."""
    def step(d, uvw):
        u, v, w = uvw
        return jax.vmap(_relax_edge_impl)(d, u, v, w), None

    out, _ = jax.lax.scan(step, dists, (us, vs, ws))
    return out


_cc_diameter = jax.jit(largest_cc_diameter)


# ---------------------------------------------------------------------------
# host-side stateful wrapper
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IncrementalDistances:
    """Churn-maintained overlay adjacency + APSP distance matrix.

    ``mode="incremental"`` applies the O(N^2) repairs above and amortizes
    deletions through the staleness counter; ``mode="full"`` rebuilds from
    scratch (through ``batcheval``) after every mutation — the baseline the
    ``fig16_churn`` benchmark compares against.
    """

    w: np.ndarray                    # (C, C) latency matrix, mutable
    adj: np.ndarray                  # (C, C) overlay, INF non-edges, 0 diag
    alive: np.ndarray                # (C,) bool; dead slots are isolated
    rebuild_threshold: int = 8       # deletions tolerated before a rebuild
    mode: str = "incremental"        # "incremental" | "full"

    def __post_init__(self):
        assert self.mode in ("incremental", "full"), self.mode
        self.w = np.asarray(self.w, np.float32).copy()
        self.adj = np.asarray(self.adj, np.float32).copy()
        c = self.w.shape[0]
        assert self.adj.shape == (c, c), (self.adj.shape, c)
        if self.alive is None:
            self.alive = np.ones(c, bool)
        self.alive = np.asarray(self.alive, bool).copy()
        # isolate dead slots so they are singleton components
        dead = np.flatnonzero(~self.alive)
        self.adj[dead, :] = float(INF)
        self.adj[:, dead] = float(INF)
        self.adj[np.arange(c), np.arange(c)] = 0.0
        self.pending_deletions = 0
        self.stats: Dict[str, int] = {"relaxations": 0, "joins": 0,
                                      "leaves": 0, "rebuilds": 0,
                                      "events": 0}
        self._dist: Optional[jnp.ndarray] = None
        self.rebuild()
        self.stats["rebuilds"] = 0       # the initial APSP is not churn cost

    # -- queries ----------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.w.shape[0]

    @property
    def n_live(self) -> int:
        return int(self.alive.sum())

    def live_ids(self) -> np.ndarray:
        return np.flatnonzero(self.alive)

    @property
    def distances(self) -> np.ndarray:
        """Current (C, C) distance matrix.  Exact when no deletions are
        pending; otherwise an elementwise lower bound on the live truth."""
        return np.asarray(self._dist)

    def live_distances(self) -> np.ndarray:
        live = self.live_ids()
        return self.distances[np.ix_(live, live)]

    def diameter(self, exact: bool = False) -> float:
        """Largest-CC diameter of the maintained overlay.  ``exact`` forces
        a rebuild first if deletions are pending."""
        if exact:
            self.refresh()
        return float(_cc_diameter(self._dist))

    # -- mutations --------------------------------------------------------

    def add_edge(self, u: int, v: int, weight: float | None = None) -> None:
        """Insert (or improve) the undirected edge (u, v)."""
        assert self.alive[u] and self.alive[v], (u, v)
        wuv = np.float32(self.w[u, v] if weight is None else weight)
        self.stats["events"] += 1
        if u == v or wuv >= self.adj[u, v]:
            return                        # no improvement: relax is a no-op
        self.adj[u, v] = self.adj[v, u] = wuv
        if self.mode == "full":
            self.rebuild()
            return
        with jit_span("incremental.relax", key=self.capacity):
            self._dist = relax_edge(self._dist, u, v, wuv)
        self.stats["relaxations"] += 1

    def remove_edge(self, u: int, v: int) -> None:
        """Delete the undirected edge (u, v); distances may go stale."""
        self.stats["events"] += 1
        if not is_edge(np.float32(self.adj[u, v])):
            return
        self.adj[u, v] = self.adj[v, u] = float(INF)
        self._note_deletion()

    def join(self, u: int, neighbours: Sequence[int],
             weights: Sequence[float] | None = None) -> None:
        """Activate slot ``u`` and attach it to live ``neighbours``."""
        assert not self.alive[u], u
        nbrs = np.asarray(list(neighbours), np.intp)
        assert self.alive[nbrs].all(), "attach edges must target live nodes"
        ws = (self.w[u, nbrs] if weights is None
              else np.asarray(list(weights), np.float32))
        self.alive[u] = True
        self.adj[u, nbrs] = np.minimum(self.adj[u, nbrs], ws)
        self.adj[nbrs, u] = self.adj[u, nbrs]
        self.stats["events"] += 1
        self.stats["joins"] += 1
        if self.mode == "full":
            self.rebuild()
            return
        row = np.full(self.capacity, float(INF), np.float32)
        row[nbrs] = self.adj[u, nbrs]
        with jit_span("incremental.join", key=self.capacity):
            self._dist = join_node(self._dist, jnp.asarray(row), u)
        self.stats["relaxations"] += 1

    def leave(self, u: int) -> None:
        """Tombstone node ``u``: isolate it and count the deletion."""
        if not self.alive[u]:
            return
        self.alive[u] = False
        self.adj[u, :] = float(INF)
        self.adj[:, u] = float(INF)
        self.adj[u, u] = 0.0
        self.stats["events"] += 1
        self.stats["leaves"] += 1
        if self.mode != "full":        # full mode rebuilds anyway below
            with jit_span("incremental.tombstone", key=self.capacity):
                self._dist = tombstone(self._dist, u)
        self._note_deletion()

    def set_latency(self, u: int, v: int, ms: float) -> None:
        """Point latency change; decreases relax, increases count as stale.

        The increase/decrease split compares against the CURRENT edge
        weight (``adj``, which ``add_edge`` may have set below ``w``) —
        comparing against ``w`` could misread an edge-weight increase as a
        decrease and break the lower-bound contract."""
        ms = float(ms)
        self.w[u, v] = self.w[v, u] = ms
        if not is_edge(np.float32(self.adj[u, v])):
            return
        old_edge = float(self.adj[u, v])
        self.stats["events"] += 1
        self.adj[u, v] = self.adj[v, u] = np.float32(ms)
        if self.mode == "full":
            self.rebuild()
        elif ms < old_edge:
            with jit_span("incremental.relax", key=self.capacity):
                self._dist = relax_edge(self._dist, u, v, np.float32(ms))
            self.stats["relaxations"] += 1
        elif ms > old_edge:
            self._note_deletion()

    def apply_latency_matrix(self, new_w: np.ndarray) -> None:
        """Bulk latency change (e.g. diurnal drift): re-weight every existing
        edge and rebuild — a matrix-wide shift has no cheap exact repair."""
        new_w = np.asarray(new_w, np.float32)
        assert new_w.shape == self.w.shape
        self.w = new_w.copy()
        mask = is_edge(self.adj)
        self.adj = np.where(mask, new_w, self.adj).astype(np.float32)
        self.stats["events"] += 1
        self.rebuild()

    # -- rebuild machinery ------------------------------------------------

    def _note_deletion(self) -> None:
        self.pending_deletions += 1
        if self.mode == "full" or self.pending_deletions >= self.rebuild_threshold:
            self.rebuild()

    def rebuild(self) -> None:
        """Full from-scratch APSP over the live adjacency via the
        instrumented ``batcheval`` engine; resets the staleness counter.

        Precision is PINNED to float32 regardless of ambient
        ``eval_options`` / ``REPRO_APSP_*`` overrides: the incremental
        relaxations layered on top of this matrix assume an exact base
        (every served distance is "exact or lower bound"), so a quantized
        rebuild would silently poison that contract.
        """
        with jit_span("incremental.rebuild", key=self.capacity):
            self._dist = jnp.asarray(
                batcheval.apsp_matrices(self.adj[None], dtype="float32")[0])
        self.pending_deletions = 0
        self.stats["rebuilds"] += 1

    def refresh(self) -> None:
        """Restore exactness if deletions are pending."""
        if self.pending_deletions:
            self.rebuild()
