"""Mamba1 (selective scan) and Mamba2 (SSD) blocks — TPU-native formulations.

Hardware adaptation (DESIGN.md §5): the CUDA selective-scan kernel is a
fused recurrent kernel; on TPU we use the standard JAX re-formulations:

* mamba1: chunked first-order recurrence.  Within a chunk the recurrence
  h_t = a_t * h_{t-1} + b_t is evaluated with ``lax.associative_scan``
  (log-depth, VPU-friendly); chunks are chained with ``lax.scan`` carrying
  the (B, d_inner, N) state so the materialized temporary stays
  (B, chunk, d_inner, N) — bounded VMEM/HBM footprint regardless of S.
* mamba2: SSD block-decomposition (Dao & Gu 2024): intra-chunk quadratic
  "attention form" (MXU matmuls over (chunk x chunk) per head) + inter-chunk
  state passing — no (B, S, d_inner, N) tensor ever exists.

Both blocks also expose a single-token ``*_step`` used by decode; its state
is the pair (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import dense_init, rms_norm

CHUNK = 128


# ---------------------------------------------------------------------------
# causal depthwise conv (shared by both)
# ---------------------------------------------------------------------------

def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, C); w: (K, C); state: (B, K-1, C) trailing inputs or None.
    Returns (y (B,S,C), new_state (B, K-1, C))."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)                     # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    y = y + b
    new_state = xp[:, -(k - 1):, :] if k > 1 else state
    return y, new_state


def _conv_step(x1: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
               state: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token conv.  x1: (B, C); state: (B, K-1, C)."""
    k = w.shape[0]
    xp = jnp.concatenate([state, x1[:, None, :]], axis=1)        # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", xp, w) + b
    return y, xp[:, 1:, :]


# ---------------------------------------------------------------------------
# mamba1
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg: ArchConfig, dtype) -> Dict[str, jnp.ndarray]:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 9)
    a = jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))
    return {
        "wx": dense_init(ks[0], d, di, dtype),       # in_proj (x branch)
        "wz": dense_init(ks[1], d, di, dtype),       # in_proj (gate branch)
        "conv_w": dense_init(ks[2], cfg.ssm_conv, di, dtype) * 0.5,
        "conv_b": jnp.zeros((di,), dtype),
        "w_dt": dense_init(ks[3], di, r, dtype),     # x_proj -> dt rank
        "w_b": dense_init(ks[4], di, n, dtype),      # x_proj -> B
        "w_c": dense_init(ks[5], di, n, dtype),      # x_proj -> C
        "dt_w": dense_init(ks[6], r, di, dtype),
        "dt_b": jnp.full((di,), -4.6, dtype),        # softplus^-1(0.01)
        "A_log": jnp.log(a).astype(jnp.float32),     # kept fp32 (exp-sensitive)
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[7], di, d, dtype),
    }


def _ssm_scan_chunked(dt: jnp.ndarray, xc: jnp.ndarray, bmat: jnp.ndarray,
                      cmat: jnp.ndarray, a: jnp.ndarray, h0: jnp.ndarray,
                      chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Selective-scan recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t,
    y_t = C_t . h_t — evaluated chunk-by-chunk so the (B, chunk, d, N) decay/
    drive temporaries (NOT (B, S, d, N)) are the only working set.

    dt, xc: (B, S, d);  bmat, cmat: (B, S, N);  a: (d, N);  h0: (B, d, N).
    Returns (y (B, S, d) fp32, h_last).
    """
    bsz, s, d = dt.shape
    n = a.shape[1]
    nc = s // chunk

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    def combine(x, y):
        (a1, b1), (a2, b2) = x, y
        return a1 * a2, a2 * b1 + b2

    def chunk_body(h, inp):
        dtq, xq, bq, cq = inp                          # (B, chunk, ...)
        decay = jnp.exp(dtq[..., None] * a)            # (B, chunk, d, N)
        drive = (dtq * xq)[..., None] * bq[:, :, None, :]
        a_cum, b_cum = jax.lax.associative_scan(combine, (decay, drive), axis=1)
        h_all = a_cum * h[:, None] + b_cum
        y = jnp.einsum("bsdn,bsn->bsd", h_all, cq)
        return h_all[:, -1], y

    h_last, y_c = jax.lax.scan(
        chunk_body, h0, (to_chunks(dt), to_chunks(xc), to_chunks(bmat),
                         to_chunks(cmat)))
    return y_c.swapaxes(0, 1).reshape(bsz, s, d), h_last


def mamba1_apply(p, x: jnp.ndarray, cfg: ArchConfig,
                 state: Tuple[jnp.ndarray, jnp.ndarray] | None = None,
                 ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Sequence-mode mamba1.  x: (B, S, d).  state: (conv_state, h) or None.
    Returns (y (B,S,d), new_state)."""
    bsz, s, _ = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    conv_state, h0 = state if state is not None else (None, None)

    xin = x @ p["wx"]                                  # (B, S, di)
    z = x @ p["wz"]
    xc, conv_state = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus((xc @ p["w_dt"]) @ p["dt_w"]
                         + p["dt_b"].astype(jnp.float32))          # (B,S,di)
    bmat = (xc @ p["w_b"]).astype(jnp.float32)                     # (B,S,N)
    cmat = (xc @ p["w_c"]).astype(jnp.float32)                     # (B,S,N)
    a = -jnp.exp(p["A_log"])                                       # (di,N)

    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)
    chunk = min(CHUNK, s)
    assert s % chunk == 0, (s, chunk)
    y, h_last = _ssm_scan_chunked(dt, xc.astype(jnp.float32), bmat, cmat,
                                  a, h0, chunk)
    y = y + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out_proj"], (conv_state, h_last)


def mamba1_step(p, x1: jnp.ndarray, cfg: ArchConfig,
                state: Tuple[jnp.ndarray, jnp.ndarray],
                ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-token decode.  x1: (B, d); state=(conv (B,K-1,di), h (B,di,N))."""
    conv_state, h = state
    xin = x1 @ p["wx"]
    z = x1 @ p["wz"]
    xc, conv_state = _conv_step(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus((xc @ p["w_dt"]) @ p["dt_w"]
                         + p["dt_b"].astype(jnp.float32))          # (B,di)
    bmat = (xc @ p["w_b"]).astype(jnp.float32)                     # (B,N)
    cmat = (xc @ p["w_c"]).astype(jnp.float32)
    a = -jnp.exp(p["A_log"])
    h = jnp.exp(dt[..., None] * a) * h \
        + (dt * xc.astype(jnp.float32))[..., None] * bmat[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cmat) + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x1.dtype)
    return y @ p["out_proj"], (conv_state, h)


def mamba1_state_shape(cfg: ArchConfig, batch: int):
    return ((batch, cfg.ssm_conv - 1, cfg.d_inner),
            (batch, cfg.d_inner, cfg.ssm_state))


# ---------------------------------------------------------------------------
# mamba2 (SSD)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ArchConfig, dtype) -> Dict[str, jnp.ndarray]:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 8)
    conv_dim = di + 2 * n
    return {
        "wz": dense_init(ks[0], d, di, dtype),
        "wx": dense_init(ks[1], d, di, dtype),
        "wb": dense_init(ks[2], d, n, dtype),
        "wc": dense_init(ks[3], d, n, dtype),
        "wdt": dense_init(ks[4], d, nh, dtype),
        "conv_w": dense_init(ks[5], cfg.ssm_conv, conv_dim, dtype) * 0.5,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_b": jnp.full((nh,), -4.6, jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),        # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[6], di, d, dtype),
    }


def mamba2_apply(p, x: jnp.ndarray, cfg: ArchConfig,
                 state: Tuple[jnp.ndarray, jnp.ndarray] | None = None,
                 ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Sequence-mode SSD.  x: (B,S,d).  state=(conv_state, h (B,nh,hd,N))."""
    bsz, s, _ = x.shape
    di, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // hd
    conv_state, h0 = state if state is not None else (None, None)

    z = x @ p["wz"]                                       # (B,S,di)
    xbc = jnp.concatenate([x @ p["wx"], x @ p["wb"], x @ p["wc"]], axis=-1)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di]
    bmat = xbc[..., di:di + n].astype(jnp.float32)        # (B,S,N)
    cmat = xbc[..., di + n:].astype(jnp.float32)          # (B,S,N)
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_b"])  # (B,S,nh)
    a = -jnp.exp(p["A_log"])                              # (nh,)

    xh = xs.reshape(bsz, s, nh, hd).astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((bsz, nh, hd, n), jnp.float32)

    chunk = min(CHUNK, s)
    assert s % chunk == 0
    nc = s // chunk
    xh_c = xh.reshape(bsz, nc, chunk, nh, hd).swapaxes(0, 1)
    b_c = bmat.reshape(bsz, nc, chunk, n).swapaxes(0, 1)
    c_c = cmat.reshape(bsz, nc, chunk, n).swapaxes(0, 1)
    dt_c = dt.reshape(bsz, nc, chunk, nh).swapaxes(0, 1)

    def chunk_body(h, inp):
        xq, bq, cq, dtq = inp                              # per-chunk slices
        la = dtq * a                                       # (B,Q,nh) log-decay
        cum = jnp.cumsum(la, axis=1)                       # (B,Q,nh)
        # intra-chunk quadratic form: M[q,k] = C_q.B_k * exp(cum_q - cum_k), q>=k
        qk = jnp.einsum("bqn,bkn->bqk", cq, bq)            # (B,Q,Q)
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]    # (B,Q,K,nh)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        m = jnp.where(causal[None, :, :, None], jnp.exp(ldiff), 0.0)
        m = m * qk[:, :, :, None]                          # (B,Q,K,nh)
        xdt = xq * dtq[..., None]                          # (B,K,nh,hd)
        y_intra = jnp.einsum("bqkh,bkhd->bqhd", m, xdt)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqn,bhdn->bqhd", cq, h) * jnp.exp(cum)[..., None]
        # new state
        wgt = jnp.exp(cum[:, -1:, :] - cum)                # (B,Q,nh)
        h_new = jnp.exp(cum[:, -1])[:, :, None, None] * h + jnp.einsum(
            "bkhd,bkn,bkh->bhdn", xdt, bq, wgt)
        return h_new, y_intra + y_inter

    h_last, y_c = jax.lax.scan(chunk_body, h0, (xh_c, b_c, c_c, dt_c))
    y = y_c.swapaxes(0, 1).reshape(bsz, s, nh, hd)
    y = y + p["D"][:, None] * xh
    y = y.reshape(bsz, s, di)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], (conv_state, h_last)


def mamba2_step(p, x1: jnp.ndarray, cfg: ArchConfig,
                state: Tuple[jnp.ndarray, jnp.ndarray],
                ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-token decode.  x1: (B, d)."""
    conv_state, h = state
    di, n, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // hd
    z = x1 @ p["wz"]
    xbc = jnp.concatenate([x1 @ p["wx"], x1 @ p["wb"], x1 @ p["wc"]], axis=-1)
    xbc, conv_state = _conv_step(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(-1, nh, hd).astype(jnp.float32)
    bmat = xbc[..., di:di + n].astype(jnp.float32)
    cmat = xbc[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus((x1 @ p["wdt"]).astype(jnp.float32) + p["dt_b"])
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)                                # (B,nh)
    h = decay[:, :, None, None] * h + jnp.einsum(
        "bhd,bn,bh->bhdn", xs, bmat, dt)
    y = jnp.einsum("bhdn,bn->bhd", h, cmat) + p["D"][:, None] * xs
    y = y.reshape(-1, di)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(x1.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], (conv_state, h)


def mamba2_state_shape(cfg: ArchConfig, batch: int):
    nh = cfg.d_inner // cfg.ssm_head_dim
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return ((batch, cfg.ssm_conv - 1, conv_dim),
            (batch, nh, cfg.ssm_head_dim, cfg.ssm_state))
