"""Model assembly: one generic decoder stack covering all 10 assigned
architectures, parameterized by ``ArchConfig``.

Layer weights are stacked on a leading block axis and iterated with
``lax.scan`` (compile-time O(1) in depth).  Architectures with a periodic
layer PATTERN (gemma3's 5 local + 1 global, llama4's dense/MoE interleave,
zamba2's every-6th shared-attention) scan over pattern BLOCKS with the
pattern unrolled inside the body, so e.g. gemma3's local layers get
window-sized KV caches while global layers get full-length ones —
the difference that makes long_500k fit (DESIGN.md §6).

Modes:
  * train:   full-sequence causal; returns (logits, aux_loss)
  * prefill: full-sequence causal + builds KV/SSM caches; returns
             (last-position logits, caches, aux)
  * decode:  single token against caches; returns (logits, new_caches)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import mamba as M
from .layers import attention_apply, init_attention, init_mlp, mlp_apply, rms_norm
from .moe import init_moe, moe_apply, router_aux_loss
from .sharding import shard

PyTree = Any
GLOBAL_WINDOW = None  # window=None => full attention


# ---------------------------------------------------------------------------
# pattern machinery
# ---------------------------------------------------------------------------

def pattern_period(cfg: ArchConfig) -> int:
    if cfg.family == "hybrid":
        return max(cfg.shared_attn_period, 1)
    if cfg.family == "moe":
        return max(cfg.moe_period, 1)
    if cfg.sliding_window is not None and cfg.global_period > 0:
        return cfg.global_period
    return 1


def layer_kind(cfg: ArchConfig, j: int) -> Dict[str, Any]:
    """Kind of the layer at pattern position j (absolute index i ≡ j mod P)."""
    if cfg.family == "ssm":
        return {"type": cfg.ssm_kind}
    if cfg.family == "hybrid":
        return {"type": cfg.ssm_kind, "shared_attn": cfg.is_attn_block(j)}
    kind = {"type": "moe" if cfg.is_moe_layer(j) else "dense"}
    if cfg.sliding_window is not None:
        kind["window"] = None if cfg.is_global_layer(j) else cfg.sliding_window
    else:
        kind["window"] = None
    return kind


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ArchConfig, kind: Dict[str, Any], key, dtype) -> PyTree:
    ks = jax.random.split(key, 6)
    t = kind["type"]
    if t in ("mamba1", "mamba2"):
        init = M.init_mamba1 if t == "mamba1" else M.init_mamba2
        return {"ln": jnp.zeros((cfg.d_model,), dtype),
                "mamba": init(ks[0], cfg, dtype)}
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "attn": init_attention(ks[0], cfg, dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype)}
    if t == "moe":
        p["moe"] = init_moe(ks[1], cfg, dtype)
        if cfg.shared_expert:
            p["shared_mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                       cfg.mlp_kind, dtype)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> PyTree:
    per = pattern_period(cfg)
    n_blocks, n_rem = divmod(cfg.n_layers, per)
    keys = jax.random.split(key, 8)
    from .layers import dense_init

    params: Dict[str, PyTree] = {
        "embed": dense_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
    if cfg.frontend == "vision":
        params["vision_proj"] = dense_init(keys[2], cfg.d_model, cfg.d_model, dtype)

    blocks = {}
    if n_blocks > 0:
        for j in range(per):
            kind = layer_kind(cfg, j)
            bkeys = jax.random.split(jax.random.fold_in(keys[3], j), n_blocks)
            blocks[f"pos{j}"] = jax.vmap(
                lambda k: _init_layer(cfg, kind, k, dtype))(bkeys)
    params["blocks"] = blocks
    params["rem"] = {
        f"rem{j}": _init_layer(cfg, layer_kind(cfg, j),
                               jax.random.fold_in(keys[4], j), dtype)
        for j in range(n_rem)
    }
    if cfg.family == "hybrid":
        params["shared_attn"] = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": init_attention(keys[5], cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": init_mlp(keys[6], cfg.d_model, cfg.d_ff, cfg.mlp_kind, dtype),
        }
    return params


def param_count(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ArchConfig, kind: Dict[str, Any], batch: int,
                 max_len: int, dtype) -> PyTree:
    t = kind["type"]
    cache: Dict[str, jnp.ndarray] = {}
    # fp8 applies to the big K/V buffers only; SSM conv state is tiny and
    # participates directly in bf16 math
    state_dtype = jnp.bfloat16 if dtype == jnp.float8_e4m3fn else dtype
    if t in ("mamba1", "mamba2"):
        shp = (M.mamba1_state_shape if t == "mamba1"
               else M.mamba2_state_shape)(cfg, batch)
        cache["conv"] = jnp.zeros(shp[0], state_dtype)
        cache["h"] = jnp.zeros(shp[1], jnp.float32)
        if kind.get("shared_attn"):
            cache["k"] = jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.hd), dtype)
            cache["v"] = jnp.zeros((batch, cfg.n_kv_heads, max_len, cfg.hd), dtype)
        return cache
    s = max_len if kind.get("window") is None else min(kind["window"], max_len)
    cache["k"] = jnp.zeros((batch, cfg.n_kv_heads, s, cfg.hd), dtype)
    cache["v"] = jnp.zeros((batch, cfg.n_kv_heads, s, cfg.hd), dtype)
    return cache


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.float32) -> PyTree:
    per = pattern_period(cfg)
    n_blocks, n_rem = divmod(cfg.n_layers, per)
    caches: Dict[str, PyTree] = {"blocks": {}, "rem": {}}
    for j in range(per):
        if n_blocks == 0:
            break
        one = _layer_cache(cfg, layer_kind(cfg, j), batch, max_len, dtype)
        caches["blocks"][f"pos{j}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_blocks,) + x.shape), one)
    for j in range(n_rem):
        caches["rem"][f"rem{j}"] = _layer_cache(
            cfg, layer_kind(cfg, j), batch, max_len, dtype)
    return caches


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------

def _write_prefill_cache(cache_kv, k_new, v_new):
    """Fill a KV cache from prefill K/V (B, Hkv, S, hd); for window-sized
    caches the last S_c positions land at their rolling slots."""
    s_c = cache_kv["k"].shape[2]
    s = k_new.shape[2]
    if s >= s_c:
        tail_pos = jnp.arange(s - s_c, s)
        slots = tail_pos % s_c
        k = cache_kv["k"].at[:, :, slots, :].set(
            k_new[:, :, s - s_c:, :].astype(cache_kv["k"].dtype))
        v = cache_kv["v"].at[:, :, slots, :].set(
            v_new[:, :, s - s_c:, :].astype(cache_kv["v"].dtype))
    else:
        k = jax.lax.dynamic_update_slice(
            cache_kv["k"], k_new.astype(cache_kv["k"].dtype), (0, 0, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache_kv["v"], v_new.astype(cache_kv["v"].dtype), (0, 0, 0, 0))
    return {"k": k, "v": v}


def _attn_mlp_layer(cfg: ArchConfig, kind, lp, x, *, positions, cache,
                    cache_pos, mesh, data_axes, mode):
    window = kind.get("window")
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if mode == "decode":
        attn_out, kv = attention_apply(
            lp["attn"], h, cfg, positions=positions, window=window,
            cache=(cache["k"], cache["v"]), cache_pos=cache_pos)
        new_kv = {"k": kv[0], "v": kv[1]}
    else:
        attn_out, _ = attention_apply(lp["attn"], h, cfg,
                                      positions=positions, window=window)
        new_kv = None
        if mode == "prefill":
            # recompute K/V once more is wasteful; attention_apply returns
            # them only in decode, so build them here from h
            new_kv = _prefill_kv(cfg, lp["attn"], h, positions, cache)
    x = x + attn_out
    x = shard(x, "batch", "seq", "embed")
    h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if kind["type"] == "moe":
        moe_out, probs = moe_apply(lp["moe"], h2, cfg, mesh=mesh,
                                   data_axes=data_axes)
        if cfg.shared_expert:
            moe_out = moe_out + mlp_apply(lp["shared_mlp"], h2, cfg.mlp_kind)
        x = x + moe_out
        aux = router_aux_loss(probs)
    else:
        x = x + mlp_apply(lp["mlp"], h2, cfg.mlp_kind)
    x = shard(x, "batch", "seq", "embed")
    new_cache = None
    if new_kv is not None:
        new_cache = dict(cache)
        new_cache.update(new_kv)
    return x, new_cache, aux


def _prefill_kv(cfg: ArchConfig, ap, h, positions, cache):
    """K/V for the prefill cache (rope'd, matching decode-time layout)."""
    from .layers import rope
    b, s, _ = h.shape
    k = h @ ap["wk"]
    v = h @ ap["wv"]
    if cfg.qkv_bias:
        k, v = k + ap["bk"], v + ap["bv"]
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    if cfg.qk_norm:
        k = rms_norm(k, ap["k_norm"], cfg.norm_eps)
    k = rope(k, positions, cfg.rope_theta)
    return _write_prefill_cache(cache, k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3))


def _ssm_layer(cfg: ArchConfig, kind, lp, x, *, cache, mode, shared_params,
               positions, cache_pos, mesh, data_axes):
    t = kind["type"]
    h = rms_norm(x, lp["ln"], cfg.norm_eps)
    state = (cache["conv"], cache["h"]) if cache is not None else None
    new_cache = dict(cache) if cache is not None else None
    if mode == "decode":
        step = M.mamba1_step if t == "mamba1" else M.mamba2_step
        y, (conv, hh) = step(lp["mamba"], h[:, 0, :], cfg, state)
        y = y[:, None, :]
        new_cache["conv"], new_cache["h"] = conv, hh
    else:
        apply = M.mamba1_apply if t == "mamba1" else M.mamba2_apply
        y, (conv, hh) = apply(lp["mamba"], h, cfg, state)
        if mode == "prefill":
            new_cache["conv"], new_cache["h"] = conv.astype(
                new_cache["conv"].dtype), hh
    x = x + y
    x = shard(x, "batch", "seq", "embed")
    aux = jnp.float32(0.0)
    if kind.get("shared_attn"):
        sp = shared_params
        hh2 = rms_norm(x, sp["ln1"], cfg.norm_eps)
        if mode == "decode":
            attn_out, new_kv = attention_apply(
                sp["attn"], hh2, cfg, positions=positions, window=None,
                cache=(cache["k"], cache["v"]), cache_pos=cache_pos)
            new_cache["k"], new_cache["v"] = new_kv[0], new_kv[1]
        else:
            attn_out, _ = attention_apply(sp["attn"], hh2, cfg,
                                          positions=positions, window=None)
            if mode == "prefill":
                kv = _prefill_kv(cfg, sp["attn"], hh2, positions,
                                 {"k": cache["k"], "v": cache["v"]})
                new_cache["k"], new_cache["v"] = kv["k"], kv["v"]
        x = x + attn_out
        x = x + mlp_apply(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps),
                          cfg.mlp_kind)
        x = shard(x, "batch", "seq", "embed")
    return x, new_cache, aux


def _apply_layer(cfg, kind, lp, x, **kw):
    if kind["type"] in ("mamba1", "mamba2"):
        return _ssm_layer(cfg, kind, lp, x, cache=kw.get("cache"),
                          mode=kw["mode"], shared_params=kw.get("shared_params"),
                          positions=kw["positions"], cache_pos=kw.get("cache_pos"),
                          mesh=kw.get("mesh"), data_axes=kw.get("data_axes"))
    return _attn_mlp_layer(cfg, kind, lp, x, positions=kw["positions"],
                           cache=kw.get("cache"), cache_pos=kw.get("cache_pos"),
                           mesh=kw.get("mesh"), data_axes=kw.get("data_axes"),
                           mode=kw["mode"])


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(
    cfg: ArchConfig,
    params: PyTree,
    tokens: jnp.ndarray,                    # (B, S) int32 (S=1 for decode)
    *,
    mode: str = "train",                    # train | prefill | decode
    caches: Optional[PyTree] = None,
    pos: Optional[jnp.ndarray] = None,      # decode: scalar position
    vision_embeds: Optional[jnp.ndarray] = None,   # (B, Np, d) stub frontend
    mesh=None,
    data_axes: Tuple[str, ...] = ("data",),
    remat: bool = False,
):
    per = pattern_period(cfg)
    kinds = [layer_kind(cfg, j) for j in range(per)]
    n_blocks, n_rem = divmod(cfg.n_layers, per)

    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.qk_norm:                          # gemma3 scales embeddings
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.frontend == "vision" and vision_embeds is not None:
        vis = vision_embeds @ params["vision_proj"]
        x = jnp.concatenate([vis.astype(x.dtype), x], axis=1)  # early fusion
    x = shard(x, "batch", "seq", "embed")

    b, s, _ = x.shape
    if mode == "decode":
        positions = pos[None].astype(jnp.int32)
        cache_pos = pos
    else:
        positions = jnp.arange(s, dtype=jnp.int32)
        cache_pos = None

    shared_params = params.get("shared_attn")
    kw = dict(mode=mode, positions=positions, cache_pos=cache_pos, mesh=mesh,
              data_axes=data_axes, shared_params=shared_params)

    def block_body(carry, xs_):
        x_, aux_ = carry
        bp, bc = xs_
        new_bc = {}
        for j in range(per):
            cache_j = bc[f"pos{j}"] if bc is not None else None
            x_, nc, aj = _apply_layer(cfg, kinds[j], bp[f"pos{j}"], x_,
                                      cache=cache_j, **kw)
            new_bc[f"pos{j}"] = nc
            aux_ = aux_ + aj
        return (x_, aux_), new_bc

    body = block_body
    if remat:
        body = jax.checkpoint(block_body, prevent_cse=False,
                              policy=jax.checkpoint_policies.nothing_saveable)

    aux = jnp.float32(0.0)
    if n_blocks > 0:
        if caches is not None:
            (x, aux), new_block_caches = jax.lax.scan(
                body, (x, aux), (params["blocks"], caches["blocks"]))
        else:
            (x, aux), _ = jax.lax.scan(
                lambda c, bp: (body(c, (bp, None))[0], None),
                (x, aux), params["blocks"])
            new_block_caches = None
    else:
        new_block_caches = caches["blocks"] if caches is not None else None

    new_rem = {}
    for j in range(n_rem):
        cache_j = caches["rem"][f"rem{j}"] if caches is not None else None
        x, nc, aj = _apply_layer(cfg, kinds[j], params["rem"][f"rem{j}"], x,
                                 cache=cache_j, **kw)
        new_rem[f"rem{j}"] = nc
        aux = aux + aj

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    # gather the (small) residual over the model axis BEFORE the vocab-sharded
    # head matmul: otherwise the partitioner resolves the model-axis conflict
    # (x sharded on d, logits sharded on V) by all-gathering full-vocab
    # dlogits in the embed-grad — tens of GB/device at 262k vocab.
    x = shard(x, "batch", "seq", None)

    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    if mode == "train_hidden":
        # memory-efficient CE path: caller contracts x @ head in chunks
        return x, head, aux
    if mode == "train":
        logits = x @ head
        logits = shard(logits, "batch", "seq", "vocab")
        return logits, aux
    # prefill/decode: only the last position's logits are needed
    logits = x[:, -1, :] @ head
    logits = shard(logits, "batch", "vocab")
    new_caches = None
    if caches is not None:
        new_caches = {"blocks": new_block_caches, "rem": new_rem}
    if mode == "prefill":
        return logits, new_caches, aux
    return logits, new_caches
