"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch,
expert parallelism over the ``model`` mesh axis.

TPU-native design (DESIGN.md §5): activations are replicated over the model
axis between layers (Megatron-style), so expert parallelism needs NO
all-to-all — each model shard gathers the tokens routed to ITS experts
(identical routing computed on every shard), runs the dense per-expert
GEMMs at static capacity C = ceil(T * top_k * cf / E), scatters weighted
outputs back, and one all-reduce (psum over "model") combines shards.  The
collective volume equals dense-TP's MLP all-reduce — measured in §Roofline.

Two entry points with identical math (tested against each other):
  * ``moe_apply(..., mesh=None)``  — single-device path (smoke tests).
  * ``moe_apply(..., mesh=mesh)``  — shard_map EP path (dry-run/training).

Tokens over capacity are dropped (standard Switch/GShard semantics; the
router's load-balancing auxiliary loss keeps drop rates low).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.configs.base import ArchConfig
from .layers import dense_init

__all__ = ["init_moe", "moe_apply", "router_aux_loss"]


def init_moe(key, cfg: ArchConfig, dtype) -> Dict[str, jnp.ndarray]:
    d, fe, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = (2.0 / (d + fe)) ** 0.5
    return {
        "router": dense_init(ks[0], d, e, jnp.float32),   # fp32 (routing-sensitive)
        "w_gate": (jax.random.normal(ks[1], (e, d, fe), jnp.float32) * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, fe), jnp.float32) * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, fe, d), jnp.float32) * scale).astype(dtype),
    }


def _capacity(t: int, cfg: ArchConfig) -> int:
    c = int(t * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _moe_local(router, w_gate, w_up, w_down, xf, *, cfg: ArchConfig,
               e_local: int, e_offset, axis: Optional[str]):
    """Per-shard MoE body.  xf: (T, d) local tokens (replicated over model);
    w_*: (e_local, ...) this shard's experts; e_offset: first expert id."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    c = _capacity(t, cfg)

    logits = xf.astype(jnp.float32) @ router                    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)                         # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    eid_f = eid.reshape(-1)                                     # (T*k,)
    gate_f = gate.reshape(-1)
    tok_f = jnp.repeat(jnp.arange(t), k)

    # position of each routed copy within its expert's capacity buffer
    onehot = jax.nn.one_hot(eid_f, e, dtype=jnp.int32)          # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0)[jnp.arange(t * k), eid_f] - 1
    keep = pos < c

    # local experts only: ids relative to this shard
    lid = eid_f - e_offset
    mine = (lid >= 0) & (lid < e_local) & keep
    didx = jnp.where(mine, lid * c + pos, e_local * c)          # OOB -> dropped
    buf = jnp.zeros((e_local * c, d), xf.dtype)
    buf = buf.at[didx].set(xf[tok_f], mode="drop")

    h = buf.reshape(e_local, c, d)
    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", h, w_up)
    out = jnp.einsum("ecf,efd->ecd", act, w_down).reshape(e_local * c, d)

    # gather back, weight by gate, accumulate the k copies per token
    picked = jnp.where(mine[:, None],
                       jnp.take(out, jnp.clip(didx, 0, e_local * c - 1), axis=0),
                       0.0)
    contrib = picked * gate_f[:, None].astype(picked.dtype)
    y = jnp.zeros((t, d), xf.dtype).at[tok_f].add(contrib)
    if axis is not None:
        y = jax.lax.psum(y, axis)
    return y, probs


def moe_apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ArchConfig,
              mesh: Optional[Mesh] = None, model_axis: str = "model",
              data_axes: Tuple[str, ...] = ("data",),
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,d), router_probs (T,E) for the aux loss)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    e = cfg.n_experts

    if mesh is None or model_axis not in mesh.shape:
        y, probs = _moe_local(p["router"], p["w_gate"], p["w_up"], p["w_down"],
                              xf, cfg=cfg, e_local=e, e_offset=0, axis=None)
        return y.reshape(b, s, d), probs

    n_shards = mesh.shape[model_axis]
    assert e % n_shards == 0, (e, n_shards)
    e_local = e // n_shards

    def body(router, wg, wu, wd, xl):
        shard_id = jax.lax.axis_index(model_axis)
        y, probs = _moe_local(router, wg, wu, wd, xl, cfg=cfg,
                              e_local=e_local, e_offset=shard_id * e_local,
                              axis=model_axis)
        return y, probs

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(data_axes, None)),
        out_specs=(P(data_axes, None), P(data_axes, None)),
        check_vma=False,
    )
    y, probs = fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], xf)
    return y.reshape(b, s, d), probs


def router_aux_loss(probs: jnp.ndarray, eid_top1: Optional[jnp.ndarray] = None,
                    ) -> jnp.ndarray:
    """Switch-style load-balancing loss: E * sum_e f_e * p_e, where f_e is
    the fraction of tokens whose top-1 choice is e and p_e the mean router
    probability of e."""
    e = probs.shape[-1]
    if eid_top1 is None:
        eid_top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(eid_top1, e, dtype=jnp.float32), axis=0)
    pmean = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * pmean)
