"""Shared model layers: RMSNorm, RoPE, GQA attention (train/prefill/decode),
SwiGLU/GELU MLP.  Pure-function + pytree-params style (no flax).

Attention dispatch: the jnp reference path (``repro.kernels.flash_attention.
ref``) is used on CPU and for dry-run lowering; on TPU the Pallas flash
kernel is numerically identical (validated in tests/test_kernels.py) and is
selected with ``impl="flash"``.  The sliding window may be a *traced* scalar
so gemma3's 5:1 local:global pattern stays inside one lax.scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

PyTree = Any
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms / rope
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (..., T, H, D), positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,T,1,half)
    cos, sin = jnp.cos(angle), jnp.sin(angle)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 8)
    d, hd = cfg.d_model, cfg.hd
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _masked_attention(q, k, v, *, causal_from: jnp.ndarray,
                      kv_valid: jnp.ndarray, window) -> jnp.ndarray:
    """fp32 masked softmax attention.

    q: (B, Hq, Tq, D); k/v: (B, Hkv, Tk, D).
    causal_from: (Tq,) absolute position of each query row.
    kv_valid:    (B, Tk) absolute position of each kv slot, or -1 if unwritten.
    window: None | int | traced scalar (effective window; large = global).
    """
    b, hq, tq, d = q.shape
    hkv = k.shape[1]
    groups = hq // hkv
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, groups, tq, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32)) * scale

    qpos = causal_from[:, None]                        # (Tq, 1)
    kpos = kv_valid[:, None, None, :]                  # (B, 1, 1, Tk)
    mask = (kpos >= 0) & (kpos <= qpos[None, None])    # causal + written
    if window is not None:
        mask &= qpos[None, None] - kpos < window
    s = jnp.where(mask[:, :, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, tq, d).astype(q.dtype)


def _chunked_attention(q, k, v, *, window, bq: int = 1024,
                       bk: int = 1024) -> jnp.ndarray:
    """Memory-bounded causal attention: flash-attention restructured as pure
    XLA (online softmax over KV panels) — numerically identical to the dense
    path but with O(bq*bk) score temporaries, so 32k-prefill lowers with a
    bounded working set on any backend.  The python loop over query blocks
    gives each block a STATIC KV extent [lo, hi): causal and sliding-window
    FLOPs are genuinely skipped, not masked (matters for the §Roofline
    compute term).  q/k/v: (B, H*, T, D) with GQA folding as in
    ``_masked_attention``.  Assumes self-attention at positions [0, T).
    """
    b, hq, t, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, t, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    bq = min(bq, t)
    assert t % bq == 0, (t, bq)
    out_blocks = []
    for qi in range(t // bq):
        q_lo, q_hi = qi * bq, (qi + 1) * bq
        lo = 0 if window is None else max(0, q_lo - (int(window) - 1))
        lo = (lo // bk) * bk
        hi = q_hi                                   # causal frontier
        qb = qf[:, :, :, q_lo:q_hi]                 # (B,hkv,g,bq,D)
        m = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hkv, g, bq), jnp.float32)
        acc = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        for k_lo in range(lo, hi, bk):
            k_hi = min(k_lo + bk, hi)
            kb = kf[:, :, k_lo:k_hi]
            vb = vf[:, :, k_lo:k_hi]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qb, kb) * scale
            qpos = q_lo + jnp.arange(bq)[:, None]
            kpos = k_lo + jnp.arange(k_hi - k_lo)[None, :]
            mask = kpos <= qpos
            if window is not None:
                mask &= qpos - kpos < int(window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None, None], p, 0.0)
            alpha = jnp.exp(m - m_new)
            l = alpha * l + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bhkd->bhgqd", p, vb)
            m = m_new
        out_blocks.append(acc / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.concatenate(out_blocks, axis=3)
    return out.reshape(b, hq, t, d).astype(q.dtype)


CHUNKED_THRESHOLD = 2048


def attention_apply(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                       # (B, T, d)
    cfg: ArchConfig,
    *,
    positions: jnp.ndarray,               # (T,) absolute positions
    window=None,                          # None | int | traced (global if huge)
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # (B,Hkv,S,hd) x2
    cache_pos: Optional[jnp.ndarray] = None,   # scalar: write index (decode)
    impl: str = "ref",
) -> Tuple[jnp.ndarray, Optional[Tuple[jnp.ndarray, jnp.ndarray]]]:
    """GQA attention for train/prefill (cache=None) and decode (cache given).

    Decode: T==1, the new K/V row is written at ``cache_pos % S`` (rolling for
    windowed layers where S == window) and attention runs over the cache.
    """
    b, t, d = x.shape
    hd, hq, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, hq, hd)
    k = k.reshape(b, t, hkv, hd)
    v = v.reshape(b, t, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q.transpose(0, 2, 1, 3)                        # (B, Hq, T, hd)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    if cache is None:
        # train / prefill: self-attention over the block
        static_window = window is None or isinstance(window, int)
        if impl == "flash" and static_window:
            from repro.kernels.flash_attention.ops import flash_attention
            out = flash_attention(q, k, v, causal=True, window=window)
        elif static_window and t > CHUNKED_THRESHOLD and t % 1024 == 0:
            out = _chunked_attention(q, k, v, window=window)
        else:
            kv_valid = jnp.broadcast_to(positions[None, :], (b, t))
            out = _masked_attention(q, k, v, causal_from=positions,
                                    kv_valid=kv_valid, window=window)
        new_cache = None
    else:
        ck, cv = cache                                  # (B, Hkv, S, hd)
        s = ck.shape[2]
        slot = (cache_pos % s).astype(jnp.int32)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, slot, 0))
        # slot i holds absolute position p ≡ i (mod s), the latest <= cache_pos
        idx = jnp.arange(s)
        abs_pos = cache_pos - ((cache_pos - idx) % s)
        kv_valid = jnp.where(abs_pos >= 0, abs_pos, -1)
        kv_valid = jnp.broadcast_to(kv_valid[None, :], (b, s))
        out = _masked_attention(q, ck, cv, causal_from=positions,
                                kv_valid=kv_valid, window=window)
        new_cache = (ck, cv)

    out = out.transpose(0, 2, 1, 3).reshape(b, t, hq * hd)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, kind: str, dtype) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"w_gate": dense_init(ks[0], d, f, dtype),
                "w_up": dense_init(ks[1], d, f, dtype),
                "w_down": dense_init(ks[2], f, d, dtype)}
    return {"w_up": dense_init(ks[0], d, f, dtype),
            "w_down": dense_init(ks[1], f, d, dtype)}


def mlp_apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
