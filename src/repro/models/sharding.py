"""Logical-axis sharding context.

Models annotate activations with LOGICAL axes (``shard(x, "batch", "seq",
"embed")``); the launcher installs ``ShardingRules`` mapping logical axes to
mesh axes.  With no rules installed (CPU smoke tests) every annotation is a
no-op, so the same model code runs single-device and multi-pod.

This indirection is the perf-iteration lever: §Perf experiments change the
rules (e.g. embed-dim sharding of the residual stream between layers —
Megatron-SP style), never the model code.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""
    rules: Dict[str, Axis]
    mesh: Optional[object] = None   # jax Mesh; needed for NamedSharding

    def spec(self, *logical: Optional[str]) -> P:
        return P(*(self.rules.get(ax) if ax else None for ax in logical))


# default logical->mesh mapping for the production mesh (see launch/mesh.py)
def default_rules(data_axes: Tuple[str, ...] = ("data",),
                  model_axis: str = "model", mesh=None) -> ShardingRules:
    return ShardingRules(mesh=mesh, rules={
        "batch": data_axes,        # batch over pod+data
        "seq": None,
        "embed": model_axis,       # residual-stream d_model (Megatron-SP carry)
        "embed_r": None,           # residual stream kept replicated (baseline)
        "heads": model_axis,
        "kv_heads": model_axis,
        "ff": model_axis,
        "vocab": model_axis,
        "experts": model_axis,
        "ssm_inner": model_axis,
        "state": None,
    })


def fsdp_rules(data_axes: Tuple[str, ...] = ("data",),
               model_axis: str = "model", mesh=None) -> ShardingRules:
    """FSDP regime: batch shards over data+model, weights are ZeRO-3 over
    model (see launch.shardings.param_specs mode="fsdp"), activations stay
    replicated across model — per-layer weight all-gathers replace TP's
    activation all-reduces (wins when weights << activations per layer)."""
    return ShardingRules(mesh=mesh, rules={
        "batch": tuple(data_axes) + (model_axis,),
        "seq": None, "embed": None, "embed_r": None,
        "heads": None, "kv_heads": None, "ff": None,
        "vocab": None, "experts": None, "ssm_inner": None, "state": None,
    })


def dp_rules(data_axes: Tuple[str, ...] = ("data",),
             model_axis: str = "model", mesh=None) -> ShardingRules:
    """DP + vocab-TP regime (§Perf hillclimb B iteration 3): per-layer
    weights replicated (no TP collectives), batch over data, ZeRO'd
    moments; ONLY the embedding/lm_head stay vocab-sharded over model so
    the fp32 CE working set stays 1/16th."""
    return ShardingRules(mesh=mesh, rules={
        "batch": tuple(data_axes),
        "seq": None, "embed": None, "embed_r": None,
        "heads": None, "kv_heads": None, "ff": None,
        "vocab": model_axis, "experts": None, "ssm_inner": None,
        "state": None,
    })


_ACTIVE: list = []


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    _ACTIVE.append(rules)
    try:
        yield
    finally:
        _ACTIVE.pop()


def active_rules() -> Optional[ShardingRules]:
    return _ACTIVE[-1] if _ACTIVE else None


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; no-op without rules."""
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.spec(*logical)
    # drop axes that don't divide the corresponding dim (e.g. batch=1 decode)
    fixed = []
    for dim, ax in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= dict(rules.mesh.shape)[a] if rules.mesh is not None else 1
        fixed.append(ax if size and dim % max(size, 1) == 0 and dim >= size else None)
    spec = P(*fixed)
    if rules.mesh is not None:
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
    return jax.lax.with_sharding_constraint(x, spec)
