"""Graph embedding + Q-head (paper §IV-D, Eqns 2-4, Fig. 4).

structure2vec-style embedding over (complete graph W, partial solution A_t):

    mu_v^{t+1} = relu( theta1 * x_v
                     + theta2 @ sum_{u in N(v)} mu_u
                     + theta3 @ sum_{u in N(v)} relu(theta4 * w(v,u)) )   (2)

    x(u) = [ w(v_t,u), theta5 @ sum_v mu_v, theta6 @ mu_{v_t}, theta7 @ mu_u ]  (3)

    Qhat(S_t, u) = theta10^T relu(theta9 relu(theta8 relu(x)))            (4)

Per Fig. 4 every neighbourhood sum is a matmul with the partial-solution
adjacency A_t, so the whole forward is MXU-shaped.  The paper types theta1 as
a scalar; we follow structure2vec (Dai et al. 2017, the paper's [52]) and use
theta1 in R^p so the degree feature spans the embedding space.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["QParams", "init_qparams", "embed", "q_values", "q_values_batch"]


class QParams(NamedTuple):
    theta1: jnp.ndarray   # (p,)     degree feature
    theta2: jnp.ndarray   # (p, p)   neighbour-embedding aggregation
    theta3: jnp.ndarray   # (p, p)   neighbour-latency aggregation
    theta4: jnp.ndarray   # (p,)     scalar latency -> R^p
    theta5: jnp.ndarray   # (p, p)   pooled graph embedding
    theta6: jnp.ndarray   # (p, p)   source-node embedding
    theta7: jnp.ndarray   # (p, p)   candidate-node embedding
    theta8: jnp.ndarray   # (h, 3p+1) MLP in
    theta9: jnp.ndarray   # (h, h)    MLP hidden
    theta10: jnp.ndarray  # (h,)      MLP out


def init_qparams(key: jax.Array, p: int = 16, h: int = 64) -> QParams:
    ks = jax.random.split(key, 10)

    def glorot(k, shape):
        fan = sum(shape) if len(shape) > 1 else shape[0] + 1
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan)

    return QParams(
        theta1=glorot(ks[0], (p,)),
        theta2=glorot(ks[1], (p, p)),
        theta3=glorot(ks[2], (p, p)),
        theta4=glorot(ks[3], (p,)),
        theta5=glorot(ks[4], (p, p)),
        theta6=glorot(ks[5], (p, p)),
        theta7=glorot(ks[6], (p, p)),
        theta8=glorot(ks[7], (h, 3 * p + 1)),
        theta9=glorot(ks[8], (h, h)),
        theta10=glorot(ks[9], (h,)),
    )


def embed(params: QParams, w: jnp.ndarray, adj: jnp.ndarray, n_rounds: int = 3) -> jnp.ndarray:
    """T rounds of Eqn. (2).  ``adj``: {0,1} partial-solution adjacency (N,N).

    Returns (N, p) node embeddings.  Both aggregation terms are matmuls
    (Fig. 4): `adj @ mu` and a masked reduction of relu(W x theta4).
    """
    n = w.shape[0]
    p = params.theta1.shape[0]
    deg = jnp.sum(adj, axis=1)                                   # x_v
    # second Fig.4 row: relu(theta4 * w(v,u)) summed over neighbours
    lat_feat = jnp.einsum("vu,vup->vp", adj, jax.nn.relu(w[:, :, None] * params.theta4))
    lat_term = lat_feat @ params.theta3.T                        # (N, p)
    deg_term = deg[:, None] * params.theta1[None, :]             # (N, p)

    def one_round(mu, _):
        agg = adj @ mu                                           # (N, p) first Fig.4 row
        mu = jax.nn.relu(deg_term + agg @ params.theta2.T + lat_term)
        return mu, None

    mu0 = jnp.zeros((n, p), jnp.float32)
    mu, _ = jax.lax.scan(one_round, mu0, None, length=n_rounds)
    return mu


@functools.partial(jax.jit, static_argnames=("n_rounds",))
def q_values(
    params: QParams,
    w: jnp.ndarray,
    adj: jnp.ndarray,
    v_t: jnp.ndarray,
    n_rounds: int = 3,
) -> jnp.ndarray:
    """Q(S_t, u) for every candidate u (Eqns 3-4).  Returns (N,)."""
    mu = embed(params, w, adj, n_rounds)
    pooled = jnp.sum(mu, axis=0) @ params.theta5.T               # (p,)
    src = mu[v_t] @ params.theta6.T                              # (p,)
    tgt = mu @ params.theta7.T                                   # (N, p)
    n = w.shape[0]
    x = jnp.concatenate(
        [w[v_t][:, None], jnp.broadcast_to(pooled, (n, pooled.shape[0])),
         jnp.broadcast_to(src, (n, src.shape[0])), tgt],
        axis=1,
    )                                                            # (N, 3p+1)
    hidden = jax.nn.relu(jax.nn.relu(x) @ params.theta8.T)
    hidden = jax.nn.relu(hidden @ params.theta9.T)
    return hidden @ params.theta10                               # (N,)


@functools.partial(jax.jit, static_argnames=("n_rounds",))
def q_values_batch(
    params: QParams,
    w: jnp.ndarray,
    adj: jnp.ndarray,
    v_t: jnp.ndarray,
    n_rounds: int = 3,
) -> jnp.ndarray:
    """Batched :func:`q_values` over (B, N, N) stacks.  Returns (B, N).

    ``n_rounds`` is a static kwarg shared across the batch — the previous
    ``vmap(..., in_axes=(None, 0, 0, 0))`` formulation had no axis spec for
    it, so passing ``n_rounds`` broke the call instead of configuring the
    embedding depth.
    """
    return jax.vmap(
        lambda w1, adj1, v1: q_values(params, w1, adj1, v1, n_rounds)
    )(w, adj, v_t)
