"""Deep Q-learning for diameter-guided ring construction (paper §IV, Alg. 1-2).

MDP (paper §IV-C):
  * state  S_t = (W, A_t, v_t): latency matrix, partial-solution adjacency,
    current end node of the ring under construction;
  * action u: next unvisited node — edge (v_t, u) is added;
  * reward r = D(G_t) - D(G_{t+1}) - alpha * w(v_t, u): telescopes to
    -D(G_T) plus the latency-shaping term.

Replay + epsilon-greedy exactly per Algorithm 2; epsilon schedule per
§VII-B.1: eps = max(1 - epoch/eps_decay, 0.05).  Host drives the (cheap,
control-flow-heavy) episode loop; the Q forward, TD update and diameter are
jit'd JAX.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from .construction import default_num_rings
from .diameter import INF, diameter
from .embedding import QParams, init_qparams, q_values
from .topology import make_latency

__all__ = ["DQNConfig", "ReplayBuffer", "train_dqn", "construct_ring_dqn",
           "dgro_overlay", "dgro_topology", "TrainLog"]


@dataclasses.dataclass
class DQNConfig:
    n: int = 20                     # nodes per training graph
    k_rings: int = 2                # rings per episode
    p: int = 16                     # embedding dim (paper: 16)
    h: int = 64                     # Q-head hidden
    n_rounds: int = 3               # embedding iterations T
    lr: float = 5e-4                # paper §VII-B.1
    gamma: float = 0.99
    alpha: float = 0.1              # latency shaping coefficient
    epochs: int = 300
    eps_decay: float = 2000.0       # paper: eps = max(1 - epoch/2000, 0.05)
    eps_min: float = 0.05
    batch_size: int = 32            # paper: 32
    buffer_capacity: int = 20000
    dist: str = "uniform"
    seed: int = 0
    updates_per_step: int = 1


class ReplayBuffer:
    """Fixed-capacity ring buffer of transitions (Alg. 2 memory M)."""

    def __init__(self, capacity: int, n: int):
        self.capacity = capacity
        self.n = n
        self.w = np.zeros((capacity, n, n), np.float32)
        self.adj = np.zeros((capacity, n, n), np.uint8)
        self.v = np.zeros((capacity,), np.int32)
        self.action = np.zeros((capacity,), np.int32)
        self.reward = np.zeros((capacity,), np.float32)
        self.adj_next = np.zeros((capacity, n, n), np.uint8)
        self.v_next = np.zeros((capacity,), np.int32)
        self.visited_next = np.zeros((capacity, n), np.uint8)
        self.done = np.zeros((capacity,), np.uint8)
        self.size = 0
        self.ptr = 0

    def push(self, w, adj, v, action, reward, adj_next, v_next, visited_next, done):
        i = self.ptr
        self.w[i] = w
        self.adj[i] = adj
        self.v[i] = v
        self.action[i] = action
        self.reward[i] = reward
        self.adj_next[i] = adj_next
        self.v_next[i] = v_next
        self.visited_next[i] = visited_next
        self.done[i] = done
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.size, size=batch)
        return (self.w[idx], self.adj[idx], self.v[idx], self.action[idx],
                self.reward[idx], self.adj_next[idx], self.v_next[idx],
                self.visited_next[idx], self.done[idx])


# ---------------------------------------------------------------------------
# jit'd TD update
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_rounds",))
def _td_update(params: QParams, opt_state, w, adj, v, action, reward,
               adj_next, v_next, visited_next, done, gamma, lr,
               n_rounds: int = 3):
    """One SGD step on the squared TD error over a replay batch."""

    def q_sa(p, w1, a1, v1, act1):
        return q_values(p, w1, a1.astype(jnp.float32), v1, n_rounds)[act1]

    def target(w1, an1, vn1, vis1, d1, r1):
        qn = q_values(params, w1, an1.astype(jnp.float32), vn1, n_rounds)
        qn = jnp.where(vis1.astype(bool), -jnp.inf, qn)
        best = jnp.max(qn)
        best = jnp.where(jnp.isfinite(best), best, 0.0)
        return r1 + gamma * best * (1.0 - d1)

    y = jax.vmap(target)(w, adj_next, v_next, visited_next,
                         done.astype(jnp.float32), reward)
    y = jax.lax.stop_gradient(y)

    def loss_fn(p):
        q = jax.vmap(q_sa, in_axes=(None, 0, 0, 0, 0))(p, w, adj, v, action)
        return jnp.mean(jnp.square(y - q))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    cfg = AdamWConfig(lr=lr, b1=0.9, b2=0.999, clip_norm=5.0)
    new_params, new_state, _ = adamw_update(cfg, grads, opt_state, params)
    return new_params, new_state, loss


@functools.partial(jax.jit, static_argnames=("n_rounds",))
def _greedy_q(params: QParams, w, adj, v, visited, n_rounds: int = 3):
    q = q_values(params, w, adj.astype(jnp.float32), v, n_rounds)
    return jnp.where(visited, -jnp.inf, q)


_diameter_jit = jax.jit(diameter)


# ---------------------------------------------------------------------------
# episodes
# ---------------------------------------------------------------------------

def _run_episode(params, cfg: DQNConfig, w: np.ndarray, eps: float,
                 rng: np.random.Generator, buffer: Optional[ReplayBuffer],
                 opt_state=None, train: bool = True):
    """Build k_rings rings with eps-greedy Q; optionally train per step."""
    n = cfg.n
    adj_w = np.full((n, n), float(INF), np.float32)   # weighted partial graph
    np.fill_diagonal(adj_w, 0.0)
    adj = np.zeros((n, n), np.uint8)                  # 0/1 adjacency for embed
    prev_d = 0.0                                      # D(G_0) := 0 (empty)
    losses = []
    perms: List[np.ndarray] = []

    for ring_i in range(cfg.k_rings):
        start = int(rng.integers(n))
        visited = np.zeros(n, np.uint8)
        visited[start] = 1
        perm = [start]
        v = start
        for _t in range(n):  # n-1 inner edges + closing edge
            closing = _t == n - 1
            if closing:
                a = start                              # close the ring
            elif rng.random() < eps:
                a = int(rng.choice(np.flatnonzero(visited == 0)))
            else:
                q = np.asarray(_greedy_q(params, w, adj, v, visited.astype(bool),
                                         cfg.n_rounds))
                a = int(np.argmax(q))
            adj_prev = adj.copy()
            adj_w[v, a] = min(adj_w[v, a], w[v, a]); adj_w[a, v] = adj_w[v, a]
            adj[v, a] = 1; adj[a, v] = 1
            new_d = float(_diameter_jit(jnp.asarray(adj_w)))
            reward = (prev_d - new_d) - cfg.alpha * float(w[v, a])
            done = closing and ring_i == cfg.k_rings - 1
            if buffer is not None and not closing:
                visited_next = visited.copy(); visited_next[a] = 1
                buffer.push(w, adj_prev, v, a, reward, adj, a, visited_next, done)
            prev_d = new_d
            if not closing:
                visited[a] = 1
                perm.append(a)
                v = a
            if train and buffer is not None and buffer.size >= cfg.batch_size:
                for _ in range(cfg.updates_per_step):
                    batch = buffer.sample(rng, cfg.batch_size)
                    params, opt_state, loss = _td_update(
                        params, opt_state, *[jnp.asarray(x) for x in batch],
                        jnp.float32(cfg.gamma), jnp.float32(cfg.lr), cfg.n_rounds)
                    losses.append(float(loss))
        perms.append(np.asarray(perm))
    return params, opt_state, prev_d, losses, perms


@dataclasses.dataclass
class TrainLog:
    epochs: List[int]
    train_diam: List[float]
    test_diam: List[float]
    loss: List[float]
    seconds: float


def train_dqn(cfg: DQNConfig, eval_every: int = 25,
              eval_graphs: int = 3) -> Tuple[QParams, TrainLog]:
    """Algorithm 2: Q-learning with experience replay."""
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    params = init_qparams(key, cfg.p, cfg.h)
    opt_state = adamw_init(params)
    buffer = ReplayBuffer(cfg.buffer_capacity, cfg.n)
    test_ws = [make_latency(cfg.dist, cfg.n, seed=10_000 + i)
               for i in range(eval_graphs)]
    log = TrainLog([], [], [], [], 0.0)
    t0 = time.time()
    for epoch in range(cfg.epochs):
        eps = max(1.0 - epoch / cfg.eps_decay, cfg.eps_min)
        w = make_latency(cfg.dist, cfg.n, seed=cfg.seed * 77_000 + epoch)
        params, opt_state, train_d, losses, _ = _run_episode(
            params, cfg, w, eps, rng, buffer, opt_state, train=True)
        if epoch % eval_every == 0 or epoch == cfg.epochs - 1:
            test_d = float(np.mean([
                construct_ring_dqn(params, cfg, tw, rng)[1] for tw in test_ws]))
            log.epochs.append(epoch)
            log.train_diam.append(train_d)
            log.test_diam.append(test_d)
            log.loss.append(float(np.mean(losses)) if losses else float("nan"))
    log.seconds = time.time() - t0
    return params, log


def construct_ring_dqn(params: QParams, cfg: DQNConfig, w: np.ndarray,
                       rng: np.random.Generator) -> Tuple[List[np.ndarray], float]:
    """Greedy (eps=0) K-ring construction with the trained Q (Alg. 1)."""
    params, _, d, _, perms = _run_episode(params, cfg, w, eps=0.0, rng=rng,
                                          buffer=None, train=False)
    return perms, d


def dgro_overlay(params: QParams, cfg: DQNConfig, w: np.ndarray,
                 n_starts: int = 10, seed: int = 0):
    """Paper §VII-B.2: build n_starts K-ring topologies with the trained Q,
    keep the best — as a :class:`repro.overlay.Overlay` (policy
    ``"dgro-dqn"``; the winning episode's diameter seeds the cache)."""
    from repro.overlay import Overlay

    best_perms, best_d = None, float("inf")
    for s in range(n_starts):
        rng = np.random.default_rng(seed + s)
        perms, d = construct_ring_dqn(params, cfg, w, rng)
        if d < best_d:
            best_perms, best_d = perms, d
    return Overlay.from_rings(
        w, best_perms, policy="dgro-dqn").cache_diameter(best_d)


def dgro_topology(params: QParams, cfg: DQNConfig, w: np.ndarray,
                  n_starts: int = 10, seed: int = 0) -> Tuple[List[np.ndarray], float]:
    """Deprecated tuple facade over :func:`dgro_overlay`."""
    from repro.core.protocols import _warn_legacy

    _warn_legacy("repro.core.qlearning.dgro_topology",
                 "repro.core.qlearning.dgro_overlay(params, cfg, w, ...)")
    ov = dgro_overlay(params, cfg, w, n_starts=n_starts, seed=seed)
    return [np.asarray(r) for r in ov.rings], ov.diameter()
