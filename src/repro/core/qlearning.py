"""Deep Q-learning for diameter-guided ring construction (paper §IV, Alg. 1-2).

MDP (paper §IV-C):
  * state  S_t = (W, A_t, v_t): latency matrix, partial-solution adjacency,
    current end node of the ring under construction;
  * action u: next unvisited node — edge (v_t, u) is added;
  * reward r = D(G_t) - D(G_{t+1}) - alpha * w(v_t, u): telescopes to
    -D(G_T) plus the latency-shaping term.

Replay + epsilon-greedy exactly per Algorithm 2; epsilon schedule per
§VII-B.1: eps = max(1 - epoch/eps_decay, 0.05).

This module is a thin facade over :mod:`repro.core.rollout`, the
device-resident vectorized episode engine: with ``cfg.rollout="device"``
(the default) an entire epoch — eps-greedy actions over ``cfg.n_envs``
parallel graphs, incremental O(N^2) relax rewards, replay pushes and TD
updates — runs as ONE jit'd ``lax.scan`` (one device call per epoch).
``cfg.rollout="host"`` keeps the original step-by-step host loop as a
debug path; both consume the same pre-generated :class:`~repro.core.
rollout.RolloutPlan` randomness, so any episode given the same plan makes
identical decisions and builds identical rings (cross-validated in
tests).  Note the caveat for full training runs: the two modes consume
the shared epoch rng differently at eval points (the device path draws
one batched eval plan, the host path one plan per eval graph), so
train_dqn trajectories diverge after the first eval even at
``n_envs=1`` — episode-level parity is the debugging contract.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.train.optimizer import adamw_init
from . import rollout
from .diameter import INF, largest_cc_diameter, relax_edge_update
from .embedding import QParams, init_qparams, q_values
from .rollout import RolloutPlan, make_plan
from .topology import make_latency

__all__ = ["DQNConfig", "ReplayBuffer", "train_dqn", "construct_ring_dqn",
           "dgro_overlay", "TrainLog"]


def __getattr__(name: str):
    if name == "dgro_topology":
        raise AttributeError(
            "repro.core.qlearning.dgro_topology was removed; use "
            "dgro_overlay(params, cfg, w, ...) which returns an Overlay "
            "(.rings / .diameter() carry what the tuple did; see "
            "overlay.build)")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class DQNConfig:
    n: int = 20                     # nodes per training graph
    k_rings: int = 2                # rings per episode
    p: int = 16                     # embedding dim (paper: 16)
    h: int = 64                     # Q-head hidden
    n_rounds: int = 3               # embedding iterations T
    lr: float = 5e-4                # paper §VII-B.1
    gamma: float = 0.99
    alpha: float = 0.1              # latency shaping coefficient
    epochs: int = 300
    eps_decay: float = 2000.0       # paper: eps = max(1 - epoch/2000, 0.05)
    eps_min: float = 0.05
    batch_size: int = 32            # paper: 32
    buffer_capacity: int = 20000
    dist: str = "uniform"
    seed: int = 0
    updates_per_step: int = 1
    rollout: str = "device"         # "device" (fused lax.scan) | "host" (debug)
    n_envs: int = 1                 # parallel environments per device epoch


class ReplayBuffer:
    """Fixed-capacity ring buffer of transitions (Alg. 2 memory M).

    Transitions store a graph id (``widx``) into a small table of epoch
    latency graphs instead of a full (N, N) copy of ``w`` per step — every
    step of an epoch shares one graph, so the table holds
    O(capacity / steps-per-epoch) matrices instead of O(capacity).  Dead
    graphs (no live transition references them) are pruned as the ring
    buffer overwrites; the device-resident buffer
    (:class:`repro.core.rollout.DeviceBuffer`) uses the same layout by
    construction.
    """

    def __init__(self, capacity: int, n: int):
        self.capacity = capacity
        self.n = n
        self.widx = np.zeros((capacity,), np.int64)
        self.adj = np.zeros((capacity, n, n), np.uint8)
        self.v = np.zeros((capacity,), np.int32)
        self.action = np.zeros((capacity,), np.int32)
        self.reward = np.zeros((capacity,), np.float32)
        self.adj_next = np.zeros((capacity, n, n), np.uint8)
        self.v_next = np.zeros((capacity,), np.int32)
        self.visited_next = np.zeros((capacity, n), np.uint8)
        self.done = np.zeros((capacity,), np.uint8)
        self.graphs: Dict[int, np.ndarray] = {}
        self._next_gid = 0
        self._last_gid: Optional[int] = None
        self.size = 0
        self.ptr = 0

    @property
    def n_graphs(self) -> int:
        return len(self.graphs)

    def register_graph(self, w: np.ndarray) -> int:
        """Intern ``w`` in the graph table, reusing the last id when the
        matrix is unchanged (the per-episode common case)."""
        w = np.asarray(w, np.float32)
        if (self._last_gid is not None
                and np.array_equal(self.graphs[self._last_gid], w)):
            return self._last_gid
        gid = self._next_gid
        self._next_gid += 1
        self.graphs[gid] = w.copy()
        self._last_gid = gid
        self._prune()
        return gid

    def _prune(self) -> None:
        """Drop graphs no live transition references.  Ids are monotone and
        the ring buffer overwrites FIFO, so everything below the minimum
        live id is dead (the latest graph is always kept)."""
        min_live = (int(self.widx[:self.size].min()) if self.size
                    else self._next_gid)
        for g in [g for g in self.graphs
                  if g < min_live and g != self._last_gid]:
            del self.graphs[g]

    def push(self, w, adj, v, action, reward, adj_next, v_next, visited_next,
             done):
        """``w`` may be a graph id from :meth:`register_graph` or a raw
        (N, N) matrix (interned on the fly)."""
        gid = int(w) if isinstance(w, (int, np.integer)) \
            else self.register_graph(w)
        i = self.ptr
        self.widx[i] = gid
        self.adj[i] = adj
        self.v[i] = v
        self.action[i] = action
        self.reward[i] = reward
        self.adj_next[i] = adj_next
        self.v_next[i] = v_next
        self.visited_next[i] = visited_next
        self.done[i] = done
        self.ptr = (self.ptr + 1) % self.capacity
        self.size = min(self.size + 1, self.capacity)

    def _gather(self, idx: np.ndarray):
        w = np.stack([self.graphs[int(g)] for g in self.widx[idx]])
        return (w, self.adj[idx], self.v[idx], self.action[idx],
                self.reward[idx], self.adj_next[idx], self.v_next[idx],
                self.visited_next[idx], self.done[idx])

    def sample(self, rng: np.random.Generator, batch: int):
        return self._gather(rng.integers(0, self.size, size=batch))

    def sample_at(self, uniforms: np.ndarray):
        """Sample via pre-generated uniforms — ``floor(u * size)``, the same
        formula the device scan applies to the same plan, so host and
        device training draw identical replay batches."""
        idx = (np.asarray(uniforms, np.float32)
               * np.float32(self.size)).astype(np.int32)
        return self._gather(np.minimum(idx, self.size - 1))


# ---------------------------------------------------------------------------
# jit'd kernels for the host debug path (math shared with the device engine)
# ---------------------------------------------------------------------------

_td_update = functools.partial(jax.jit, static_argnames=("n_rounds",))(
    rollout.td_update_impl)


@functools.partial(jax.jit, static_argnames=("n_rounds",))
def _greedy_q(params: QParams, w, adj, v, visited, n_rounds: int = 3):
    q = q_values(params, w, adj.astype(jnp.float32), v, n_rounds)
    return jnp.where(visited, -jnp.inf, q)


_relax_jit = jax.jit(relax_edge_update)
_cc_diameter_jit = jax.jit(largest_cc_diameter)


# ---------------------------------------------------------------------------
# host episode loop — rollout="host" debug path, mirrors the device scan
# ---------------------------------------------------------------------------

def _run_episode(params, cfg: DQNConfig, w: np.ndarray, eps: float,
                 plan: RolloutPlan, env: int = 0,
                 buffer: Optional[ReplayBuffer] = None, opt_state=None,
                 train: bool = True, gid: Optional[int] = None):
    """Build k_rings rings step by step on the host (debug mirror).

    Consumes column ``env`` of ``plan`` with the exact decision formulas of
    :func:`repro.core.rollout.rollout_episodes` (same eps coin, same
    ``floor(u * n_unvisited)`` random pick, same incremental-relax reward),
    so device and host trajectories match at fixed plans.
    """
    n = cfg.n
    dist = np.full((n, n), float(INF), np.float32)
    np.fill_diagonal(dist, 0.0)
    dist = jnp.asarray(dist)                          # APSP of partial graph
    adj = np.zeros((n, n), np.uint8)                  # 0/1 adjacency for embed
    prev_d = 0.0                                      # D(G_0) := 0 (empty)
    losses: List[float] = []
    rewards: List[float] = []
    perms: List[np.ndarray] = []

    for ring_i in range(cfg.k_rings):
        start = int(plan.starts[env, ring_i])
        visited = np.zeros(n, np.uint8)
        visited[start] = 1
        perm = [start]
        v = start
        for _t in range(n):  # n-1 inner edges + closing edge
            t = ring_i * n + _t
            closing = _t == n - 1
            if closing:
                a = start                              # close the ring
            elif np.float32(plan.eps_u[t, env]) < np.float32(eps):
                unvis = np.flatnonzero(visited == 0)
                ridx = int(np.float32(plan.choice_u[t, env])
                           * np.float32(len(unvis)))
                a = int(unvis[min(ridx, len(unvis) - 1)])
            else:
                q = np.asarray(_greedy_q(params, w, adj, v,
                                         visited.astype(bool), cfg.n_rounds))
                a = int(np.argmax(q))
            adj_prev = adj.copy()
            adj[v, a] = 1; adj[a, v] = 1
            w_edge = np.float32(w[v, a])
            dist = _relax_jit(dist, v, a, w_edge)
            new_d = float(_cc_diameter_jit(dist))
            reward = float(np.float32(prev_d) - np.float32(new_d)
                           - np.float32(cfg.alpha) * w_edge)
            rewards.append(reward)
            done = closing and ring_i == cfg.k_rings - 1
            if buffer is not None and not closing:
                visited_next = visited.copy(); visited_next[a] = 1
                buffer.push(w if gid is None else gid, adj_prev, v, a, reward,
                            adj, a, visited_next, done)
            prev_d = new_d
            if not closing:
                visited[a] = 1
                perm.append(a)
                v = a
            if train and buffer is not None and buffer.size >= cfg.batch_size:
                for u_i in range(cfg.updates_per_step):
                    batch = buffer.sample_at(plan.sample_u[t, u_i])
                    params, opt_state, loss = _td_update(
                        params, opt_state, *[jnp.asarray(x) for x in batch],
                        jnp.float32(cfg.gamma), jnp.float32(cfg.lr),
                        cfg.n_rounds)
                    losses.append(float(loss))
        perms.append(np.asarray(perm))
    return (params, opt_state, prev_d, losses, perms,
            np.asarray(rewards, np.float32))


@dataclasses.dataclass
class TrainLog:
    epochs: List[int]
    train_diam: List[float]
    test_diam: List[float]
    loss: List[float]
    seconds: float
    steps_per_sec: float = 0.0


def _plan_arrays(plan: RolloutPlan):
    return (jnp.asarray(plan.starts), jnp.asarray(plan.eps_u),
            jnp.asarray(plan.choice_u))


def _eval_diameters_device(params, cfg: DQNConfig, test_ws,
                           rng: np.random.Generator) -> float:
    """Greedy construction on all eval graphs in ONE batched rollout call."""
    plan = make_plan(rng, len(test_ws), cfg.k_rings, cfg.n)
    _, _, d = rollout.rollout_episodes(
        params, jnp.asarray(np.stack(test_ws), jnp.float32),
        *_plan_arrays(plan), 0.0, cfg.alpha,
        k_rings=cfg.k_rings, n_rounds=cfg.n_rounds)
    return float(np.mean(np.asarray(d)))


def train_dqn(cfg: DQNConfig, eval_every: int = 25,
              eval_graphs: int = 3) -> Tuple[QParams, TrainLog]:
    """Algorithm 2: Q-learning with experience replay.

    ``cfg.rollout="device"`` runs each epoch as one fused device call over
    ``cfg.n_envs`` graphs (the :mod:`repro.core.rollout` engine);
    ``"host"`` keeps the original per-step host loop for debugging.
    """
    assert cfg.rollout in ("device", "host"), cfg.rollout
    rng = np.random.default_rng(cfg.seed)
    key = jax.random.PRNGKey(cfg.seed)
    params = init_qparams(key, cfg.p, cfg.h)
    opt_state = adamw_init(params)
    test_ws = [make_latency(cfg.dist, cfg.n, seed=10_000 + i)
               for i in range(eval_graphs)]
    log = TrainLog([], [], [], [], 0.0)
    n, k, n_envs = cfg.n, cfg.k_rings, cfg.n_envs
    t0 = time.time()

    if cfg.rollout == "device":
        slots = rollout.graph_slots(cfg.buffer_capacity, n_envs, k, n)
        buf = rollout.init_buffer(cfg.buffer_capacity, n, slots)
        for epoch in range(cfg.epochs):
            eps = max(1.0 - epoch / cfg.eps_decay, cfg.eps_min)
            ws = np.stack([
                make_latency(cfg.dist, n,
                             seed=cfg.seed * 77_000 + epoch * n_envs + i)
                for i in range(n_envs)])
            plan = make_plan(rng, n_envs, k, n, cfg.updates_per_step,
                             cfg.batch_size)
            gids = jnp.asarray((np.arange(n_envs) + epoch * n_envs) % slots,
                               jnp.int32)
            params, opt_state, buf, d, losses, _a, _r = rollout.train_epoch(
                params, opt_state, buf, jnp.asarray(ws, jnp.float32), gids,
                *_plan_arrays(plan), jnp.asarray(plan.sample_u),
                eps, cfg.gamma, cfg.lr, cfg.alpha,
                k_rings=k, n_rounds=cfg.n_rounds, batch_size=cfg.batch_size,
                updates_per_step=cfg.updates_per_step)
            if epoch % eval_every == 0 or epoch == cfg.epochs - 1:
                losses = np.asarray(losses)
                losses = losses[np.isfinite(losses)]
                log.epochs.append(epoch)
                log.train_diam.append(float(np.mean(np.asarray(d))))
                log.test_diam.append(
                    _eval_diameters_device(params, cfg, test_ws, rng))
                log.loss.append(float(np.mean(losses)) if losses.size
                                else float("nan"))
    else:
        buffer = ReplayBuffer(cfg.buffer_capacity, n)
        for epoch in range(cfg.epochs):
            eps = max(1.0 - epoch / cfg.eps_decay, cfg.eps_min)
            train_ds, losses = [], []
            for i in range(n_envs):
                w = make_latency(cfg.dist, n,
                                 seed=cfg.seed * 77_000 + epoch * n_envs + i)
                plan = make_plan(rng, 1, k, n, cfg.updates_per_step,
                                 cfg.batch_size)
                gid = buffer.register_graph(w)
                params, opt_state, train_d, ls, _, _ = _run_episode(
                    params, cfg, w, eps, plan, 0, buffer, opt_state,
                    train=True, gid=gid)
                train_ds.append(train_d)
                losses.extend(ls)
            if epoch % eval_every == 0 or epoch == cfg.epochs - 1:
                test_d = float(np.mean([
                    construct_ring_dqn(params, cfg, tw, rng)[1]
                    for tw in test_ws]))
                log.epochs.append(epoch)
                log.train_diam.append(float(np.mean(train_ds)))
                log.test_diam.append(test_d)
                log.loss.append(float(np.mean(losses)) if losses
                                else float("nan"))
    log.seconds = time.time() - t0
    log.steps_per_sec = (cfg.epochs * n_envs * k * n) / max(log.seconds, 1e-9)
    return params, log


def construct_ring_dqn(params: QParams, cfg: DQNConfig, w: np.ndarray,
                       rng: np.random.Generator) -> Tuple[List[np.ndarray], float]:
    """Greedy (eps=0) K-ring construction with the trained Q (Alg. 1).

    Both rollout modes consume ``rng`` identically (one plan draw), so they
    produce the same rings at the same seed.
    """
    plan = make_plan(rng, 1, cfg.k_rings, cfg.n)
    if cfg.rollout == "host":
        _, _, d, _, perms, _ = _run_episode(params, cfg, w, 0.0, plan, 0,
                                            buffer=None, train=False)
        return perms, d
    actions, _, d = rollout.rollout_episodes(
        params, jnp.asarray(w, jnp.float32)[None], *_plan_arrays(plan),
        0.0, cfg.alpha, k_rings=cfg.k_rings, n_rounds=cfg.n_rounds)
    perms = rollout.perms_from_actions(plan.starts, np.asarray(actions),
                                       cfg.k_rings, cfg.n)[0]
    return perms, float(np.asarray(d)[0])


def dgro_overlay(params: QParams, cfg: DQNConfig, w: np.ndarray,
                 n_starts: int = 10, seed: int = 0):
    """Paper §VII-B.2: build n_starts K-ring topologies with the trained Q,
    keep the best — as a :class:`repro.overlay.Overlay` (policy
    ``"dgro-dqn"``; the winning episode's diameter seeds the cache).

    With ``cfg.rollout="device"`` all ``n_starts`` constructions run as ONE
    vmapped batched rollout call instead of a sequential host loop; per-
    start plans come from ``default_rng(seed + s)`` in both modes, so the
    winning rings match the host path at fixed seeds.
    """
    from repro.overlay import Overlay

    n, k = cfg.n, cfg.k_rings
    if cfg.rollout == "host":
        best_perms, best_d = None, float("inf")
        for s in range(n_starts):
            rng = np.random.default_rng(seed + s)
            perms, d = construct_ring_dqn(params, cfg, w, rng)
            if d < best_d:
                best_perms, best_d = perms, d
        return Overlay.from_rings(
            w, best_perms, policy="dgro-dqn").cache_diameter(best_d)

    plans = [make_plan(np.random.default_rng(seed + s), 1, k, n)
             for s in range(n_starts)]
    starts = np.concatenate([p.starts for p in plans], axis=0)    # (S, K)
    eps_u = np.concatenate([p.eps_u for p in plans], axis=1)      # (T, S)
    choice_u = np.concatenate([p.choice_u for p in plans], axis=1)
    w_b = np.broadcast_to(np.asarray(w, np.float32), (n_starts, n, n))
    actions, _, d = rollout.rollout_episodes(
        params, jnp.asarray(w_b), jnp.asarray(starts), jnp.asarray(eps_u),
        jnp.asarray(choice_u), 0.0, cfg.alpha,
        k_rings=k, n_rounds=cfg.n_rounds)
    d = np.asarray(d)
    best = int(np.argmin(d))
    perms = rollout.perms_from_actions(starts, np.asarray(actions), k, n)[best]
    return Overlay.from_rings(
        w, perms, policy="dgro-dqn").cache_diameter(float(d[best]))
