"""Genetic-algorithm baseline (paper §VII-A.2).

The paper benchmarks DGRO against a GA that searches 100,000 K-ring
topologies per graph instance and keeps the best diameter.  Genome = K ring
permutations; operators: tournament selection, order crossover (OX1) per
ring, swap mutation.  ``budget`` counts diameter evaluations, matching the
paper's 1e5 budget semantics (tests/benchmarks use smaller budgets).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from .diameter import adjacency_from_rings, diameter_scipy

__all__ = ["GAConfig", "ga_search", "random_search"]


@dataclasses.dataclass(frozen=True)
class GAConfig:
    k_rings: int = 2
    population: int = 50
    budget: int = 2000          # total diameter evaluations (paper: 1e5)
    tournament: int = 4
    crossover_rate: float = 0.9
    mutation_rate: float = 0.2
    seed: int = 0


def _evaluate(w: np.ndarray, genome: List[np.ndarray]) -> float:
    return diameter_scipy(adjacency_from_rings(w, genome))


def _ox1(rng: np.random.Generator, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Order crossover: copy a slice of parent a, fill the rest in b's order."""
    n = len(a)
    i, j = sorted(rng.integers(0, n, size=2))
    child = np.full(n, -1, dtype=a.dtype)
    child[i:j + 1] = a[i:j + 1]
    used = set(child[i:j + 1].tolist())
    fill = [x for x in b if x not in used]
    pos = [idx for idx in range(n) if not (i <= idx <= j)]
    child[pos] = fill
    return child


def _mutate(rng: np.random.Generator, perm: np.ndarray) -> np.ndarray:
    out = perm.copy()
    i, j = rng.integers(0, len(perm), size=2)
    out[i], out[j] = out[j], out[i]
    return out


def ga_search(w: np.ndarray, cfg: GAConfig) -> Tuple[List[np.ndarray], float, int]:
    """Returns (best genome, best diameter, evaluations used)."""
    rng = np.random.default_rng(cfg.seed)
    n = w.shape[0]
    pop = [[rng.permutation(n) for _ in range(cfg.k_rings)]
           for _ in range(cfg.population)]
    fit = [_evaluate(w, g) for g in pop]
    evals = len(pop)
    best_i = int(np.argmin(fit))
    best, best_d = [p.copy() for p in pop[best_i]], fit[best_i]

    while evals < cfg.budget:
        # tournament selection of two parents
        def pick():
            idx = rng.integers(0, cfg.population, size=cfg.tournament)
            return pop[idx[np.argmin([fit[i] for i in idx])]]

        pa, pb = pick(), pick()
        child = []
        for r in range(cfg.k_rings):
            c = (_ox1(rng, pa[r], pb[r]) if rng.random() < cfg.crossover_rate
                 else pa[r].copy())
            if rng.random() < cfg.mutation_rate:
                c = _mutate(rng, c)
            child.append(c)
        d = _evaluate(w, child)
        evals += 1
        # steady-state replacement of the worst member
        worst = int(np.argmax(fit))
        if d < fit[worst]:
            pop[worst], fit[worst] = child, d
        if d < best_d:
            best, best_d = [c.copy() for c in child], d
    return best, best_d, evals


def random_search(w: np.ndarray, k_rings: int, budget: int,
                  seed: int = 0) -> Tuple[List[np.ndarray], float]:
    """Pure random K-ring search — the paper's "random" normalizer."""
    rng = np.random.default_rng(seed)
    n = w.shape[0]
    best, best_d = None, float("inf")
    for _ in range(budget):
        genome = [rng.permutation(n) for _ in range(k_rings)]
        d = _evaluate(w, genome)
        if d < best_d:
            best, best_d = genome, d
    return best, best_d
