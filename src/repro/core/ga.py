"""Genetic-algorithm baseline (paper §VII-A.2), batched.

The paper benchmarks DGRO against a GA that searches 100,000 K-ring
topologies per graph instance and keeps the best diameter.  Genome = K ring
permutations; operators: tournament selection, order crossover (OX1) per
ring, swap mutation.  ``budget`` counts diameter evaluations, matching the
paper's 1e5 budget semantics (tests/benchmarks use smaller budgets).

Evaluation goes through ``repro.core.batcheval``: each generation's children
are stacked as one (B, N, N) adjacency tensor and scored by the vmapped
APSP in a single device call, so ``evolve`` issues O(generations) device
calls instead of O(budget) per-genome host Dijkstras.  Survival is
(mu + lambda) elitist: the best ``population`` of parents+children carry
over, which dominates the old steady-state loop at equal budget.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from . import batcheval

__all__ = ["GAConfig", "EvolveResult", "evolve", "ga_search", "random_search"]


@dataclasses.dataclass(frozen=True)
class GAConfig:
    k_rings: int = 2
    population: int = 50
    budget: int = 2000          # total diameter evaluations (paper: 1e5)
    tournament: int = 4
    crossover_rate: float = 0.9
    mutation_rate: float = 0.2
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class EvolveResult:
    best: List[np.ndarray]      # K ring permutations
    best_diameter: float
    evaluations: int
    generations: int
    history: List[float]        # best-so-far diameter after each generation

    def to_overlay(self, w: np.ndarray):
        """The winning genome as a :class:`repro.overlay.Overlay` (the GA's
        final fitness pre-populates the diameter cache)."""
        from repro.overlay import Overlay

        return Overlay.from_rings(
            w, self.best, policy="ga").cache_diameter(self.best_diameter)


def _ox1(rng: np.random.Generator, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Order crossover: copy a slice of parent a, fill the rest in b's order."""
    n = len(a)
    i, j = sorted(rng.integers(0, n, size=2))
    child = np.full(n, -1, dtype=a.dtype)
    child[i:j + 1] = a[i:j + 1]
    used = set(child[i:j + 1].tolist())
    fill = [x for x in b if x not in used]
    pos = [idx for idx in range(n) if not (i <= idx <= j)]
    child[pos] = fill
    return child


def _mutate(rng: np.random.Generator, perm: np.ndarray) -> np.ndarray:
    out = perm.copy()
    i, j = rng.integers(0, len(perm), size=2)
    out[i], out[j] = out[j], out[i]
    return out


def _tournament(rng: np.random.Generator, fit: np.ndarray, k: int) -> int:
    idx = rng.integers(0, len(fit), size=k)
    return int(idx[np.argmin(fit[idx])])


def evolve(w: np.ndarray, cfg: GAConfig) -> EvolveResult:
    """Generational GA: breed a full cohort on the host, score it as ONE
    batched device call, keep the elite (mu + lambda)."""
    rng = np.random.default_rng(cfg.seed)
    n = w.shape[0]
    pop = np.stack([[rng.permutation(n) for _ in range(cfg.k_rings)]
                    for _ in range(cfg.population)])          # (P, K, N)
    fit = batcheval.diameters_of_rings(w, pop).astype(np.float64)
    evals = cfg.population
    history = [float(fit.min())]

    while evals < cfg.budget:
        n_children = min(cfg.population, cfg.budget - evals)
        children = np.empty((n_children, cfg.k_rings, n), dtype=pop.dtype)
        for c in range(n_children):
            pa = pop[_tournament(rng, fit, cfg.tournament)]
            pb = pop[_tournament(rng, fit, cfg.tournament)]
            for r in range(cfg.k_rings):
                ch = (_ox1(rng, pa[r], pb[r])
                      if rng.random() < cfg.crossover_rate else pa[r].copy())
                if rng.random() < cfg.mutation_rate:
                    ch = _mutate(rng, ch)
                children[c, r] = ch
        child_fit = batcheval.diameters_of_rings(w, children).astype(np.float64)
        evals += n_children
        all_fit = np.concatenate([fit, child_fit])
        survivors = np.argsort(all_fit, kind="stable")[:cfg.population]
        pool = np.concatenate([pop, children])
        pop, fit = pool[survivors], all_fit[survivors]
        history.append(float(fit.min()))

    best_i = int(np.argmin(fit))
    best = [pop[best_i, r].copy() for r in range(cfg.k_rings)]
    return EvolveResult(best, float(fit[best_i]), evals,
                        len(history) - 1, history)


def ga_search(w: np.ndarray, cfg: GAConfig) -> Tuple[List[np.ndarray], float, int]:
    """Returns (best genome, best diameter, evaluations used)."""
    res = evolve(w, cfg)
    return res.best, res.best_diameter, res.evaluations


def random_search(w: np.ndarray, k_rings: int, budget: int,
                  seed: int = 0,
                  host_chunk: int | None = None) -> Tuple[List[np.ndarray], float]:
    """Pure random K-ring search — the paper's "random" normalizer.

    Scored in batched slabs so a 1e5 budget never materializes the full
    (budget, N, N) adjacency tensor; the slab size scales with N to keep
    each host-side stack under ~256 MiB (4096 genomes max).
    """
    rng = np.random.default_rng(seed)
    n = w.shape[0]
    if host_chunk is None:
        host_chunk = min(4096, max(1, (1 << 28) // (4 * n * n)))
    best, best_d = None, float("inf")
    done = 0
    while done < budget:
        m = min(host_chunk, budget - done)
        genomes = np.stack([[rng.permutation(n) for _ in range(k_rings)]
                            for _ in range(m)])               # (m, K, N)
        d = batcheval.diameters_of_rings(w, genomes)
        i = int(np.argmin(d))
        if float(d[i]) < best_d:
            best, best_d = [genomes[i, r].copy() for r in range(k_rings)], float(d[i])
        done += m
    return best, best_d
