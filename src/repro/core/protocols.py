"""Baseline P2P overlay topologies the paper compares against (§V-A, §VII).

* Chord   — identifier ring from a consistent hash (random permutation) plus
            finger edges to the 2^j-th successor (Stoica et al. 2001).
* RAPID   — K random rings from K consistent hash functions (Suresh et al.
            2018); expander-like but latency-oblivious.
* Perigee — latency-aware neighbour selection (Mao et al. 2020): each node
            keeps its d lowest-latency neighbours.  The paper always combines
            Perigee with a ring "otherwise no connectivity guarantee".

Each builder returns ``(adjacency, rings)`` where ``adjacency`` is the
weighted overlay (INF on non-edges) and ``rings`` the list of ring
permutations it embeds (the part DGRO's selection is allowed to swap).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from .construction import default_num_rings, nearest_ring, random_ring
from .diameter import (adjacency_from_edges, adjacency_from_rings, is_edge,
                       ring_edges)

__all__ = ["chord", "rapid", "perigee", "node_degrees", "with_replaced_rings"]

Overlay = Tuple[np.ndarray, List[np.ndarray]]


def chord(w: np.ndarray, rng: np.random.Generator) -> Overlay:
    """Chord: hash-ordered ring + fingers at power-of-two offsets."""
    n = w.shape[0]
    perm = random_ring(rng, n)  # identifier-space order
    edges = list(ring_edges(perm))
    # finger j of the node at ring position i points 2^j positions ahead
    j = 1
    while (1 << j) < n:
        off = 1 << j
        for i in range(n):
            edges.append((perm[i], perm[(i + off) % n]))
        j += 1
    return adjacency_from_edges(w, edges), [perm]


def rapid(w: np.ndarray, rng: np.random.Generator, k: int | None = None) -> Overlay:
    """RAPID: K independent consistent-hash (random) rings."""
    n = w.shape[0]
    k = k or default_num_rings(n)
    rings = [random_ring(rng, n) for _ in range(k)]
    return adjacency_from_rings(w, rings), rings


def perigee(
    w: np.ndarray,
    rng: np.random.Generator,
    degree: int | None = None,
    ring_kind: str = "random",
) -> Overlay:
    """Perigee: per-node d nearest (lowest-latency) neighbours + one ring.

    ``ring_kind`` in {"random", "nearest"} selects the connectivity ring —
    the knob DGRO's §V selection turns (Figs. 7/11/15).
    """
    n = w.shape[0]
    degree = degree or default_num_rings(n)
    edges = []
    for u in range(n):
        order = np.argsort(w[u])
        nearest = [v for v in order if v != u][:degree]
        edges.extend((u, v) for v in nearest)
    if ring_kind == "random":
        ring = random_ring(rng, n)
    elif ring_kind == "nearest":
        ring = nearest_ring(w, start=int(rng.integers(n)))
    else:
        raise ValueError(ring_kind)
    edges.extend(ring_edges(ring))
    return adjacency_from_edges(w, edges), [ring]


def node_degrees(adj: np.ndarray) -> np.ndarray:
    """Per-node overlay degree (number of actual edges per row)."""
    return is_edge(adj).sum(axis=1)


def with_replaced_rings(
    w: np.ndarray,
    base_edges_adj: np.ndarray,
    old_rings: List[np.ndarray],
    new_rings: List[np.ndarray],
) -> np.ndarray:
    """Rebuild an overlay with some rings swapped (DGRO ring selection).

    ``base_edges_adj`` must be the overlay *without* the old rings; callers
    that only have the full overlay should rebuild from scratch instead.
    """
    d = np.array(base_edges_adj, copy=True)
    for ring in new_rings:
        for u, v in ring_edges(ring):
            d[u, v] = min(d[u, v], w[u, v])
            d[v, u] = min(d[v, u], w[v, u])
    return d
