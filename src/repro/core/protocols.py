"""DEPRECATED tuple facade over ``repro.overlay`` (§V-A baselines).

The Chord / RAPID / Perigee builders used to live here and return raw
``(adjacency, rings)`` tuples.  They are now registered builders in
:mod:`repro.overlay` (``overlay.build("chord", w, rng=rng)`` etc.); the
functions below are thin shims that unwrap an :class:`~repro.overlay.Overlay`
for call sites that still expect tuples.  Each shim emits a
``DeprecationWarning`` exactly once per process.

New code should use::

    from repro import overlay
    ov = overlay.build("perigee", w, overlay.PerigeeConfig(ring="nearest"),
                       rng=rng)
    ov.adjacency, ov.rings        # what the tuple used to carry
"""
from __future__ import annotations

import warnings
from typing import List, Sequence, Tuple

import numpy as np

from .diameter import is_edge, ring_edges

__all__ = ["chord", "rapid", "perigee", "node_degrees", "with_replaced_rings"]

_WARNED: set = set()


def _warn_legacy(name: str, replacement: str) -> None:
    """One DeprecationWarning per legacy shim per process (shared by the
    tuple facades here and in selection / qlearning)."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {replacement} "
        f"(the repro.overlay API replaces (adjacency, rings) tuples)",
        DeprecationWarning, stacklevel=3)


def chord(w: np.ndarray, rng: np.random.Generator
          ) -> Tuple[np.ndarray, List]:
    """Deprecated: ``overlay.build("chord", w, rng=rng)``."""
    _warn_legacy("repro.core.protocols.chord",
                 'overlay.build("chord", w, rng=rng)')
    from repro import overlay
    return overlay.build("chord", w, rng=rng).to_tuple()


def rapid(w: np.ndarray, rng: np.random.Generator, k: int | None = None
          ) -> Tuple[np.ndarray, List]:
    """Deprecated: ``overlay.build("rapid", w, overlay.RapidConfig(k=k), ...)``."""
    _warn_legacy("repro.core.protocols.rapid",
                 'overlay.build("rapid", w, k=k, rng=rng)')
    from repro import overlay
    return overlay.build("rapid", w, overlay.RapidConfig(k=k),
                         rng=rng).to_tuple()


def perigee(
    w: np.ndarray,
    rng: np.random.Generator,
    degree: int | None = None,
    ring_kind: str = "random",
) -> Tuple[np.ndarray, List]:
    """Deprecated: ``overlay.build("perigee", w, overlay.PerigeeConfig(...))``."""
    _warn_legacy("repro.core.protocols.perigee",
                 'overlay.build("perigee", w, degree=d, ring=kind, rng=rng)')
    from repro import overlay
    return overlay.build(
        "perigee", w, overlay.PerigeeConfig(degree=degree, ring=ring_kind),
        rng=rng).to_tuple()


def node_degrees(adj: np.ndarray) -> np.ndarray:
    """Per-node overlay degree (number of actual edges per row)."""
    return is_edge(adj).sum(axis=1)


def with_replaced_rings(
    w: np.ndarray,
    base_edges_adj: np.ndarray,
    old_rings: Sequence[np.ndarray],
    new_rings: Sequence[np.ndarray],
) -> np.ndarray:
    """Deprecated: :meth:`repro.overlay.Overlay.replace_rings`.

    Rebuild an overlay with its rings swapped.  ``base_edges_adj`` must be
    the overlay *without* the old rings; callers that only have the full
    overlay should rebuild from scratch instead.  The replacement set must
    match the old ring count — a silently changed count would alter the
    per-node degree budget.
    """
    _warn_legacy("repro.core.protocols.with_replaced_rings",
                 "Overlay.replace_rings(new_rings)")
    if len(new_rings) != len(old_rings):
        raise ValueError(
            f"replacement ring count {len(new_rings)} != current "
            f"{len(old_rings)}; rebuild the overlay to change the ring count")
    d = np.array(base_edges_adj, copy=True)
    for ring in new_rings:
        for u, v in ring_edges(ring):
            d[u, v] = min(d[u, v], w[u, v])
            d[v, u] = min(d[v, u], w[v, u])
    return d
