"""Tuple-era protocol helpers — the builders now live in ``repro.overlay``.

The Chord / RAPID / Perigee construction rules used to live here and return
raw ``(adjacency, rings)`` tuples; they moved to registered builders in
:mod:`repro.overlay` (PR 3) and the deprecation shims that bridged the two
APIs are now REMOVED (two PR cycles past the deprecation).  Importing a
removed name raises ``AttributeError`` with the replacement spelled out::

    from repro import overlay
    ov = overlay.build("chord", w, rng=rng)       # was protocols.chord
    ov.adjacency, ov.rings                        # what the tuple carried

Only :func:`node_degrees` remains — a plain adjacency utility with no
Overlay equivalent at the raw-matrix level.
"""
from __future__ import annotations

import numpy as np

from .diameter import is_edge

__all__ = ["node_degrees"]

_REMOVED = {
    "chord": 'overlay.build("chord", w, rng=rng)',
    "rapid": 'overlay.build("rapid", w, overlay.RapidConfig(k=k), rng=rng)',
    "perigee": 'overlay.build("perigee", w, overlay.PerigeeConfig(...), rng=rng)',
    "with_replaced_rings": "Overlay.replace_rings(new_rings)",
}


def __getattr__(name: str):
    if name in _REMOVED:
        raise AttributeError(
            f"repro.core.protocols.{name} was removed; use {_REMOVED[name]} "
            f"(the repro.overlay API replaced (adjacency, rings) tuples; "
            f"see overlay.build)")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def node_degrees(adj: np.ndarray) -> np.ndarray:
    """Per-node overlay degree (number of actual edges per row)."""
    return is_edge(adj).sum(axis=1)
