"""Ring construction (paper §IV-B, Algorithm 1).

A solution is a permutation ``perm`` of the N nodes; the ring is
perm[0] -> perm[1] -> ... -> perm[N-1] -> perm[0].  K-ring topologies are
unions of K such rings.  Constructors:

* ``random_ring``    — the consistent-hash ring of Chord/RAPID (§II, §V).
* ``nearest_ring``   — the paper's "shortest ring": sequentially select the
                       nearest available neighbour (§V last ¶).
* ``greedy_ring``    — Algorithm 1 with an arbitrary score function; the DQN
                       plugs its Q-function in here (score = Q(S_t, u)).
* ``nearest_ring_jax`` — jit-able nearest-neighbour constructor (fori_loop),
                       used by the parallel builders (§VI).
* ``nearest_rings_batched`` — the same constructor vmapped over an
                       (M, P, P) stack of latency blocks: the device-batched
                       parallel engine builds every partition's segment in
                       ONE jit'd call (INF-padded blocks keep pad nodes
                       unreachable until the real nodes are exhausted).
"""
from __future__ import annotations

from typing import Callable, List, Sequence

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "random_ring",
    "nearest_ring",
    "greedy_ring",
    "nearest_ring_jax",
    "nearest_rings_batched",
    "k_rings",
]

ScoreFn = Callable[[np.ndarray, np.ndarray, int, np.ndarray], np.ndarray]
# signature: (W, visited_mask, current_node, partial_perm) -> scores (N,)


def random_ring(rng: np.random.Generator, n: int) -> np.ndarray:
    """Uniformly random permutation — models the consistent-hash logical ring."""
    return rng.permutation(n)


def greedy_ring(
    w: np.ndarray,
    score_fn: ScoreFn,
    start: int = 0,
) -> np.ndarray:
    """Algorithm 1: sequentially add the argmax-score node (host loop).

    At step t the candidate set is the unvisited nodes; ``score_fn`` scores
    every node and visited ones are masked to -inf.
    """
    n = w.shape[0]
    perm = np.empty(n, dtype=np.int64)
    perm[0] = start
    visited = np.zeros(n, dtype=bool)
    visited[start] = True
    cur = start
    for t in range(1, n):
        scores = np.asarray(score_fn(w, visited, cur, perm[:t]), dtype=np.float64)
        scores[visited] = -np.inf
        cur = int(np.argmax(scores))
        perm[t] = cur
        visited[cur] = True
    return perm


def nearest_ring(w: np.ndarray, start: int = 0) -> np.ndarray:
    """The paper's "shortest ring": greedy nearest-available-neighbour."""

    def score(w, visited, cur, _perm):
        return -w[cur]

    return greedy_ring(w, score, start)


def nearest_ring_jax(w: jnp.ndarray, start: jnp.ndarray) -> jnp.ndarray:
    """jit-able nearest-neighbour ring (used inside shard_map, §VI)."""
    n = w.shape[0]

    def body(t, state):
        perm, visited, cur = state
        d = jnp.where(visited, jnp.inf, w[cur])
        nxt = jnp.argmin(d)
        return perm.at[t].set(nxt), visited.at[nxt].set(True), nxt

    perm0 = jnp.zeros((n,), jnp.int32).at[0].set(start)
    visited0 = jnp.zeros((n,), bool).at[start].set(True)
    perm, _, _ = jax.lax.fori_loop(1, n, body, (perm0, visited0, start))
    return perm


@jax.jit
def nearest_rings_batched(blocks: jnp.ndarray,
                          starts: jnp.ndarray) -> jnp.ndarray:
    """Nearest-neighbour rings for an (M, P, P) latency-block stack — all M
    partitions in one jit'd vmap (the device-batched parallel engine, §VI).

    Blocks holding fewer than P real nodes pad the extra rows/cols with a
    large-but-finite sentinel (``diameter.INF``): every real unvisited node
    scores below the sentinel, so the greedy argmin exhausts the real nodes
    first and ``perm[:size]`` is exactly the block's own ring order.
    Returns (M, P) int32 permutations of each padded block.
    """
    return jax.vmap(nearest_ring_jax)(blocks, starts)


def k_rings(
    w: np.ndarray,
    k: int,
    kind: str = "random",
    rng: np.random.Generator | None = None,
    starts: Sequence[int] | None = None,
) -> List[np.ndarray]:
    """K rings of a given kind ("random" | "nearest" | "mixed:<m>").

    ``mixed:<m>`` builds m random rings and (k - m) nearest rings — the
    RAPID hybrid of the paper's ablation (§VII-C.2, Figs. 12/16).
    """
    rng = rng or np.random.default_rng(0)
    n = w.shape[0]
    if starts is None:
        starts = list(rng.integers(0, n, size=k))
    if kind.startswith("mixed:"):
        m = int(kind.split(":")[1])
        assert 0 <= m <= k, (m, k)
        kinds = ["random"] * m + ["nearest"] * (k - m)
    else:
        kinds = [kind] * k
    rings = []
    for i, kk in enumerate(kinds):
        if kk == "random":
            rings.append(random_ring(rng, n))
        elif kk == "nearest":
            rings.append(nearest_ring(w, start=int(starts[i % len(starts)])))
        else:
            raise ValueError(f"unknown ring kind {kk!r}")
    return rings


def default_num_rings(n: int) -> int:
    """Paper: each node keeps log(N) outgoing connections; one ring buys one
    outgoing edge per node, so K = ceil(log2 N) rings."""
    return max(1, int(np.ceil(np.log2(max(n, 2)))))
