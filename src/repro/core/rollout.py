"""Device-resident vectorized DQN episode engine (paper §IV, Algs. 1-2).

The original Q-learning driver ran every episode from the host: one device
round-trip per action (Q forward), a full O(N^3 log N) min-plus APSP per
edge added (the reward), and a host-side replay buffer — O(k * N *
updates) device calls per epoch.  This module fuses an entire epoch into
ONE jit'd ``lax.scan``:

* **E parallel environments** — independent latency graphs advance in
  lockstep under ``vmap``; an epoch processes an (E, N, N) stack.
* **eps-greedy inside the scan** — a fixed-shape masked
  :func:`repro.core.embedding.q_values_batch` scores all E states per step;
  random exploration consumes pre-generated uniforms (:class:`RolloutPlan`)
  so the host debug path can replay the *identical* decision sequence.
* **incremental rewards** — the scan carries the exact APSP matrix of the
  partial solution and repairs it per edge with the O(N^2)
  :func:`repro.core.diameter.relax_edge_update` (shared with
  ``dynamics.incremental``), replacing the per-edge O(N^3) full APSP.
* **device replay buffer** — fixed-capacity transition arrays plus a write
  pointer live in the scan carry.  Transitions store a *graph index* into a
  small ring table of epoch graphs instead of a full (N, N) latency copy
  per step (every step of an epoch shares one graph).
* **fused TD updates** — once the buffer holds a batch,
  ``jax.lax.cond`` switches on per-step AdamW TD updates, sampling via the
  plan's uniforms.

Determinism contract: the engine draws NO randomness of its own.  All
stochastic decisions come from a :class:`RolloutPlan` pre-generated on the
host from a ``numpy.random.Generator``, so a host loop consuming the same
plan (``qlearning._run_episode``) makes identical decisions and builds
identical rings — the parity tests in ``tests/test_rollout.py`` assert
this.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.diameter import INF, largest_cc_diameter, relax_edge_update
from repro.core.embedding import QParams, q_values, q_values_batch
from repro.train.optimizer import AdamWConfig, adamw_update

__all__ = [
    "RolloutPlan", "make_plan", "DeviceBuffer", "init_buffer",
    "graph_slots", "rollout_episodes", "train_epoch", "td_update_impl",
    "perms_from_actions",
]


# ---------------------------------------------------------------------------
# pre-generated randomness (shared by device scan and host debug loop)
# ---------------------------------------------------------------------------

class RolloutPlan(NamedTuple):
    """Every random draw an epoch makes, generated up front on the host.

    ``starts``: (E, K) ring start nodes; ``eps_u``/``choice_u``: (T, E)
    uniforms for the eps-greedy coin and the random-action pick
    (T = K * N steps); ``sample_u``: (T, U, B) uniforms for replay
    sampling (empty when not training).
    """

    starts: np.ndarray
    eps_u: np.ndarray
    choice_u: np.ndarray
    sample_u: np.ndarray


def make_plan(rng: np.random.Generator, n_envs: int, k_rings: int, n: int,
              updates_per_step: int = 0, batch_size: int = 0) -> RolloutPlan:
    t = k_rings * n
    starts = rng.integers(0, n, size=(n_envs, k_rings)).astype(np.int32)
    eps_u = rng.random((t, n_envs), dtype=np.float32)
    choice_u = rng.random((t, n_envs), dtype=np.float32)
    if updates_per_step and batch_size:
        sample_u = rng.random((t, updates_per_step, batch_size),
                              dtype=np.float32)
    else:
        sample_u = np.zeros((t, 0, 0), np.float32)
    return RolloutPlan(starts, eps_u, choice_u, sample_u)


# ---------------------------------------------------------------------------
# device-resident replay buffer (arrays + write pointer in the scan carry)
# ---------------------------------------------------------------------------

class DeviceBuffer(NamedTuple):
    """Alg. 2 memory M as a pytree of fixed-shape device arrays.

    ``table`` is a small ring of epoch latency graphs; transitions store
    ``widx`` (an index into it) instead of a per-step (N, N) copy.
    """

    table: jnp.ndarray         # (G, N, N) f32 epoch-graph ring
    widx: jnp.ndarray          # (C,) i32 graph index
    adj: jnp.ndarray           # (C, N, N) u8 pre-action adjacency
    v: jnp.ndarray             # (C,) i32
    action: jnp.ndarray        # (C,) i32
    reward: jnp.ndarray        # (C,) f32
    adj_next: jnp.ndarray      # (C, N, N) u8
    v_next: jnp.ndarray        # (C,) i32
    visited_next: jnp.ndarray  # (C, N) u8
    done: jnp.ndarray          # (C,) f32
    size: jnp.ndarray          # () i32
    ptr: jnp.ndarray           # () i32


def graph_slots(capacity: int, n_envs: int, k_rings: int, n: int) -> int:
    """Ring-table size that guarantees no live transition's graph is ever
    overwritten: a transition survives at most ceil(C / pushes-per-epoch)
    epochs (FIFO overwrite), so one extra epoch of slots is enough."""
    pushes_per_epoch = max(n_envs * k_rings * (n - 1), 1)
    return n_envs * (int(np.ceil(capacity / pushes_per_epoch)) + 1)


def init_buffer(capacity: int, n: int, slots: int) -> DeviceBuffer:
    return DeviceBuffer(
        table=jnp.zeros((slots, n, n), jnp.float32),
        widx=jnp.zeros((capacity,), jnp.int32),
        adj=jnp.zeros((capacity, n, n), jnp.uint8),
        v=jnp.zeros((capacity,), jnp.int32),
        action=jnp.zeros((capacity,), jnp.int32),
        reward=jnp.zeros((capacity,), jnp.float32),
        adj_next=jnp.zeros((capacity, n, n), jnp.uint8),
        v_next=jnp.zeros((capacity,), jnp.int32),
        visited_next=jnp.zeros((capacity, n), jnp.uint8),
        done=jnp.zeros((capacity,), jnp.float32),
        size=jnp.zeros((), jnp.int32),
        ptr=jnp.zeros((), jnp.int32),
    )


def _push(buf: DeviceBuffer, gids, adj_prev, v, a, reward, adj_next,
          visited_next, done) -> DeviceBuffer:
    cap = buf.v.shape[0]
    e = v.shape[0]
    idx = (buf.ptr + jnp.arange(e, dtype=jnp.int32)) % cap
    return buf._replace(
        widx=buf.widx.at[idx].set(gids),
        adj=buf.adj.at[idx].set(adj_prev.astype(jnp.uint8)),
        v=buf.v.at[idx].set(v),
        action=buf.action.at[idx].set(a),
        reward=buf.reward.at[idx].set(reward),
        adj_next=buf.adj_next.at[idx].set(adj_next.astype(jnp.uint8)),
        v_next=buf.v_next.at[idx].set(a),
        visited_next=buf.visited_next.at[idx].set(
            visited_next.astype(jnp.uint8)),
        done=buf.done.at[idx].set(done.astype(jnp.float32)),
        size=jnp.minimum(buf.size + e, cap),
        ptr=(buf.ptr + e) % cap,
    )


# ---------------------------------------------------------------------------
# TD update (shared math: host jit wrapper in qlearning, in-scan here)
# ---------------------------------------------------------------------------

def td_update_impl(params: QParams, opt_state, w, adj, v, action, reward,
                   adj_next, v_next, visited_next, done, gamma, lr,
                   n_rounds: int = 3):
    """One AdamW step on the squared TD error over a replay batch."""

    def q_sa(p, w1, a1, v1, act1):
        return q_values(p, w1, a1.astype(jnp.float32), v1, n_rounds)[act1]

    def target(w1, an1, vn1, vis1, d1, r1):
        qn = q_values(params, w1, an1.astype(jnp.float32), vn1, n_rounds)
        qn = jnp.where(vis1.astype(bool), -jnp.inf, qn)
        best = jnp.max(qn)
        best = jnp.where(jnp.isfinite(best), best, 0.0)
        return r1 + gamma * best * (1.0 - d1)

    y = jax.vmap(target)(w, adj_next, v_next, visited_next,
                         done.astype(jnp.float32), reward)
    y = jax.lax.stop_gradient(y)

    def loss_fn(p):
        q = jax.vmap(q_sa, in_axes=(None, 0, 0, 0, 0))(p, w, adj, v, action)
        return jnp.mean(jnp.square(y - q))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    cfg = AdamWConfig(lr=lr, b1=0.9, b2=0.999, clip_norm=5.0)
    new_params, new_state, _ = adamw_update(cfg, grads, opt_state, params)
    return new_params, new_state, loss


# ---------------------------------------------------------------------------
# the fused episode step (shared by rollout-only and training scans)
# ---------------------------------------------------------------------------

def _select_actions(params, w_batch, adj, visited, v, cur_start, eps_u_t,
                    choice_u_t, eps, closing, n_rounds: int):
    """Fixed-shape eps-greedy over all E environments (one batched Q call).

    The random branch picks the ``floor(u * n_unvisited)``-th unvisited
    node — the same formula the host debug loop applies to the same plan
    uniforms, so decisions match bit-for-bit."""
    q = q_values_batch(params, w_batch, adj, v, n_rounds=n_rounds)  # (E, N)
    q = jnp.where(visited, -jnp.inf, q)
    greedy = jnp.argmax(q, axis=1).astype(jnp.int32)
    n_unvis = jnp.sum(~visited, axis=1).astype(jnp.int32)
    ridx = (choice_u_t * n_unvis.astype(jnp.float32)).astype(jnp.int32)
    ridx = jnp.minimum(ridx, n_unvis - 1)
    order = jnp.cumsum(~visited, axis=1).astype(jnp.int32) - 1   # (E, N)
    rand_a = jnp.argmax((order == ridx[:, None]) & ~visited,
                        axis=1).astype(jnp.int32)
    a = jnp.where(eps_u_t < eps, rand_a, greedy)
    return jnp.where(closing, cur_start, a)


def _apply_edge(w_batch, dist, adj, v, a, prev_d, alpha):
    """Add edge (v, a) in every env: O(N^2) relax + largest-CC diameter."""
    e_ix = jnp.arange(v.shape[0])
    w_edge = w_batch[e_ix, v, a]
    adj = adj.at[e_ix, v, a].set(1.0)
    adj = adj.at[e_ix, a, v].set(1.0)
    dist = jax.vmap(relax_edge_update)(dist, v, a, w_edge)
    new_d = jax.vmap(largest_cc_diameter)(dist)
    reward = prev_d - new_d - alpha * w_edge
    return dist, adj, new_d, reward, w_edge


def _stretch_potential(dist, opt):
    """Mean routing stretch of the partial solution, per env.

    ``dist``: (E, N, N) partial-overlay APSP (INF where unreached);
    ``opt``: (E, N, N) full-graph APSP.  Averages ``dist/opt`` over
    finite off-diagonal pairs — the potential whose per-step decrease the
    optional ``stretch_weight`` reward term pays out (pairs the overlay
    has not yet connected contribute nothing, so the term only rewards
    tightening paths that exist, never merely connecting new ones — the
    base diameter reward already owns connectivity)."""
    n = dist.shape[-1]
    offdiag = ~jnp.eye(n, dtype=bool)
    finite = (dist < INF / 2) & offdiag
    ratio = jnp.where(finite, dist / jnp.maximum(opt, jnp.float32(1e-6)), 0.0)
    cnt = jnp.sum(finite, axis=(1, 2)).astype(jnp.float32)
    return jnp.sum(ratio, axis=(1, 2)) / jnp.maximum(cnt, 1.0)


def _episode_init(n_envs: int, n: int):
    dist0 = jnp.full((n_envs, n, n), INF, jnp.float32)
    ar = jnp.arange(n)
    dist0 = dist0.at[:, ar, ar].set(0.0)
    return (dist0,
            jnp.zeros((n_envs, n, n), jnp.float32),      # adjacency (0/1)
            jnp.zeros((n_envs, n), bool),                # visited
            jnp.zeros((n_envs,), jnp.int32),             # v
            jnp.zeros((n_envs,), jnp.int32),             # current ring start
            jnp.zeros((n_envs,), jnp.float32))           # prev diameter


def _step_masks(k_rings: int, n: int):
    """Static per-step flags: is this step a ring start / a closing edge?"""
    t = np.arange(k_rings * n)
    return (jnp.asarray(t % n == 0), jnp.asarray(t % n == n - 1),
            jnp.asarray(t // n == k_rings - 1))


def _reset_ring(ring_start, start_t, visited, v, cur_start, pad_mask=None):
    n_envs, n = visited.shape
    onehot = jnp.zeros((n_envs, n), bool).at[
        jnp.arange(n_envs), start_t].set(True)
    if pad_mask is not None:     # padded envs: pad nodes are never selectable
        onehot = onehot | pad_mask
    visited = jnp.where(ring_start, onehot, visited)
    v = jnp.where(ring_start, start_t, v)
    cur_start = jnp.where(ring_start, start_t, cur_start)
    return visited, v, cur_start


# ---------------------------------------------------------------------------
# public engine entry points
# ---------------------------------------------------------------------------

def rollout_episodes(params: QParams, w_batch: jnp.ndarray,
                     starts: jnp.ndarray, eps_u: jnp.ndarray,
                     choice_u: jnp.ndarray, eps, alpha, *,
                     k_rings: int, n_rounds: int = 3, sizes=None,
                     stretch_weight: float = 0.0):
    """Build K rings in each of E environments — ONE device call.

    (Host wrapper: the jit'd engine is ``_rollout_episodes_jit``; this
    shim times each call through ``repro.obs``'s JIT-aware span, keyed by
    the retrace-triggering shape/static args, so the first-call compile
    and the steady-state execute land in separate histograms.)
    """
    from repro.obs import jit_span
    key = (tuple(w_batch.shape), k_rings, n_rounds, sizes is None,
           float(stretch_weight))
    with jit_span("rollout.rollout_episodes", key=key):
        return _rollout_episodes_jit(
            params, w_batch, starts, eps_u, choice_u, eps, alpha,
            k_rings=k_rings, n_rounds=n_rounds, sizes=sizes,
            stretch_weight=float(stretch_weight))


@functools.partial(jax.jit,
                   static_argnames=("k_rings", "n_rounds", "stretch_weight"))
def _rollout_episodes_jit(params: QParams, w_batch: jnp.ndarray,
                          starts: jnp.ndarray, eps_u: jnp.ndarray,
                          choice_u: jnp.ndarray, eps, alpha, *,
                          k_rings: int, n_rounds: int = 3, sizes=None,
                          stretch_weight: float = 0.0):
    """Build K rings in each of E environments — ONE device call.

    ``w_batch``: (E, N, N) latency stack; ``starts``/``eps_u``/``choice_u``
    from :func:`make_plan`.  Returns ``(actions (T, E), rewards (T, E),
    final_diameter (E,))`` with T = K * N scan steps.

    ``sizes`` (optional, (E,) int) marks env e's graph as occupying only
    nodes ``[0, sizes[e])`` of the padded N-node block (the parallel
    construction engine batches unequal partitions this way): pad nodes are
    masked visited at every ring reset, the closing edge fires per-env at
    step ``sizes[e] - 1``, and later steps of that ring are no-ops (state
    frozen, reward 0).  ``sizes=None`` (the default) is exactly the
    full-size behavior; env starts must satisfy ``starts[e] < sizes[e]``.

    ``stretch_weight`` (static, default 0.0) adds a routing-stretch
    shaping term: each step additionally pays
    ``stretch_weight * (potential(dist) - potential(dist'))`` where the
    potential is :func:`_stretch_potential` against the full-graph APSP
    of ``w_batch``.  The falsy default skips the branch at TRACE time, so
    ``stretch_weight=0.0`` is bit-identical to the unshaped engine (same
    compiled program — the parity gate in ``benchmarks/fig19_routing.py``
    and ``tests/test_routing.py`` assert this).
    """
    n_envs, n = w_batch.shape[0], w_batch.shape[1]
    if stretch_weight:
        from repro.core.batcheval import batched_apsp
        opt = batched_apsp(w_batch)                       # (E, N, N)
        sw = jnp.float32(stretch_weight)
    ring_start, _, _ = _step_masks(k_rings, n)
    rt = jnp.asarray(np.tile(np.arange(n, dtype=np.int32), k_rings))  # (T,)
    start_t = jnp.repeat(starts.T, n, axis=0)            # (T, E)
    eps = jnp.float32(eps)
    alpha = jnp.float32(alpha)
    sizes = (jnp.full((n_envs,), n, jnp.int32) if sizes is None
             else jnp.asarray(sizes, jnp.int32))
    pad_mask = jnp.arange(n, dtype=jnp.int32)[None, :] >= sizes[:, None]

    def step(carry, xs):
        dist, adj, visited, v, cur_start, prev_d = carry
        rs, rt_t, st, eu, cu = xs
        visited, v, cur_start = _reset_ring(rs, st, visited, v, cur_start,
                                            pad_mask)
        cl = rt_t == sizes - 1        # (E,) per-env ring-closing step
        active = rt_t < sizes         # (E,) padded envs idle past their size
        a = _select_actions(params, w_batch, adj, visited, v, cur_start,
                            eu, cu, eps, cl, n_rounds)
        dist2, adj2, new_d, reward, _ = _apply_edge(
            w_batch, dist, adj, v, a, prev_d, alpha)
        if stretch_weight:
            reward = reward + sw * (_stretch_potential(dist, opt)
                                    - _stretch_potential(dist2, opt))
        act3 = active[:, None, None]
        dist = jnp.where(act3, dist2, dist)
        adj = jnp.where(act3, adj2, adj)
        new_d = jnp.where(active, new_d, prev_d)
        reward = jnp.where(active, reward, 0.0)
        visited = jnp.where(active[:, None],
                            visited.at[jnp.arange(n_envs), a].set(True),
                            visited)
        v = jnp.where(cl | ~active, v, a)
        return (dist, adj, visited, v, cur_start, new_d), (a, reward)

    carry0 = _episode_init(n_envs, n)
    (dist, *_rest, prev_d), (actions, rewards) = jax.lax.scan(
        step, carry0, (ring_start, rt, start_t, eps_u, choice_u))
    return actions, rewards, prev_d


@functools.partial(jax.jit, static_argnames=(
    "k_rings", "n_rounds", "batch_size", "updates_per_step",
    "stretch_weight"),
    donate_argnames=("buf",))
def train_epoch(params: QParams, opt_state, buf: DeviceBuffer,
                w_batch: jnp.ndarray, gids: jnp.ndarray, starts: jnp.ndarray,
                eps_u: jnp.ndarray, choice_u: jnp.ndarray,
                sample_u: jnp.ndarray, eps, gamma, lr, alpha, *,
                k_rings: int, n_rounds: int = 3, batch_size: int = 32,
                updates_per_step: int = 1, stretch_weight: float = 0.0):
    """One full training epoch (Alg. 2) fused into a single device call.

    Episodes over the (E, N, N) graph stack with eps-greedy actions,
    incremental-relax rewards, transition pushes into the device buffer
    (graph table slots ``gids``) and — once the buffer holds
    ``batch_size`` transitions — ``updates_per_step`` TD/AdamW updates per
    step via ``lax.cond``.  Returns ``(params, opt_state, buf,
    final_diameter (E,), losses (T,), actions (T, E), rewards (T, E))``;
    ``losses`` is the per-step mean over the step's TD updates, NaN on
    steps before the buffer fills.  ``buf`` is donated — the caller must
    rebind it to the returned buffer and not reuse the argument.

    ``stretch_weight`` (static, default 0.0): same optional stretch
    shaping as :func:`rollout_episodes` — the shaped reward is what lands
    in the replay buffer, so the Q function trains against it.  The falsy
    default compiles to the identical unshaped program.
    """
    n_envs, n = w_batch.shape[0], w_batch.shape[1]
    if stretch_weight:
        from repro.core.batcheval import batched_apsp
        opt = batched_apsp(w_batch)
        sw = jnp.float32(stretch_weight)
    ring_start, closing, last_ring = _step_masks(k_rings, n)
    start_t = jnp.repeat(starts.T, n, axis=0)
    eps = jnp.float32(eps)
    gamma = jnp.float32(gamma)
    lr = jnp.float32(lr)
    alpha = jnp.float32(alpha)
    buf = buf._replace(table=buf.table.at[gids].set(w_batch))

    def td_updates(ops):
        p, o, b, su = ops
        total = jnp.float32(0.0)
        for ui in range(updates_per_step):
            idx = (su[ui] * b.size.astype(jnp.float32)).astype(jnp.int32)
            idx = jnp.minimum(idx, b.size - 1)
            p, o, loss = td_update_impl(
                p, o, b.table[b.widx[idx]], b.adj[idx], b.v[idx],
                b.action[idx], b.reward[idx], b.adj_next[idx], b.v_next[idx],
                b.visited_next[idx], b.done[idx], gamma, lr, n_rounds)
            total = total + loss
        return p, o, total / updates_per_step

    def td_skip(ops):
        p, o, _b, _su = ops
        return p, o, jnp.float32(jnp.nan)

    def step(carry, xs):
        p, o, b, dist, adj, visited, v, cur_start, prev_d = carry
        rs, cl, last, st, eu, cu, su = xs
        visited, v, cur_start = _reset_ring(rs, st, visited, v, cur_start)
        adj_prev = adj
        a = _select_actions(p, w_batch, adj, visited, v, cur_start,
                            eu, cu, eps, cl, n_rounds)
        dist_prev = dist
        dist, adj, new_d, reward, _ = _apply_edge(
            w_batch, dist, adj, v, a, prev_d, alpha)
        if stretch_weight:
            reward = reward + sw * (_stretch_potential(dist_prev, opt)
                                    - _stretch_potential(dist, opt))
        visited_next = visited.at[jnp.arange(n_envs), a].set(True)
        done = jnp.broadcast_to(cl & last, (n_envs,))
        b = jax.lax.cond(
            cl, lambda bb: bb,
            lambda bb: _push(bb, gids, adj_prev, v, a, reward, adj,
                             visited_next, done), b)
        visited = visited_next
        v = jnp.where(cl, v, a)
        p, o, loss = jax.lax.cond(b.size >= batch_size, td_updates, td_skip,
                                  (p, o, b, su))
        return (p, o, b, dist, adj, visited, v, cur_start, new_d), \
            (a, reward, loss)

    carry0 = (params, opt_state, buf) + _episode_init(n_envs, n)
    xs = (ring_start, closing, last_ring, start_t, eps_u, choice_u, sample_u)
    (params, opt_state, buf, *_rest, prev_d), (actions, rewards, losses) = \
        jax.lax.scan(step, carry0, xs)
    return params, opt_state, buf, prev_d, losses, actions, rewards


# ---------------------------------------------------------------------------
# host-side helpers
# ---------------------------------------------------------------------------

def perms_from_actions(starts: np.ndarray, actions: np.ndarray,
                       k_rings: int, n: int) -> List[List[np.ndarray]]:
    """Reassemble ring permutations from scan outputs.

    ``starts``: (E, K); ``actions``: (T, E).  Ring r of env e is its start
    node followed by the first N-1 actions of that ring's steps (the N-th
    action is the closing edge back to the start).
    """
    starts = np.asarray(starts)
    actions = np.asarray(actions)
    out: List[List[np.ndarray]] = []
    for e in range(starts.shape[0]):
        perms = []
        for r in range(k_rings):
            perm = np.empty(n, np.int64)
            perm[0] = starts[e, r]
            perm[1:] = actions[r * n:(r + 1) * n - 1, e]
            perms.append(perm)
        out.append(perms)
    return out
