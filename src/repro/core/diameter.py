"""Diameter / APSP primitives.

Two implementations, cross-validated in tests:

* ``apsp`` / ``diameter``: jit-able JAX min-plus matrix-squaring APSP
  (O(N^3 log N)).  Used inside the Q-learning reward (small N, on-device) and
  on TPU, where the inner min-plus step is the Pallas kernel in
  ``repro.kernels.minplus`` (CPU falls back to the jnp oracle automatically).
* ``diameter_scipy``: host-side Dijkstra oracle (scipy csgraph) for large-N
  benchmark sweeps — the paper itself uses NetworkX; scipy is ~100x faster
  and agrees exactly (see tests/test_diameter.py).

Disconnected graphs follow the paper (§IV-C): "the diameter of the largest
connected component is adopted".
"""
from __future__ import annotations

import functools
from typing import Iterable, Sequence

import numpy as np

import jax
import jax.numpy as jnp

INF = jnp.float32(1e9)  # finite "infinity": avoids inf-inf NaN in min-plus

__all__ = [
    "INF",
    "is_edge",
    "neighbour_lists",
    "adjacency_from_edges",
    "ring_edges",
    "adjacency_from_rings",
    "minplus",
    "apsp",
    "relax_edge_update",
    "largest_cc_diameter",
    "diameter",
    "diameter_of_rings",
    "diameter_scipy",
]


# ---------------------------------------------------------------------------
# graph assembly
# ---------------------------------------------------------------------------

def is_edge(adj):
    """Boolean mask of actual edges in a weighted adjacency matrix.

    An entry is an edge iff it is strictly positive (excludes the 0 diagonal)
    and below the INF sentinel.  The ``INF / 2`` guard absorbs sentinel
    round-off from device round-trips; works on numpy and jax arrays alike.
    """
    return (adj > 0) & (adj < float(INF) / 2)


def neighbour_lists(adj: np.ndarray) -> list:
    """Per-node neighbour index lists, from one vectorized ``is_edge`` pass.

    Event loops that look up neighbours per event should call this once per
    overlay instead of re-scanning adjacency rows."""
    mask = np.asarray(is_edge(adj))
    return [np.flatnonzero(mask[u]) for u in range(mask.shape[0])]


def ring_edges(perm: np.ndarray) -> np.ndarray:
    """Edges of the ring perm[0] -> perm[1] -> ... -> perm[-1] -> perm[0]."""
    perm = np.asarray(perm)
    return np.stack([perm, np.roll(perm, -1)], axis=1)


def adjacency_from_edges(w: np.ndarray, edges: Iterable[Sequence[int]]) -> np.ndarray:
    """Weighted adjacency with INF on non-edges, 0 diagonal (undirected).

    Vectorized scatter: ``np.minimum.at`` handles duplicate edges exactly like
    the per-edge ``min`` loop it replaced (parallel-edge weight = min).
    """
    n = w.shape[0]
    d = np.full((n, n), float(INF), dtype=np.float32)
    np.fill_diagonal(d, 0.0)
    e = np.asarray(edges if isinstance(edges, np.ndarray) else list(edges),
                   dtype=np.intp).reshape(-1, 2)
    if e.size:
        if e.min() < 0 or e.max() >= n:
            raise ValueError(
                f"edge endpoints must lie in [0, {n}); got range "
                f"[{e.min()}, {e.max()}]")
        u, v = e[:, 0], e[:, 1]
        np.minimum.at(d, (u, v), w[u, v].astype(np.float32))
        np.minimum.at(d, (v, u), w[v, u].astype(np.float32))
    return d


def adjacency_from_rings(w: np.ndarray, perms: Sequence[np.ndarray]) -> np.ndarray:
    """Union of K rings as a weighted adjacency matrix.

    Every ring must be a permutation of ``range(n)`` — a shorter / repeated
    ring would silently produce an overlay over the wrong node set.
    """
    n = w.shape[0]
    ident = np.arange(n)
    for i, p in enumerate(perms):
        p = np.asarray(p)
        if p.shape != (n,) or not np.array_equal(np.sort(p), ident):
            raise ValueError(
                f"ring {i} is not a permutation of range({n}): "
                f"shape {p.shape}, unique {np.unique(p).size}")
    edges = np.concatenate([ring_edges(p) for p in perms], axis=0)
    return adjacency_from_edges(w, edges)


# ---------------------------------------------------------------------------
# JAX min-plus APSP
# ---------------------------------------------------------------------------

def _minplus_jnp(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(A ⊗ B)[i,j] = min_k A[i,k] + B[k,j] — the tropical-semiring matmul."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def minplus(a: jnp.ndarray, b: jnp.ndarray, *, use_kernel: bool = False) -> jnp.ndarray:
    """Min-plus product; Pallas tiled kernel on TPU when requested."""
    if use_kernel:
        from repro.kernels.minplus import ops as minplus_ops

        return minplus_ops.minplus(a, b)
    return _minplus_jnp(a, b)


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def apsp(adj: jnp.ndarray, *, use_kernel: bool = False) -> jnp.ndarray:
    """All-pairs shortest paths by repeated min-plus squaring.

    ``adj`` is a weighted adjacency matrix (0 diag, INF non-edges).  After
    ceil(log2(N-1)) squarings D contains shortest-path distances.
    """
    n = adj.shape[0]
    n_iters = max(1, int(np.ceil(np.log2(max(n - 1, 2)))))

    def body(_, d):
        return minplus(d, d, use_kernel=use_kernel)

    return jax.lax.fori_loop(0, n_iters, body, adj)


def relax_edge_update(dist: jnp.ndarray, u: jnp.ndarray, v: jnp.ndarray,
                      wuv: jnp.ndarray) -> jnp.ndarray:
    """Exact O(N^2) repair of an APSP matrix after inserting edge (u, v).

    With positive weights a new shortest path crosses the inserted edge at
    most once, so ``D' = min(D, D[:,u] + w + D[v,:], D[:,v] + w + D[u,:])``
    is exact.  Shared by the churn engine (``dynamics.incremental``) and the
    DQN rollout engine (``core.rollout``), which uses it as the in-scan
    carry update replacing a full O(N^3) APSP per reward.
    """
    du = dist[:, u]                       # distances into u
    dv = dist[:, v]
    via = jnp.minimum(du[:, None] + wuv + dist[v, :][None, :],
                      dv[:, None] + wuv + dist[u, :][None, :])
    return jnp.minimum(dist, via)


def largest_cc_diameter(d: jnp.ndarray) -> jnp.ndarray:
    """Diameter of the largest connected component given APSP distances
    (paper §IV-C).  Shared by the unbatched path and ``core.batcheval``.

    Accepts reduced-precision distance matrices (the bf16 / int16-quantized
    eval paths in ``batcheval``): the comparison runs in float32, and the
    ``INF / 2`` threshold keeps the sentinel provable under quantization —
    bf16 rounds the 1e9 sentinel to ~9.98e8 and the int16 grid leaves it
    untouched by construction, both comfortably above 5e8, while any REAL
    path cost that neared 5e8 would long since have overflowed the latency
    model's scale.  Always returns float32.
    """
    d = d.astype(jnp.float32)
    finite = d < INF / 2
    sizes = jnp.sum(finite, axis=1)
    anchor = jnp.argmax(sizes)          # a node in the largest component
    mask = finite[anchor]
    pair = mask[:, None] & mask[None, :]
    return jnp.max(jnp.where(pair, d, 0.0))


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def diameter(adj: jnp.ndarray, *, use_kernel: bool = False) -> jnp.ndarray:
    """Weighted diameter of the largest connected component (paper §IV-C)."""
    return largest_cc_diameter(apsp(adj, use_kernel=use_kernel))


def diameter_of_rings(w: np.ndarray, perms: Sequence[np.ndarray]) -> float:
    """Diameter of the union-of-rings overlay, via the JAX path."""
    return float(diameter(jnp.asarray(adjacency_from_rings(w, perms))))


# ---------------------------------------------------------------------------
# scipy oracle (host)
# ---------------------------------------------------------------------------

def diameter_scipy(adj: np.ndarray) -> float:
    """Host-side oracle: Dijkstra over the sparse overlay."""
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import connected_components, dijkstra

    adj = np.asarray(adj, dtype=np.float64)
    sp = csr_matrix(np.where(is_edge(adj), adj, 0.0))
    ncomp, labels = connected_components(sp, directed=False)
    if ncomp > 1:
        largest = np.bincount(labels).argmax()
        keep = np.flatnonzero(labels == largest)
        sp = sp[np.ix_(keep, keep)]
    dist = dijkstra(sp, directed=False)
    return float(dist.max())
