"""Latency-matrix generators for the four distributions evaluated in the paper.

The paper (§VII-A) evaluates DGRO on:
  * Uniform{1..10}            (synthetic)
  * Gaussian N(5, 1)          (synthetic)
  * FABRIC   (17 physical sites: 14 US, 1 JP, 2 EU; per-node jitter N(5,1))
  * Bitnode  (nodes sampled over 7 geographic regions, iPlane latencies)

All generators return a symmetric (n, n) float32 latency matrix with zero
diagonal.  Units are milliseconds (WAN) — the framework's DCN model in
`repro.launch.mesh` reuses these generators at microsecond scale.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "uniform_latency",
    "gaussian_latency",
    "fabric_latency",
    "bitnode_latency",
    "make_latency",
    "DISTRIBUTIONS",
    "N_FABRIC_SITES",
]


def _symmetrize(m: np.ndarray) -> np.ndarray:
    out = np.triu(m, 1)
    out = out + out.T
    np.fill_diagonal(out, 0.0)
    return out.astype(np.float32)


def uniform_latency(rng: np.random.Generator, n: int) -> np.ndarray:
    """X ~ Uniform{1, 2, ..., 10} (paper §VII-A.1)."""
    m = rng.integers(1, 11, size=(n, n)).astype(np.float32)
    return _symmetrize(m)


def gaussian_latency(rng: np.random.Generator, n: int) -> np.ndarray:
    """Y ~ N(5, 1), clipped to be strictly positive (paper §VII-A.1)."""
    m = rng.normal(5.0, 1.0, size=(n, n)).astype(np.float32)
    m = np.clip(m, 0.1, None)
    return _symmetrize(m)


# --- FABRIC ----------------------------------------------------------------
# 17 sites: 14 across the US, 1 in Japan, 2 in Europe (paper §VII-A.1).  We
# model inter-site one-way latency from great-circle distance at ~2/3 c plus a
# small router overhead; coordinates approximate the public FABRIC sites.
_FABRIC_SITES = np.array([
    # lon, lat
    (-122.27, 37.87),   # UCSD/SDSC-ish west coast
    (-122.06, 36.97),
    (-118.24, 34.05),   # LA
    (-111.89, 40.76),   # SLC
    (-104.99, 39.74),   # Denver
    (-96.80, 32.78),    # Dallas
    (-95.37, 29.76),    # Houston
    (-87.63, 41.88),    # Chicago (StarLight)
    (-86.16, 39.77),    # Indiana
    (-84.39, 33.75),    # Atlanta
    (-77.04, 38.91),    # Washington DC
    (-74.01, 40.71),    # New York
    (-71.06, 42.36),    # Boston
    (-122.33, 47.61),   # Seattle
    (139.69, 35.69),    # Tokyo
    (-0.13, 51.51),     # London
    (8.68, 50.11),      # Frankfurt
], dtype=np.float64)

# node i is assigned to site i % N_FABRIC_SITES (see fabric_latency);
# regional churn scenarios rely on the same assignment
N_FABRIC_SITES = len(_FABRIC_SITES)


def _greatcircle_ms(coords: np.ndarray) -> np.ndarray:
    lon = np.radians(coords[:, 0])[:, None]
    lat = np.radians(coords[:, 1])[:, None]
    dlon = lon - lon.T
    cosd = np.sin(lat) * np.sin(lat.T) + np.cos(lat) * np.cos(lat.T) * np.cos(dlon)
    dist_km = 6371.0 * np.arccos(np.clip(cosd, -1.0, 1.0))
    # one-way latency: distance / (0.66 c) + 2 ms router/queuing overhead
    ms = dist_km / (0.66 * 299.79) + 2.0
    np.fill_diagonal(ms, 0.0)
    return ms


def fabric_latency(rng: np.random.Generator, n: int) -> np.ndarray:
    """FABRIC model: latency(u, v) = site_latency(i, j) + jitter(u) + jitter(v).

    Nodes are assigned round-robin to the 17 sites (paper: 1..58 nodes per
    site); per-node response times ~ N(5, 1) (paper §VII-A.3).
    """
    site_ms = _greatcircle_ms(_FABRIC_SITES)
    site_of = np.arange(n) % len(_FABRIC_SITES)
    node_ms = np.clip(rng.normal(5.0, 1.0, size=n), 0.1, None)
    m = site_ms[np.ix_(site_of, site_of)] + node_ms[:, None] + node_ms[None, :]
    # intra-site pairs still pay both endpoints' processing latency
    return _symmetrize(m)


# --- Bitnode ---------------------------------------------------------------
# 7 regions (paper: North America, South America, Europe, Asia, Africa,
# China, Oceania) with an iPlane-style inter-region RTT/2 table (ms).
_BITNODE_REGIONS = ["NA", "SA", "EU", "AS", "AF", "CN", "OC"]
_BITNODE_WEIGHTS = np.array([0.32, 0.04, 0.36, 0.12, 0.02, 0.06, 0.08])
_BITNODE_MS = np.array([
    #  NA    SA    EU    AS    AF    CN    OC
    [ 20.0,  75., 45.0,  90., 120.,  95., 80.],   # NA
    [ 75.0,  25., 95.0, 160., 150., 170., 140.],  # SA
    [ 45.0,  95., 12.0,  80.,  70., 110., 130.],  # EU
    [ 90.0, 160., 80.0,  30.,  130., 50., 65.],   # AS
    [120.0, 150., 70.0, 130.,  40., 150., 160.],  # AF
    [ 95.0, 170., 110.,  50., 150.,  18., 90.],   # CN
    [ 80.0, 140., 130.,  65., 160.,  90., 15.],   # OC
], dtype=np.float64)


def bitnode_latency(rng: np.random.Generator, n: int) -> np.ndarray:
    """Bitnode model: nodes sampled over 7 geographic regions (paper §VII-A)."""
    region_of = rng.choice(len(_BITNODE_REGIONS), size=n, p=_BITNODE_WEIGHTS)
    base = _BITNODE_MS[np.ix_(region_of, region_of)]
    jitter = rng.gamma(2.0, 2.5, size=(n, n))  # heavy-ish tail, last-mile variance
    return _symmetrize(base + jitter)


DISTRIBUTIONS = {
    "uniform": uniform_latency,
    "gaussian": gaussian_latency,
    "fabric": fabric_latency,
    "bitnode": bitnode_latency,
}


def make_latency(dist: str, n: int, seed: int = 0) -> np.ndarray:
    """Build an (n, n) latency matrix for a named distribution."""
    try:
        fn = DISTRIBUTIONS[dist]
    except KeyError:
        raise ValueError(f"unknown distribution {dist!r}; options {list(DISTRIBUTIONS)}")
    return fn(np.random.default_rng(seed), n)
