"""Self-adaptive ring selection (paper §V, Algorithm 3).

Each node samples K latencies to existing neighbours (L_local) and K to
random nodes (L_global, L_min); a gossip round-robin averages the three
statistics network-wide; the clustering ratio

    rho = (L_local_bar - L_min_bar) / (L_global_bar - L_min_bar)

classifies the overlay:  rho -> 0 means the topology is too clustered
(neighbours are as close as the global minimum — long jumps missing), so a
RANDOM ring is added;  rho -> 1 means the topology is latency-oblivious
(neighbours look like random samples), so the NEAREST ("shortest") ring is
added.  (The paper's prose and Alg. 3 disagree on the inequality direction;
we follow the prose + the Chord/Perigee case studies: Chord has rho ~ 1 and
receives the shortest ring, Perigee has rho ~ 0 and receives the random
ring.)
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Literal, Sequence, Tuple

import numpy as np

from . import batcheval
from .construction import nearest_ring, random_ring
from .diameter import neighbour_lists

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (overlay -> here)
    from repro.overlay import Overlay

__all__ = ["LatencyStats", "measure_latency_stats", "clustering_ratio",
           "select_ring_kind", "score_candidate_rings", "adapt"]


def __getattr__(name: str):
    if name == "adapt_overlay":
        raise AttributeError(
            "repro.core.selection.adapt_overlay was removed; use "
            "selection.adapt(Overlay.from_adjacency(w, adj, "
            "fold_weights=True), ...) (the repro.overlay API replaced "
            "(adjacency, rings) tuples; see overlay.build)")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass(frozen=True)
class LatencyStats:
    l_local: float    # network-averaged mean latency to current neighbours
    l_global: float   # network-averaged mean latency to random peers
    l_min: float      # network-averaged min latency over the random samples
    rounds: int       # gossip rounds used for aggregation


def _gossip_average(values: np.ndarray, adj: np.ndarray,
                    rng: np.random.Generator, rounds: int) -> np.ndarray:
    """Push-sum gossip averaging along overlay edges (Alg. 3 lines 12-19).

    values: (n, d) per-node statistics.  Returns per-node estimates after
    ``rounds`` gossip rounds; exact mean is the fixed point.
    """
    n = values.shape[0]
    est = np.concatenate([values, np.ones((n, 1))], axis=1)  # push-sum weight
    neigh = neighbour_lists(adj)
    for _ in range(rounds):
        out = est * 0.5                      # keep half, send half
        incoming = np.zeros_like(est)
        for u in range(n):
            if len(neigh[u]) == 0:
                incoming[u] += est[u] * 0.5
                continue
            tgt = rng.choice(neigh[u])
            incoming[tgt] += est[u] * 0.5
        est = out + incoming
    return est[:, :-1] / np.clip(est[:, -1:], 1e-12, None)


def measure_latency_stats(
    w: np.ndarray,
    adj: np.ndarray,
    k_samples: int | None = None,
    gossip_rounds: int = 30,
    seed: int | np.random.SeedSequence = 0,
) -> LatencyStats:
    """Algorithm 3: per-node sampling + gossip aggregation.

    Sample sizes are clamped to the available populations: a node has at
    most n-1 global peers (and len(neigh) neighbours), so ``k`` larger than
    that — the default k at n=2, or an explicit ``k_samples`` on a small or
    churned-down network — measures every peer instead of raising.
    """
    rng = np.random.default_rng(seed)
    n = w.shape[0]
    if n < 2:         # a lone node has no peers to sample
        return LatencyStats(0.0, 0.0, 0.0, gossip_rounds)
    k = k_samples or max(2, int(np.ceil(np.log2(n))))
    k_global = min(k, n - 1)
    per_node = np.zeros((n, 3), np.float64)
    neigh_lists = neighbour_lists(adj)
    for u in range(n):
        neigh = neigh_lists[u]
        if len(neigh) == 0:
            neigh = np.array([(u + 1) % n])
        r = rng.choice(neigh, size=min(k, len(neigh)), replace=False)
        g = rng.choice(np.delete(np.arange(n), u), size=k_global,
                      replace=False)
        per_node[u, 0] = w[u, r].mean()       # L_local
        per_node[u, 1] = w[u, g].mean()       # L_global
        per_node[u, 2] = w[u, g].min()        # L_min
    agg = _gossip_average(per_node, adj, rng, gossip_rounds)
    mean = agg.mean(axis=0)                   # all nodes converge to ~ the mean
    return LatencyStats(float(mean[0]), float(mean[1]), float(mean[2]),
                        gossip_rounds)


def clustering_ratio(stats: LatencyStats) -> float:
    denom = stats.l_global - stats.l_min
    if denom <= 1e-12:
        return 0.5
    return float(np.clip((stats.l_local - stats.l_min) / denom, 0.0, 1.5))


RingKind = Literal["random", "nearest", "keep"]


def select_ring_kind(rho: float, eps: float = 0.3) -> RingKind:
    """rho < eps -> too clustered -> add RANDOM ring;
    rho > 1-eps -> too random -> add NEAREST ring;  else keep."""
    if rho < eps:
        return "random"
    if rho > 1.0 - eps:
        return "nearest"
    return "keep"


def score_candidate_rings(w: np.ndarray, adj: np.ndarray,
                          rings: Sequence[np.ndarray]) -> np.ndarray:
    """Diameters of ``adj`` augmented with each candidate ring, scored as one
    batched device call (``repro.core.batcheval``).  Returns (B,) floats."""
    overlays = batcheval.overlay_with_rings(adj, w, np.stack(rings)[:, None, :])
    return batcheval.diameters(overlays)


def adapt(
    overlay: "Overlay",
    eps: float = 0.3,
    seed: int = 0,
    n_candidates: int = 4,
) -> Tuple["Overlay", RingKind, float]:
    """One DGRO adaptation step: measure -> classify -> add the chosen ring.

    ``n_candidates`` rings of the selected kind (random permutations, or
    nearest rings from distinct start nodes) are generated and ALL their
    augmented overlays are scored in one batched diameter call; the best
    candidate is added via :meth:`Overlay.add_ring`.  Returns
    (new overlay, ring kind added, rho); ``kind == "keep"`` returns the
    input overlay unchanged.

    The measurement and candidate-proposal streams are independent child
    sequences spawned from ``seed`` (``np.random.SeedSequence.spawn``) —
    seeding both from the same integer would correlate the latency samples
    with the proposed rings while still being deterministic per seed.
    """
    w, adj = overlay.w, overlay.adjacency
    n = w.shape[0]
    meas_seed, cand_seed = np.random.SeedSequence(seed).spawn(2)
    stats = measure_latency_stats(w, adj, seed=meas_seed)
    rho = clustering_ratio(stats)
    kind = select_ring_kind(rho, eps)
    if kind == "keep":
        return overlay, kind, rho
    rng = np.random.default_rng(cand_seed)
    if kind == "random":
        rings = [random_ring(rng, n) for _ in range(n_candidates)]
    else:
        starts = rng.choice(n, size=min(n_candidates, n), replace=False)
        rings = [nearest_ring(w, start=int(s)) for s in starts]
    scores = score_candidate_rings(w, adj, rings)
    best = np.stack(rings)[int(np.argmin(scores))]
    return overlay.add_ring(best), kind, rho
