"""Batched topology evaluation — the bulk diameter/APSP engine.

Everything DGRO measures (GA populations, candidate ring selection,
partitioned construction, design-space sweeps, service re-optimization)
reduces to "score many candidate overlays by diameter".  This module turns
that into memory-bounded device calls that scale to N=4096+ with batches in
the hundreds.

Layout of the module:

* graph assembly — ``rings_to_edges`` / ``adjacency_batch_from_edges`` /
  ``adjacency_batch_from_rings`` build (B, N, N) tensors with vectorized
  numpy scatters; ``overlay_with_rings`` fuses a base overlay with B
  candidate rings; ``pad_adjacency_blocks`` pads variable-size blocks into
  one batch; :class:`RingBlockSource` is the LAZY equivalent — it hands the
  streaming facade one chunk of dense matrices at a time, so a 100k-genome
  GA budget never materializes a (B, N, N) host tensor either.
* device compute — ``batched_apsp`` / ``batched_diameter`` are jit'd per
  chunk.  Three interchangeable methods (cross-validated in tests):
  ``"fw"`` (vectorized Floyd-Warshall, the CPU speed path), ``"squaring"``
  (min-plus squaring; batched Pallas kernel on TPU), and ``"tiled"``
  (blocked Floyd-Warshall over a (N/T, N/T) block grid —
  ``kernels.minplus.apsp_tiled`` — whose working set is panels, not cubes;
  the TPU default past ``REPRO_APSP_TILED_N`` nodes).
* host facade — ``diameters`` / ``apsp_matrices`` / ``diameters_of_rings``
  STREAM the batch through fixed-size chunks (``default_chunk`` sizes them
  from a per-method memory model, ``REPRO_APSP_MEM_BYTES`` overrides the
  budget): peak device footprint is one chunk, never the whole batch.
  Optional reduced-precision evaluation (``dtype="bfloat16"`` or
  ``"int16"``-quantized latencies) measures its own error on float32
  probes and falls back to an exact rerun past ``exact_rtol``.
  ``eval_options`` scopes any of these knobs over a call tree.
* sharded compute — ``diameters_sharded`` shards the batch axis over a
  device mesh (``launch.mesh.make_eval_mesh``); ``apsp_rowshard`` shards
  the ROW-BLOCK axis of one huge matrix (min-plus squaring with an
  all-gather per squaring, following the ``parallel_ring_shmap`` pattern).

Instrumentation: every engine call lands in the pre-registered
``repro_apsp_seconds{method, phase}`` histogram (compile/execute split via
``obs.jit_phase``) and updates the ``repro_apsp_workingset_bytes`` gauge
with the modeled per-call device footprint; quantized evals record their
measured error and ``repro_apsp_exact_fallbacks_total``.
``last_eval_report()`` returns the same facts programmatically.
"""
from __future__ import annotations

import contextlib
import functools
import os
import threading
import time
from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.obs import REGISTRY, jit_phase, jit_span
from repro.obs.tracing import SPAN_BUCKETS_S

from .diameter import INF, is_edge, largest_cc_diameter

__all__ = [
    "rings_to_edges",
    "adjacency_batch_from_edges",
    "adjacency_batch_from_rings",
    "overlay_with_rings",
    "pad_adjacency_blocks",
    "RingBlockSource",
    "batched_apsp",
    "batched_diameter",
    "diameters",
    "diameters_of_rings",
    "diameters_sharded",
    "apsp_matrices",
    "apsp_rowshard",
    "quantize_latency",
    "eval_options",
    "last_eval_report",
    "default_chunk",
    "workingset_bytes",
]

METHODS = ("fw", "squaring", "tiled")
DTYPES = ("float32", "bfloat16", "int16")
DEFAULT_BUDGET_BYTES = 1 << 28          # ~256 MiB of device temporaries
DEFAULT_TILED_N = 512                   # TPU auto-switch to the tiled path
DEFAULT_EXACT_RTOL = 0.05               # quantized-eval fallback threshold

_APSP_SECONDS = REGISTRY.histogram(
    "repro_apsp_seconds",
    "device wall time per APSP/diameter engine call, compile/execute split",
    labels=("method", "phase"), buckets=SPAN_BUCKETS_S)
_APSP_WORKINGSET = REGISTRY.gauge(
    "repro_apsp_workingset_bytes",
    "modeled peak device working set of the last engine call")
_APSP_QUANT_ERR = REGISTRY.gauge(
    "repro_apsp_quant_rel_err",
    "measured relative diameter error of the last reduced-precision eval")
_APSP_FALLBACKS = REGISTRY.counter(
    "repro_apsp_exact_fallbacks_total",
    "reduced-precision evals that exceeded exact_rtol and re-ran in float32")


# ---------------------------------------------------------------------------
# graph assembly (host, vectorized)
# ---------------------------------------------------------------------------

def rings_to_edges(genomes) -> np.ndarray:
    """``(B, K, N)`` ring permutations -> ``(B, K*N, 2)`` edge lists.

    Accepts a (B, K, N) array, a (B, N) array (K=1), or a nested list of
    per-genome ring permutations.
    """
    g = np.asarray(genomes, dtype=np.intp)
    if g.ndim == 2:
        g = g[:, None, :]
    assert g.ndim == 3, g.shape
    nxt = np.roll(g, -1, axis=-1)
    return np.stack([g, nxt], axis=-1).reshape(g.shape[0], -1, 2)


def adjacency_batch_from_edges(w: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Batch of weighted adjacencies from per-candidate edge lists.

    ``edges`` is (B, E, 2); returns (B, N, N) float32 with INF on non-edges
    and 0 diagonal.  The scatter is one ``np.minimum.at`` over both edge
    directions, so duplicate/parallel edges resolve to the min weight
    exactly like the scalar loop in ``diameter.adjacency_from_edges``.
    """
    w = np.asarray(w)
    n = w.shape[0]
    e = np.asarray(edges, dtype=np.intp)
    assert e.ndim == 3 and e.shape[-1] == 2, e.shape
    b = e.shape[0]
    d = np.full((b, n, n), float(INF), dtype=np.float32)
    d[:, np.arange(n), np.arange(n)] = 0.0
    if e.shape[1]:
        bi = np.broadcast_to(np.arange(b)[:, None], e.shape[:2])
        u, v = e[..., 0], e[..., 1]
        np.minimum.at(d, (bi, u, v), w[u, v].astype(np.float32))
        np.minimum.at(d, (bi, v, u), w[v, u].astype(np.float32))
    return d


def adjacency_batch_from_rings(w: np.ndarray, genomes) -> np.ndarray:
    """(B, K, N) ring permutations -> (B, N, N) union-of-rings adjacencies."""
    return adjacency_batch_from_edges(w, rings_to_edges(genomes))


def overlay_with_rings(adj: np.ndarray, w: np.ndarray, rings) -> np.ndarray:
    """B candidate overlays: the base ``adj`` each augmented with one ring."""
    cand = adjacency_batch_from_rings(w, rings)
    return np.minimum(np.asarray(adj, np.float32)[None], cand)


def pad_adjacency_blocks(blocks: Sequence[np.ndarray]) -> np.ndarray:
    """Pad variable-size adjacencies to one (B, N_max, N_max) batch.

    Padded nodes are isolated (INF rows/cols, 0 diagonal): each is a
    singleton component, so the largest-CC diameter of the padded graph
    equals the block's own diameter whenever the block has >= 1 node.
    """
    blocks = [np.asarray(b, np.float32) for b in blocks]
    n_max = max(b.shape[0] for b in blocks)
    out = np.full((len(blocks), n_max, n_max), float(INF), dtype=np.float32)
    out[:, np.arange(n_max), np.arange(n_max)] = 0.0
    for i, b in enumerate(blocks):
        out[i, :b.shape[0], :b.shape[0]] = b
    return out


class RingBlockSource:
    """Lazy adjacency source: assembles (chunk, N, N) blocks on demand.

    The streaming facade accepts any object with ``__len__``, ``.n`` and
    ``.block(lo, hi)``; this one defers ``adjacency_batch_from_rings`` so
    ``diameters_of_rings`` holds at most ONE chunk of dense matrices on the
    host — at B=100k, N=4096 the eager tensor would be 6.7 TB.
    """

    def __init__(self, w: np.ndarray, genomes):
        self.w = np.asarray(w)
        g = np.asarray(genomes, dtype=np.intp)
        if g.ndim == 2:
            g = g[:, None, :]
        assert g.ndim == 3, g.shape
        self.genomes = g

    def __len__(self) -> int:
        return self.genomes.shape[0]

    @property
    def n(self) -> int:
        return self.w.shape[0]

    def block(self, lo: int, hi: int) -> np.ndarray:
        return adjacency_batch_from_rings(self.w, self.genomes[lo:hi])


class _ArraySource:
    """Adapter giving an eager (B, N, N) array the block-source protocol."""

    def __init__(self, adjs: np.ndarray):
        adjs = np.asarray(adjs, dtype=np.float32)
        assert adjs.ndim == 3 and adjs.shape[1] == adjs.shape[2], adjs.shape
        self.adjs = adjs

    def __len__(self) -> int:
        return self.adjs.shape[0]

    @property
    def n(self) -> int:
        return self.adjs.shape[-1]

    def block(self, lo: int, hi: int) -> np.ndarray:
        return self.adjs[lo:hi]


def _as_source(adjs):
    if hasattr(adjs, "block") and hasattr(adjs, "n"):
        return adjs
    return _ArraySource(adjs)


# ---------------------------------------------------------------------------
# scoped evaluation options
# ---------------------------------------------------------------------------

_OPT_KEYS = frozenset({"method", "dtype", "chunk", "tile", "use_kernel",
                       "budget_bytes", "exact_rtol"})
_OPT_ENV = {
    "method": "REPRO_APSP_METHOD",
    "dtype": "REPRO_APSP_DTYPE",
    "chunk": "REPRO_APSP_CHUNK",
    "tile": "REPRO_APSP_TILE",
    "budget_bytes": "REPRO_APSP_MEM_BYTES",
    "exact_rtol": "REPRO_APSP_RTOL",
}
_OPT_PARSE = {"chunk": int, "tile": int, "budget_bytes": int,
              "exact_rtol": float}

_ctx = threading.local()


@contextlib.contextmanager
def eval_options(**opts):
    """Scope engine knobs over a call tree without threading kwargs.

    ``with eval_options(dtype="bfloat16", method="tiled"): ...`` makes
    every facade call inside the block (including ones buried in
    ``selection.adapt`` or the service re-optimizer) pick up the options.
    Precedence: explicit call-site kwarg > innermost ``eval_options`` >
    ``REPRO_APSP_*`` env var > built-in default.  Keys: method, dtype,
    chunk, tile, use_kernel, budget_bytes, exact_rtol.
    """
    unknown = set(opts) - _OPT_KEYS
    if unknown:
        raise ValueError(f"unknown eval options {sorted(unknown)}; "
                         f"known: {sorted(_OPT_KEYS)}")
    if opts.get("method") is not None and opts["method"] not in METHODS:
        raise ValueError(f"unknown method {opts['method']!r}; "
                         f"options {METHODS}")
    if opts.get("dtype") is not None and opts["dtype"] not in DTYPES:
        raise ValueError(f"unknown dtype {opts['dtype']!r}; options {DTYPES}")
    stack = getattr(_ctx, "stack", None)
    if stack is None:
        stack = _ctx.stack = []
    stack.append(dict(opts))
    try:
        yield
    finally:
        stack.pop()


def _opt(name: str, explicit=None):
    """Resolve one option: explicit > context > env > None."""
    if explicit is not None:
        return explicit
    for frame in reversed(getattr(_ctx, "stack", []) or []):
        if frame.get(name) is not None:
            return frame[name]
    env = _OPT_ENV.get(name)
    if env and env in os.environ:
        return _OPT_PARSE.get(name, str)(os.environ[env])
    return None


_report = threading.local()


def last_eval_report() -> dict:
    """Facts about this thread's most recent facade call: resolved method /
    dtype / chunk / tile, modeled working-set bytes, device call count,
    measured quantization error and whether the exact fallback fired."""
    return dict(getattr(_report, "data", {}))


# ---------------------------------------------------------------------------
# device compute (jit, one call per chunk)
# ---------------------------------------------------------------------------

def _batched_minplus(a: jnp.ndarray, b: jnp.ndarray,
                     use_kernel: bool) -> jnp.ndarray:
    """One batched min-plus squaring step, via the kernels.minplus entry
    point — compiled Pallas grid-over-batch on TPU, vmapped jnp oracle on
    CPU — so the default TPU path actually runs the kernel.  ``use_kernel``
    forces the Pallas body (interpret mode off-TPU) for cross-validation."""
    from repro.kernels.minplus import ops as minplus_ops

    return minplus_ops.minplus_batched(a, b, force_kernel=use_kernel)


def _auto_method(use_kernel: bool, n: Optional[int] = None,
                 tiled_n: int = DEFAULT_TILED_N) -> str:
    """Backend- and size-aware default: TPU runs min-plus squaring (the
    batched Pallas kernel) until the tiled blocked-FW engine wins past
    ``tiled_n`` nodes; CPU runs vectorized FW, whose fused rank-1 update
    beats both the squaring oracle's (B, N, N, N) broadcast and the tiled
    fallback's per-block dispatch (measured in benchmarks/fig20_scale)."""
    if jax.default_backend() == "tpu":
        if n is not None and n >= tiled_n:
            return "tiled"
        return "squaring"
    return "squaring" if use_kernel else "fw"


def _resolve_method(use_kernel: bool, method: Optional[str],
                    n: Optional[int] = None) -> str:
    if method is not None:
        assert method in METHODS, method
        return method
    return _auto_method(use_kernel, n)


@functools.partial(jax.jit, static_argnames=("use_kernel", "method",
                                             "symmetric", "dtype", "tile"))
def batched_apsp(adjs: jnp.ndarray, *, use_kernel: bool = False,
                 method: str | None = None, symmetric: bool = True,
                 dtype: str = "float32",
                 tile: int | None = None) -> jnp.ndarray:
    """All-pairs shortest paths for a (B, N, N) stack of adjacencies.

    Three interchangeable algorithms (cross-validated in tests):

    * ``"fw"`` — batched vectorized Floyd-Warshall, O(N^3) with only a
      (B, N, N) temporary per step (unrolled x8 to amortize loop dispatch);
      the CPU default — its rank-1 broadcast-min step is memory-light,
      which on CPU beats squaring's (B, N, N, N) broadcast temporaries by
      an order of magnitude.
    * ``"squaring"`` — batched min-plus matrix squaring, O(N^3 log N) built
      from large tiled products; the TPU default at moderate N (the batched
      Pallas kernel runs one (N, N) min-plus tile per grid step) and forced
      whenever ``use_kernel`` is set.
    * ``"tiled"`` — blocked Floyd-Warshall over a (N/T, N/T) block grid
      (``kernels.minplus.apsp_tiled``), one matrix at a time via
      ``lax.map``: O(N^3) like fw but with panel-sized working sets, the
      TPU default past ``DEFAULT_TILED_N`` nodes (VMEM-resident tiles).

    ``symmetric`` (default) lets FW read only the contiguous row slice
    ``d[:, k, :]`` — valid for the undirected overlays every builder in
    this module produces (both edge directions are scattered).  Pass
    ``symmetric=False`` for directed inputs.  ``dtype`` selects the
    compute precision (``"float32"``/``"bfloat16"``); the result keeps it
    (``largest_cc_diameter`` re-widens downstream).
    """
    method = _resolve_method(use_kernel, method, adjs.shape[-1])
    assert dtype in ("float32", "bfloat16"), dtype
    adjs = adjs.astype(dtype)
    n = adjs.shape[-1]
    if method == "fw":
        def fw_body(k, d):
            if symmetric:
                col = row = d[:, k, :]
            else:
                col, row = d[:, :, k], d[:, k, :]
            return jnp.minimum(d, col[:, :, None] + row[:, None, :])

        return jax.lax.fori_loop(0, n, fw_body, adjs, unroll=8)

    if method == "tiled":
        from repro.kernels.minplus import ops as minplus_ops

        return jax.lax.map(
            lambda d: minplus_ops.apsp_tiled(
                d, tile=tile, force_kernel=use_kernel, symmetric=symmetric),
            adjs)

    assert method == "squaring", method
    n_iters = max(1, int(np.ceil(np.log2(max(n - 1, 2)))))

    def body(_, d):
        return _batched_minplus(d, d, use_kernel)

    return jax.lax.fori_loop(0, n_iters, body, adjs)


@functools.partial(jax.jit, static_argnames=("use_kernel", "method",
                                             "symmetric", "dtype", "tile"))
def batched_diameter(adjs: jnp.ndarray, *, use_kernel: bool = False,
                     method: str | None = None, symmetric: bool = True,
                     dtype: str = "float32",
                     tile: int | None = None) -> jnp.ndarray:
    """(B, N, N) adjacencies -> (B,) float32 largest-CC diameters."""
    d = batched_apsp(adjs, use_kernel=use_kernel, method=method,
                     symmetric=symmetric, dtype=dtype, tile=tile)
    return jax.vmap(largest_cc_diameter)(d)


# ---------------------------------------------------------------------------
# memory model
# ---------------------------------------------------------------------------

def workingset_bytes(chunk: int, n: int, method: str = "fw", *,
                     dtype: str = "float32", tile: int | None = None,
                     use_kernel: bool = False) -> int:
    """Modeled peak device working set of one engine call, per method.

    * ``fw`` (and kernel/TPU squaring): the (chunk, N, N) carry plus the
      rank-1 broadcast temporary and XLA's copy slack — 8 N^2 slabs per
      batch item (empirically calibrated against the previous engine).
    * CPU-oracle ``squaring``: the dense (chunk, N, N, N) broadcast-min
      temporary dominates everything else.
    * ``tiled``: the (chunk, N, N) input stack (``lax.map`` holds it
      whole) plus ONE matrix in flight — two padded copies and three
      (tile, N) panels — the whole point of the blocked engine.
    """
    item = 2 if dtype == "bfloat16" else 4
    if method == "squaring" and not (use_kernel
                                     or jax.default_backend() == "tpu"):
        return item * chunk * n ** 3
    if method == "tiled":
        from repro.kernels.minplus.ops import default_tile

        t = tile or default_tile(n)
        npad = -(-n // t) * t
        return item * (chunk * n * n + 2 * npad * npad + 3 * t * npad)
    return item * chunk * n * n * 8


def default_chunk(n: int, method: str = "fw",
                  budget_bytes: int | None = None, *,
                  dtype: str = "float32", tile: int | None = None,
                  use_kernel: bool = False) -> int:
    """Largest batch chunk whose modeled working set (``workingset_bytes``,
    which knows the per-method temporaries) stays under the budget.

    The budget defaults to ``REPRO_APSP_MEM_BYTES`` when set, else 256 MiB.
    Always >= 1: a single matrix must fit regardless (at N=4096 fp32 one
    fw item models at ~512 MiB — the engine then simply runs chunk=1).
    """
    if budget_bytes is None:
        budget_bytes = _opt("budget_bytes") or DEFAULT_BUDGET_BYTES
    one = workingset_bytes(1, n, method, dtype=dtype, tile=tile,
                           use_kernel=use_kernel)
    fixed = 0
    if method == "tiled":
        # panels + padded copies are shared across the chunk, not per-item
        item = 2 if dtype == "bfloat16" else 4
        fixed = one - item * n * n
        one = item * n * n
    return max(1, (budget_bytes - fixed) // max(1, one))


def quantize_latency(adjs: np.ndarray, bits: int = 16):
    """Quantize finite latencies to a uniform ``2**bits - 1``-level grid.

    Only ``is_edge`` entries move: the 0 diagonal and the 1e9 INF sentinel
    pass through BIT-EXACT, so ``largest_cc_diameter``'s ``INF / 2``
    connectivity test stays provable on quantized inputs.  Returns
    ``(quantized, scale)``; per-edge error is at most ``scale / 2``, so a
    shortest path of H hops is off by at most ``H * scale / 2``.
    """
    a = np.asarray(adjs, np.float32)
    mask = np.asarray(is_edge(a))
    if not mask.any():
        return a.copy(), 0.0
    levels = (1 << bits) - 1
    scale = float(a[mask].max()) / levels
    q = np.where(mask, np.rint(a / max(scale, 1e-30)) * scale, a)
    return q.astype(np.float32), scale


# ---------------------------------------------------------------------------
# host facade (streaming)
# ---------------------------------------------------------------------------

def _observe_call(method: str, key, seconds: float, ws_bytes: int) -> None:
    if not REGISTRY.enabled:
        return
    _APSP_SECONDS.labels(method=method,
                         phase=jit_phase("batcheval.apsp", key)).observe(
        seconds)
    _APSP_WORKINGSET.set(ws_bytes)


def _stream(src, b: int, n: int, fn, *, chunk: int, method: str,
            compute_dtype: str, quantize: bool, symmetric: bool,
            use_kernel: bool, tile: Optional[int], ws_bytes: int):
    """Drive ``fn`` over fixed-size chunks of ``src``, never holding more
    than one (chunk, N, N) block on host or device.  The trailing partial
    chunk is padded by repeating its first matrix so every device call has
    the SAME compiled shape (one trace, not one per remainder)."""
    outs = []
    calls = 0
    max_scale = 0.0
    single = b <= chunk
    for lo in range(0, b, chunk):
        hi = min(b, lo + chunk)
        blk = np.asarray(src.block(lo, hi), np.float32)
        if quantize:
            blk, scale = quantize_latency(blk)
            max_scale = max(max_scale, scale)
        if not single and hi - lo < chunk:
            blk = np.concatenate(
                [blk, np.repeat(blk[:1], chunk - (hi - lo), axis=0)], axis=0)
        t0 = time.perf_counter()
        res = np.asarray(fn(jnp.asarray(blk)))
        _observe_call(method,
                      (blk.shape[0], n, use_kernel, method, symmetric,
                       compute_dtype, tile),
                      time.perf_counter() - t0, ws_bytes)
        outs.append(res[:hi - lo])
        calls += 1
    return outs, calls, max_scale


def diameters(adjs, *, use_kernel: bool = False, method: str | None = None,
              symmetric: bool = True, chunk: int | None = None,
              dtype: str | None = None, tile: int | None = None,
              exact_rtol: float | None = None) -> np.ndarray:
    """Diameters for a batch of adjacencies, as a host (B,) float32 array.

    ``adjs`` is a (B, N, N) array or any lazy block source (``__len__``,
    ``.n``, ``.block(lo, hi)`` — e.g. :class:`RingBlockSource`).  The batch
    is STREAMED through fixed-size device chunks: peak memory is one
    (chunk, N, N) block plus the method's temporaries, never the whole
    batch — B=64 at N=4096 runs on a single host in a few hundred MB.

    ``dtype`` picks the evaluation precision: ``"float32"`` (exact),
    ``"bfloat16"`` (half-traffic compute), or ``"int16"`` (latencies
    quantized to a 16-bit grid, evaluated in f32).  Reduced-precision runs
    re-score a probe subset in float32 and, if the measured relative error
    exceeds ``exact_rtol`` (default 0.05), fall back to a full float32
    rerun — callers always get a result within the bound or exact.
    All knobs resolve through ``eval_options`` / ``REPRO_APSP_*`` env vars.
    """
    src = _as_source(adjs)
    b, n = len(src), src.n
    if b == 0:
        return np.zeros((0,), np.float32)
    use_kernel = bool(use_kernel or _opt("use_kernel"))
    method = _opt("method", method)
    if method is None:
        method = _auto_method(use_kernel, n,
                              int(os.environ.get("REPRO_APSP_TILED_N",
                                                 DEFAULT_TILED_N)))
    assert method in METHODS, method
    dtype = _opt("dtype", dtype) or "float32"
    assert dtype in DTYPES, dtype
    tile = _opt("tile", tile)
    chunk = _opt("chunk", chunk) or default_chunk(
        n, method, dtype=dtype, tile=tile, use_kernel=use_kernel)
    rtol = _opt("exact_rtol", exact_rtol)
    if rtol is None and dtype != "float32":
        rtol = DEFAULT_EXACT_RTOL
    compute_dtype = "bfloat16" if dtype == "bfloat16" else "float32"
    quantize = dtype == "int16"
    ws = workingset_bytes(min(b, chunk), n, method, dtype=compute_dtype,
                          tile=tile, use_kernel=use_kernel)

    def run(cdt: str, quant: bool):
        fn = lambda blk: batched_diameter(  # noqa: E731
            blk, use_kernel=use_kernel, method=method, symmetric=symmetric,
            dtype=cdt, tile=tile)
        return _stream(src, b, n, fn, chunk=chunk, method=method,
                       compute_dtype=cdt, quantize=quant,
                       symmetric=symmetric, use_kernel=use_kernel,
                       tile=tile, ws_bytes=ws)

    if b <= chunk:
        # small batches keep the legacy one-shot span (and its exact
        # unpadded shape, preserving bit-parity with the pre-streaming path)
        with jit_span("batcheval.diameters",
                      key=(b, n, use_kernel, method, symmetric, dtype)):
            outs, calls, max_scale = run(compute_dtype, quantize)
    else:
        outs, calls, max_scale = run(compute_dtype, quantize)
    out = np.concatenate(outs) if len(outs) > 1 else outs[0]

    rep = {"b": b, "n": n, "method": method, "dtype": dtype, "chunk": chunk,
           "tile": tile, "workingset_bytes": ws, "device_calls": calls,
           "quant_scale": max_scale, "quant_rel_err": 0.0, "fallback": False}
    if dtype != "float32":
        rel, out, fellback = _verify_quantized(src, b, out, rtol, run)
        rep["quant_rel_err"], rep["fallback"] = rel, fellback
        if fellback:
            rep["dtype"] = "float32"
    _report.data = rep
    return out


def _verify_quantized(src, b: int, out: np.ndarray, rtol: Optional[float],
                      run) -> tuple:
    """Measure reduced-precision error on float32 probes; past ``rtol``,
    re-run the whole batch exactly (the exactness-fallback contract)."""
    probes = np.arange(0, b, max(1, b // 8))[:8]
    ref = np.concatenate([
        np.asarray(batched_diameter(
            jnp.asarray(np.asarray(src.block(int(i), int(i) + 1),
                                   np.float32))))
        for i in probes])
    denom = np.maximum(np.abs(ref), 1e-6)
    rel = float(np.max(np.abs(out[probes] - ref) / denom)) if len(ref) else 0.0
    if REGISTRY.enabled:
        _APSP_QUANT_ERR.set(rel)
    if rtol is not None and rel > rtol:
        _APSP_FALLBACKS.inc()
        outs, _, _ = run("float32", False)
        out = np.concatenate(outs) if len(outs) > 1 else outs[0]
    return rel, out, bool(rtol is not None and rel > rtol)


def apsp_matrices(adjs, *, use_kernel: bool = False,
                  method: str | None = None, symmetric: bool = True,
                  chunk: int | None = None, dtype: str | None = None,
                  tile: int | None = None) -> np.ndarray:
    """Full (B, N, N) float32 APSP distance matrices, streamed per chunk.

    The matrix-returning sibling of ``diameters`` for consumers that need
    distances (the churn engine's rebuild, routing stretch): same method /
    chunk / dtype resolution and the same ``repro_apsp_seconds``
    instrumentation, with the result re-widened to float32 on host.  The
    HOST output is dense (the caller asked for it); only device memory is
    bounded.  No probe-verification here — reduced precision is the
    caller's explicit contract for distances.
    """
    src = _as_source(adjs)
    b, n = len(src), src.n
    if b == 0:
        return np.zeros((0, n, n), np.float32)
    use_kernel = bool(use_kernel or _opt("use_kernel"))
    method = _opt("method", method)
    if method is None:
        method = _auto_method(use_kernel, n,
                              int(os.environ.get("REPRO_APSP_TILED_N",
                                                 DEFAULT_TILED_N)))
    dtype = _opt("dtype", dtype) or "float32"
    tile = _opt("tile", tile)
    chunk = _opt("chunk", chunk) or default_chunk(
        n, method, dtype=dtype, tile=tile, use_kernel=use_kernel)
    compute_dtype = "bfloat16" if dtype == "bfloat16" else "float32"
    ws = workingset_bytes(min(b, chunk), n, method, dtype=compute_dtype,
                          tile=tile, use_kernel=use_kernel)

    def fn(blk):
        d = batched_apsp(blk, use_kernel=use_kernel, method=method,
                         symmetric=symmetric, dtype=compute_dtype, tile=tile)
        return d.astype(jnp.float32)

    outs, calls, max_scale = _stream(
        src, b, n, fn, chunk=chunk, method=method,
        compute_dtype=compute_dtype, quantize=dtype == "int16",
        symmetric=symmetric, use_kernel=use_kernel, tile=tile, ws_bytes=ws)
    _report.data = {"b": b, "n": n, "method": method, "dtype": dtype,
                    "chunk": chunk, "tile": tile, "workingset_bytes": ws,
                    "device_calls": calls, "quant_scale": max_scale,
                    "quant_rel_err": 0.0, "fallback": False}
    return np.concatenate(outs) if len(outs) > 1 else outs[0]


def diameters_of_rings(w: np.ndarray, genomes, *, use_kernel: bool = False,
                       method: str | None = None,
                       chunk: int | None = None,
                       dtype: str | None = None) -> np.ndarray:
    """Score B K-ring genomes by overlay diameter, streaming the adjacency
    assembly chunk-by-chunk (never a dense (B, N, N) host tensor)."""
    return diameters(RingBlockSource(w, genomes), use_kernel=use_kernel,
                     method=method, chunk=chunk, dtype=dtype)


# ---------------------------------------------------------------------------
# sharded compute (multi-device)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _sharded_diameter_fn(mesh, axis: str, use_kernel: bool, method: str,
                         symmetric: bool, dtype: str, tile: Optional[int]):
    from jax.sharding import PartitionSpec as P

    from repro import compat

    fn = compat.shard_map(
        lambda a: batched_diameter(a, use_kernel=use_kernel, method=method,
                                   symmetric=symmetric, dtype=dtype,
                                   tile=tile),
        mesh=mesh, in_specs=(P(axis),), out_specs=P(axis))
    return jax.jit(fn)


def diameters_sharded(adjs, *, mesh=None, axis: str = "batch",
                      use_kernel: bool = False, method: str | None = None,
                      symmetric: bool = True, dtype: str | None = None,
                      tile: int | None = None) -> np.ndarray:
    """``diameters`` with the batch axis sharded over a device mesh.

    Follows the ``parallel_ring_shmap`` pattern: pad B to a multiple of
    the mesh axis, place the stack with a ``NamedSharding``, and run
    ``batched_diameter`` per shard under ``compat.shard_map`` (no
    collectives — each device scores its own sub-batch).  With no mesh, a
    1D ``launch.mesh.make_eval_mesh`` over all local devices is built; on
    a single device this degrades to the streaming facade.
    """
    from repro import compat

    adjs = np.asarray(adjs, np.float32)
    assert adjs.ndim == 3 and adjs.shape[1] == adjs.shape[2], adjs.shape
    b, n = adjs.shape[0], adjs.shape[-1]
    if b == 0:
        return np.zeros((0,), np.float32)
    if mesh is None:
        from repro.launch.mesh import make_eval_mesh

        mesh = make_eval_mesh(axis=axis)
    k = int(mesh.shape[axis])
    if k <= 1:
        return diameters(adjs, use_kernel=use_kernel, method=method,
                         symmetric=symmetric, dtype=dtype, tile=tile)
    use_kernel = bool(use_kernel or _opt("use_kernel"))
    method = _opt("method", method) or _auto_method(use_kernel, n)
    dtype = _opt("dtype", dtype) or "float32"
    tile = _opt("tile", tile)
    compute_dtype = "bfloat16" if dtype == "bfloat16" else "float32"
    if dtype == "int16":
        adjs, _ = quantize_latency(adjs)
    pad = (-b) % k
    if pad:
        adjs = np.concatenate([adjs, np.repeat(adjs[:1], pad, axis=0)],
                              axis=0)
    fn = _sharded_diameter_fn(mesh, axis, use_kernel, method, symmetric,
                              compute_dtype, tile)
    placed = jax.device_put(adjs, compat.named_sharding(mesh, axis))
    t0 = time.perf_counter()
    out = np.asarray(fn(placed))
    per = adjs.shape[0] // k
    ws = workingset_bytes(per, n, method, dtype=compute_dtype, tile=tile,
                          use_kernel=use_kernel)
    _observe_call(method, ("sharded", k, per, n, use_kernel, method,
                           symmetric, compute_dtype, tile),
                  time.perf_counter() - t0, ws)
    _report.data = {"b": b, "n": n, "method": method, "dtype": dtype,
                    "chunk": per, "tile": tile, "workingset_bytes": ws,
                    "device_calls": 1, "devices": k, "quant_rel_err": 0.0,
                    "fallback": False}
    return out[:b]


@functools.lru_cache(maxsize=32)
def _rowshard_fn(mesh, axis: str, npad: int, n_iters: int):
    from jax.sharding import PartitionSpec as P

    from repro import compat

    def local(loc):
        def squaring(_, loc):
            full = jax.lax.all_gather(loc, axis, axis=0, tiled=True)

            def pivot(k, acc):
                col = jax.lax.dynamic_slice_in_dim(loc, k, 1, axis=1)
                row = jax.lax.dynamic_slice_in_dim(full, k, 1, axis=0)
                return jnp.minimum(acc, col + row)

            return jax.lax.fori_loop(0, npad, pivot, loc, unroll=8)

        return jax.lax.fori_loop(0, n_iters, squaring, loc)

    fn = compat.shard_map(local, mesh=mesh, in_specs=(P(axis, None),),
                          out_specs=P(axis, None))
    return jax.jit(fn)


def apsp_rowshard(adj: np.ndarray, *, mesh=None,
                  axis: str = "rows") -> np.ndarray:
    """APSP of ONE (N, N) matrix with the ROW-BLOCK axis sharded.

    Min-plus squaring where each device owns an (N/k, N) row block and
    re-gathers the full matrix once per squaring (``all_gather`` over the
    mesh axis, log2(N) rounds) — the row-parallel complement of
    ``diameters_sharded`` for matrices too large to score one-per-device.
    Pads N to a mesh multiple with isolated singleton nodes.
    """
    adj = np.asarray(adj, np.float32)
    assert adj.ndim == 2 and adj.shape[0] == adj.shape[1], adj.shape
    n = adj.shape[0]
    if mesh is None:
        from repro.launch.mesh import make_eval_mesh

        mesh = make_eval_mesh(axis=axis)
    k = int(mesh.shape[axis])
    npad = -(-n // k) * k
    if npad != n:
        padded = np.full((npad, npad), float(INF), np.float32)
        padded[np.arange(npad), np.arange(npad)] = 0.0
        padded[:n, :n] = adj
        adj = padded
    n_iters = max(1, int(np.ceil(np.log2(max(npad - 1, 2)))))
    from repro import compat

    fn = _rowshard_fn(mesh, axis, npad, n_iters)
    placed = jax.device_put(adj, compat.named_sharding(mesh, axis))
    t0 = time.perf_counter()
    out = np.asarray(fn(placed))
    item = 4
    ws = item * (npad * npad + 2 * (npad // k) * npad)
    _observe_call("squaring", ("rowshard", k, npad),
                  time.perf_counter() - t0, ws)
    return out[:n, :n]
