"""Batched topology evaluation — the bulk diameter/APSP engine.

Everything DGRO measures (GA populations, candidate ring selection,
partitioned construction, design-space sweeps) reduces to "score many
candidate overlays by diameter".  This module stacks candidates as a
``(B, N, N)`` adjacency tensor and computes all diameters in ONE jit'd
device call: a batched APSP (vmapped min-plus squaring on TPU, vectorized
Floyd-Warshall on CPU — see ``batched_apsp``) followed by the paper's
largest-connected-component diameter rule (§IV-C), per batch element.

Layout of the module:

* graph assembly — ``rings_to_edges`` / ``adjacency_batch_from_edges`` /
  ``adjacency_batch_from_rings`` build the (B, N, N) tensor with vectorized
  numpy scatters (no per-edge Python loops); ``overlay_with_rings`` fuses a
  base overlay with B candidate rings; ``pad_adjacency_blocks`` pads
  variable-size blocks into one batch (padded nodes are isolated singleton
  components, which the largest-CC rule ignores).
* device compute — ``batched_apsp`` / ``batched_diameter`` are jit'd over
  the whole batch; on TPU the inner min-plus step is the batched Pallas
  kernel (grid over the batch axis), on CPU the vmapped jnp oracle.
* host facade — ``diameters`` / ``diameters_of_rings`` bound peak memory by
  folding oversized batches into a ``lax.map`` over fixed-size chunks, so a
  100k-candidate GA budget never materializes a B*N^3 broadcast temporary,
  while still issuing a single device call.
"""
from __future__ import annotations

import functools
from typing import Sequence

import numpy as np

import jax
import jax.numpy as jnp

from .diameter import INF, largest_cc_diameter

__all__ = [
    "rings_to_edges",
    "adjacency_batch_from_edges",
    "adjacency_batch_from_rings",
    "overlay_with_rings",
    "pad_adjacency_blocks",
    "batched_apsp",
    "batched_diameter",
    "diameters",
    "diameters_of_rings",
]


# ---------------------------------------------------------------------------
# graph assembly (host, vectorized)
# ---------------------------------------------------------------------------

def rings_to_edges(genomes) -> np.ndarray:
    """``(B, K, N)`` ring permutations -> ``(B, K*N, 2)`` edge lists.

    Accepts a (B, K, N) array, a (B, N) array (K=1), or a nested list of
    per-genome ring permutations.
    """
    g = np.asarray(genomes, dtype=np.intp)
    if g.ndim == 2:
        g = g[:, None, :]
    assert g.ndim == 3, g.shape
    nxt = np.roll(g, -1, axis=-1)
    return np.stack([g, nxt], axis=-1).reshape(g.shape[0], -1, 2)


def adjacency_batch_from_edges(w: np.ndarray, edges: np.ndarray) -> np.ndarray:
    """Batch of weighted adjacencies from per-candidate edge lists.

    ``edges`` is (B, E, 2); returns (B, N, N) float32 with INF on non-edges
    and 0 diagonal.  The scatter is one ``np.minimum.at`` over both edge
    directions, so duplicate/parallel edges resolve to the min weight
    exactly like the scalar loop in ``diameter.adjacency_from_edges``.
    """
    w = np.asarray(w)
    n = w.shape[0]
    e = np.asarray(edges, dtype=np.intp)
    assert e.ndim == 3 and e.shape[-1] == 2, e.shape
    b = e.shape[0]
    d = np.full((b, n, n), float(INF), dtype=np.float32)
    d[:, np.arange(n), np.arange(n)] = 0.0
    if e.shape[1]:
        bi = np.broadcast_to(np.arange(b)[:, None], e.shape[:2])
        u, v = e[..., 0], e[..., 1]
        np.minimum.at(d, (bi, u, v), w[u, v].astype(np.float32))
        np.minimum.at(d, (bi, v, u), w[v, u].astype(np.float32))
    return d


def adjacency_batch_from_rings(w: np.ndarray, genomes) -> np.ndarray:
    """(B, K, N) ring permutations -> (B, N, N) union-of-rings adjacencies."""
    return adjacency_batch_from_edges(w, rings_to_edges(genomes))


def overlay_with_rings(adj: np.ndarray, w: np.ndarray, rings) -> np.ndarray:
    """B candidate overlays: the base ``adj`` each augmented with one ring."""
    cand = adjacency_batch_from_rings(w, rings)
    return np.minimum(np.asarray(adj, np.float32)[None], cand)


def pad_adjacency_blocks(blocks: Sequence[np.ndarray]) -> np.ndarray:
    """Pad variable-size adjacencies to one (B, N_max, N_max) batch.

    Padded nodes are isolated (INF rows/cols, 0 diagonal): each is a
    singleton component, so the largest-CC diameter of the padded graph
    equals the block's own diameter whenever the block has >= 1 node.
    """
    blocks = [np.asarray(b, np.float32) for b in blocks]
    n_max = max(b.shape[0] for b in blocks)
    out = np.full((len(blocks), n_max, n_max), float(INF), dtype=np.float32)
    out[:, np.arange(n_max), np.arange(n_max)] = 0.0
    for i, b in enumerate(blocks):
        out[i, :b.shape[0], :b.shape[0]] = b
    return out


# ---------------------------------------------------------------------------
# device compute (jit, one call per batch)
# ---------------------------------------------------------------------------

def _batched_minplus(a: jnp.ndarray, b: jnp.ndarray,
                     use_kernel: bool) -> jnp.ndarray:
    """One batched min-plus squaring step, via the kernels.minplus entry
    point — compiled Pallas grid-over-batch on TPU, vmapped jnp oracle on
    CPU — so the default TPU path actually runs the kernel.  ``use_kernel``
    forces the Pallas body (interpret mode off-TPU) for cross-validation."""
    from repro.kernels.minplus import ops as minplus_ops

    return minplus_ops.minplus_batched(a, b, force_kernel=use_kernel)


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "method", "symmetric"))
def batched_apsp(adjs: jnp.ndarray, *, use_kernel: bool = False,
                 method: str | None = None,
                 symmetric: bool = True) -> jnp.ndarray:
    """All-pairs shortest paths for a (B, N, N) stack of adjacencies.

    Two interchangeable algorithms (cross-validated in tests):

    * ``"squaring"`` — batched min-plus matrix squaring, O(N^3 log N) but
      built from large tiled products; this is the TPU path (the batched
      Pallas kernel runs one (N, N) min-plus tile per grid step) and is
      forced whenever ``use_kernel`` is set.
    * ``"fw"`` — batched vectorized Floyd-Warshall, O(N^3) with only a
      (B, N, N) temporary per step (unrolled x8 to amortize loop dispatch);
      the CPU default — its rank-1 broadcast-min step is memory-light,
      which on CPU beats squaring's (B, N, N, N) broadcast temporaries by
      an order of magnitude.

    ``symmetric`` (default) lets FW read only the contiguous row slice
    ``d[:, k, :]`` — valid for the undirected overlays every builder in
    this module produces (both edge directions are scattered).  Pass
    ``symmetric=False`` for directed inputs.
    """
    method = _resolve_method(use_kernel, method)
    n = adjs.shape[-1]
    if method == "fw":
        def fw_body(k, d):
            if symmetric:
                col = row = d[:, k, :]
            else:
                col, row = d[:, :, k], d[:, k, :]
            return jnp.minimum(d, col[:, :, None] + row[:, None, :])

        return jax.lax.fori_loop(0, n, fw_body, adjs, unroll=8)

    assert method == "squaring", method
    n_iters = max(1, int(np.ceil(np.log2(max(n - 1, 2)))))

    def body(_, d):
        return _batched_minplus(d, d, use_kernel)

    return jax.lax.fori_loop(0, n_iters, body, adjs)


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "method", "symmetric"))
def batched_diameter(adjs: jnp.ndarray, *, use_kernel: bool = False,
                     method: str | None = None,
                     symmetric: bool = True) -> jnp.ndarray:
    """(B, N, N) adjacencies -> (B,) largest-CC diameters, one device call."""
    d = batched_apsp(adjs, use_kernel=use_kernel, method=method,
                     symmetric=symmetric)
    return jax.vmap(largest_cc_diameter)(d)


@functools.partial(jax.jit,
                   static_argnames=("use_kernel", "method", "symmetric"))
def _batched_diameter_chunked(stack: jnp.ndarray, *, use_kernel: bool = False,
                              method: str | None = None,
                              symmetric: bool = True) -> jnp.ndarray:
    """(C, chunk, N, N) -> (C, chunk): sequential map over fixed-size chunks
    inside one jit, bounding peak memory at the per-chunk temporaries."""
    return jax.lax.map(
        lambda a: batched_diameter(a, use_kernel=use_kernel, method=method,
                                   symmetric=symmetric),
        stack)


# ---------------------------------------------------------------------------
# host facade
# ---------------------------------------------------------------------------

def _resolve_method(use_kernel: bool, method: str | None) -> str:
    if method is not None:
        return method
    return "squaring" if use_kernel or jax.default_backend() == "tpu" else "fw"


def default_chunk(n: int, method: str = "fw",
                  budget_bytes: int = 1 << 28) -> int:
    """Largest batch chunk whose per-step fp32 temporaries stay under
    ``budget_bytes`` (~256 MiB).

    Only the CPU jnp-oracle squaring materializes a (chunk, N, N, N)
    broadcast; the TPU Pallas kernel is tiled (a few VMEM blocks per step)
    and Floyd-Warshall touches a few (chunk, N, N) slabs, so those paths
    size by N^2 and keep big batches in one grid launch."""
    dense_squaring = method == "squaring" and jax.default_backend() != "tpu"
    per_item = 4 * n ** 3 if dense_squaring else 4 * n * n * 8
    return max(1, budget_bytes // max(1, per_item))


def diameters(adjs: np.ndarray, *, use_kernel: bool = False,
              method: str | None = None, symmetric: bool = True,
              chunk: int | None = None) -> np.ndarray:
    """Diameters for a (B, N, N) adjacency stack, as a host (B,) array.

    Issues exactly ONE device call: small batches go straight through
    ``batched_diameter``; larger ones are padded to a multiple of ``chunk``
    and folded through a ``lax.map`` so memory stays bounded.
    """
    from repro.obs import jit_span
    adjs = np.asarray(adjs, dtype=np.float32)
    assert adjs.ndim == 3 and adjs.shape[1] == adjs.shape[2], adjs.shape
    b, n = adjs.shape[0], adjs.shape[-1]
    if b == 0:
        return np.zeros((0,), np.float32)
    chunk = chunk or default_chunk(n, _resolve_method(use_kernel, method))
    if b <= chunk:
        with jit_span("batcheval.diameters",
                      key=(b, n, use_kernel, method, symmetric)):
            out = batched_diameter(jnp.asarray(adjs), use_kernel=use_kernel,
                                   method=method, symmetric=symmetric)
        return np.asarray(out)
    pad = (-b) % chunk
    if pad:
        adjs = np.concatenate([adjs, np.repeat(adjs[:1], pad, axis=0)], axis=0)
    stack = adjs.reshape(-1, chunk, n, n)
    with jit_span("batcheval.diameters",
                  key=("chunked", chunk, n, use_kernel, method, symmetric)):
        out = _batched_diameter_chunked(jnp.asarray(stack),
                                        use_kernel=use_kernel,
                                        method=method, symmetric=symmetric)
    return np.asarray(out).reshape(-1)[:b]


def diameters_of_rings(w: np.ndarray, genomes, *, use_kernel: bool = False,
                       method: str | None = None,
                       chunk: int | None = None) -> np.ndarray:
    """Score B K-ring genomes by overlay diameter in one batched call."""
    return diameters(adjacency_batch_from_rings(w, genomes),
                     use_kernel=use_kernel, method=method, chunk=chunk)
