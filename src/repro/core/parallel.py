"""Parallel ring construction (paper §VI, Algorithm 4).

N nodes are strided into M partitions (§VI / Alg. 4: "a random ring is
segmented into M partitions using a same stride, each partition's starting
node determined by a consistent hash" — Fig. 14 is the *benchmark* of this
scheme, not its definition).  Each partition orders its own nodes
concurrently, then the segments are stitched into one ring.

The partition build is device-batched: the M strided partitions (sizes
``ceil(N/M)`` or ``floor(N/M)`` — any ``1 <= M``, no ``N % M`` restriction;
``M > N`` just leaves trailing partitions empty) are padded to a common
block size P = ``ceil(N/M)`` and ALL segments are constructed in ONE jit'd
device call over the (M, P, P) latency-block stack.  Constructors are
pluggable:

* ``"nearest"`` — vmapped :func:`construction.nearest_rings_batched`
  (INF-padded blocks keep pad nodes unreachable until the real nodes are
  exhausted, so ``perm[:size]`` is each block's own ring order);
* ``"dqn"``     — the vectorized DQN rollout engine
  (:func:`repro.core.rollout.rollout_episodes`) with partitions as the
  environment batch and per-env ``sizes`` masking the padding, so
  DQN-quality segments come at nearest-neighbour wall clock.

Stitching: ``"naive"`` connects segment i's tail to segment i+1's head
(Alg. 4 line 14); ``"scored"`` additionally tries rotations/reflections of
every segment — each candidate keeps the segment's own ring edges and only
moves which edge the inter-partition closure breaks — and scores ALL
candidate merged rings in ONE batched ``batcheval.diameters`` call,
keeping the best (the long-jump/clustering trade-off of ring augmentation:
naive tail-to-head closures leave diameter on the table).

Three engines, cross-validated in tests (all consume the same
:class:`PartitionPlan` host randomness, so a fixed seed produces identical
segments on every path):

* :func:`parallel_ring` / :func:`parallel_ring_scored` — the device-batched
  engine above (single device, one call for all partitions);
* :func:`parallel_ring_host`  — per-partition numpy loop, the pre-batched
  reference implementation and the fig14 speedup baseline;
* :func:`parallel_ring_shmap` — ``shard_map`` over a ``partitions`` mesh
  axis: one padded block per device for the multi-device path.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from . import batcheval
from .construction import nearest_ring, nearest_ring_jax, nearest_rings_batched
from .diameter import INF, adjacency_from_rings

__all__ = ["partition_nodes", "PartitionPlan", "plan_partitions",
           "SegmentDQNConfig", "stitch_segments", "score_partition_blocks",
           "parallel_ring", "parallel_rings", "parallel_ring_scored",
           "parallel_ring_host", "parallel_overlay", "parallel_ring_shmap"]


# ---------------------------------------------------------------------------
# partition planning (shared host randomness for every engine)
# ---------------------------------------------------------------------------

def partition_nodes(n: int, m: int, rng: np.random.Generator) -> List[np.ndarray]:
    """Stride a random base ring into M partitions (paper §VI / Alg. 4)."""
    base = rng.permutation(n)
    return [base[i::m] for i in range(m)]


class PartitionPlan(NamedTuple):
    """Everything random about one Alg. 4 build, drawn up front on the host.

    ``parts``: per-partition node ids (trailing partitions are empty when
    M > N); ``sizes``: (M,) partition sizes; ``starts``: (M,) local
    consistent-hash start indices (0 for empty partitions, which draw no
    randomness).  Every engine (host loop, device batch, shard_map) consumes
    the same plan, so a fixed seed builds identical segments on all paths.
    """

    parts: List[np.ndarray]
    sizes: np.ndarray
    starts: np.ndarray

    @property
    def p_max(self) -> int:
        """Padded block size P = ceil(N/M) (1 when every partition is empty)."""
        return max(1, int(self.sizes.max()))


def plan_partitions(n: int, m: int, rng: np.random.Generator) -> PartitionPlan:
    if m < 1:
        raise ValueError(f"need at least one partition, got m={m}")
    parts = partition_nodes(n, m, rng)
    sizes = np.array([len(p) for p in parts], dtype=np.int32)
    starts = np.array([int(rng.integers(s)) if s else 0 for s in sizes],
                      dtype=np.int32)
    return PartitionPlan(parts, sizes, starts)


def _padded_blocks(w: np.ndarray, plan: PartitionPlan,
                   fill: float) -> np.ndarray:
    """(M, P, P) stack of per-partition latency blocks, padded with ``fill``
    (host assembly — the shard_map path ships one block per device)."""
    p = plan.p_max
    out = np.full((len(plan.parts), p, p), fill, dtype=np.float32)
    for i, nodes in enumerate(plan.parts):
        s = len(nodes)
        if s:
            out[i, :s, :s] = w[np.ix_(nodes, nodes)]
    return out


def _plans_index(plans: Sequence[PartitionPlan], p: int) -> np.ndarray:
    """(B*M, P) node-id rows for every partition of every plan, -1 padded —
    the device gathers the latency blocks itself (see `_gather_blocks`), so
    the host never materializes B*M (P, P) copies of w's entries."""
    rows = np.full((sum(len(pl.parts) for pl in plans), p), -1, dtype=np.int32)
    r = 0
    for plan in plans:
        for nodes in plan.parts:
            rows[r, :len(nodes)] = nodes
            r += 1
    return rows


@jax.jit
def _gather_blocks(w: jnp.ndarray, idx: jnp.ndarray, fill) -> jnp.ndarray:
    """(B*M, P) padded node-id rows -> (B*M, P, P) latency blocks on device."""

    def one(idx_i):
        pad = idx_i < 0
        ii = jnp.where(pad, 0, idx_i)
        block = w[ii[:, None], ii[None, :]]
        return jnp.where(pad[:, None] | pad[None, :], fill, block)

    return jax.vmap(one)(idx)


@jax.jit
def _gather_nearest_perms(w: jnp.ndarray, idx: jnp.ndarray,
                          starts: jnp.ndarray) -> jnp.ndarray:
    """Fused gather + nearest-ring build for every padded block row: ONE
    device call constructs all B*M partition segments of B ring builds."""
    return nearest_rings_batched(_gather_blocks(w, idx, INF), starts)


def _extract_segments(plan: PartitionPlan, perms: np.ndarray) -> List[np.ndarray]:
    """Local padded-block perms -> global node-id segments (empties kept)."""
    return [nodes[perms[i, :len(nodes)]] for i, nodes in enumerate(plan.parts)]


# ---------------------------------------------------------------------------
# per-partition constructors (one device call for ALL partitions of ALL builds)
# ---------------------------------------------------------------------------

def _nearest_perms_fused(w: np.ndarray, plans: Sequence[PartitionPlan]):
    """One fused gather+build device call for every partition of every
    plan.  Returns ``(idx (B*M, P), perms (B*M, P))`` in plan order."""
    p = max(pl.p_max for pl in plans)
    idx = _plans_index(plans, p)
    starts = np.concatenate([pl.starts for pl in plans])
    perms = np.asarray(_gather_nearest_perms(
        jnp.asarray(w), jnp.asarray(idx), jnp.asarray(starts)))
    return idx, perms


def _segments_nearest_many(w: np.ndarray,
                           plans: Sequence[PartitionPlan]) -> List[List[np.ndarray]]:
    _, perms = _nearest_perms_fused(w, plans)
    out, r = [], 0
    for plan in plans:
        out.append(_extract_segments(plan, perms[r:r + len(plan.parts)]))
        r += len(plan.parts)
    return out


def _segments_nearest(w: np.ndarray, plan: PartitionPlan) -> List[np.ndarray]:
    return _segments_nearest_many(w, [plan])[0]


def _nearest_merged_naive(w: np.ndarray,
                          plans: Sequence[PartitionPlan]) -> List[np.ndarray]:
    """Fast path for nearest + naive stitch: one fused device build, then
    ONE vectorized gather/mask turns all B*M padded perms into the B merged
    rings (no per-partition host loop).  Bit-identical to extracting the
    segments and concatenating them in partition order."""
    idx, perms = _nearest_perms_fused(w, plans)
    sizes = np.concatenate([pl.sizes for pl in plans])
    gathered = np.take_along_axis(idx, perms, axis=1)     # global node ids
    real = np.arange(idx.shape[1], dtype=np.int32)[None, :] < sizes[:, None]
    return np.split(gathered[real].astype(np.intp), len(plans))


@dataclasses.dataclass(frozen=True)
class SegmentDQNConfig:
    """Training recipe for the ``"dqn"`` per-partition constructor: a small
    deep-Q ring builder trained on graphs of the padded block size, then
    rolled out greedily over all M partition blocks in one vmapped call.

    ``train_seed`` seeds the training run only — build seeds randomize the
    partition plans, not the Q-network, so repeated builds at the same
    block size reuse one cached training run.
    """
    epochs: int = 40
    dist: str = "uniform"
    alpha: float = 0.1
    n_envs: int = 4
    train_seed: int = 0


# trained segment-constructor params, keyed by (block size, recipe) — an
# M-sweep (fig14) or repeated builder calls reuse one training run; FIFO
# eviction keeps a handful of (p, recipe) combinations resident
_SEGMENT_PARAMS_CACHE: dict = {}
_SEGMENT_PARAMS_CACHE_MAX = 8


def _segment_qparams(p: int, dqn: SegmentDQNConfig):
    from .qlearning import DQNConfig, train_dqn   # jax-heavy, import lazily

    key = (p, dqn)
    if key not in _SEGMENT_PARAMS_CACHE:
        dcfg = DQNConfig(n=p, k_rings=1, epochs=dqn.epochs,
                         eps_decay=max(dqn.epochs // 2, 1), dist=dqn.dist,
                         alpha=dqn.alpha, seed=dqn.train_seed,
                         n_envs=dqn.n_envs)
        params, _ = train_dqn(dcfg, eval_every=max(dqn.epochs, 1),
                              eval_graphs=1)
        while len(_SEGMENT_PARAMS_CACHE) >= _SEGMENT_PARAMS_CACHE_MAX:
            _SEGMENT_PARAMS_CACHE.pop(next(iter(_SEGMENT_PARAMS_CACHE)))
        _SEGMENT_PARAMS_CACHE[key] = (params, dcfg)
    return _SEGMENT_PARAMS_CACHE[key]


def _segments_dqn_many(w: np.ndarray, plans: Sequence[PartitionPlan],
                       dqn: SegmentDQNConfig) -> List[List[np.ndarray]]:
    """DQN-ordered segments: all B*M partitions ARE the rollout environment
    batch of ONE vmapped episode call.

    Pad latencies are 0 (not INF — the Q embedding consumes ``w``) and pad
    nodes are excluded via the engine's per-env ``sizes`` masking; the
    greedy (eps=0) episode needs no plan uniforms.
    """
    from . import rollout   # jax-heavy, import lazily

    p = max(pl.p_max for pl in plans)
    params, dcfg = _segment_qparams(p, dqn)
    idx = _plans_index(plans, p)
    starts = np.concatenate([pl.starts for pl in plans])
    sizes = np.concatenate([pl.sizes for pl in plans])
    blocks = _gather_blocks(jnp.asarray(w), jnp.asarray(idx), 0.0)
    zeros = jnp.zeros((p, len(starts)), jnp.float32)     # T = k_rings * P = P
    actions, _, _ = rollout.rollout_episodes(
        params, blocks, jnp.asarray(starts[:, None]), zeros, zeros,
        0.0, dqn.alpha, k_rings=1, n_rounds=dcfg.n_rounds,
        sizes=jnp.asarray(sizes))
    actions = np.asarray(actions)                        # (P, B*M)
    perms = np.empty((len(starts), p), dtype=np.int64)
    for i, s in enumerate(sizes):
        if s:
            perms[i, 0] = starts[i]
            perms[i, 1:s] = actions[:s - 1, i]           # step s-1 closes
    out, r = [], 0
    for plan in plans:
        out.append(_extract_segments(plan, perms[r:r + len(plan.parts)]))
        r += len(plan.parts)
    return out


# ---------------------------------------------------------------------------
# stitch refinement
# ---------------------------------------------------------------------------

def _orient(seg: np.ndarray, rot: int, flip: bool) -> np.ndarray:
    s = np.roll(seg, -rot)
    return s[::-1] if flip else s


def _greedy_chain(w: np.ndarray, segs: List[np.ndarray],
                  flip_first: bool) -> np.ndarray:
    """Chain segments greedily: rotate each so its head is the node nearest
    the previous segment's tail (rotations keep the segment's ring edges —
    they only move which edge the closure breaks)."""
    out = [_orient(segs[0], 0, flip_first)]
    for seg in segs[1:]:
        tail = out[-1][-1]
        out.append(_orient(seg, int(np.argmin(w[tail, seg])), False))
    return np.concatenate(out)


def stitch_segments(w: np.ndarray, segments: Sequence[np.ndarray],
                    stitch: str = "naive", n_candidates: int = 16,
                    seed: int = 0,
                    eval_opts: Optional[dict] = None) -> np.ndarray:
    """Merge per-partition segments into one ring permutation.

    ``"naive"``: concatenate in partition order (Alg. 4 line 14 — segment
    i's tail connects to segment i+1's head, the last back to the first).
    ``"scored"``: build ``n_candidates`` merges where each segment may be
    rotated/reflected (every candidate preserves each segment's own ring
    edges; only the edge broken by the inter-partition closure moves) —
    the naive merge, two greedy nearest-entry chains, and random
    orientations — then score ALL of them in ONE batched diameter call and
    keep the best.  Empty segments are dropped.
    """
    if stitch not in ("naive", "scored"):
        raise ValueError(f"unknown stitch {stitch!r}; options "
                         f"('naive', 'scored')")
    segs = [np.asarray(s) for s in segments if len(s)]
    if not segs:
        raise ValueError("no non-empty segments to stitch")
    naive = np.concatenate(segs)
    if stitch == "naive" or len(segs) == 1:
        return naive
    # a child stream distinct from default_rng(seed): the plan already
    # consumed that exact stream, and correlated draws would tie the
    # candidate orientations to the base permutation (cf. selection.adapt)
    rng = np.random.default_rng(np.random.SeedSequence([seed, 1]))
    cands = [naive, _greedy_chain(w, segs, False), _greedy_chain(w, segs, True)]
    for _ in range(max(0, n_candidates - len(cands))):
        cands.append(np.concatenate([
            _orient(s, int(rng.integers(len(s))), bool(rng.integers(2)))
            for s in segs]))
    rings = np.stack(cands)
    with batcheval.eval_options(**(eval_opts or {})):
        scores = batcheval.diameters_of_rings(w, rings[:, None, :])
    return rings[int(np.argmin(scores))]


def score_partition_blocks(w: np.ndarray,
                           segments: Sequence[np.ndarray],
                           eval_opts: Optional[dict] = None) -> np.ndarray:
    """Per-partition ring diameters, all non-empty blocks in ONE padded
    device batch (padded nodes are isolated singletons the largest-CC rule
    ignores).

    Returns one score per REQUESTED partition — ``NaN`` for empty blocks
    (M > N leaves trailing partitions empty), so the result always has
    ``len(segments)`` entries aligned with the input.
    """
    segments = [np.asarray(s) for s in segments]
    scores = np.full(len(segments), np.nan, dtype=np.float32)
    idx = [i for i, s in enumerate(segments) if len(s)]
    if not idx:
        return scores
    blocks = []
    for i in idx:
        seg = segments[i]
        sub_w = w[np.ix_(seg, seg)]
        blocks.append(adjacency_from_rings(sub_w, [np.arange(len(seg))]))
    with batcheval.eval_options(**(eval_opts or {})):
        scores[idx] = batcheval.diameters(
            batcheval.pad_adjacency_blocks(blocks))
    return scores


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------

def _build_segments_many(w: np.ndarray, plans: Sequence[PartitionPlan],
                         constructor: str,
                         dqn: Optional[SegmentDQNConfig]) -> List[List[np.ndarray]]:
    # blocks of <= 2 nodes have a unique ring order — the DQN adds nothing
    if constructor == "dqn" and max(pl.p_max for pl in plans) > 2:
        return _segments_dqn_many(w, plans, dqn or SegmentDQNConfig())
    if constructor in ("nearest", "dqn"):
        return _segments_nearest_many(w, plans)
    raise ValueError(f"unknown constructor {constructor!r}; options "
                     f"('nearest', 'dqn')")


def parallel_rings(w: np.ndarray, m: int, seeds: Sequence[int],
                   constructor: str = "nearest", stitch: str = "naive",
                   n_stitch_candidates: int = 16,
                   dqn: Optional[SegmentDQNConfig] = None,
                   eval_opts: Optional[dict] = None) -> List[np.ndarray]:
    """B independent Algorithm-4 builds in ONE device call.

    All ``len(seeds) * M`` partition segments go through a single fused
    gather + construct call (the B*M padded blocks are the batch axis), so
    building a whole K-ring topology — or a fleet of candidate rings —
    costs one dispatch instead of B.  Returns one merged ring per seed;
    each build draws its own :class:`PartitionPlan` from its seed, exactly
    as the single-build entry points do.
    """
    if not len(seeds):
        return []
    w = np.asarray(w, dtype=np.float32)
    plans = [plan_partitions(w.shape[0], m, np.random.default_rng(s))
             for s in seeds]
    if constructor == "nearest" and stitch == "naive":
        return _nearest_merged_naive(w, plans)
    many = _build_segments_many(w, plans, constructor, dqn)
    return [stitch_segments(w, segs, stitch=stitch,
                            n_candidates=n_stitch_candidates, seed=int(s),
                            eval_opts=eval_opts)
            for segs, s in zip(many, seeds)]


def parallel_ring_scored(
        w: np.ndarray, m: int, seed: int = 0, score_blocks: bool = False,
        constructor: str = "nearest", stitch: str = "naive",
        n_stitch_candidates: int = 16,
        dqn: Optional[SegmentDQNConfig] = None,
        eval_opts: Optional[dict] = None,
) -> Tuple[np.ndarray, np.ndarray | None]:
    """Algorithm 4 on the device-batched engine + optional quality signal.

    Returns (merged ring permutation, per-partition block ring diameters or
    None).  The block scores — used by the construction monitor and the
    fig14 benchmark — come from one padded batched diameter call and carry
    one entry per requested partition (NaN for empty blocks).
    """
    w = np.asarray(w, dtype=np.float32)
    rng = np.random.default_rng(seed)
    plan = plan_partitions(w.shape[0], m, rng)
    segments = _build_segments_many(w, [plan], constructor, dqn)[0]
    ring = stitch_segments(w, segments, stitch=stitch,
                           n_candidates=n_stitch_candidates, seed=seed,
                           eval_opts=eval_opts)
    scores = (score_partition_blocks(w, segments, eval_opts=eval_opts)
              if score_blocks else None)
    return ring, scores


def parallel_ring(w: np.ndarray, m: int, seed: int = 0,
                  constructor: str = "nearest",
                  stitch: str = "naive") -> np.ndarray:
    """Algorithm 4, device-batched: all M partition segments in one jit'd
    call, then stitch.  Returns the merged ring permutation."""
    return parallel_ring_scored(w, m, seed=seed, constructor=constructor,
                                stitch=stitch)[0]


def parallel_ring_host(w: np.ndarray, m: int, seed: int = 0,
                       stitch: str = "naive") -> np.ndarray:
    """Algorithm 4 as the pre-batched host reference: a Python loop of
    per-partition numpy nearest-neighbour builds.  Consumes the same
    :class:`PartitionPlan` randomness as the batched engine, so segments
    (and the merged ring) are identical at a fixed seed — the fig14
    benchmark gates the batched engine's speedup against this loop."""
    w = np.asarray(w, dtype=np.float32)
    rng = np.random.default_rng(seed)
    plan = plan_partitions(w.shape[0], m, rng)
    segments = []
    for nodes, start in zip(plan.parts, plan.starts):
        if len(nodes) == 0:
            segments.append(nodes)
            continue
        sub_w = w[np.ix_(nodes, nodes)]
        segments.append(nodes[nearest_ring(sub_w, start=int(start))])
    return stitch_segments(w, segments, stitch=stitch, seed=seed)


def parallel_overlay(w: np.ndarray, m: int, seed: int = 0,
                     score_blocks: bool = False,
                     constructor: str = "nearest", stitch: str = "naive",
                     dqn: Optional[SegmentDQNConfig] = None):
    """Algorithm 4 as an :class:`repro.overlay.Overlay`.

    Returns ``(overlay, block_scores)`` where the overlay holds the merged
    ring and ``block_scores`` the per-partition ring diameters (``None``
    unless ``score_blocks``; NaN entries mark empty partitions).
    """
    from repro.overlay import Overlay

    perm, scores = parallel_ring_scored(
        w, m, seed=seed, score_blocks=score_blocks, constructor=constructor,
        stitch=stitch, dqn=dqn)
    return Overlay.from_rings(w, [perm], policy="parallel"), scores


def parallel_ring_shmap(w: np.ndarray, mesh: Mesh, axis: str = "partitions",
                        seed: int = 0, stitch: str = "naive") -> np.ndarray:
    """Algorithm 4 with shard_map: one padded partition block per device
    along ``axis`` — the multi-device path of the batched engine.

    Any ``1 <= M`` works: partitions are padded to P = ceil(N/M) exactly
    like the single-device batch (INF padding; non-divisible N and M > N
    just shrink or empty the trailing blocks), and the same
    :class:`PartitionPlan` randomness keeps the result bit-identical to
    :func:`parallel_ring` / :func:`parallel_ring_host` at a fixed seed.
    """
    m = mesh.shape[axis]
    w = np.asarray(w, dtype=np.float32)
    rng = np.random.default_rng(seed)
    plan = plan_partitions(w.shape[0], m, rng)
    blocks = _padded_blocks(w, plan, float(INF))
    starts = plan.starts[:, None].astype(np.int32)

    def build_one(block, start):
        # block: (1, P, P) local shard; start: (1, 1)
        perm = nearest_ring_jax(block[0], start[0, 0])
        return perm[None]

    fn = shard_map(
        build_one, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None)),
        out_specs=P(axis, None),
    )
    perms = np.asarray(jax.jit(fn)(jnp.asarray(blocks), jnp.asarray(starts)))
    segments = _extract_segments(plan, perms)
    return stitch_segments(w, segments, stitch=stitch, seed=seed)
