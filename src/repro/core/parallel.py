"""Parallel ring construction (paper §VI, Algorithm 4).

N nodes are strided into M partitions (paper Fig. 14: "a random ring is
segmented into M partitions using a same stride, each partition's starting
node determined by a consistent hash").  Each partition orders its own nodes
concurrently (nearest-neighbour or DQN), then segments are stitched: the
last node of partition i connects to the first node of partition i+1.

Two implementations, cross-validated in tests:
  * ``parallel_ring``      — host (numpy) reference, trivially parallel.
  * ``parallel_ring_shmap``— shard_map over a ``partitions`` mesh axis; each
    device builds one partition with the jit'd nearest-neighbour constructor
    and the stitch is expressed with collective semantics (the per-partition
    perm is all-gathered and concatenated — the ring-closure edges are
    implied by segment order, matching Alg. 4 line 14).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from . import batcheval
from .construction import nearest_ring, nearest_ring_jax
from .diameter import adjacency_from_rings

__all__ = ["partition_nodes", "parallel_ring", "parallel_ring_scored",
           "parallel_overlay", "score_partition_blocks",
           "parallel_ring_shmap"]


def partition_nodes(n: int, m: int, rng: np.random.Generator) -> List[np.ndarray]:
    """Stride a random base ring into M partitions (paper §VII-C.4)."""
    base = rng.permutation(n)
    return [base[i::m] for i in range(m)]


def parallel_ring(w: np.ndarray, m: int, seed: int = 0) -> np.ndarray:
    """Algorithm 4 on the host: per-partition nearest-neighbour order, then
    stitch segments end-to-end.  Returns the merged ring permutation."""
    return parallel_ring_scored(w, m, seed=seed)[0]


def score_partition_blocks(w: np.ndarray,
                           segments: List[np.ndarray]) -> np.ndarray:
    """Per-partition ring diameters, all M blocks in ONE padded device batch.

    Each segment's local ring adjacency (over its own latency block) is
    padded to the largest partition size and stacked; padded nodes are
    isolated singletons that the largest-CC rule ignores, so the scores
    equal each block's own ring diameter.
    """
    blocks = []
    for seg in segments:
        sub_w = w[np.ix_(seg, seg)]
        blocks.append(adjacency_from_rings(sub_w, [np.arange(len(seg))]))
    return batcheval.diameters(batcheval.pad_adjacency_blocks(blocks))


def parallel_ring_scored(
        w: np.ndarray, m: int, seed: int = 0,
        score_blocks: bool = False) -> Tuple[np.ndarray, np.ndarray | None]:
    """Algorithm 4 + optional per-partition quality signal.

    Returns (merged ring permutation, per-block ring diameters or None).
    The block scores — used by the construction monitor and the fig14
    benchmark — come from one padded batched diameter call rather than M
    host Dijkstras.
    """
    rng = np.random.default_rng(seed)
    n = w.shape[0]
    parts = partition_nodes(n, m, rng)
    segments = []
    for nodes in parts:
        if len(nodes) == 0:
            continue
        sub_w = w[np.ix_(nodes, nodes)]
        start = int(rng.integers(len(nodes)))          # consistent-hash start
        local = nearest_ring(sub_w, start=start)
        segments.append(nodes[local])
    scores = score_partition_blocks(w, segments) if score_blocks else None
    return np.concatenate(segments), scores


def parallel_overlay(w: np.ndarray, m: int, seed: int = 0,
                     score_blocks: bool = False):
    """Algorithm 4 as an :class:`repro.overlay.Overlay`.

    Returns ``(overlay, block_scores)`` where the overlay holds the merged
    ring and ``block_scores`` the per-partition ring diameters (``None``
    unless ``score_blocks``).
    """
    from repro.overlay import Overlay

    perm, scores = parallel_ring_scored(w, m, seed=seed,
                                        score_blocks=score_blocks)
    return Overlay.from_rings(w, [perm], policy="parallel"), scores


def parallel_ring_shmap(w: np.ndarray, mesh: Mesh, axis: str = "partitions",
                        seed: int = 0) -> np.ndarray:
    """Algorithm 4 with shard_map: one partition per device along ``axis``.

    The node->partition assignment is strided over a random base ring; each
    shard runs the jit'd nearest-neighbour constructor over its local block
    of the latency matrix, then the merged ring is the concatenation of
    per-partition segments (ring closure per Alg. 4 line 14).
    """
    m = mesh.shape[axis]
    n = w.shape[0]
    assert n % m == 0, f"N={n} must divide into {m} partitions"
    rng = np.random.default_rng(seed)
    base = rng.permutation(n)
    nodes_by_part = np.stack([base[i::m] for i in range(m)])     # (m, n/m)
    # per-partition local latency blocks, gathered host-side once
    blocks = np.stack([w[np.ix_(p, p)] for p in nodes_by_part])  # (m, n/m, n/m)
    starts = rng.integers(0, n // m, size=(m, 1)).astype(np.int32)

    def build_one(block, start):
        # block: (1, n/m, n/m) local shard; start: (1, 1)
        perm = nearest_ring_jax(block[0], start[0, 0])
        return perm[None]

    fn = shard_map(
        build_one, mesh=mesh,
        in_specs=(P(axis, None, None), P(axis, None)),
        out_specs=P(axis, None),
    )
    local_perms = np.asarray(jax.jit(fn)(jnp.asarray(blocks), jnp.asarray(starts)))
    segments = [nodes_by_part[i][local_perms[i]] for i in range(m)]
    return np.concatenate(segments)
