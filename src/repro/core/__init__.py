"""DGRO core: the paper's contribution (diameter-guided ring optimization).

Submodules:
  topology      — the four latency distributions of §VII-A
  diameter      — min-plus APSP (JAX/Pallas) + scipy oracle, largest-CC rule
  batcheval     — batched (B, N, N) diameter engine (vmapped APSP, one
                  device call per candidate batch; chunked for memory)
  construction  — Algorithm 1 ring constructors (random/nearest/greedy/K-ring)
  embedding     — Eqns 2-4 graph embedding + Q-head (structure2vec style)
  rollout       — device-resident vectorized episode engine: one jit'd
                  lax.scan per epoch over E vmapped environments, with
                  incremental-relax rewards, a device replay buffer and
                  fused TD updates
  qlearning     — Algorithm 2 DQN facade over the rollout engine
                  (rollout="device" default; "host" debug loop retained)
  selection     — Algorithm 3 gossip latency measurement + rho ring selection
  parallel      — Algorithm 4 partitioned construction (host + shard_map)
  ga            — genetic-algorithm and random-search baselines (§VII-A.2)
  protocols     — DEPRECATED tuple facade; the Chord / RAPID / Perigee
                  builders live in ``repro.overlay`` (§V-A)

Overlay construction and manipulation lives in ``repro.overlay`` (immutable
``Overlay`` pytree + builder registry); this package holds the algorithms
the builders are made of.
"""
from . import (batcheval, construction, diameter, ga, protocols, selection,  # noqa: F401
               topology)

# embedding/qlearning/parallel import jax-heavy deps; import lazily where used.
