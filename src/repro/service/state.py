"""The control plane's live state: one lock, one engine, one served Overlay.

:class:`ServiceState` owns

* a :class:`~repro.dynamics.engine.ChurnEngine` ingesting Trace-format
  events through the live :meth:`~repro.dynamics.engine.ChurnEngine.process`
  path (SWIM-confirmed failures, splice joins, tombstoned leaves — exactly
  the replay semantics, fed one event at a time);
* the **served Overlay** — a lazily-rebuilt, immutable
  :class:`~repro.overlay.Overlay` snapshot of the live sub-fleet.  The
  async re-optimizer computes its candidate on a *frozen copy* (the second
  buffer) and :meth:`commit_reopt` swaps the result in under the lock in
  O(ring) relaxations — queries never wait on the optimization itself;
* the snapshot cadence for crash recovery (``repro.service.snapshots``).

Staleness contract (inherited from ``dynamics.incremental``): between
deletion-triggered rebuilds the distance matrix is an elementwise LOWER
bound on the live truth, so every distance the API serves is either exact
(``pending_deletions == 0``) or a provable lower bound — never an
overestimate.  ``/v1/stats`` exposes which.

Locking: one ``RLock`` over engine mutations and reads.  Every query is
O(C) – O(C^2) numpy work; the only expensive operations are the explicit
``exact=True`` diameter refresh and the re-optimizer's candidate scoring,
which runs outside the lock by design.
"""
from __future__ import annotations

import dataclasses
import time as _time
import weakref
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import serde
from repro.core.diameter import INF, is_edge
from repro.dynamics.engine import POLICIES, ChurnEngine
from repro.dynamics.scenarios import Event, Trace
from repro.obs import REGISTRY, TimedRLock, get_logger, kv, span
from repro.overlay import Overlay

from . import snapshots as snaps

__all__ = ["ServiceState", "ReoptJob"]

_log = get_logger(__name__)

# -- instruments (process-global; registration is idempotent) ---------------
_EVENTS = REGISTRY.counter(
    "repro_service_events_ingested_total",
    "events accepted through ServiceState.ingest (POST /v1/events)")
_INGEST_BATCHES = REGISTRY.counter(
    "repro_service_ingest_batches_total", "ingest calls (event batches)")
_QUERIES = REGISTRY.counter(
    "repro_service_queries_total",
    "state queries served, by endpoint kind and staleness bound",
    labels=("kind", "bound"))
_SNAPSHOTS = REGISTRY.counter(
    "repro_service_snapshots_total", "committed snapshots, by reason",
    labels=("reason",))
_REOPT_EDGES = REGISTRY.counter(
    "repro_service_reopt_edges_applied_total",
    "re-optimization edges landed as incremental relaxations")

_STALE_GAUGE = REGISTRY.gauge(
    "repro_service_stale_entries",
    "pending tombstoned deletions (distance matrix is a lower bound when > 0)")
_VERSION_GAUGE = REGISTRY.gauge(
    "repro_service_overlay_version", "served overlay swap generation")
_NLIVE_GAUGE = REGISTRY.gauge(
    "repro_service_n_live", "live nodes in the served fleet")
_PENDING_CONF_GAUGE = REGISTRY.gauge(
    "repro_service_pending_confirmations",
    "failures detected but not yet SWIM-confirmed")
_SNAP_AGE_GAUGE = REGISTRY.gauge(
    "repro_service_snapshot_age_seconds",
    "monotonic seconds since the last committed snapshot (-1 before any)")
_UPTIME_GAUGE = REGISTRY.gauge(
    "repro_service_uptime_seconds", "monotonic seconds since state boot")


def _bind_state_gauges(state: "ServiceState") -> None:
    """Point the scrape-time gauges at ``state`` (the newest instance wins —
    one daemon per process in production).  Callbacks hold only a weakref
    and read plain ints/floats WITHOUT the state lock: a scrape never
    blocks on (or deadlocks with) an in-flight ingest."""
    ref = weakref.ref(state)

    def fld(fn, default=0.0):
        def read():
            s = ref()
            return float(fn(s)) if s is not None else default
        return read

    _STALE_GAUGE.set_function(fld(lambda s: s.engine.inc.pending_deletions))
    _VERSION_GAUGE.set_function(fld(lambda s: s.version))
    _NLIVE_GAUGE.set_function(fld(lambda s: s.engine.inc.n_live))
    _PENDING_CONF_GAUGE.set_function(
        fld(lambda s: s.engine.pending_confirmations))
    _SNAP_AGE_GAUGE.set_function(fld(
        lambda s: (_time.monotonic() - s.last_snapshot_monotonic
                   if s.last_snapshot_monotonic is not None else -1.0),
        default=-1.0))
    _UPTIME_GAUGE.set_function(fld(lambda s: s.uptime_s))
    if state.is_hier:
        # scrape-time hier gauges (pre-registered in repro.obs; the engine
        # .set()s them too, but the callback always reads the live value)
        from repro.obs import HIER_CLUSTERS, HIER_HEADRING_DIAMETER
        HIER_CLUSTERS.set_function(fld(lambda s: s.engine.n_clusters))
        HIER_HEADRING_DIAMETER.set_function(
            fld(lambda s: s.engine.head_inc.diameter()
                if s.engine.n_clusters > 1 else 0.0))


@dataclasses.dataclass(frozen=True)
class ReoptJob:
    """A frozen copy of the live fleet for the background optimizer: the
    second overlay buffer.  ``version`` records the swap generation the copy
    was taken at (informational — commit reconciles against the CURRENT
    alive set, so a stale job is still safe to land)."""
    live: np.ndarray          # global slot ids, ascending
    overlay: Overlay          # live-subfleet overlay (local indexing)
    version: int


class ServiceState:
    """Lock-guarded live overlay + distance state behind the /v1 API."""

    def __init__(self, engine: ChurnEngine, *, policy_name: str,
                 snapshot_dir: Optional[str] = None, keep_snapshots: int = 3,
                 version: int = 0, events_ingested: int = 0,
                 snapshot_seq: int = 0):
        self.lock = TimedRLock(
            registry=REGISTRY, name="repro_service_lock_wait_seconds",
            help="wait to acquire the ServiceState lock (handler threads "
                 "vs re-optimizer contention)")
        self.engine = engine
        self.is_hier = hasattr(engine, "head_inc")
        self.policy_name = policy_name
        self.snapshot_dir = snapshot_dir
        self.keep_snapshots = keep_snapshots
        self.version = version                  # bumped on every reopt swap
        self.events_ingested = events_ingested  # externally submitted events
        self.queries_served = 0
        self.reopts_completed = 0
        self.reopts_kept = 0                    # adapt said "keep"
        self.snapshot_seq = snapshot_seq
        self.events_since_snapshot = 0
        self.events_since_reopt = 0
        # wall clock is metadata only (snapshots, logs); every duration —
        # uptime, snapshot age — comes from the monotonic clock, so a step
        # of the system clock never corrupts them
        self.started_at = _time.time()
        self._started_monotonic = _time.monotonic()
        self.last_snapshot_monotonic: Optional[float] = None
        self._overlay: Optional[Overlay] = None
        self._overlay_live: Optional[np.ndarray] = None
        _bind_state_gauges(self)
        _log.info(kv("state.boot", policy=policy_name, version=version,
                     n_live=engine.inc.n_live, capacity=engine.inc.capacity))

    @property
    def uptime_s(self) -> float:
        return _time.monotonic() - self._started_monotonic

    # -- constructors -----------------------------------------------------

    @classmethod
    def fresh(cls, world: Trace, *, policy: str = "dgro",
              k_rings: Optional[int] = None, detect_failures: bool = True,
              rebuild_threshold: int = 8, seed: int = 0,
              snapshot_dir: Optional[str] = None,
              keep_snapshots: int = 3) -> "ServiceState":
        """Boot from a world spec (a :class:`Trace`; its events, if any, are
        ignored — the service ingests events over the API).

        The policy's *inline* self-repair cadence is disabled for DGRO: in
        the service the re-optimizer owns adaptation, asynchronously, so an
        ingest never blocks on ring selection.

        ``policy="dgro-hier"`` boots a :class:`repro.hier.HierChurnEngine`
        instead: cluster-partitioned state, cluster-local maintenance, the
        re-optimizer then owns the HEAD RING.  ``k_rings`` and
        ``detect_failures`` do not apply there (hier failures confirm
        immediately).
        """
        if policy == "dgro-hier":
            from repro.hier import HierChurnEngine
            engine = HierChurnEngine(
                Trace(n0=world.n0, capacity=world.capacity, dist=world.dist,
                      seed=world.seed, events=[], name=world.name),
                rebuild_threshold=rebuild_threshold, seed=seed)
            return cls(engine, policy_name=policy, snapshot_dir=snapshot_dir,
                       keep_snapshots=keep_snapshots)
        if policy not in POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; options "
                f"{sorted(POLICIES) + ['dgro-hier']}")
        kw: Dict = {}
        if policy in ("dgro", "rapid"):
            kw["k_rings"] = k_rings
        if policy == "dgro":
            kw["adapt_every"] = 2**31          # async reopt replaces inline
        pol = POLICIES[policy](**kw)
        engine = ChurnEngine(
            Trace(n0=world.n0, capacity=world.capacity, dist=world.dist,
                  seed=world.seed, events=[], name=world.name),
            pol, detect_failures=detect_failures,
            rebuild_threshold=rebuild_threshold, seed=seed)
        return cls(engine, policy_name=policy, snapshot_dir=snapshot_dir,
                   keep_snapshots=keep_snapshots)

    @classmethod
    def restore(cls, snapshot_dir: str, *,
                keep_snapshots: int = 3) -> "ServiceState":
        """Recover from the newest committed snapshot (crash restart).

        The distance matrix is recomputed exactly from the snapshot
        adjacency, so the restored service starts torn-state-free: it serves
        precisely the overlay the snapshot committed, and nothing newer.
        """
        found = snaps.latest_snapshot(snapshot_dir)
        if found is None:
            raise FileNotFoundError(
                f"no committed service snapshot under {snapshot_dir}")
        seq, p = found
        wd = p["world"]
        world = Trace(n0=wd["n0"], capacity=wd["capacity"], dist=wd["dist"],
                      seed=wd["seed"], events=[], name=wd.get("name", "world"))
        if p.get("kind") == "service_snapshot_hier":
            return cls._restore_hier(world, seq, p, snapshot_dir,
                                     keep_snapshots)
        c = world.capacity
        pol = POLICIES[p["policy"]]()
        pol.rings = [list(map(int, ring)) for ring in p["policy_rings"]]
        if p["policy"] == "dgro":
            pol.adapt_every = 2**31
        w = np.asarray(p["w"], np.float32)
        adj = np.full((c, c), float(INF), np.float32)
        np.fill_diagonal(adj, 0.0)
        for u, v, wt in p["edges"]:
            adj[int(u), int(v)] = adj[int(v), int(u)] = np.float32(wt)
        alive = np.zeros(c, bool)
        alive[np.asarray(p["alive"], np.intp)] = True
        engine = ChurnEngine.restore(
            world, pol, w=w, adj=adj, alive=alive,
            latency_factor=np.asarray(p["latency_factor"], np.float32),
            drift_scale=np.asarray(p["drift_scale"], np.float32),
            clock=p["time"], events_processed=p["events_processed"],
            detect_failures=p["detect_failures"],
            rebuild_threshold=p["rebuild_threshold"], seed=p["seed"])
        state = cls(engine, policy_name=p["policy"],
                    snapshot_dir=snapshot_dir, keep_snapshots=keep_snapshots,
                    version=p["version"], events_ingested=p["events_ingested"],
                    snapshot_seq=seq)
        return state

    @classmethod
    def _restore_hier(cls, world: Trace, seq: int, p: Dict,
                      snapshot_dir: str, keep_snapshots: int
                      ) -> "ServiceState":
        """Recover a hierarchical deployment from a schema-2 snapshot."""
        from repro.hier import HierChurnEngine, HierConfig, latency_from_spec
        c = world.capacity
        alive = np.zeros(c, bool)
        alive[np.asarray(p["alive"], np.intp)] = True
        lat = (latency_from_spec(p["latency"])
               if p.get("latency") is not None else None)
        engine = HierChurnEngine.restore(
            world, HierConfig(cluster_size=int(p.get("cluster_size", 0))),
            slot_cluster=np.asarray(p["slot_cluster"], np.int64),
            alive=alive,
            edges=np.asarray(p["edges"], np.intp).reshape(-1, 2),
            heads={int(k): int(v) for k, v in p["heads"].items()},
            latency_factor=np.asarray(p["latency_factor"], np.float32),
            drift_scale=np.asarray(p["drift_scale"], np.float32),
            lat=lat, clock=p["time"],
            events_processed=p["events_processed"],
            rebuild_threshold=p["rebuild_threshold"], seed=p["seed"])
        return cls(engine, policy_name=p["policy"],
                   snapshot_dir=snapshot_dir, keep_snapshots=keep_snapshots,
                   version=p["version"],
                   events_ingested=p["events_ingested"], snapshot_seq=seq)

    @classmethod
    def open(cls, world: Trace, snapshot_dir: Optional[str] = None,
             **fresh_kw) -> "ServiceState":
        """Restore if a committed snapshot exists, else boot fresh."""
        if snapshot_dir and snaps.latest_snapshot(snapshot_dir) is not None:
            return cls.restore(snapshot_dir,
                               keep_snapshots=fresh_kw.get("keep_snapshots", 3))
        return cls.fresh(world, snapshot_dir=snapshot_dir, **fresh_kw)

    # -- ingest -----------------------------------------------------------

    def ingest(self, events: Sequence[Event]) -> Dict:
        """Apply externally-arriving events in order.  Events applied before
        a failure stay applied (the caller sees the index that failed)."""
        applied = 0
        with self.lock:
            for i, e in enumerate(events):
                try:
                    applied += self.engine.process(e)
                except ValueError as err:
                    raise ValueError(
                        f"event {i} ({e.kind} t={e.time}) rejected after "
                        f"{applied} applied: {err}") from err
            self.events_ingested += len(events)
            self.events_since_snapshot += len(events)
            self.events_since_reopt += len(events)
            self._overlay = None
            _EVENTS.inc(len(events))
            _INGEST_BATCHES.inc()
            return {"accepted": len(events), "applied": applied,
                    "clock": self.engine.clock, "n_live": self.engine.inc.n_live,
                    "pending_confirmations": self.engine.pending_confirmations,
                    "version": self.version}

    # -- queries ----------------------------------------------------------

    def _count_query(self, kind: str = "stats") -> None:
        """Count one served query, labelled by endpoint kind and by whether
        the answer came from an exact matrix or a staleness lower bound —
        the scraped exact-vs-lower ratio is the staleness health signal."""
        self.queries_served += 1
        bound = ("exact" if self.engine.inc.pending_deletions == 0
                 else "lower")
        _QUERIES.labels(kind=kind, bound=bound).inc()

    def stats(self) -> Dict:
        with self.lock:
            self._count_query("stats")
            inc = self.engine.inc
            extra = ({"clusters": self.engine.n_clusters,
                      "reorg": dict(self.engine.reorg_stats)}
                     if self.is_hier else {})
            return {
                **extra,
                "policy": self.policy_name,
                "version": self.version,
                "clock": self.engine.clock,
                "n_live": inc.n_live,
                "capacity": inc.capacity,
                "events_ingested": self.events_ingested,
                "events_processed": self.engine.events_processed,
                "pending_confirmations": self.engine.pending_confirmations,
                "pending_deletions": inc.pending_deletions,
                "distances_are": ("exact" if inc.pending_deletions == 0
                                  else "lower-bound"),
                "maintenance": dict(inc.stats),
                "reopts_completed": self.reopts_completed,
                "reopts_kept": self.reopts_kept,
                "queries_served": self.queries_served,
                "snapshot_seq": self.snapshot_seq,
                "started_at_unixtime": self.started_at,
                "uptime_s": self.uptime_s,
            }

    def diameter(self, exact: bool = False) -> Dict:
        with self.lock:
            self._count_query("diameter")
            inc = self.engine.inc
            d = inc.diameter(exact=exact)
            return {"diameter": d,
                    "exact": bool(exact or inc.pending_deletions == 0),
                    "pending_deletions": inc.pending_deletions,
                    "n_live": inc.n_live, "version": self.version}

    def route(self, src: int, dst: int) -> Dict:
        """Distance + greedy next-hop path from the maintained matrix.

        The distance is exact when no deletions are pending, otherwise a
        provable lower bound.  The path comes from the SHARED greedy
        router (``repro.routing.route_single_host`` — the same float32
        next-hop rule as the device batch router and the fig19
        benchmark): latency-greedy descent over ``adj[u, v] + D[v, dst]``.
        Under a stale matrix the descent can dead-end or exhaust its hop
        budget, in which case ``path`` is ``None`` and only the distance
        bound is served.

        Response keys beyond the original contract (additive only):
        ``hops`` (path edge count, ``None`` when undelivered), ``stretch``
        (delivered latency / served distance — >= 1 against a lower
        bound), and ``hop_bounds`` (per-hop ``"exact"``/``"lower"`` stamp
        of the distance estimate that guided the descent).
        """
        from repro.routing import record_route, route_single_host
        with self.lock:
            self._count_query("route")
            inc = self.engine.inc
            if self.is_hier:
                return self._route_hier(src, dst)
            for name, u in (("src", src), ("dst", dst)):
                if not 0 <= u < inc.capacity:
                    raise ValueError(f"{name}={u} outside capacity "
                                     f"[0, {inc.capacity})")
                if not inc.alive[u]:
                    raise ValueError(f"{name}={u} is not a live node")
            D = inc.distances
            d = float(D[src, dst])
            reachable = d < float(INF) / 2
            stale = inc.pending_deletions > 0
            bound = "lower" if stale else "exact"
            path: Optional[List[int]] = None
            hops: Optional[int] = None
            stretch: Optional[float] = None
            if reachable:
                walk, lat, n_hops, outcome = route_single_host(
                    np.asarray(inc.adj, np.float32),
                    np.asarray(D[:, dst], np.float32), src, dst,
                    policy="latency", hop_budget=int(inc.n_live))
                if outcome == "delivered":
                    path, hops = walk, n_hops
                    stretch = float(lat) / d if d > 0 else 1.0
            else:
                outcome = "unreachable"
            record_route("latency", outcome, hops)
            return {"src": src, "dst": dst,
                    "distance": d if reachable else None,
                    "reachable": reachable, "stale": stale,
                    "bound": bound, "path": path,
                    "hops": hops, "stretch": stretch,
                    "hop_bounds": [bound] * hops if hops else None,
                    "version": self.version}

    def _route_hier(self, src: int, dst: int) -> Dict:
        """Hier branch of :meth:`route` (caller holds the lock): the
        distance bound composes cluster legs through the head ring; the
        path is the engine's three-leg greedy walk.  Same response keys,
        plus ``hops_by_level``."""
        from repro.routing import record_route
        eng = self.engine
        for name, u in (("src", src), ("dst", dst)):
            if not 0 <= u < eng.capacity:
                raise ValueError(f"{name}={u} outside capacity "
                                 f"[0, {eng.capacity})")
            s = eng.states[eng.cluster_of(u)]
            if not s.inc.alive[int(np.searchsorted(s.slots, u))]:
                raise ValueError(f"{name}={u} is not a live node")
        d, bound = eng.distance_bound(src, dst)
        reachable = d < float(INF) / 2
        stale = bound == "lower"
        path: Optional[List[int]] = None
        hops: Optional[int] = None
        stretch: Optional[float] = None
        hops_by_level: Optional[Dict[str, int]] = None
        if reachable:
            walk, lat, levels, outcome = eng.route(src, dst)
            if outcome == "delivered":
                path, hops_by_level = walk, levels
                hops = levels["local"] + levels["head"]
                stretch = float(lat) / d if d > 0 else 1.0
        else:
            outcome = "unreachable"
        record_route("latency", outcome, hops)
        return {"src": src, "dst": dst,
                "distance": float(d) if reachable else None,
                "reachable": reachable, "stale": stale,
                "bound": bound, "path": path,
                "hops": hops, "stretch": stretch,
                "hops_by_level": hops_by_level,
                "hop_bounds": [bound] * hops if hops else None,
                "version": self.version}

    def adjacency(self) -> Dict:
        with self.lock:
            self._count_query("adjacency")
            if self.is_hier:
                e, wts = self.engine.weighted_edges()
                live = self.engine.live_ids()
                return {"nodes": [int(u) for u in live],
                        "edges": [[int(u), int(v), float(wt)]
                                  for (u, v), wt in zip(e, wts)],
                        "n_live": int(live.size), "version": self.version}
            inc = self.engine.inc
            live = inc.live_ids()
            sub = inc.adj[np.ix_(live, live)]
            ii, jj = np.nonzero(np.triu(np.asarray(is_edge(sub)), 1))
            edges = [[int(live[i]), int(live[j]), float(sub[i, j])]
                     for i, j in zip(ii, jj)]
            return {"nodes": [int(u) for u in live], "edges": edges,
                    "n_live": int(len(live)), "version": self.version}

    # -- the served Overlay (double buffer A) -----------------------------

    def _head_ring_copy(self) -> "tuple[Overlay, np.ndarray, np.ndarray]":
        """(head-ring Overlay, active cluster ids, their heads' global
        ids) from the maintained head graph — the hierarchical stand-ins
        for the flat path's dense live copies.  Caller holds the lock."""
        eng = self.engine
        act = np.array(sorted(c for c, s in eng.states.items()
                              if s.head >= 0), np.intp)
        heads = np.array([eng.states[int(c)].head for c in act], np.intp)
        wl = eng.head_inc.w[np.ix_(act, act)].copy()
        adjl = eng.head_inc.adj[np.ix_(act, act)].copy()
        ov = Overlay.from_adjacency(wl, adjl, policy="dgro-hier-head",
                                    fold_weights=True)
        return ov, act, heads

    def overlay(self) -> "tuple[Overlay, np.ndarray]":
        """(served Overlay over the live sub-fleet, global slot ids).

        Rebuilt lazily after mutations; the rebuilt object is immutable, so
        handing it out of the lock is safe.  Hierarchical deployments
        serve the HEAD RING here (ids = the heads' global node ids) — the
        dense whole-fleet overlay is exactly what the hierarchy exists to
        avoid; per-cluster topologies are reachable via ``/v1/adjacency``.
        """
        with self.lock:
            if self._overlay is None:
                if self.is_hier:
                    ov, _act, heads = self._head_ring_copy()
                    self._overlay = ov
                    self._overlay_live = heads
                else:
                    live = self.engine.inc.live_ids().copy()
                    wl = self.engine.w[np.ix_(live, live)]
                    adjl = self.engine.inc.adj[np.ix_(live, live)]
                    self._overlay = Overlay.from_adjacency(
                        wl, adjl, policy=self.policy_name, fold_weights=True)
                    self._overlay_live = live
            return self._overlay, self._overlay_live

    # -- re-optimization (double buffer B) --------------------------------

    def capture(self) -> ReoptJob:
        """Freeze a copy of the live fleet for the background optimizer.

        Hierarchical deployments freeze the HEAD RING instead (``live``
        holds cluster ids): the optimizer then improves inter-cluster
        latency — cluster-interior maintenance is already local and
        cheap — and the unchanged ``adapt``/``dqn`` machinery runs on it
        as on any flat overlay.
        """
        with self.lock:
            if self.is_hier:
                ov, act, _heads = self._head_ring_copy()
                return ReoptJob(live=act, overlay=ov, version=self.version)
            live = self.engine.inc.live_ids().copy()
            wl = self.engine.w[np.ix_(live, live)].copy()
            adjl = self.engine.inc.adj[np.ix_(live, live)].copy()
            version = self.version
        # Overlay construction is O(C^2) validation — outside the lock
        ov = Overlay.from_adjacency(wl, adjl, policy=self.policy_name,
                                    fold_weights=True)
        return ReoptJob(live=live, overlay=ov, version=version)

    def commit_reopt(self, job: ReoptJob, new_overlay: Overlay) -> Dict:
        """Atomically swap the optimized overlay in.

        The candidate was computed on ``job``'s frozen copy; membership may
        have moved on since, so the merge applies the candidate's NEW edges
        only between still-live nodes, as exact incremental relaxations
        (distances only improve — the staleness lower bound is preserved).
        One lock acquisition covers relax + version bump + served-overlay
        swap, so a query sees either the old topology or the new one,
        never a half-merged state.
        """
        new_edges = np.argwhere(np.triu(
            np.asarray(is_edge(new_overlay.adjacency))
            & ~np.asarray(is_edge(job.overlay.adjacency)), 1))
        with self.lock:
            applied = 0
            if self.is_hier:
                # job.live holds CLUSTER ids; land head-ring edges between
                # clusters that are still active
                eng = self.engine
                for i, j in new_edges:
                    a, b = int(job.live[i]), int(job.live[j])
                    if (eng.states.get(a) is not None
                            and eng.states.get(b) is not None
                            and eng.states[a].head >= 0
                            and eng.states[b].head >= 0):
                        eng.head_inc.add_edge(
                            a, b, float(new_overlay.adjacency[i, j]))
                        applied += 1
            else:
                alive = self.engine.alive
                for i, j in new_edges:
                    u, v = int(job.live[i]), int(job.live[j])
                    if alive[u] and alive[v]:
                        self.engine.inc.add_edge(
                            u, v, float(new_overlay.adjacency[i, j]))
                        applied += 1
            self.version += 1
            self.reopts_completed += 1
            self.events_since_reopt = 0
            self._overlay = None             # next overlay() serves buffer B
            _REOPT_EDGES.inc(applied)
            _log.info(kv("reopt.commit", version=self.version,
                         edges_added=applied,
                         edges_proposed=int(len(new_edges))))
            return {"version": self.version, "edges_added": applied,
                    "edges_proposed": int(len(new_edges))}

    # -- snapshots --------------------------------------------------------

    def snapshot_payload(self) -> Dict:
        """Full capacity-level state as a serde-versioned dict.  Refreshes
        pending deletions first so the recorded diameter is exact — the
        restart-consistency invariant the fig17 gate checks."""
        with self.lock:
            if self.is_hier:
                return self._snapshot_payload_hier()
            eng = self.engine
            inc = eng.inc
            inc.refresh()
            live = inc.live_ids()
            sub_is_edge = np.triu(np.asarray(is_edge(inc.adj)), 1)
            ii, jj = np.nonzero(sub_is_edge)
            return {
                "kind": "service_snapshot",
                "time": eng.clock,
                "events_processed": eng.events_processed,
                "events_ingested": self.events_ingested,
                "version": self.version,
                "policy": self.policy_name,
                "policy_rings": [[int(u) for u in ring]
                                 for ring in getattr(eng.policy, "rings", [])],
                "world": {"n0": eng.trace.n0, "capacity": eng.trace.capacity,
                          "dist": eng.trace.dist, "seed": eng.trace.seed,
                          "name": eng.trace.name},
                "w": [[float(x) for x in row] for row in inc.w],
                "latency_factor": [float(x) for x in eng.latency_factor],
                "drift_scale": [float(x) for x in eng.drift_scale],
                "alive": [int(u) for u in live],
                "edges": [[int(u), int(v), float(inc.adj[u, v])]
                          for u, v in zip(ii, jj)],
                "diameter": inc.diameter(),
                "detect_failures": eng.detect_failures,
                "rebuild_threshold": inc.rebuild_threshold,
                "seed": 0,
                # wall clock is snapshot METADATA only — restore logic and
                # all durations use event clocks / the monotonic clock
                "wall_time": _time.time(),
            }

    def _snapshot_payload_hier(self) -> Dict:
        """Hier snapshot (serde schema 2; caller holds the lock): the
        slot->cluster map, heads, live ids, and the GLOBAL edge list
        (intra-cluster + head ring).  Edge weights rehydrate on restore
        from the latency model and the drift/straggler factors — the
        restored topology is edge-for-edge the committed one."""
        from repro.hier import DenseLatency
        eng = self.engine
        eng.refresh()
        return {
            "kind": "service_snapshot_hier",
            "time": eng.clock,
            "events_processed": eng.events_processed,
            "events_ingested": self.events_ingested,
            "version": self.version,
            "policy": self.policy_name,
            "world": {"n0": eng.trace.n0, "capacity": eng.trace.capacity,
                      "dist": eng.trace.dist, "seed": eng.trace.seed,
                      "name": eng.trace.name},
            # None = dense latency from the world trace (recomputed on
            # restore); lazy models serialize their (tiny) spec instead
            "latency": (None if isinstance(eng.lat, DenseLatency)
                        else eng.lat.to_spec()),
            "cluster_size": eng.cfg.cluster_size,
            "slot_cluster": [int(c) for c in eng._slot_cluster],
            "heads": {str(c): int(s.head) for c, s in eng.states.items()},
            "alive": [int(u) for u in eng.live_ids()],
            "edges": [[int(u), int(v)] for u, v in eng.edge_list()],
            "latency_factor": [float(x) for x in eng.latency_factor],
            "drift_scale": [float(x) for x in eng.drift_scale],
            "diameter": eng.diameter(),
            "rebuild_threshold": eng.rebuild_threshold,
            "seed": 0,
            "wall_time": _time.time(),
        }

    def write_snapshot(self, reason: str = "periodic") -> Optional[str]:
        """Atomic-commit a snapshot (no-op without a snapshot dir)."""
        if not self.snapshot_dir:
            return None
        with span("snapshot.write"):
            payload = self.snapshot_payload()
            payload["reason"] = reason
            with self.lock:
                self.snapshot_seq += 1
                seq = self.snapshot_seq
                self.events_since_snapshot = 0
            path = snaps.write_snapshot(
                self.snapshot_dir, seq, payload, keep=self.keep_snapshots,
                schema=serde.HIER_SCHEMA if self.is_hier else None)
        self.last_snapshot_monotonic = _time.monotonic()
        _SNAPSHOTS.labels(reason=reason).inc()
        _log.info(kv("snapshot.committed", seq=seq, reason=reason,
                     path=path))
        return path
