"""repro.service — overlay-as-a-service: the live membership control plane.

The batch pipeline (``dynamics.engine`` replaying a finished trace) turned
into a daemon: a long-running process that owns a live
:class:`~repro.overlay.Overlay`, ingests Trace-format churn/latency events
over a versioned HTTP API, answers topology queries from the
incrementally-maintained distance matrix (bounded staleness: served
distances are exact or provable lower bounds), re-optimizes asynchronously
with an atomic double-buffered swap, and crash-recovers from atomic-commit
JSON snapshots.

Modules:
  state       — ``ServiceState``: the lock-guarded engine + served Overlay
  server      — ``ServiceServer`` + ``python -m repro.service.server`` daemon
  reoptimizer — background adapt/DQN worker (capture → optimize → swap →
                snapshot)
  snapshots   — atomic-commit snapshot files (COMMITTED-marker protocol)
  client      — stdlib HTTP client (``ServiceClient``)
"""
from .client import ServiceClient, ServiceError  # noqa: F401
from .reoptimizer import Reoptimizer  # noqa: F401
from .server import ServiceServer  # noqa: F401
from .snapshots import latest_snapshot, list_snapshots, write_snapshot  # noqa: F401
from .state import ReoptJob, ServiceState  # noqa: F401

__all__ = [
    "ServiceClient", "ServiceError", "Reoptimizer", "ServiceServer",
    "ServiceState", "ReoptJob", "write_snapshot", "latest_snapshot",
    "list_snapshots",
]
