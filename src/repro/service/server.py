"""The /v1 HTTP control-plane daemon.

Endpoints (all JSON, all ``repro.serde`` schema-stamped):

=========================  ==================================================
``GET  /v1/health``        liveness + schema/version handshake
``GET  /v1/stats``         counters, staleness state, maintenance stats
``GET  /v1/metrics``       Prometheus text exposition (``?format=json`` for
                           the serde-stamped JSON export) — NOT wrapped in
                           the JSON envelope
``GET  /v1/diameter``      largest-CC diameter (``?exact=1`` forces refresh)
``GET  /v1/route``         ``?src=&dst=``: distance bound + greedy path
``GET  /v1/adjacency``     live nodes + weighted edge list
``GET  /v1/overlay``       the served Overlay's JSON + global id mapping
``POST /v1/events``        Trace-format events: ``{"events": [...]}``
``POST /v1/reoptimize``    trigger an async re-optimization cycle
``POST /v1/snapshot``      force an atomic-commit snapshot
``POST /v1/shutdown``      graceful stop (final snapshot, then exit)
=========================  ==================================================

Every request lands in the ``repro_http_requests_total{method,endpoint,
status}`` counter and the ``repro_http_request_seconds{endpoint}``
histogram, and is logged (DEBUG) through the structured ``repro.obs``
logger — ``BaseHTTPRequestHandler``'s raw-stderr ``log_message`` is routed
there too, so ``REPRO_LOG_LEVEL`` controls all of it.

Any other ``/vN/`` prefix answers 404 with the supported versions — clients
from the future fail loudly at the handshake, mirroring what
``repro.serde`` does for payloads.

Run the daemon (prints ``SERVING host=... port=...`` when ready)::

    PYTHONPATH=src python -m repro.service.server --n0 64 --dist bitnode \
        --policy dgro --port 0 --snapshot-dir /tmp/dgro-snaps

The server is a stdlib ``ThreadingHTTPServer``: handler threads share the
one ``ServiceState`` lock, the re-optimizer runs beside them, and queries
keep being answered from the bounded-staleness distance matrix while a
re-optimization or snapshot is in flight.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional
from urllib.parse import parse_qs, urlparse

from repro import serde
from repro.dynamics.scenarios import Event, Trace
from repro.obs import REGISTRY, configure as configure_logging, get_logger, kv
from repro.obs.metrics import LATENCY_BUCKETS_S

from .reoptimizer import Reoptimizer
from .state import ServiceState

__all__ = ["ServiceServer", "main"]

API_VERSIONS = ("v1",)

_log = get_logger(__name__)

# endpoint label values are drawn from this closed set (unknown paths fold
# into "_unknown") so a scanner can't blow up the metric cardinality
_ENDPOINTS = frozenset({
    "health", "stats", "metrics", "diameter", "route", "adjacency",
    "overlay", "events", "reoptimize", "snapshot", "shutdown"})

_HTTP_REQS = REGISTRY.counter(
    "repro_http_requests_total",
    "HTTP requests served, by method / endpoint / status code",
    labels=("method", "endpoint", "status"))
_HTTP_LAT = REGISTRY.histogram(
    "repro_http_request_seconds",
    "request handling wall time, by endpoint",
    labels=("endpoint",), buckets=LATENCY_BUCKETS_S)


class _Handler(BaseHTTPRequestHandler):
    """Routes /v1/* onto the shared ServiceState / Reoptimizer."""

    server_version = "repro-service/1"
    protocol_version = "HTTP/1.1"

    # set by ServiceServer
    state: ServiceState
    reopt: Optional[Reoptimizer]
    shutdown_event: threading.Event

    # per-request instrumentation scratch
    _status: int = 0
    _endpoint: str = "_unknown"

    def log_message(self, fmt, *args):
        """http.server's raw-stderr path, routed into the structured
        logger (DEBUG — per-request records; errors go via log_error)."""
        _log.debug(kv("http.server", client=self.address_string(),
                      msg=fmt % args))

    def log_error(self, fmt, *args):
        _log.warning(kv("http.server_error", client=self.address_string(),
                        msg=fmt % args))

    # -- plumbing ---------------------------------------------------------

    def _reply(self, code: int, payload: Dict) -> None:
        self._reply_bytes(code, serde.dumps(payload).encode(),
                          "application/json")

    def _reply_text(self, code: int, text: str,
                    content_type: str = "text/plain; version=0.0.4; "
                                        "charset=utf-8") -> None:
        self._reply_bytes(code, text.encode(), content_type)

    def _reply_bytes(self, code: int, body: bytes,
                     content_type: str) -> None:
        self._status = code
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _error(self, code: int, message: str) -> None:
        self._reply(code, {"error": message})

    def _route_version(self) -> Optional[str]:
        """Returns the path below /v1, or None after answering an error."""
        path = urlparse(self.path).path.rstrip("/")
        parts = [p for p in path.split("/") if p]
        if len(parts) > 1 and parts[1] in _ENDPOINTS:
            self._endpoint = parts[1]
        if not parts or not parts[0].startswith("v"):
            self._error(404, f"endpoints live under /{API_VERSIONS[0]}/")
            return None
        if parts[0] not in API_VERSIONS:
            self._error(404, f"unsupported API version {parts[0]!r}; "
                             f"supported: {list(API_VERSIONS)}")
            return None
        return "/".join(parts[1:])

    def _read_body(self) -> Optional[Dict]:
        try:
            n = int(self.headers.get("Content-Length", 0))
            raw = self.rfile.read(n).decode() if n else "{}"
            return serde.loads(raw, what="request body")
        except (ValueError, json.JSONDecodeError) as e:
            self._error(400, f"bad request body: {e}")
            return None

    # -- instrumentation wrapper ------------------------------------------

    def _instrumented(self, method: str, handler) -> None:
        """Per-endpoint latency histogram + status-code counter around the
        actual dispatch; the endpoint label is resolved by _route_version
        and unknown paths fold into ``_unknown``."""
        self._status = 0
        self._endpoint = "_unknown"
        t0 = time.perf_counter()
        try:
            handler()
        finally:
            dt = time.perf_counter() - t0
            status = str(self._status or 500)
            _HTTP_LAT.labels(endpoint=self._endpoint).observe(dt)
            _HTTP_REQS.labels(method=method, endpoint=self._endpoint,
                              status=status).inc()
            _log.debug(kv("http.request", method=method, path=self.path,
                          status=status, ms=dt * 1e3))

    # -- GET --------------------------------------------------------------

    def do_GET(self) -> None:  # noqa: N802 - BaseHTTPRequestHandler API
        self._instrumented("GET", self._do_get)

    def _do_get(self) -> None:
        sub = self._route_version()
        if sub is None:
            return
        q = parse_qs(urlparse(self.path).query)
        try:
            if sub == "health":
                self._reply(200, {"status": "ok",
                                  "api_versions": list(API_VERSIONS),
                                  "version": self.state.version})
            elif sub == "stats":
                self._reply(200, self.state.stats())
            elif sub == "metrics":
                if q.get("format", [""])[0] == "json":
                    # render_json is already serde-stamped — send verbatim
                    self._reply_text(200, REGISTRY.render_json(),
                                     content_type="application/json")
                else:
                    self._reply_text(200, REGISTRY.render_prometheus())
            elif sub == "diameter":
                exact = q.get("exact", ["0"])[0] in ("1", "true")
                self._reply(200, self.state.diameter(exact=exact))
            elif sub == "route":
                try:
                    src = int(q["src"][0])
                    dst = int(q["dst"][0])
                except (KeyError, ValueError):
                    return self._error(400, "route needs integer ?src=&dst=")
                self._reply(200, self.state.route(src, dst))
            elif sub == "adjacency":
                self._reply(200, self.state.adjacency())
            elif sub == "overlay":
                ov, live = self.state.overlay()
                self._reply(200, {"overlay": json.loads(ov.to_json()),
                                  "live": [int(u) for u in live],
                                  "version": self.state.version})
            else:
                self._error(404, f"unknown endpoint /v1/{sub}")
        except ValueError as e:
            self._error(400, str(e))

    # -- POST -------------------------------------------------------------

    def do_POST(self) -> None:  # noqa: N802
        self._instrumented("POST", self._do_post)

    def _do_post(self) -> None:
        sub = self._route_version()
        if sub is None:
            return
        body = self._read_body()
        if body is None:
            return
        try:
            if sub == "events":
                raw = body.get("events")
                if raw is None and "event" in body:
                    raw = [body["event"]]
                if not isinstance(raw, list):
                    return self._error(
                        400, 'POST /v1/events needs {"events": [...]} '
                             '(Trace-format event dicts)')
                try:
                    events = [Event.from_dict(e) for e in raw]
                except (TypeError, ValueError) as e:
                    return self._error(400, f"bad event: {e}")
                try:
                    res = self.state.ingest(events)
                except ValueError as e:
                    # out-of-order clock / capacity violations: conflict
                    return self._error(409, str(e))
                if self.reopt is not None:
                    self.reopt.notify()
                self._reply(200, res)
            elif sub == "reoptimize":
                if self.reopt is None:
                    return self._error(409, "re-optimizer disabled")
                self.reopt.trigger()
                self._reply(202, {"triggered": True,
                                  "in_flight": self.reopt.in_flight,
                                  "cycles": self.reopt.cycles})
            elif sub == "snapshot":
                path = self.state.write_snapshot(reason="api")
                if path is None:
                    return self._error(409, "no snapshot dir configured")
                self._reply(200, {"path": path,
                                  "seq": self.state.snapshot_seq})
            elif sub == "shutdown":
                self._reply(200, {"stopping": True})
                self.shutdown_event.set()
            else:
                self._error(404, f"unknown endpoint /v1/{sub}")
        except ValueError as e:
            self._error(400, str(e))


class ServiceServer:
    """Owns the HTTP server thread + state + re-optimizer lifecycle."""

    def __init__(self, state: ServiceState, *, host: str = "127.0.0.1",
                 port: int = 0, reopt_every: int = 32,
                 snapshot_every: int = 64, reopt_method: str = "adapt",
                 reopt_enabled: bool = True, reopt_eps: float = 0.3,
                 seed: int = 0):
        self.state = state
        self.shutdown_event = threading.Event()
        self.reopt = (Reoptimizer(state, every=reopt_every,
                                  method=reopt_method, seed=seed,
                                  snapshot_every=snapshot_every,
                                  eps=reopt_eps)
                      if reopt_enabled else None)
        handler = type("BoundHandler", (_Handler,), {
            "state": state, "reopt": self.reopt,
            "shutdown_event": self.shutdown_event})
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        self._thread: Optional[threading.Thread] = None

    @property
    def host(self) -> str:
        return self.httpd.server_address[0]

    @property
    def port(self) -> int:
        return self.httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ServiceServer":
        if self.reopt is not None:
            self.reopt.start()
        self._thread = threading.Thread(target=self.httpd.serve_forever,
                                        daemon=True, name="repro-service-http")
        self._thread.start()
        _log.info(kv("server.start", host=self.host, port=self.port,
                     reopt=self.reopt is not None))
        return self

    def stop(self, final_snapshot: bool = True) -> None:
        if self.reopt is not None:
            self.reopt.stop()
        if final_snapshot:
            self.state.write_snapshot(reason="shutdown")
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(10)
        _log.info(kv("server.stop", final_snapshot=final_snapshot))

    def serve_until_shutdown(self) -> None:
        """Block until POST /v1/shutdown (the __main__ daemon loop)."""
        self.start()
        print(f"SERVING host={self.host} port={self.port}", flush=True)
        try:
            self.shutdown_event.wait()
        except KeyboardInterrupt:
            pass
        self.stop()
        print("STOPPED", flush=True)


def main(argv=None) -> None:
    # the daemon defaults to info-level structured logs on stderr; the
    # SERVING/STOPPED stdout lines below stay — they are the boot protocol
    # the smoke tools parse
    configure_logging(default="info")
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks an ephemeral port (printed on SERVING)")
    ap.add_argument("--n0", type=int, default=64)
    ap.add_argument("--capacity", type=int, default=None,
                    help="slot capacity (default 2*n0)")
    ap.add_argument("--dist", default="bitnode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="dgro")
    ap.add_argument("--k-rings", type=int, default=None)
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--reopt-every", type=int, default=32)
    ap.add_argument("--snapshot-every", type=int, default=64)
    ap.add_argument("--reopt-method", default="adapt",
                    choices=("adapt", "dqn"))
    ap.add_argument("--reopt-eps", type=float, default=0.3,
                    help="adapt's keep-band half-width (larger = swap more)")
    ap.add_argument("--no-reopt", action="store_true")
    ap.add_argument("--no-detect-failures", action="store_true")
    args = ap.parse_args(argv)

    world = Trace(n0=args.n0, capacity=args.capacity or 2 * args.n0,
                  dist=args.dist, seed=args.seed, events=[], name="service")
    state = ServiceState.open(
        world, snapshot_dir=args.snapshot_dir, policy=args.policy,
        k_rings=args.k_rings, detect_failures=not args.no_detect_failures,
        seed=args.seed)
    server = ServiceServer(state, host=args.host, port=args.port,
                           reopt_every=args.reopt_every,
                           snapshot_every=args.snapshot_every,
                           reopt_method=args.reopt_method,
                           reopt_eps=args.reopt_eps,
                           reopt_enabled=not args.no_reopt, seed=args.seed)
    server.serve_until_shutdown()


if __name__ == "__main__":
    main()
