"""Tiny stdlib client for the /v1 control-plane API.

Used by the CI smoke job, the fig17 benchmark, and the quickstart example —
and small enough to crib for real integrations: every call is one HTTP
round-trip, every payload is ``repro.serde`` schema-checked on the way in.

    from repro.service.client import ServiceClient
    c = ServiceClient("http://127.0.0.1:8371")
    c.wait_ready()
    c.post_events(trace.events[:10])
    c.route(0, 5)["distance"]
    c.diameter()["diameter"]
"""
from __future__ import annotations

import json
import time
import urllib.error
import urllib.parse
import urllib.request
from typing import Dict, Iterable, List, Optional, Sequence

from repro import serde
from repro.dynamics.scenarios import Event, Trace

__all__ = ["ServiceClient", "ServiceError"]


class ServiceError(RuntimeError):
    """Non-2xx response; carries the HTTP status and the server's message."""

    def __init__(self, status: int, message: str):
        super().__init__(f"HTTP {status}: {message}")
        self.status = status
        self.message = message


class ServiceClient:
    def __init__(self, base_url: str, timeout: float = 30.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    # -- plumbing ---------------------------------------------------------

    def _request(self, method: str, path: str,
                 params: Optional[Dict] = None,
                 payload: Optional[Dict] = None) -> Dict:
        url = f"{self.base_url}{path}"
        if params:
            url += "?" + urllib.parse.urlencode(params)
        data = serde.dumps(payload).encode() if payload is not None else None
        req = urllib.request.Request(
            url, data=data, method=method,
            headers={"Content-Type": "application/json"} if data else {})
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return serde.loads(resp.read().decode(),
                                   what=f"{method} {path} response")
        except urllib.error.HTTPError as e:
            try:
                msg = json.loads(e.read().decode()).get("error", str(e))
            except Exception:  # noqa: BLE001 - error body is best-effort
                msg = str(e)
            raise ServiceError(e.code, msg) from None

    def _get(self, path: str, **params) -> Dict:
        return self._request("GET", path, params=params or None)

    def _post(self, path: str, payload: Optional[Dict] = None) -> Dict:
        return self._request("POST", path, payload=payload or {})

    # -- queries ----------------------------------------------------------

    def health(self) -> Dict:
        return self._get("/v1/health")

    def stats(self) -> Dict:
        return self._get("/v1/stats")

    def metrics_text(self) -> str:
        """Raw Prometheus text from ``GET /v1/metrics`` (not JSON — parse
        with :func:`repro.obs.parse_prometheus`)."""
        url = f"{self.base_url}/v1/metrics"
        req = urllib.request.Request(url, method="GET")
        try:
            with urllib.request.urlopen(req, timeout=self.timeout) as resp:
                return resp.read().decode()
        except urllib.error.HTTPError as e:
            raise ServiceError(e.code, e.read().decode()[:200]) from None

    def metrics(self) -> Dict:
        """Parsed scrape: ``{series: {labels-tuple: value}}``."""
        from repro.obs import parse_prometheus
        return parse_prometheus(self.metrics_text())

    def diameter(self, exact: bool = False) -> Dict:
        return self._get("/v1/diameter", **({"exact": 1} if exact else {}))

    def route(self, src: int, dst: int) -> Dict:
        return self._get("/v1/route", src=src, dst=dst)

    def adjacency(self) -> Dict:
        return self._get("/v1/adjacency")

    def overlay(self) -> Dict:
        return self._get("/v1/overlay")

    # -- ingest / control -------------------------------------------------

    def post_events(self, events: Sequence[Event]) -> Dict:
        return self._post("/v1/events",
                          {"events": [e.to_dict() for e in events]})

    def stream_trace(self, trace: Trace, chunk: int = 8) -> List[Dict]:
        """Stream a whole trace through /v1/events in time-ordered chunks."""
        events = sorted(trace.events, key=lambda e: e.time)
        return [self.post_events(events[i:i + chunk])
                for i in range(0, len(events), chunk)]

    def reoptimize(self) -> Dict:
        return self._post("/v1/reoptimize")

    def snapshot(self) -> Dict:
        return self._post("/v1/snapshot")

    def shutdown(self) -> Dict:
        return self._post("/v1/shutdown")

    # -- helpers ----------------------------------------------------------

    def wait_ready(self, timeout: float = 30.0, poll: float = 0.1) -> Dict:
        """Poll /v1/health until the daemon answers (boot barrier).

        Deadlines run on the monotonic clock: a wall-clock step (NTP slew,
        suspend/resume) can neither fire the timeout early nor stall it.
        """
        deadline = time.monotonic() + timeout
        last: Exception = RuntimeError("unreachable")
        while time.monotonic() < deadline:
            try:
                return self.health()
            except (ServiceError, urllib.error.URLError, OSError) as e:
                last = e
                time.sleep(poll)
        raise TimeoutError(
            f"service at {self.base_url} not ready after {timeout}s: {last}")

    def wait_version(self, at_least: int, timeout: float = 60.0,
                     poll: float = 0.05) -> Dict:
        """Block until a re-optimization swap lands (version >= at_least)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            st = self.stats()
            if st["version"] >= at_least:
                return st
            time.sleep(poll)
        raise TimeoutError(f"version never reached {at_least} in {timeout}s")
