"""``python -m repro.service`` — run the control-plane daemon."""
from .server import main

main()
