"""Atomic-commit service snapshots (crash recovery for ``repro.service``).

Same contract as ``repro.checkpoint``: everything is written into
``snap_<seq>.tmp``, the ``COMMITTED`` marker is written LAST, and the
directory is renamed into place — readers ignore directories without the
marker, so a daemon killed mid-save (or mid-reoptimize, between the overlay
swap and the snapshot commit) can never restore a torn snapshot; it comes
back on the previous committed one.

The payload is one ``state.json`` (``repro.serde`` schema-versioned): the
full capacity-level world — current latency matrix, overlay edge list,
alive mask, drift/straggler factors, the policy's ring membership — plus
the counters and the exact diameter at commit time, so a restart can verify
it serves the same topology the snapshot recorded.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

from repro import serde

__all__ = ["write_snapshot", "latest_snapshot", "list_snapshots"]

_MARKER = "COMMITTED"           # same atomic-commit marker as repro.checkpoint


def _snap_dir(directory: str, seq: int) -> str:
    return os.path.join(directory, f"snap_{seq:08d}")


def write_snapshot(directory: str, seq: int, payload: Dict[str, Any], *,
                   keep: int = 3, schema: Optional[int] = None) -> str:
    """Atomically commit ``payload`` as snapshot ``seq``; prune old ones.

    ``schema`` stamps the payload's serde schema (default flat schema 1;
    hierarchical service snapshots pass ``serde.HIER_SCHEMA``).  Returns
    the committed directory path.
    """
    os.makedirs(directory, exist_ok=True)
    final = _snap_dir(directory, seq)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    with open(os.path.join(tmp, "state.json"), "w") as f:
        f.write(serde.dumps(payload, schema=schema))
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    for s in list_snapshots(directory)[:-keep]:
        shutil.rmtree(_snap_dir(directory, s), ignore_errors=True)
    return final


def list_snapshots(directory: str) -> List[int]:
    """Committed snapshot sequence numbers, ascending."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        path = os.path.join(directory, name)
        if (name.startswith("snap_") and not name.endswith(".tmp")
                and os.path.exists(os.path.join(path, _MARKER))):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def latest_snapshot(directory: str) -> Optional[Tuple[int, Dict[str, Any]]]:
    """(seq, payload) of the newest committed snapshot, or None."""
    seqs = list_snapshots(directory)
    if not seqs:
        return None
    seq = seqs[-1]
    with open(os.path.join(_snap_dir(directory, seq), "state.json")) as f:
        raw = f.read()
    try:
        payload = serde.loads(raw, what=f"service snapshot {seq}")
    except json.JSONDecodeError as e:
        raise ValueError(
            f"committed snapshot {seq} holds unparseable JSON: {e}") from e
    return seq, payload
