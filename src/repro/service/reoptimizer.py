"""Background re-optimization worker: adapt off the hot path, swap atomically.

The worker wakes when enough events have accumulated (``every``) or when
explicitly triggered (``POST /v1/reoptimize``), then runs one cycle:

1. **capture** — freeze a copy of the live fleet (``ServiceState.capture``,
   the second overlay buffer);
2. **optimize** — run DGRO ring selection (``core.selection.adapt``) or a
   DQN ring reconstruction on the frozen copy, entirely OUTSIDE the state
   lock: ingest and queries proceed at full speed while this runs;
3. **swap** — ``ServiceState.commit_reopt`` lands the new ring's edges as
   incremental relaxations between still-live nodes and bumps the version,
   all under one short lock acquisition;
4. **snapshot** — atomic-commit the post-swap state for crash recovery.

A crash between (3) and (4) is the classic torn-state window; the
atomic-commit snapshot protocol makes it safe (restart restores the LAST
committed snapshot — the pre-swap overlay — and simply re-optimizes again).
That window is crash-injectable for tests: set
``REPRO_SERVICE_CRASH_AFTER_SWAP=1`` (hard ``os._exit``) or pass a
``crash_hook`` callable.
"""
from __future__ import annotations

import os
import threading
import traceback
from typing import Callable, Optional

import numpy as np

from repro.core import batcheval, selection
from repro.obs import REGISTRY, get_logger, kv, span

from .state import ServiceState

__all__ = ["Reoptimizer"]

_CRASH_ENV = "REPRO_SERVICE_CRASH_AFTER_SWAP"

_log = get_logger(__name__)

# cycle outcomes: swapped (new overlay landed), kept (adapt said keep),
# skipped (fleet too small), error (cycle raised; daemon survives)
_CYCLES = REGISTRY.counter(
    "repro_reopt_cycles_total", "re-optimization cycles, by outcome",
    labels=("outcome",))


class Reoptimizer:
    """Owns the background thread; one optimization cycle in flight at most."""

    def __init__(self, state: ServiceState, *, every: int = 32,
                 method: str = "adapt", seed: int = 0,
                 snapshot_every: int = 64, eps: float = 0.3,
                 eval_opts: Optional[dict] = None,
                 crash_hook: Optional[Callable[[], None]] = None):
        if method not in ("adapt", "dqn"):
            raise ValueError(f"unknown reopt method {method!r}; "
                             f"options ('adapt', 'dqn')")
        self.state = state
        self.every = every
        self.method = method
        self.eps = eps                  # adapt's "keep" band half-width
        # scoped batcheval knobs for candidate SCORING only (dtype/method/
        # chunk...); reduced precision is safe here because the commit path
        # re-lands the chosen ring as exact incremental relaxations — a
        # mis-ranked candidate costs quality, never correctness
        self.eval_opts = dict(eval_opts or {})
        self.snapshot_every = snapshot_every
        self.crash_hook = crash_hook
        self._rng = np.random.default_rng(seed)
        self._cond = threading.Condition()
        self._stop = False
        self._forced = 0
        self._thread: Optional[threading.Thread] = None
        self.in_flight = False          # an optimize+swap cycle is running
        self.cycles = 0
        self.last_error: Optional[str] = None

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "Reoptimizer":
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-reoptimizer")
        self._thread.start()
        return self

    def stop(self, timeout: float = 30.0) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)

    def notify(self) -> None:
        """Called by the server after each ingest batch."""
        with self._cond:
            self._cond.notify_all()

    def trigger(self) -> None:
        """Force a cycle regardless of the event cadence."""
        with self._cond:
            self._forced += 1
            self._cond.notify_all()

    # -- the loop ---------------------------------------------------------

    def _due(self) -> bool:
        return (self._forced > 0
                or self.state.events_since_reopt >= self.every
                or self.state.events_since_snapshot >= self.snapshot_every)

    def _loop(self) -> None:
        while True:
            with self._cond:
                while not self._stop and not self._due():
                    self._cond.wait(timeout=0.5)
                if self._stop:
                    return
                forced = self._forced > 0
                if forced:
                    self._forced -= 1
            try:
                if forced or self.state.events_since_reopt >= self.every:
                    self.step(force=forced)
                elif self.state.events_since_snapshot >= self.snapshot_every:
                    self.state.write_snapshot(reason="cadence")
            except Exception:  # noqa: BLE001 - a failed cycle must not kill the daemon
                self.last_error = traceback.format_exc()
                _CYCLES.labels(outcome="error").inc()
                _log.exception(kv("reopt.cycle_failed", method=self.method))

    # -- one cycle --------------------------------------------------------

    def step(self, force: bool = False) -> Optional[dict]:
        """One capture → optimize → swap → snapshot cycle (synchronous).

        Exposed for tests and the benchmark; the daemon thread calls it too.
        Returns the commit result, or None when nothing was swapped (too few
        live nodes, or adapt said "keep").
        """
        self.in_flight = True
        try:
            with span("reopt.capture"):
                job = self.state.capture()
            if len(job.live) < 4:
                _CYCLES.labels(outcome="skipped").inc()
                return None
            seed = int(self._rng.integers(2**31))
            with span("reopt.optimize"):
                new_ov = self._optimize(job, seed)
            if new_ov is None:
                with self.state.lock:
                    self.state.reopts_kept += 1
                    self.state.events_since_reopt = 0
                _CYCLES.labels(outcome="kept").inc()
                _log.info(kv("reopt.cycle", outcome="kept",
                             method=self.method, n_live=len(job.live)))
                return None
            with span("reopt.commit"):
                res = self.state.commit_reopt(job, new_ov)
            self.cycles += 1
            _CYCLES.labels(outcome="swapped").inc()
            _log.info(kv("reopt.cycle", outcome="swapped",
                         method=self.method, n_live=len(job.live),
                         version=res["version"],
                         edges_added=res["edges_added"]))
            self._maybe_crash()          # the torn-state window under test
            self.state.write_snapshot(reason="reopt")
            return res
        finally:
            self.in_flight = False

    def _optimize(self, job, seed: int):
        """Compute the candidate overlay on the frozen copy (no locks)."""
        with batcheval.eval_options(**self.eval_opts):
            return self._optimize_inner(job, seed)

    def _optimize_inner(self, job, seed: int):
        if self.method == "adapt":
            new_ov, kind, _rho = selection.adapt(job.overlay, eps=self.eps,
                                                 seed=seed)
            return None if kind == "keep" else new_ov
        # "dqn": reconstruct a fresh DGRO-DQN ring set over the frozen
        # latency block and graft it (additively) onto the live overlay
        from repro import overlay as overlay_api
        built = overlay_api.build(
            "dgro-dqn", job.overlay.w,
            overlay_api.DGRODQNConfig(epochs=4, n_starts=2), seed=seed)
        merged = job.overlay
        for ring in built.rings:
            merged = merged.add_ring(ring)
        return merged

    def _maybe_crash(self) -> None:
        if self.crash_hook is not None:
            self.crash_hook()
        if os.environ.get(_CRASH_ENV) == "1":
            os._exit(17)        # simulate a hard crash mid-window
