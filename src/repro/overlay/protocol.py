"""The topology protocol — what every layer above ``repro.overlay`` needs.

Historically the whole stack (dynamics, routing, service, benchmarks)
hard-assumed *the* :class:`~repro.overlay.Overlay` dataclass and its dense
(N, N) latency matrix.  That caps the repo around N=4096.  The protocol
below is the small surface those layers actually consume, so a topology can
be the flat ``Overlay`` (unchanged semantics, bit-identical caches) or the
two-level :class:`~repro.hier.HierarchicalOverlay` (paper §VI composed:
cluster-local DGRO rings + a DGRO ring over cluster heads) without any call
site caring which.

Distance semantics are *bounds with a stamp* — the same ``exact | lower``
contract ``dynamics.incremental`` and the service already serve:

* ``distance_bound(u, v) -> (value, "exact" | "lower")`` — never an
  overestimate; ``"exact"`` when nothing is stale at either level;
* ``diameter_bound() -> (value, "exact" | "upper")`` — never an
  underestimate of the topology's true diameter (the flat implementation
  is always exact; the hierarchical one is exact when its cluster
  distance matrices are, and an eccentricity-composed upper bound when
  they are evaluated lazily at large N).
"""
from __future__ import annotations

from typing import Protocol, Tuple, runtime_checkable

import numpy as np

from repro import serde

__all__ = ["Topology", "from_topology_json"]


@runtime_checkable
class Topology(Protocol):
    """Structural protocol both overlay implementations satisfy.

    ``n`` / ``policy`` are attributes; everything else is behaviour.  Node
    ids in ``edge_list`` / ``distance_bound`` / ``subset`` are indices into
    ``range(n)`` (the implementation's own node numbering).
    """

    policy: str

    @property
    def n(self) -> int: ...

    def edge_list(self) -> np.ndarray:
        """(E, 2) unique undirected edges (u < v)."""
        ...

    def distance_bound(self, u: int, v: int) -> Tuple[float, str]:
        """(shortest-path value, ``"exact" | "lower"``) — never an
        overestimate."""
        ...

    def diameter_bound(self) -> Tuple[float, str]:
        """(diameter value, ``"exact" | "upper"``) — never an
        underestimate."""
        ...

    def subset(self, alive) -> "Topology":
        """Restrict to the live nodes, reindexing to ``range(n_live)``."""
        ...

    def to_json(self) -> str:
        """Serde-stamped snapshot; ``from_topology_json`` restores it."""
        ...


def from_topology_json(s: str) -> "Topology":
    """Parse either topology implementation from its JSON snapshot.

    Flat ``Overlay`` payloads are schema 1; ``HierarchicalOverlay``
    payloads are schema 2 with ``"kind": "hier_overlay"``.  Dispatch is by
    payload, so callers that accept "a topology" (service snapshots, trace
    sidecars) need exactly one entry point.
    """
    d = serde.loads(s, what="topology JSON")
    if serde.payload_schema(d) >= 2 or d.get("kind") == "hier_overlay":
        from repro.hier import HierarchicalOverlay
        return HierarchicalOverlay.from_json(s)
    from .core import Overlay
    return Overlay.from_json(s)
