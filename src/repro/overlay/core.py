"""The :class:`Overlay` type — the repo's core currency.

An overlay is what every DGRO workload manipulates: a weighted latency
matrix ``w`` over N nodes, the ring permutations embedded in the topology
(the part ring selection is allowed to swap, paper §V), and any extra
non-ring edges a protocol adds (Chord fingers, Perigee nearest-neighbour
links).  The weighted adjacency (0 diagonal, INF sentinel on non-edges) is
*derived* from ``(w, rings, extra_edges)`` at construction, so an Overlay
can never hold an adjacency that disagrees with its rings.

Design:

* **immutable** — a frozen dataclass; "mutations" are functional updates
  (:meth:`replace_rings`, :meth:`add_ring`, :meth:`subset`) that return new
  instances and share ``w``.
* **JAX pytree** — registered with ``jax.tree_util``; the array fields
  (``w``, ``adjacency``, ``extra_edges``, each ring) are leaves and the
  policy name is static, so Overlays pass through ``tree_map`` / ``jit``
  boundaries untouched.
* **lazily cached analytics** — :meth:`distances` (APSP), :meth:`diameter`
  (largest-CC rule, §IV-C) and degree statistics are computed on first use
  through :mod:`repro.core.batcheval` and memoized on the instance; the
  cache is dropped (never copied) by functional updates and pytree
  round-trips.

Legacy code that wants the old ``(adjacency, rings)`` tuple calls
:meth:`to_tuple`; :meth:`from_adjacency` wraps an existing adjacency whose
edge weights come from ``w`` (the invariant every builder in this repo
maintains).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import serde
from repro.core import batcheval
from repro.core.diameter import (INF, adjacency_from_edges, is_edge,
                                 largest_cc_diameter, ring_edges)

__all__ = ["Overlay"]


def _as_ring_tuple(rings) -> Tuple[np.ndarray, ...]:
    return tuple(np.asarray(r, dtype=np.intp) for r in rings)


def _validate_rings(rings: Tuple[np.ndarray, ...], n: int) -> None:
    ident = np.arange(n)
    for i, p in enumerate(rings):
        if p.shape != (n,) or not np.array_equal(np.sort(p), ident):
            raise ValueError(
                f"ring {i} is not a permutation of range({n}): "
                f"shape {p.shape}, unique {np.unique(p).size}")


@dataclasses.dataclass(frozen=True, eq=False)
class Overlay:
    """Immutable overlay: latency matrix + rings (+ extra edges).

    ``adjacency`` is ALWAYS derived in ``__post_init__`` (it is not an init
    field, so ``dataclasses.replace`` re-derives it too); only the pytree
    unflattener bypasses derivation, with leaves that came from a prior
    instance.
    """

    w: np.ndarray
    rings: Tuple[np.ndarray, ...] = ()
    extra_edges: np.ndarray | None = None
    policy: str = "custom"
    adjacency: np.ndarray = dataclasses.field(init=False)
    _cache: Dict[str, object] = dataclasses.field(
        default_factory=dict, init=False, repr=False)

    def __post_init__(self):
        w = np.asarray(self.w, dtype=np.float32)
        n = w.shape[0]
        if w.ndim != 2 or w.shape[0] != w.shape[1]:
            raise ValueError(f"w must be square, got shape {w.shape}")
        rings = _as_ring_tuple(self.rings)
        _validate_rings(rings, n)
        extra = (np.zeros((0, 2), dtype=np.intp) if self.extra_edges is None
                 else np.asarray(self.extra_edges, dtype=np.intp).reshape(-1, 2))
        object.__setattr__(self, "w", w)
        object.__setattr__(self, "rings", rings)
        object.__setattr__(self, "extra_edges", extra)
        object.__setattr__(self, "adjacency",
                           adjacency_from_edges(w, self._all_edges()))

    def _all_edges(self) -> np.ndarray:
        parts = [ring_edges(p) for p in self.rings] + [self.extra_edges]
        return np.concatenate(parts, axis=0)

    # -- basic shape ------------------------------------------------------

    @property
    def n(self) -> int:
        """Number of nodes."""
        return self.w.shape[0]

    @property
    def num_rings(self) -> int:
        return len(self.rings)

    def edge_list(self) -> np.ndarray:
        """(E, 2) unique undirected edges (u < v) of the overlay."""
        return np.argwhere(np.triu(np.asarray(is_edge(self.adjacency)), 1))

    # -- lazily cached analytics (via core.batcheval) ---------------------

    def distances(self) -> np.ndarray:
        """(N, N) all-pairs shortest-path matrix (INF = unreachable)."""
        if "distances" not in self._cache:
            d = batcheval.batched_apsp(jnp.asarray(self.adjacency)[None])[0]
            self._cache["distances"] = np.asarray(d)
        return self._cache["distances"]

    def diameter(self) -> float:
        """Weighted diameter of the largest connected component (§IV-C)."""
        if "diameter" not in self._cache:
            self._cache["diameter"] = float(
                largest_cc_diameter(jnp.asarray(self.distances())))
        return self._cache["diameter"]

    # -- topology-protocol bounds (repro.overlay.protocol) ----------------
    # The flat Overlay's distances are always exact, so the bound stamps
    # are constant; these wrappers exist so flat and hierarchical overlays
    # answer the same questions through the same surface.

    def distance_bound(self, u: int, v: int) -> Tuple[float, str]:
        """(exact shortest-path latency, ``"exact"``)."""
        return float(self.distances()[int(u), int(v)]), "exact"

    def diameter_bound(self) -> Tuple[float, str]:
        """(exact diameter, ``"exact"``)."""
        return self.diameter(), "exact"

    def cache_diameter(self, d: float) -> "Overlay":
        """Pre-seed the diameter cache and return self.

        The sanctioned entry point for builders (GA, DQN, rho-selection)
        that already scored this exact topology — saves the second APSP
        without reaching into the private cache."""
        self._cache["diameter"] = float(d)
        return self

    def is_connected(self) -> bool:
        return bool((self.distances() < float(INF) / 2).all())

    def degrees(self) -> np.ndarray:
        """Per-node overlay degree."""
        if "degrees" not in self._cache:
            self._cache["degrees"] = np.asarray(
                is_edge(self.adjacency)).sum(axis=1)
        return self._cache["degrees"]

    def degree_stats(self) -> Dict[str, float]:
        deg = self.degrees()
        return {"min": float(deg.min()), "mean": float(deg.mean()),
                "max": float(deg.max())}

    # -- functional updates -----------------------------------------------

    def replace_rings(self, new_rings: Sequence[np.ndarray]) -> "Overlay":
        """Swap the ring set (DGRO ring selection); extra edges are kept.

        The replacement must have the SAME ring count — a silently changed
        count would alter per-node degree budgets (one ring buys one
        outgoing edge per node, §IV-B).
        """
        new_rings = _as_ring_tuple(new_rings)
        if len(new_rings) != len(self.rings):
            raise ValueError(
                f"replacement ring count {len(new_rings)} != current "
                f"{len(self.rings)}; use add_ring() to grow the ring set")
        return Overlay(self.w, new_rings, self.extra_edges, self.policy)

    def add_ring(self, perm: np.ndarray) -> "Overlay":
        """Augment the overlay with one more ring (Alg. 3 repair step)."""
        return Overlay(self.w, self.rings + (np.asarray(perm, np.intp),),
                       self.extra_edges, self.policy)

    def subset(self, alive) -> "Overlay":
        """Restrict to the live nodes (churn): drop dead nodes from every
        ring (stitching predecessor to successor) and from the extra edges,
        reindexing to ``range(n_live)``.  Accepts a boolean mask or an index
        array.

        The index path validates once and sorts at most once (already-
        sorted inputs — the common case: ``live_ids()`` output, cluster
        member lists — pass through untouched), and the latency matrix is
        sliced in a single advanced-indexing step, so the only (k, k)
        allocation is the submatrix itself.  Out-of-range or duplicate
        indices raise instead of being silently dropped.
        """
        alive = np.asarray(alive)
        if alive.dtype == bool:
            if alive.shape != (self.n,):
                raise ValueError(
                    f"boolean subset mask must have shape ({self.n},), got "
                    f"{alive.shape}")
            idx = np.flatnonzero(alive)
        else:
            idx = np.asarray(alive, dtype=np.intp).ravel()
            if idx.size:
                if int(idx.min()) < 0 or int(idx.max()) >= self.n:
                    raise ValueError(
                        f"subset indices must lie in [0, {self.n}), got "
                        f"range [{idx.min()}, {idx.max()}]")
                d = np.diff(idx)
                if (d < 0).any():               # sort once, only if needed
                    idx = np.sort(idx)
                    d = np.diff(idx)
                if (d == 0).any():
                    raise ValueError(
                        "subset indices contain duplicates; pass each live "
                        "node at most once")
        if idx.size == 0:
            raise ValueError("subset() needs at least one live node")
        keep = np.zeros(self.n, dtype=bool)
        keep[idx] = True
        remap = np.full(self.n, -1, dtype=np.intp)
        remap[idx] = np.arange(idx.size)
        rings = tuple(remap[p[keep[p]]] for p in self.rings)
        e = self.extra_edges
        e = e[keep[e[:, 0]] & keep[e[:, 1]]] if e.size else e
        return Overlay(self.w[np.ix_(idx, idx)], rings,
                       remap[e] if e.size else None, self.policy)

    # -- conversions ------------------------------------------------------

    def to_tuple(self) -> Tuple[np.ndarray, List]:
        """Legacy ``(adjacency, rings)`` view (pre-Overlay call sites)."""
        return self.adjacency, [np.asarray(r) for r in self.rings]

    @classmethod
    def from_rings(cls, w: np.ndarray, rings: Sequence[np.ndarray],
                   policy: str = "custom") -> "Overlay":
        """Union-of-rings overlay (no extra edges)."""
        return cls(w, _as_ring_tuple(rings), None, policy)

    @classmethod
    def from_adjacency(cls, w: np.ndarray, adj: np.ndarray,
                       rings: Sequence[np.ndarray] = (),
                       policy: str = "custom",
                       fold_weights: bool = False) -> "Overlay":
        """Wrap an existing adjacency whose edge weights come from ``w``.

        All edges not covered by ``rings`` are recorded as extra edges; the
        derived adjacency must reproduce ``adj`` exactly (edge weights equal
        ``w`` at the edges — the invariant every builder here maintains).

        ``fold_weights=True`` accepts adjacencies with custom edge weights
        (e.g. ``IncrementalDistances.add_edge(weight=...)`` set a link below
        its latency): the deviating weights are folded into the stored ``w``
        so the overlay is representable; off-edge latencies keep ``w``.
        """
        adj = np.asarray(adj, dtype=np.float32)
        if fold_weights:
            w = np.where(np.asarray(is_edge(adj)), adj,
                         np.asarray(w, np.float32))
        rings = _as_ring_tuple(rings)
        covered = np.zeros(adj.shape, dtype=bool)
        for p in rings:
            e = ring_edges(p)
            covered[e[:, 0], e[:, 1]] = covered[e[:, 1], e[:, 0]] = True
        extra = np.argwhere(np.triu(np.asarray(is_edge(adj)) & ~covered, 1))
        ov = cls(w, rings, extra, policy)
        mask = np.asarray(is_edge(adj))
        if not (np.allclose(ov.adjacency[mask], adj[mask], rtol=1e-5, atol=1e-5)
                and np.array_equal(mask, np.asarray(is_edge(ov.adjacency)))):
            raise ValueError(
                "adjacency disagrees with w at its edges; Overlay can only "
                "represent overlays whose edge weights come from w")
        return ov

    # -- serialization ----------------------------------------------------

    def to_json(self) -> str:
        """Snapshot (w + rings + extra edges + policy) as JSON.

        ``from_json`` rebuilds the identical Overlay (adjacency re-derived),
        so churn traces and benchmark artifacts can record the overlay they
        started from next to the events they replayed.  The payload carries
        the repo-wide ``"schema"`` field (``repro.serde``); the historical
        ``"version": 1`` field is kept so pre-schema readers still load it.
        """
        return serde.dumps({
            "version": 1,
            "policy": self.policy,
            "n": self.n,
            "w": [[float(x) for x in row] for row in self.w],
            "rings": [[int(x) for x in p] for p in self.rings],
            "extra_edges": [[int(u), int(v)] for u, v in self.extra_edges],
        }, indent=None)

    @classmethod
    def from_json(cls, s: str) -> "Overlay":
        d = serde.loads(s, what="Overlay JSON")
        if serde.payload_schema(d) != 1 or d.get("kind") == "hier_overlay":
            raise ValueError(
                "payload is a hierarchical (schema-2) topology; load it "
                "with repro.hier.HierarchicalOverlay.from_json or "
                "repro.overlay.from_topology_json")
        if d.get("version", 1) != 1:
            raise ValueError(f"unknown Overlay JSON version {d.get('version')!r}")
        return cls(np.asarray(d["w"], np.float32),
                   _as_ring_tuple(d["rings"]),
                   np.asarray(d["extra_edges"], np.intp).reshape(-1, 2),
                   d["policy"])

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Overlay":
        with open(path) as f:
            return cls.from_json(f.read())

    # -- misc -------------------------------------------------------------

    def equals(self, other: "Overlay") -> bool:
        """Structural equality (arrays compared by value)."""
        return (self.policy == other.policy
                and self.num_rings == other.num_rings
                and np.array_equal(self.w, other.w)
                and np.array_equal(self.extra_edges, other.extra_edges)
                and all(np.array_equal(a, b)
                        for a, b in zip(self.rings, other.rings))
                and np.array_equal(self.adjacency, other.adjacency))

    def __repr__(self) -> str:  # compact: matrices don't belong in repr
        return (f"Overlay(policy={self.policy!r}, n={self.n}, "
                f"rings={self.num_rings}, extra_edges={len(self.extra_edges)})")


def _overlay_flatten(ov: Overlay):
    children = (ov.w, ov.adjacency, ov.extra_edges) + ov.rings
    return children, (ov.policy, len(ov.rings))


def _overlay_unflatten(aux, children) -> Overlay:
    policy, n_rings = aux
    w, adjacency, extra_edges, *rings = children
    ov = object.__new__(Overlay)
    object.__setattr__(ov, "w", w)
    object.__setattr__(ov, "adjacency", adjacency)
    object.__setattr__(ov, "extra_edges", extra_edges)
    object.__setattr__(ov, "rings", tuple(rings[:n_rings]))
    object.__setattr__(ov, "policy", policy)
    object.__setattr__(ov, "_cache", {})
    return ov


jax.tree_util.register_pytree_node(Overlay, _overlay_flatten,
                                   _overlay_unflatten)
