"""repro.overlay — the unified overlay API.

Every DGRO workload manipulates the same object: an overlay (latency matrix,
embedded rings, derived adjacency).  This package is its home — an immutable
JAX-pytree :class:`Overlay` plus a string-keyed builder registry — replacing
the ad-hoc ``(adjacency, rings)`` tuples the repo grew up on::

    from repro import overlay

    w = make_latency("fabric", 64, seed=0)
    ov = overlay.build("dgro", w, seed=0)        # rho-adaptive construction
    ov.diameter()                                # lazily cached, batcheval
    ov2 = ov.add_ring(perm)                      # functional updates
    overlay.Overlay.from_json(ov.to_json())      # snapshot / restore

Registered builders and the paper sections they reproduce:

====================  =====================================================
builder               paper section
====================  =====================================================
``"dgro"``            §V adaptive selection: rho-guided random/nearest ring
                      mix, best candidate by batched diameter (Alg. 3)
``"dgro-dqn"``        §IV Algs. 1-2: deep-Q ring construction — trains the
                      DQN, then batched multi-start greedy rollouts through
                      the device episode engine (``core.rollout``)
``"chord"``           §II/§V-A baseline: identifier ring + 2^j fingers
``"rapid"``           §V-A baseline: K consistent-hash rings
``"perigee"``         §V-A baseline: d nearest neighbours + one ring
``"ga"``              §VII-A.2 genetic-algorithm K-ring search
``"nearest"``         §V "shortest ring": greedy nearest-available
``"random"``          §IV-B random K-ring (the paper's normalizer)
``"parallel"``        §VI Alg. 4 partitioned construction (M segments, one
                      device-batched build; constructor/stitch knobs)
``"kleinberg"``       routing baseline: base ring + q harmonic long links
                      per node (P ∝ 1/ringdist^exponent, Kleinberg 2000)
``"papillon"``        routing baseline: bounded-degree deterministic
                      butterfly long links (Abraham, Malkhi & Manku 2005)
``"dgro-hier"``       §VI composed two-level hierarchy: latency-clustered
                      partitions with cluster-local rings + a DGRO ring
                      over cluster heads (``repro.hier``, lazily resolved;
                      ``kind="hier"`` — returns a ``HierarchicalOverlay``)
====================  =====================================================

Both overlay implementations — the flat :class:`Overlay` and
:class:`repro.hier.HierarchicalOverlay` — satisfy the small
:class:`~repro.overlay.protocol.Topology` protocol (``n``, ``edge_list``,
``distance_bound``/``diameter_bound``, ``subset``, serde);
:func:`from_topology_json` restores either from its JSON snapshot.

New policies register with ``@overlay.register("name", config=Cfg)`` and are
immediately buildable everywhere (benchmarks, churn engine, examples)
without touching call sites.
"""
from .core import Overlay  # noqa: F401
from .protocol import Topology, from_topology_json  # noqa: F401
from .registry import build, builders, get_builder, register  # noqa: F401
from .policies import (ChordConfig, DGROConfig, DGRODQNConfig,  # noqa: F401
                       GAConfig, KleinbergConfig, NearestRingsConfig,
                       PapillonConfig, ParallelConfig, PerigeeConfig,
                       RandomRingsConfig, RapidConfig,
                       chord_finger_edges, nearest_neighbour_edges)

__all__ = [
    "Overlay", "Topology", "from_topology_json",
    "build", "builders", "get_builder", "register",
    "ChordConfig", "DGROConfig", "DGRODQNConfig", "GAConfig",
    "KleinbergConfig", "NearestRingsConfig", "PapillonConfig",
    "ParallelConfig", "PerigeeConfig", "RandomRingsConfig", "RapidConfig",
    "chord_finger_edges", "nearest_neighbour_edges",
]
