"""String-keyed overlay builder registry.

Builders are functions ``fn(w, cfg, rng) -> Overlay`` registered under a
policy name together with their config dataclass::

    @register("chord", config=ChordConfig)
    def _build_chord(w, cfg, rng):
        ...

Consumers construct overlays without touching policy internals::

    ov = overlay.build("chord", w, seed=0)                  # default config
    ov = overlay.build("rapid", w, RapidConfig(k=4), rng=rng)
    ov = overlay.build("perigee", w, ring="nearest", seed=3)  # field override

New policies (future PRs: sharded builds, served topologies) plug in through
``@register`` instead of editing call sites.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import numpy as np

from .core import Overlay

__all__ = ["register", "build", "builders", "get_builder", "BuilderSpec"]

BuilderFn = Callable[[np.ndarray, object, np.random.Generator], Overlay]


@dataclasses.dataclass(frozen=True)
class BuilderSpec:
    name: str
    fn: BuilderFn
    config_cls: Optional[type]
    # "flat" builders return a dense Overlay; "hier" builders return a
    # HierarchicalOverlay (topology-protocol object, no dense adjacency) —
    # flat-only invariants (global ring routing, dense APSP parity) filter
    # on this
    kind: str = "flat"

    def default_config(self, **overrides):
        if self.config_cls is None:
            if overrides:
                raise ValueError(
                    f"builder {self.name!r} takes no config fields, got "
                    f"{sorted(overrides)}")
            return None
        return self.config_cls(**overrides)


_REGISTRY: Dict[str, BuilderSpec] = {}

# builders that live OUTSIDE repro.overlay (above it in the layering) and
# self-register on import: resolved lazily so `import repro.overlay` stays
# light and the layering stays acyclic
_LAZY_MODULES: Dict[str, str] = {"dgro-hier": "repro.hier"}


def _resolve_lazy(name: Optional[str] = None) -> None:
    import importlib
    for key, module in _LAZY_MODULES.items():
        if (name is None or name == key) and key not in _REGISTRY:
            importlib.import_module(module)


def register(name: str, *, config: Optional[type] = None,
             kind: str = "flat"):
    """Decorator: register an overlay builder under ``name``."""

    def deco(fn: BuilderFn) -> BuilderFn:
        if name in _REGISTRY:
            raise ValueError(f"builder {name!r} already registered")
        _REGISTRY[name] = BuilderSpec(name=name, fn=fn, config_cls=config,
                                      kind=kind)
        return fn

    return deco


def builders() -> Dict[str, Optional[type]]:
    """Registered builder names -> config class (None = no config)."""
    _resolve_lazy()
    return {name: spec.config_cls for name, spec in sorted(_REGISTRY.items())}


def get_builder(name: str) -> BuilderSpec:
    _resolve_lazy(name)
    try:
        return _REGISTRY[name]
    except KeyError:
        _resolve_lazy()     # the message must list lazy builders too
        # sorted, comma-joined: a stable message tests/docs can rely on
        raise ValueError(
            f"unknown overlay builder {name!r}; registered builders: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def build(name: str, w: np.ndarray, cfg=None, *,
          rng: np.random.Generator | None = None, seed: int = 0,
          **overrides) -> Overlay:
    """Build a named overlay over the latency matrix ``w``.

    ``cfg`` is the builder's config dataclass instance; when omitted, the
    default config is built with ``overrides`` applied as field values.
    Randomness comes from ``rng`` (or ``np.random.default_rng(seed)``).

    Beyond the paper's diameter-oriented builders (``"dgro"``,
    ``"dgro-dqn"``, ``"chord"``, ``"rapid"``, ``"perigee"``, ``"ga"``,
    ``"nearest"``, ``"random"``, ``"parallel"``), two routing-native
    small-world baselines back the ``repro.routing`` workloads:

    * ``"kleinberg"`` — base ring + ``q`` long links per node drawn with
      probability ∝ ``1/ringdist^exponent`` (harmonic at the default
      exponent 1.0, the greedy-routable optimum for a 1-D ring);
    * ``"papillon"`` — deterministic bounded-degree cyclic-butterfly long
      links (arity ``k``), ring-greedy routable in O(log N) hops.

    ``builders()`` lists everything currently registered.
    """
    spec = get_builder(name)
    if cfg is not None and overrides:
        raise ValueError("pass either cfg or field overrides, not both")
    if cfg is None:
        cfg = spec.default_config(**overrides)
    elif spec.config_cls is not None and not isinstance(cfg, spec.config_cls):
        raise TypeError(
            f"builder {name!r} expects {spec.config_cls.__name__}, got "
            f"{type(cfg).__name__}")
    if rng is None:
        rng = np.random.default_rng(seed)
    w = np.asarray(w, dtype=np.float32)
    ov = spec.fn(w, cfg, rng)
    if ov.policy != name:     # builders may leave the stamping to the registry
        # in-place stamp on the freshly built (unaliased) instance: keeps the
        # derived adjacency and any cache_diameter() the builder pre-seeded
        object.__setattr__(ov, "policy", name)
    return ov
