"""Per-policy overlay builders + their config dataclasses.

One builder per topology policy the paper evaluates; each maps to a paper
section (see ``repro.overlay.__doc__`` for the full table).  The edge-rule
helpers (:func:`chord_finger_edges`, :func:`nearest_neighbour_edges`) are
the single source of truth for the Chord / Perigee construction rules —
``dynamics.engine`` reuses them for join-time repairs instead of
re-implementing them.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import batcheval
from repro.core.construction import (default_num_rings, k_rings, nearest_ring,
                                     random_ring)
from repro.core.ga import GAConfig, evolve
from repro.core.selection import (clustering_ratio, measure_latency_stats,
                                  select_ring_kind)

from .core import Overlay
from .registry import register

__all__ = [
    "RandomRingsConfig", "NearestRingsConfig", "ChordConfig", "RapidConfig",
    "PerigeeConfig", "DGROConfig", "DGRODQNConfig", "GAConfig",
    "ParallelConfig", "KleinbergConfig", "PapillonConfig",
    "chord_finger_edges", "nearest_neighbour_edges",
]


# ---------------------------------------------------------------------------
# shared edge rules (also used by dynamics.engine join repairs)
# ---------------------------------------------------------------------------

def chord_finger_edges(ring: Sequence[int], pos: int) -> List[Tuple[int, int]]:
    """Chord finger edges of the node at ring position ``pos``: one edge to
    the 2^j-th successor for every 2^j < n (Stoica et al. 2001)."""
    n = len(ring)
    u = int(ring[pos])
    edges = []
    j = 1
    while (1 << j) < n:
        edges.append((u, int(ring[(pos + (1 << j)) % n])))
        j += 1
    return edges


def nearest_neighbour_edges(w: np.ndarray, candidates: np.ndarray, u: int,
                            degree: int) -> List[Tuple[int, int]]:
    """Perigee rule: ``u``'s ``degree`` lowest-latency peers among
    ``candidates`` (Mao et al. 2020).  Stable sort keeps ties deterministic."""
    candidates = np.asarray(candidates)
    others = candidates[candidates != u]
    order = others[np.argsort(w[u, others], kind="stable")]
    return [(int(u), int(v)) for v in order[:degree]]


def _connectivity_ring(kind: str, w: np.ndarray,
                       rng: np.random.Generator) -> np.ndarray:
    """The one connectivity ring Chord / Perigee embed: "random" (stock
    consistent-hash) or "nearest" (the swap DGRO's selection applies)."""
    if kind == "random":
        return random_ring(rng, w.shape[0])
    if kind == "nearest":
        return nearest_ring(w, start=int(rng.integers(w.shape[0])))
    raise ValueError(f"unknown ring kind {kind!r}; options ('random', "
                     f"'nearest')")


# ---------------------------------------------------------------------------
# baseline rings (§IV-B constructors as stand-alone topologies)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RandomRingsConfig:
    """K consistent-hash (uniformly random) rings; K defaults to ceil(log2 N)
    (the paper's per-node log(N) connection budget)."""
    k: Optional[int] = None


def _k_random_rings(w: np.ndarray, k: Optional[int],
                    rng: np.random.Generator, policy: str) -> Overlay:
    n = w.shape[0]
    k = default_num_rings(n) if k is None else k
    return Overlay.from_rings(w, [random_ring(rng, n) for _ in range(k)],
                              policy=policy)


@register("random", config=RandomRingsConfig)
def _build_random(w: np.ndarray, cfg: RandomRingsConfig,
                  rng: np.random.Generator) -> Overlay:
    return _k_random_rings(w, cfg.k, rng, "random")


@dataclasses.dataclass(frozen=True)
class NearestRingsConfig:
    """K greedy nearest-neighbour ("shortest", §V last ¶) rings from random
    start nodes."""
    k: int = 1


@register("nearest", config=NearestRingsConfig)
def _build_nearest(w: np.ndarray, cfg: NearestRingsConfig,
                   rng: np.random.Generator) -> Overlay:
    n = w.shape[0]
    starts = rng.integers(0, n, size=cfg.k)
    return Overlay.from_rings(
        w, [nearest_ring(w, start=int(s)) for s in starts], policy="nearest")


# ---------------------------------------------------------------------------
# protocol baselines (§V-A, §VII)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChordConfig:
    """Identifier ring + power-of-two fingers; ``ring`` picks the
    connectivity ring kind ("random" = stock Chord, "nearest" = the swap
    DGRO's selection applies in Figs. 7/11/15)."""
    ring: str = "random"


@register("chord", config=ChordConfig)
def _build_chord(w: np.ndarray, cfg: ChordConfig,
                 rng: np.random.Generator) -> Overlay:
    n = w.shape[0]
    perm = _connectivity_ring(cfg.ring, w, rng)
    fingers = [e for pos in range(n) for e in chord_finger_edges(perm, pos)]
    return Overlay(w, (perm,), np.asarray(fingers, np.intp).reshape(-1, 2),
                   policy="chord")


@dataclasses.dataclass(frozen=True)
class RapidConfig:
    """K independent consistent-hash rings (Suresh et al. 2018); K defaults
    to ceil(log2 N)."""
    k: Optional[int] = None


@register("rapid", config=RapidConfig)
def _build_rapid(w: np.ndarray, cfg: RapidConfig,
                 rng: np.random.Generator) -> Overlay:
    return _k_random_rings(w, cfg.k, rng, "rapid")


@dataclasses.dataclass(frozen=True)
class PerigeeConfig:
    """Per-node ``degree`` lowest-latency neighbours + one connectivity ring
    ("the paper always combines Perigee with a ring"); ``degree`` defaults
    to ceil(log2 N)."""
    degree: Optional[int] = None
    ring: str = "random"


@register("perigee", config=PerigeeConfig)
def _build_perigee(w: np.ndarray, cfg: PerigeeConfig,
                   rng: np.random.Generator) -> Overlay:
    n = w.shape[0]
    degree = default_num_rings(n) if cfg.degree is None else cfg.degree
    everyone = np.arange(n)
    edges = [e for u in range(n)
             for e in nearest_neighbour_edges(w, everyone, u, degree)]
    ring = _connectivity_ring(cfg.ring, w, rng)
    return Overlay(w, (ring,), np.asarray(edges, np.intp).reshape(-1, 2),
                   policy="perigee")


# ---------------------------------------------------------------------------
# routing-native small-world baselines (repro.routing workloads)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KleinbergConfig:
    """Navigable small world (Kleinberg 2000): a base connectivity ring
    plus ``q`` long links per node, drawn with probability proportional to
    ``ringdist^-exponent`` (exponent 1 is the harmonic distribution — the
    greedy-routable optimum for a 1-D ring).  ``q`` defaults to
    ceil(log2 N), matching the paper's per-node connection budget."""
    q: Optional[int] = None
    exponent: float = 1.0
    ring: str = "random"


@register("kleinberg", config=KleinbergConfig)
def _build_kleinberg(w: np.ndarray, cfg: KleinbergConfig,
                     rng: np.random.Generator) -> Overlay:
    n = w.shape[0]
    perm = _connectivity_ring(cfg.ring, w, rng)
    if n <= 3:                       # the ring already connects everyone
        return Overlay(w, (perm,), None, policy="kleinberg")
    q = default_num_rings(n) if cfg.q is None else cfg.q
    offsets = np.arange(2, n - 1)    # ring edges already cover offsets 1, n-1
    p = np.minimum(offsets, n - offsets) ** -float(cfg.exponent)
    p /= p.sum()
    edges = [(int(perm[pos]), int(perm[(pos + int(off)) % n]))
             for pos in range(n)
             for off in rng.choice(offsets, size=q, p=p)]
    return Overlay(w, (perm,), np.asarray(edges, np.intp).reshape(-1, 2),
                   policy="kleinberg")


@dataclasses.dataclass(frozen=True)
class PapillonConfig:
    """Papillon-style cyclic butterfly (Abraham, Malkhi & Manku 2005):
    with arity ``k`` and L = ceil(log_k N) levels, the node at ring
    position ``i`` (level ``i mod L``) adds deterministic long links to
    positions ``i + j * k^(L-1-level)`` for j = 1..k — bounded degree
    (2 ring + 2k long links), no randomness beyond the ring itself, and
    ring-distance-greedy routable in O(log N) hops."""
    k: int = 2
    ring: str = "random"


@register("papillon", config=PapillonConfig)
def _build_papillon(w: np.ndarray, cfg: PapillonConfig,
                    rng: np.random.Generator) -> Overlay:
    if cfg.k < 2:
        raise ValueError(f"papillon arity k must be >= 2, got {cfg.k}")
    n = w.shape[0]
    perm = _connectivity_ring(cfg.ring, w, rng)
    levels = max(1, int(np.ceil(np.log(max(n, 2)) / np.log(cfg.k))))
    edges = []
    for pos in range(n):
        stride = cfg.k ** (levels - 1 - (pos % levels))
        for j in range(1, cfg.k + 1):
            tgt = (pos + j * stride) % n
            if tgt != pos:
                edges.append((int(perm[pos]), int(perm[tgt])))
    extra = np.asarray(edges, np.intp).reshape(-1, 2) if edges else None
    return Overlay(w, (perm,), extra, policy="papillon")


# ---------------------------------------------------------------------------
# DGRO adaptive construction (§V) and search baselines (§VII-A.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DGROConfig:
    """rho-guided mixed-ring construction: measure the clustering ratio on a
    random probe overlay (Alg. 3), shortlist random/nearest ring mixes near
    the indicated regime, keep the best diameter (scored in ONE batched
    device call).  ``k`` defaults to ceil(log2 N) rings."""
    k: Optional[int] = None
    n_candidates: int = 4
    eps: float = 0.3
    stats_seed: int = 0


@register("dgro", config=DGROConfig)
def _build_dgro(w: np.ndarray, cfg: DGROConfig,
                rng: np.random.Generator) -> Overlay:
    n = w.shape[0]
    k = default_num_rings(n) if cfg.k is None else cfg.k
    probe = Overlay.from_rings(w, k_rings(w, k, "random", rng), policy="dgro")
    if n >= 4:        # the gossip sampler needs >= k random peers per node
        stats = measure_latency_stats(w, probe.adjacency, seed=cfg.stats_seed)
        rho = clustering_ratio(stats)
    else:
        rho = 0.5
    kind = select_ring_kind(rho, cfg.eps)
    if kind == "nearest":      # too random -> mostly nearest rings
        ms = range(0, min(2, k) + 1)
    elif kind == "random":     # too clustered -> mostly random rings
        ms = range(max(0, k - 2), k + 1)
    else:
        ms = range(0, k + 1, max(1, k // cfg.n_candidates))
    candidates = [k_rings(w, k, f"mixed:{m}", rng) for m in ms]
    scores = batcheval.diameters_of_rings(w, np.stack(
        [np.stack(rings) for rings in candidates]))
    best = candidates[int(np.argmin(scores))]
    return Overlay.from_rings(w, best,
                              policy="dgro").cache_diameter(scores.min())


@dataclasses.dataclass(frozen=True)
class DGRODQNConfig:
    """§IV Algs. 1-2: train the deep-Q ring constructor on graphs of the
    target size and distribution, then keep the best of ``n_starts``
    greedy constructions — all of them built in ONE vmapped rollout call
    through the device episode engine (``repro.core.rollout``).
    ``rollout="host"`` switches to the step-by-step debug loop."""
    k: Optional[int] = None
    epochs: int = 60
    n_starts: int = 10
    dist: str = "uniform"
    rollout: str = "device"


@register("dgro-dqn", config=DGRODQNConfig)
def _build_dgro_dqn(w: np.ndarray, cfg: DGRODQNConfig,
                    rng: np.random.Generator) -> Overlay:
    from repro.core.qlearning import (DQNConfig, dgro_overlay,  # jax-heavy
                                      train_dqn)

    n = w.shape[0]
    k = default_num_rings(n) if cfg.k is None else cfg.k
    seed = int(rng.integers(2**31))
    dcfg = DQNConfig(n=n, k_rings=k, epochs=cfg.epochs,
                     eps_decay=max(cfg.epochs // 2, 1), dist=cfg.dist,
                     seed=seed, rollout=cfg.rollout)
    params, _ = train_dqn(dcfg, eval_every=max(cfg.epochs, 1), eval_graphs=1)
    return dgro_overlay(params, dcfg, w, n_starts=cfg.n_starts, seed=seed)


@register("ga", config=GAConfig)
def _build_ga(w: np.ndarray, cfg: GAConfig,
              rng: np.random.Generator) -> Overlay:
    """Genetic-algorithm K-ring search (the GA consumes ``cfg.seed``, not
    ``rng`` — its evolution loop owns its own generator)."""
    return evolve(w, cfg).to_overlay(w)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Algorithm 4 on the device-batched engine: one ring built by M
    concurrent partitions (all segments in one jit'd call), plus
    ``extra_random`` whole-fleet random rings.

    ``constructor`` picks the per-partition builder: ``"nearest"`` (vmapped
    greedy nearest-neighbour) or ``"dqn"`` (the vectorized rollout engine
    with partitions as the environment batch; ``dqn_epochs`` sizes its
    training run).  ``stitch`` picks the segment merge: ``"naive"``
    (tail-to-head, Alg. 4 line 14) or ``"scored"`` (segment
    rotations/reflections scored in one batched diameter call).
    """
    m: int = 4
    extra_random: int = 0
    constructor: str = "nearest"
    stitch: str = "scored"
    dqn_epochs: int = 40


@register("parallel", config=ParallelConfig)
def _build_parallel(w: np.ndarray, cfg: ParallelConfig,
                    rng: np.random.Generator) -> Overlay:
    from repro.core.parallel import (SegmentDQNConfig,  # jax.sharding is heavy
                                     parallel_overlay)

    ov, _ = parallel_overlay(w, cfg.m, seed=int(rng.integers(2**31)),
                             constructor=cfg.constructor, stitch=cfg.stitch,
                             dqn=SegmentDQNConfig(epochs=cfg.dqn_epochs))
    for _ in range(cfg.extra_random):
        ov = ov.add_ring(random_ring(rng, w.shape[0]))
    return ov
