"""Seeded source/destination pair sampling for routing workloads.

Three traffic mixes, all returning a ``(P, 2)`` intp array of distinct
src/dst pairs from one ``numpy`` Generator (deterministic per seed):

* ``"uniform"`` — both endpoints uniform over the fleet (the classic
  all-to-all probe);
* ``"hotspot"`` — a small set of hot destinations receives
  ``hotspot_frac`` of the traffic (aggregation points, bootstrap seeds);
* ``"regional"`` — with probability ``locality`` the destination shares
  the source's FABRIC site (``i % N_FABRIC_SITES``, the same assignment
  ``core.topology`` and the regional churn scenarios use), modelling
  intra-site chatter with occasional cross-country hops.
"""
from __future__ import annotations

import numpy as np

from repro.core.topology import N_FABRIC_SITES

__all__ = ["WORKLOADS", "sample_pairs"]

#: workload mixes, in the order fig19 reports them
WORKLOADS = ("uniform", "hotspot", "regional")


def _uniform_pair(rng: np.random.Generator, n: int) -> tuple:
    src = int(rng.integers(n))
    dst = int(rng.integers(n - 1))
    return src, dst + (dst >= src)          # uniform over the other n-1


def sample_pairs(n: int, n_pairs: int, kind: str = "uniform", *,
                 seed: int = 0, rng: np.random.Generator | None = None,
                 hotspots: int = 4, hotspot_frac: float = 0.8,
                 locality: float = 0.8) -> np.ndarray:
    """Sample ``n_pairs`` distinct src/dst pairs over ``n`` nodes."""
    if kind not in WORKLOADS:
        raise ValueError(f"unknown workload {kind!r}; options {WORKLOADS}")
    if n < 2:
        raise ValueError(f"need >= 2 nodes to sample pairs, got {n}")
    rng = np.random.default_rng(seed) if rng is None else rng
    pairs = np.empty((n_pairs, 2), np.intp)
    if kind == "uniform":
        for i in range(n_pairs):
            pairs[i] = _uniform_pair(rng, n)
        return pairs
    if kind == "hotspot":
        hot = rng.choice(n, size=min(int(hotspots), n), replace=False)
        for i in range(n_pairs):
            if rng.random() < hotspot_frac:
                dst = int(hot[rng.integers(len(hot))])
                src = int(rng.integers(n - 1))
                pairs[i] = src + (src >= dst), dst
            else:
                pairs[i] = _uniform_pair(rng, n)
        return pairs
    # regional: prefer a same-FABRIC-site destination
    site_of = np.arange(n) % N_FABRIC_SITES
    for i in range(n_pairs):
        src = int(rng.integers(n))
        mates = np.flatnonzero(site_of == site_of[src])
        mates = mates[mates != src]
        if mates.size and rng.random() < locality:
            pairs[i] = src, int(mates[rng.integers(mates.size)])
        else:
            dst = int(rng.integers(n - 1))
            pairs[i] = src, dst + (dst >= src)
    return pairs
