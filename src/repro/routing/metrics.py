"""Routing-run summaries + the routing observability instruments.

:class:`RoutingSummary` is the serde-stamped artifact shape the fig19
matrix embeds (one row per builder × workload × policy).  The module also
registers the routing defaults on the process-global ``repro.obs``
registry — the SAME two instruments the service's ``/v1/route`` endpoint
and the fig19 benchmark record into, so a live scrape and a benchmark
artifact always agree on what a "route request" is:

* ``repro_route_hops`` — hop-count histogram of delivered routes;
* ``repro_route_requests_total{policy,outcome}`` — requests by next-hop
  policy (``ring`` / ``latency``) and outcome (``delivered`` /
  ``dead_end`` / ``exhausted`` / ``unreachable``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro import serde
from repro.core.diameter import INF
from repro.obs import REGISTRY

from .greedy import RouteResult

__all__ = [
    "HOP_BUCKETS",
    "ROUTE_HOPS",
    "ROUTE_REQUESTS",
    "record_route",
    "record_route_batch",
    "RoutingSummary",
    "summarize",
]

# hop counts are small integers: power-of-two-ish bounds up to deep walks
HOP_BUCKETS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128)

ROUTE_HOPS = REGISTRY.histogram(
    "repro_route_hops", "hop count per delivered greedy route",
    buckets=HOP_BUCKETS)
ROUTE_REQUESTS = REGISTRY.counter(
    "repro_route_requests_total",
    "greedy route requests, by next-hop policy and outcome",
    labels=("policy", "outcome"))


def record_route(policy: str, outcome: str,
                 hops: Optional[int] = None) -> None:
    """Count one route request; delivered routes also land in the hop
    histogram."""
    ROUTE_REQUESTS.labels(policy=policy, outcome=outcome).inc()
    if outcome == "delivered" and hops is not None:
        ROUTE_HOPS.observe(int(hops))


def record_route_batch(policy: str, result: RouteResult) -> None:
    """Record every pair of a batched routing call (one counter bump per
    outcome class, one histogram observation per delivered pair)."""
    n_delivered = int(result.success.sum())
    n_dead = int(result.failed.sum())
    n_exhausted = result.n_pairs - n_delivered - n_dead
    for outcome, count in (("delivered", n_delivered), ("dead_end", n_dead),
                           ("exhausted", n_exhausted)):
        if count:
            ROUTE_REQUESTS.labels(policy=policy, outcome=outcome).inc(count)
    for h in result.hops[result.success]:
        ROUTE_HOPS.observe(int(h))


@dataclasses.dataclass(frozen=True)
class RoutingSummary:
    """Aggregate routing quality of one (builder, workload, policy) cell.

    Stretch statistics are over DELIVERED pairs only (NaN when nothing
    was delivered); ``success_rate`` counts delivery over pairs whose
    endpoints are connected at all, so a partitioned fleet doesn't charge
    the router for physics.
    """

    builder: str
    workload: str
    policy: str
    n: int
    n_pairs: int
    hop_budget: int
    success_rate: float
    hops_mean: float
    hops_max: int
    latency_mean: float
    stretch_mean: float
    stretch_p99: float
    stretch_max: float

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return serde.dumps({"kind": "routing_summary", **self.to_dict()})

    @classmethod
    def from_json(cls, s: str) -> "RoutingSummary":
        d = serde.loads(s, what="RoutingSummary JSON")
        if d.pop("kind", "routing_summary") != "routing_summary":
            raise ValueError("not a routing_summary payload")
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)})


def summarize(result: RouteResult, *, builder: str = "custom",
              workload: str = "custom", policy: str = "latency",
              n: int = 0, hop_budget: int = 0) -> RoutingSummary:
    """Fold a :class:`RouteResult` into one :class:`RoutingSummary`."""
    reachable = (np.isnan(result.optimum)
                 | (result.optimum < float(INF) / 2))
    denom = max(int(reachable.sum()), 1)
    ok = result.success
    stretch = result.stretch[ok & np.isfinite(result.stretch)]
    return RoutingSummary(
        builder=builder, workload=workload, policy=policy, n=int(n),
        n_pairs=result.n_pairs, hop_budget=int(hop_budget),
        success_rate=float(ok.sum()) / denom,
        hops_mean=float(result.hops[ok].mean()) if ok.any() else float("nan"),
        hops_max=int(result.hops.max()) if result.n_pairs else 0,
        latency_mean=(float(result.latency[ok].mean()) if ok.any()
                      else float("nan")),
        stretch_mean=float(stretch.mean()) if stretch.size else float("nan"),
        stretch_p99=(float(np.percentile(stretch, 99)) if stretch.size
                     else float("nan")),
        stretch_max=float(stretch.max()) if stretch.size else float("nan"),
    )
