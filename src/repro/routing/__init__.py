"""repro.routing — overlays evaluated as routing fabrics.

The paper scores overlays by diameter; this package scores them by what a
message actually experiences: greedy next-hop routing over a ``(P, 2)``
batch of source/destination pairs, end to end on device (ROADMAP item 3).

    from repro import overlay, routing
    from repro.core.topology import make_latency

    w = make_latency("bitnode", 256, seed=0)
    ov = overlay.build("kleinberg", w, seed=0)
    pairs = routing.sample_pairs(256, 1024, "hotspot", seed=0)
    res = routing.route_overlay(ov, pairs, policy="ring")
    routing.summarize(res, builder="kleinberg", workload="hotspot",
                      policy="ring", n=256, hop_budget=256)

Layout: :mod:`~repro.routing.greedy` (the jit'd batched router + its
numpy parity/serving reference), :mod:`~repro.routing.workload` (seeded
uniform / hotspot / regional pair mixes), :mod:`~repro.routing.metrics`
(serde-stamped summaries + the ``repro_route_*`` observability defaults
shared with ``repro.service``'s ``/v1/route``).
"""
from .greedy import (POLICIES, RouteResult, latency_keys,  # noqa: F401
                     ring_distance_keys, ring_positions, route_overlay,
                     route_pairs, route_pairs_host, route_single_host)
from .metrics import (HOP_BUCKETS, ROUTE_HOPS, ROUTE_REQUESTS,  # noqa: F401
                      RoutingSummary, record_route, record_route_batch,
                      summarize)
from .workload import WORKLOADS, sample_pairs  # noqa: F401

__all__ = [
    "POLICIES", "RouteResult", "latency_keys", "ring_distance_keys",
    "ring_positions", "route_overlay", "route_pairs", "route_pairs_host",
    "route_single_host",
    "HOP_BUCKETS", "ROUTE_HOPS", "ROUTE_REQUESTS", "RoutingSummary",
    "record_route", "record_route_batch", "summarize",
    "WORKLOADS", "sample_pairs",
]
