"""Batched greedy routing over an overlay — one jit'd device call.

Diameter says how good an overlay *could* be; greedy routing says how good
it *is* to a node that only knows its neighbours plus a per-destination
potential.  This module routes a ``(P, 2)`` batch of source/destination
pairs in ONE device call: a fixed-length ``lax.scan`` over the hop budget
whose per-step advance is ``vmap``-ed across the pair batch, with masked
termination — delivered and dead-ended pairs freeze while the rest keep
walking, and a batch-wide ``lax.cond`` skips the remaining steps entirely
once every pair has settled (the scan length never changes, so neither
does the compiled program).  Each hop scores only a degree-packed
neighbour table (:func:`_neighbor_table`), so per-hop work scales with
the overlay degree rather than N.

Two next-hop policies, selected statically:

* ``"ring"`` — Papillon-style ring-distance greedy: hop to the neighbour
  minimising circular distance to the destination on the base ring,
  requiring strict progress (so routing on any overlay that embeds the
  full ring always terminates and succeeds — the ±1 ring edges always
  make progress).
* ``"latency"`` — potential descent on ``adj[u, v] + D[v, dst]`` where
  ``D`` is a distance matrix honouring the ``dynamics.incremental``
  contract: exact, or an elementwise LOWER bound (between deletion-
  triggered rebuilds).  With an exact ``D`` the descent follows a
  shortest path (stretch exactly 1); with a stale lower bound it can
  wander, which the hop budget and per-pair failure flags absorb.

The numpy reference (:func:`route_single_host` / :func:`route_pairs_host`)
applies the *identical* float32 decision rule, so the fig19 parity gate
can assert hop/latency equality bit-for-bit — and it doubles as the one
shared implementation ``repro.service``'s ``/v1/route`` serves paths from.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.diameter import INF

__all__ = [
    "POLICIES",
    "RouteResult",
    "ring_positions",
    "ring_distance_keys",
    "latency_keys",
    "route_pairs",
    "route_overlay",
    "route_single_host",
    "route_pairs_host",
]

#: next-hop policies, in the order fig19 reports them
POLICIES = ("ring", "latency")

# score assigned to non-edges / useless hops; must stay above any real
# ``adj + D`` sum (each < INF) yet well inside float32 range
_BLOCKED = jnp.float32(4.0) * INF
_HALF_INF = float(INF) / 2


@dataclasses.dataclass(frozen=True)
class RouteResult:
    """Per-pair outcome of one batched routing call.

    ``stretch`` is path latency over the APSP optimum between the
    endpoints: exactly 1.0 for an optimal route, NaN for pairs that were
    not delivered (or whose optimum is unknown/INF).  ``failed`` marks
    dead ends (no useful neighbour); pairs that are neither delivered nor
    failed ran out of hop budget.
    """

    pairs: np.ndarray      # (P, 2) intp src/dst
    hops: np.ndarray       # (P,) int32
    latency: np.ndarray    # (P,) float32 accumulated path latency
    success: np.ndarray    # (P,) bool delivered
    failed: np.ndarray     # (P,) bool dead-ended (vs budget-exhausted)
    optimum: np.ndarray    # (P,) float32 APSP d(src, dst)
    stretch: np.ndarray    # (P,) float32; NaN unless delivered

    @property
    def n_pairs(self) -> int:
        return int(self.pairs.shape[0])

    def outcome(self, p: int) -> str:
        if self.success[p]:
            return "delivered"
        return "dead_end" if self.failed[p] else "exhausted"


# ---------------------------------------------------------------------------
# per-destination potentials ("keys")
# ---------------------------------------------------------------------------

def ring_positions(ring: np.ndarray) -> np.ndarray:
    """``pos[node] = index of node on the ring`` for a ring permutation."""
    ring = np.asarray(ring, np.intp)
    pos = np.empty(ring.shape[0], np.intp)
    pos[ring] = np.arange(ring.shape[0])
    return pos


def ring_distance_keys(ring: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """(P, N) circular ring distance from every node to each pair's dst."""
    pos = ring_positions(ring)
    n = pos.shape[0]
    delta = np.abs(pos[None, :] - pos[np.asarray(dst, np.intp)][:, None])
    return np.minimum(delta, n - delta).astype(np.float32)


def latency_keys(dist: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """(P, N) lower-bound distance from every node to each pair's dst."""
    return np.asarray(dist, np.float32)[:, np.asarray(dst, np.intp)].T


def _keys_for(policy: str, dst: np.ndarray, dist: Optional[np.ndarray],
              ring: Optional[np.ndarray]) -> np.ndarray:
    if policy == "latency":
        if dist is None:
            raise ValueError("latency policy needs the distance matrix")
        return latency_keys(dist, dst)
    if policy == "ring":
        if ring is None:
            raise ValueError("ring policy needs a base ring permutation")
        return ring_distance_keys(ring, dst)
    raise ValueError(f"unknown routing policy {policy!r}; options {POLICIES}")


# ---------------------------------------------------------------------------
# the device router
# ---------------------------------------------------------------------------

def _neighbor_table(adj: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Pack an (N, N) adjacency into a padded neighbour table.

    Returns ``(nbr_idx (N, D) int32, nbr_w (N, D) float32)`` with D the
    max degree: row u lists u's neighbours in ASCENDING node order (so a
    first-min argmin over the row breaks score ties exactly like the host
    reference's argmin over all N nodes) and their edge latencies, padded
    with ``_BLOCKED`` weights.  The device scan's per-hop work then
    scales with the overlay degree, not with N.
    """
    adj = np.asarray(adj, np.float32)
    n = adj.shape[0]
    edge = (adj > 0) & (adj < _HALF_INF)
    d = max(int(edge.sum(axis=1).max(initial=0)), 1)
    # stable argsort of ~edge floats edges first, ascending node order
    order = np.argsort(~edge, axis=1, kind="stable")[:, :d].astype(np.int32)
    valid = np.take_along_axis(edge, order, axis=1)
    w = np.take_along_axis(adj, order, axis=1)
    return order, np.where(valid, w, np.float32(_BLOCKED))


def _advance_one(nbr_idx, nbr_w, policy: str, key_row, cur, lat, hops, done,
                 failed):
    """One greedy hop for ONE pair (vmapped over the batch by the scan
    body).  ``key_row`` is the pair's (N,) potential toward its dst.

    Scores only the ≤ D packed neighbours of ``cur``.  Real-edge scores
    are bit-identical to the host reference's dense
    ``where(edge, adj + key, BLOCKED)`` row — pad entries differ
    (``_BLOCKED + key`` vs ``_BLOCKED``) but both stay ``>= _HALF_INF``,
    and a pad argmin winner only occurs on the stuck branch where the
    index is discarded; the ascending-node-order packing preserves the
    first-min tie break.
    """
    cands = nbr_idx[cur]                                  # (D,)
    wrow = nbr_w[cur]                                     # (D,)
    if policy == "latency":
        score = wrow + key_row[cands]
    else:
        score = jnp.where(wrow < _HALF_INF, key_row[cands], _BLOCKED)
    j = jnp.argmin(score)
    nxt = cands[j]
    best = score[j]
    if policy == "latency":
        stuck = best >= _HALF_INF          # no neighbour with a finite bound
    else:
        stuck = best >= key_row[cur]       # ring greedy demands strict progress
    active = ~done & ~failed
    move = active & ~stuck
    failed = failed | (active & stuck)
    lat = lat + jnp.where(move, wrow[j], 0.0)
    hops = hops + move.astype(jnp.int32)
    cur = jnp.where(move, nxt, cur)
    return cur, lat, hops, failed


@functools.partial(jax.jit, static_argnames=("policy", "hop_budget"))
def _route_batch_jit(nbr_idx: jnp.ndarray, nbr_w: jnp.ndarray,
                     keys: jnp.ndarray, src: jnp.ndarray,
                     dst: jnp.ndarray, *, policy: str, hop_budget: int):
    """Route all P pairs in one call: fixed-length scan over the hop
    budget, per-pair advance vmapped across the batch, masked termination
    (settled pairs freeze; fully-settled batches skip the remaining steps
    through a batch-wide ``lax.cond``)."""
    p = src.shape[0]
    advance = jax.vmap(
        functools.partial(_advance_one, nbr_idx, nbr_w, policy),
        in_axes=(0, 0, 0, 0, 0, 0))

    def step(carry, _):
        cur, lat, hops, done, failed = carry

        def live(c):
            cur, lat, hops, done, failed = c
            cur, lat, hops, failed = advance(keys, cur, lat, hops, done,
                                             failed)
            done = done | (cur == dst)
            return cur, lat, hops, done, failed

        carry = jax.lax.cond(jnp.any(~done & ~failed), live, lambda c: c,
                             carry)
        return carry, None

    carry0 = (src.astype(jnp.int32), jnp.zeros((p,), jnp.float32),
              jnp.zeros((p,), jnp.int32), src == dst, jnp.zeros((p,), bool))
    (cur, lat, hops, done, failed), _ = jax.lax.scan(
        step, carry0, None, length=hop_budget)
    return hops, lat, done, failed


def _stretch(lat: np.ndarray, success: np.ndarray,
             optimum: np.ndarray) -> np.ndarray:
    out = np.full(lat.shape, np.nan, np.float32)
    ok = success & (optimum < _HALF_INF)
    pos = ok & (optimum > 0)
    out[pos] = lat[pos] / optimum[pos]
    out[ok & (optimum == 0)] = 1.0          # src == dst: trivially optimal
    return out


def route_pairs(adj: np.ndarray, dist: Optional[np.ndarray],
                pairs: np.ndarray, *, policy: str = "latency",
                ring: Optional[np.ndarray] = None,
                hop_budget: Optional[int] = None) -> RouteResult:
    """Route a (P, 2) pair batch over an adjacency in one device call.

    ``dist`` guides the ``"latency"`` policy (exact or lower bound, per
    the incremental-maintenance contract) and, when given, prices the
    stretch denominator; ``ring`` is the base ring the ``"ring"`` policy
    descends on.  ``hop_budget`` defaults to N (a strict-descent walk can
    never need more).
    """
    adj = np.asarray(adj, np.float32)
    pairs = np.asarray(pairs, np.intp).reshape(-1, 2)
    n = adj.shape[0]
    src, dst = pairs[:, 0], pairs[:, 1]
    budget = n if hop_budget is None else int(hop_budget)
    keys = _keys_for(policy, dst, dist, ring)
    nbr_idx, nbr_w = _neighbor_table(adj)
    from repro.obs import jit_span
    with jit_span("routing.route_pairs",
                  key=(pairs.shape[0], n, nbr_idx.shape[1], policy, budget)):
        hops, lat, done, failed = _route_batch_jit(
            jnp.asarray(nbr_idx), jnp.asarray(nbr_w), jnp.asarray(keys),
            jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32),
            policy=policy, hop_budget=budget)
    hops, lat = np.asarray(hops), np.asarray(lat)
    success, failed = np.asarray(done), np.asarray(failed)
    optimum = (latency_keys(dist, dst)[np.arange(len(src)), src]
               if dist is not None
               else np.full(len(src), np.nan, np.float32))
    return RouteResult(pairs=pairs, hops=hops, latency=lat, success=success,
                       failed=failed, optimum=optimum,
                       stretch=_stretch(lat, success, optimum))


def route_overlay(ov, pairs: np.ndarray, *, policy: str = "latency",
                  hop_budget: Optional[int] = None) -> RouteResult:
    """Route over an :class:`~repro.overlay.Overlay`: the latency policy
    descends on the overlay's exact APSP matrix (``batcheval``), the ring
    policy on its first embedded ring."""
    ring = np.asarray(ov.rings[0]) if ov.rings else None
    return route_pairs(ov.adjacency, ov.distances(), pairs, policy=policy,
                       ring=ring, hop_budget=hop_budget)


# ---------------------------------------------------------------------------
# numpy reference (parity oracle + the service's path-serving router)
# ---------------------------------------------------------------------------

def route_single_host(adj: np.ndarray, key_to_dst: np.ndarray, src: int,
                      dst: int, *, policy: str = "latency",
                      hop_budget: Optional[int] = None
                      ) -> Tuple[List[int], float, int, str]:
    """Greedy-route ONE pair on the host, recording the path.

    Applies bit-for-bit the same float32 next-hop rule as the device scan
    (same scores, same first-min tie break), so the batched router and
    this loop agree exactly on every hop.  Returns ``(path, latency,
    hops, outcome)`` with outcome one of ``"delivered"`` / ``"dead_end"``
    / ``"exhausted"``.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown routing policy {policy!r}; "
                         f"options {POLICIES}")
    adj = np.asarray(adj, np.float32)
    key = np.asarray(key_to_dst, np.float32)
    budget = adj.shape[0] if hop_budget is None else int(hop_budget)
    blocked = np.float32(_BLOCKED)
    cur, lat, hops = int(src), np.float32(0.0), 0
    path = [cur]
    if cur == int(dst):
        return path, float(lat), hops, "delivered"
    for _ in range(budget):
        adjrow = adj[cur]
        edge = (adjrow > 0) & (adjrow < _HALF_INF)
        if policy == "latency":
            score = np.where(edge, adjrow + key, blocked)
            nxt = int(np.argmin(score))
            stuck = float(score[nxt]) >= _HALF_INF
        else:
            score = np.where(edge, key, blocked)
            nxt = int(np.argmin(score))
            stuck = float(score[nxt]) >= float(key[cur])
        if stuck:
            return path, float(lat), hops, "dead_end"
        lat = np.float32(lat + adjrow[nxt])
        hops += 1
        cur = nxt
        path.append(cur)
        if cur == int(dst):
            return path, float(lat), hops, "delivered"
    return path, float(lat), hops, "exhausted"


def route_pairs_host(adj: np.ndarray, dist: Optional[np.ndarray],
                     pairs: np.ndarray, *, policy: str = "latency",
                     ring: Optional[np.ndarray] = None,
                     hop_budget: Optional[int] = None) -> RouteResult:
    """Per-pair host loop over :func:`route_single_host` — the baseline
    the fig19 speedup gate measures and the parity oracle for the
    batched router."""
    adj = np.asarray(adj, np.float32)
    pairs = np.asarray(pairs, np.intp).reshape(-1, 2)
    budget = adj.shape[0] if hop_budget is None else int(hop_budget)
    keys = _keys_for(policy, pairs[:, 1], dist, ring)
    p = pairs.shape[0]
    hops = np.zeros(p, np.int32)
    lat = np.zeros(p, np.float32)
    success = np.zeros(p, bool)
    failed = np.zeros(p, bool)
    for i, (s, d) in enumerate(pairs):
        _, lat_i, hops_i, outcome = route_single_host(
            adj, keys[i], int(s), int(d), policy=policy, hop_budget=budget)
        lat[i], hops[i] = lat_i, hops_i
        success[i] = outcome == "delivered"
        failed[i] = outcome == "dead_end"
    optimum = (latency_keys(dist, pairs[:, 1])[np.arange(p), pairs[:, 0]]
               if dist is not None else np.full(p, np.nan, np.float32))
    return RouteResult(pairs=pairs, hops=hops, latency=lat, success=success,
                       failed=failed, optimum=optimum,
                       stretch=_stretch(lat, success, optimum))
