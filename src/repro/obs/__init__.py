"""repro.obs — zero-dependency observability: metrics, tracing, logging.

The measurement substrate for the whole stack (stdlib only):

  metrics   — process-global thread-safe registry of counters / gauges /
              fixed-bucket histograms (p50/p90/p99 from bucket counts,
              label support, Prometheus text + serde-stamped JSON export)
  tracing   — nestable ``span()`` wall-time timers, the JIT-aware
              ``jit_span()`` (first-call compile vs steady-state execute),
              and ``TimedRLock`` (lock-wait histograms)
  logsetup  — structured one-line ``key=value`` stdlib logging,
              ``REPRO_LOG_LEVEL``-controlled

Consumers: ``repro.service`` (``GET /v1/metrics``, per-endpoint latency,
staleness gauges), ``repro.dynamics`` (event counters, incremental-vs-
rebuild maintenance timing), the jit'd core entry points
(``batcheval.diameters``, ``rollout.rollout_episodes``), and
``benchmarks/common.py`` (the same histogram implementation computes
BENCH JSON percentiles).  ``benchmarks/fig18_obs.py`` gates the whole
layer's overhead at <= 5% of the uninstrumented path.
"""
from .logsetup import configure, get_logger, kv  # noqa: F401
from .metrics import (REGISTRY, Counter, Gauge, Histogram,  # noqa: F401
                      MetricsRegistry, parse_prometheus)
from .tracing import (TimedRLock, current_span, jit_phase,  # noqa: F401
                      jit_span, reset_jit_state, span)

# Pre-registered hierarchical-overlay defaults: present (at zero) in every
# scrape even before ``repro.hier`` is imported, so dashboards and the
# service smoke test can pin panels/assertions on them unconditionally.
# ``repro.hier`` records into these same instruments (idempotent specs).
HIER_CLUSTERS = REGISTRY.gauge(
    "repro_hier_clusters",
    "cluster count of the currently served hierarchical overlay")
HIER_HEADRING_DIAMETER = REGISTRY.gauge(
    "repro_hier_headring_diameter",
    "diameter (ms) of the hierarchical overlay's head ring")
HIER_ROUTE_HOPS = REGISTRY.histogram(
    "repro_hier_route_hops",
    "per-level hop count of delivered hierarchical routes",
    labels=("level",),
    buckets=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128))

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "parse_prometheus", "span", "current_span", "jit_span", "jit_phase",
    "reset_jit_state", "TimedRLock", "configure", "get_logger", "kv",
    "HIER_CLUSTERS", "HIER_HEADRING_DIAMETER", "HIER_ROUTE_HOPS",
]
