"""repro.obs — zero-dependency observability: metrics, tracing, logging.

The measurement substrate for the whole stack (stdlib only):

  metrics   — process-global thread-safe registry of counters / gauges /
              fixed-bucket histograms (p50/p90/p99 from bucket counts,
              label support, Prometheus text + serde-stamped JSON export)
  tracing   — nestable ``span()`` wall-time timers, the JIT-aware
              ``jit_span()`` (first-call compile vs steady-state execute),
              and ``TimedRLock`` (lock-wait histograms)
  logsetup  — structured one-line ``key=value`` stdlib logging,
              ``REPRO_LOG_LEVEL``-controlled

Consumers: ``repro.service`` (``GET /v1/metrics``, per-endpoint latency,
staleness gauges), ``repro.dynamics`` (event counters, incremental-vs-
rebuild maintenance timing), the jit'd core entry points
(``batcheval.diameters``, ``rollout.rollout_episodes``), and
``benchmarks/common.py`` (the same histogram implementation computes
BENCH JSON percentiles).  ``benchmarks/fig18_obs.py`` gates the whole
layer's overhead at <= 5% of the uninstrumented path.
"""
from .logsetup import configure, get_logger, kv  # noqa: F401
from .metrics import (REGISTRY, Counter, Gauge, Histogram,  # noqa: F401
                      MetricsRegistry, parse_prometheus)
from .tracing import (TimedRLock, current_span, jit_phase,  # noqa: F401
                      jit_span, reset_jit_state, span)

__all__ = [
    "REGISTRY", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "parse_prometheus", "span", "current_span", "jit_span", "jit_phase",
    "reset_jit_state", "TimedRLock", "configure", "get_logger", "kv",
]
