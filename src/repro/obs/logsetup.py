"""Structured stdlib logging: one-line ``key=value`` records.

Replaces the bare ``print``/stderr paths in the runtime layers with
``logging`` under the ``repro`` namespace, formatted as greppable
single-line records::

    ts=2026-08-08T12:00:01.123Z level=info logger=repro.service.server \
event=http.request method=GET path=/v1/diameter status=200 ms=1.42

Control the level with ``REPRO_LOG_LEVEL`` (debug/info/warning/error;
default ``warning`` so library use stays quiet, the daemon's ``__main__``
bumps its default to ``info``).  ``configure()`` is idempotent and only
touches the ``repro`` logger — embedding applications keep their root
logging config.

Use :func:`kv` to build the message payload — it quotes values containing
whitespace and renders floats compactly::

    log = get_logger(__name__)
    log.info(kv("reopt.cycle", outcome="swapped", edges=12, ms=34.5))
"""
from __future__ import annotations

import logging
import os
import sys
import time
from typing import Optional

__all__ = ["configure", "get_logger", "kv", "ENV_LEVEL"]

ENV_LEVEL = "REPRO_LOG_LEVEL"
_ROOT = "repro"
_configured = False


def kv(event: str, **fields) -> str:
    """``event=<event> k=v ...`` with minimal quoting."""
    parts = [f"event={_quote(event)}"]
    for k, v in fields.items():
        parts.append(f"{k}={_quote(v)}")
    return " ".join(parts)


def _quote(v) -> str:
    if isinstance(v, float):
        s = f"{v:.6g}"
    elif isinstance(v, bool):
        s = str(v).lower()
    else:
        s = str(v)
    if any(c in s for c in ' "=\n') or s == "":
        s = '"' + s.replace("\\", "\\\\").replace('"', '\\"') \
                   .replace("\n", "\\n") + '"'
    return s


class KVFormatter(logging.Formatter):
    """``ts=<iso8601Z> level=<lvl> logger=<name> <message>``."""

    converter = time.gmtime

    def format(self, record: logging.LogRecord) -> str:
        ts = time.strftime("%Y-%m-%dT%H:%M:%S", self.converter(record.created))
        ms = int(record.msecs)
        head = (f"ts={ts}.{ms:03d}Z level={record.levelname.lower()} "
                f"logger={record.name}")
        msg = record.getMessage()
        line = f"{head} {msg}" if msg else head
        if record.exc_info:
            line += " exc=" + _quote(self.formatException(record.exc_info))
        return line


def _level_from_env(default: str) -> int:
    name = os.environ.get(ENV_LEVEL, default).strip().upper()
    level = logging.getLevelName(name)
    if not isinstance(level, int):
        return logging.getLevelName(default.upper())
    return level


def configure(default: str = "warning", *, stream=None,
              force: bool = False) -> logging.Logger:
    """Install the kv handler on the ``repro`` logger (idempotent).

    ``REPRO_LOG_LEVEL`` overrides ``default``; ``force=True`` reinstalls
    (tests changing the env var mid-process).
    """
    global _configured
    root = logging.getLogger(_ROOT)
    if _configured and not force:
        return root
    for h in list(root.handlers):
        root.removeHandler(h)
    handler = logging.StreamHandler(stream or sys.stderr)
    handler.setFormatter(KVFormatter())
    root.addHandler(handler)
    root.setLevel(_level_from_env(default))
    root.propagate = False
    _configured = True
    return root


def get_logger(name: Optional[str] = None) -> logging.Logger:
    """Logger under the ``repro`` namespace, configuring on first use."""
    configure()
    if not name or name == _ROOT:
        return logging.getLogger(_ROOT)
    if not name.startswith(_ROOT + ".") :
        name = f"{_ROOT}.{name}"
    return logging.getLogger(name)
