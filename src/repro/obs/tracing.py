"""Span timers and JIT-aware timing over the global metrics registry.

Three tools, all ``time.perf_counter``-based and thread-safe:

* :func:`span` — nestable wall-time spans recorded into the
  ``repro_span_seconds{span=...}`` histogram (plus a ``repro_spans_total``
  counter), with a thread-local stack so nested spans know their parent
  (``current_span()``); the re-optimizer's capture/optimize/commit phases
  and the snapshot writer use these.

* :func:`jit_span` — the JIT-aware variant for jit'd entry points
  (``batcheval.diameters``, ``rollout.rollout_episodes``, the incremental
  relax/join/rebuild updates).  jax compiles on first call per
  (function, static-shape) combination, so a naive histogram mixes
  multi-second compiles into the microsecond steady state.  ``jit_span``
  keys each timing by ``(name, key)`` — pass the shape/static-arg tuple as
  ``key`` — and routes the FIRST observation per key into
  ``repro_jit_compile_seconds{fn=...}`` and every later one into
  ``repro_jit_execute_seconds{fn=...}``.

* :class:`TimedRLock` — an RLock whose *acquisition wait* is observed into
  a histogram (re-entrant acquisitions are not recorded: the owner never
  waits).  ``ServiceState.lock`` is one of these, so lock contention
  between the HTTP handler threads and the re-optimizer is measurable.
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Tuple

from .metrics import LATENCY_BUCKETS_S, REGISTRY, Histogram, MetricsRegistry

__all__ = ["span", "current_span", "jit_span", "jit_phase",
           "reset_jit_state", "TimedRLock"]

_local = threading.local()

# spans can be long (an exact-diameter refresh, a DQN reopt): stretch the
# default bucket range upward
SPAN_BUCKETS_S: Tuple[float, ...] = (
    .0005, .001, .0025, .005, .01, .025, .05, .1, .25, .5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def _span_instruments(registry: MetricsRegistry):
    return (registry.histogram(
                "repro_span_seconds", "wall time per named span",
                labels=("span",), buckets=SPAN_BUCKETS_S),
            registry.counter(
                "repro_spans_total", "completed spans", labels=("span",)))


# instruments on the default registry are resolved ONCE at import — span()
# and jit_span() sit on ingest/relax hot paths, and re-resolving through the
# registry lock per call is measurable (the fig18 overhead gate)
_DEFAULT_SPAN = _span_instruments(REGISTRY)


def _jit_instruments(registry: MetricsRegistry):
    return (registry.histogram(
                "repro_jit_compile_seconds",
                "first-call (traced+compiled) time per jit entry point",
                labels=("fn",), buckets=SPAN_BUCKETS_S),
            registry.histogram(
                "repro_jit_execute_seconds",
                "steady-state execute time per jit entry point",
                labels=("fn",), buckets=SPAN_BUCKETS_S))


_DEFAULT_JIT = _jit_instruments(REGISTRY)


def _stack() -> list:
    st = getattr(_local, "spans", None)
    if st is None:
        st = _local.spans = []
    return st


def current_span() -> Optional[str]:
    """Name of the innermost active span on this thread, or None."""
    st = _stack()
    return st[-1] if st else None


# labelled-child handles resolved once per span/fn name on the default
# registry — plain dict reads, no locks, on the hot path (racy writes are
# harmless: labels() dedupes children under the instrument lock)
_span_children: dict = {}
_jit_children: dict = {}


class span:
    """``with span("reopt.capture"): ...`` — record the block's wall time.

    Nesting is explicit: each span records its own duration under its own
    name (inclusive of children), and ``current_span()`` exposes the
    innermost name while inside the block.  A class-based context manager
    (not ``@contextmanager``): span sits on ingest/relax hot paths and the
    generator protocol alone costs more than the two clock reads.
    """

    __slots__ = ("_name", "_registry", "_hist", "_ctr", "_t0", "_on")

    def __init__(self, name: str, *, registry: MetricsRegistry = REGISTRY):
        self._name = name
        self._registry = registry

    def __enter__(self) -> "span":
        reg = self._registry
        if not reg.enabled:          # disabled: no clock reads, no lookups
            self._on = False
            return self
        self._on = True
        if reg is REGISTRY:
            pair = _span_children.get(self._name)
            if pair is None:
                hist, ctr = _DEFAULT_SPAN
                pair = (hist.labels(span=self._name),
                        ctr.labels(span=self._name))
                _span_children[self._name] = pair
        else:
            hist, ctr = _span_instruments(reg)
            pair = (hist.labels(span=self._name), ctr.labels(span=self._name))
        self._hist, self._ctr = pair
        _stack().append(self._name)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        if self._on:
            dt = time.perf_counter() - self._t0
            _stack().pop()
            self._hist.observe(dt)
            self._ctr.inc()
        return False


# ---------------------------------------------------------------------------
# JIT-aware timing
# ---------------------------------------------------------------------------

_jit_lock = threading.Lock()
_jit_seen: set = set()


def reset_jit_state() -> None:
    """Forget which (name, key) combinations have been seen (tests)."""
    with _jit_lock:
        _jit_seen.clear()


def _is_first(name: str, key) -> bool:
    k = (name, key)
    if k in _jit_seen:               # lock-free steady state (atomic read)
        return False
    with _jit_lock:
        if k in _jit_seen:
            return False
        _jit_seen.add(k)
        return True


def jit_phase(name: str, key=None) -> str:
    """Compile/execute split for callers that time a jit'd call themselves.

    Returns ``"compile"`` on the first call per (name, key) and
    ``"execute"`` after — the same split ``jit_span`` applies, exposed as
    a label value for code that observes its own histogram (e.g. the
    ``repro_apsp_seconds{method, phase}`` engine timings in
    ``core.batcheval``).  Shares ``reset_jit_state()`` with ``jit_span``.
    """
    return "compile" if _is_first(name, key) else "execute"


class jit_span:
    """Time a jit'd call, separating first-call compile from steady state.

    ``key`` should capture whatever triggers retracing (shapes, static
    args); the first observation per (name, key) lands in
    ``repro_jit_compile_seconds``, the rest in
    ``repro_jit_execute_seconds``.
    """

    __slots__ = ("_name", "_key", "_registry", "_hist", "_t0", "_on")

    def __init__(self, name: str, key=None, *,
                 registry: MetricsRegistry = REGISTRY):
        self._name = name
        self._key = key
        self._registry = registry

    def __enter__(self) -> "jit_span":
        reg = self._registry
        if not reg.enabled:          # disabled: don't even consume "first"
            self._on = False
            return self
        self._on = True
        first = _is_first(self._name, self._key)
        if reg is REGISTRY:
            ck = (first, self._name)
            h = _jit_children.get(ck)
            if h is None:
                h = _DEFAULT_JIT[0 if first else 1].labels(fn=self._name)
                _jit_children[ck] = h
        else:
            compile_h, execute_h = _jit_instruments(reg)
            h = (compile_h if first else execute_h).labels(fn=self._name)
        self._hist = h
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        if self._on:
            self._hist.observe(time.perf_counter() - self._t0)
        return False


# ---------------------------------------------------------------------------
# lock-wait measurement
# ---------------------------------------------------------------------------

class TimedRLock:
    """Drop-in re-entrant lock recording acquisition *wait* time.

    Only top-level acquisitions are observed — a re-entrant acquire by the
    owning thread never blocks, and recording it would drown the histogram
    in zeros.  API-compatible with ``threading.RLock`` for ``with``-block
    and ``acquire``/``release`` use.
    """

    def __init__(self, histogram: Optional[Histogram] = None, *,
                 registry: MetricsRegistry = REGISTRY,
                 name: str = "repro_lock_wait_seconds",
                 help: str = "time spent waiting to acquire a shared lock"):
        self._lock = threading.RLock()
        self._hist = histogram if histogram is not None else \
            registry.histogram(name, help, buckets=LATENCY_BUCKETS_S)
        self._owner: Optional[int] = None
        self._depth = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        me = threading.get_ident()
        if self._owner == me:                  # re-entrant: no wait
            ok = self._lock.acquire(blocking, timeout)
            if ok:
                self._depth += 1
            return ok
        t0 = time.perf_counter()
        ok = self._lock.acquire(blocking, timeout)
        if ok:
            self._hist.observe(time.perf_counter() - t0)
            self._owner = me
            self._depth = 1
        return ok

    def release(self) -> None:
        if self._owner != threading.get_ident():
            raise RuntimeError("cannot release un-acquired TimedRLock")
        self._depth -= 1
        if self._depth == 0:
            self._owner = None
        self._lock.release()

    def __enter__(self) -> "TimedRLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()
