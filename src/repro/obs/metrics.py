"""Process-global metrics: counters, gauges, fixed-bucket histograms.

Zero-dependency (stdlib only) instrumentation shared by every runtime
layer — the service daemon, the churn engine, the jit'd core entry points,
and the benchmarks all record into the same instruments, so a live
``GET /v1/metrics`` scrape and a ``BENCH_*.json`` artifact are computed by
exactly one implementation.

Design:

* one process-global :data:`REGISTRY` (a :class:`MetricsRegistry`); unit
  tests and A/B benchmarks construct private registries instead;
* registration is **idempotent for identical specs** (module reloads in
  tests re-register safely) and **raises for conflicting specs** — the same
  name with a different type, help, label set, or bucket layout is a
  programming error surfaced at registration time, not at scrape time;
* instruments are thread-safe (one lock per instrument; N threads
  incrementing a counter sum exactly) and cheap when the registry is
  disabled (``set_enabled(False)`` turns every record into one boolean
  check — the fig18 benchmark gates the enabled path within 5% of this);
* histograms use **fixed cumulative buckets**: p50/p90/p99 are estimated
  from bucket counts by linear interpolation, so the error is bounded by
  the width of the containing bucket (property-tested against numpy
  percentiles);
* exports: :meth:`MetricsRegistry.render_prometheus` (text exposition
  format, served by ``GET /v1/metrics``) and
  :meth:`MetricsRegistry.render_json` (``repro.serde`` schema-stamped).

Naming follows Prometheus conventions: counters end in ``_total``,
timings are ``*_seconds`` histograms, gauges are instantaneous values.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro import serde

__all__ = [
    "DEFAULT_BUCKETS",
    "LATENCY_BUCKETS_S",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "parse_prometheus",
]

# the Prometheus client default buckets (seconds): sub-ms to 10s
DEFAULT_BUCKETS: Tuple[float, ...] = (
    .005, .01, .025, .05, .1, .25, .5, 1.0, 2.5, 5.0, 10.0)

# finer low end for loopback request / lock-wait latencies (seconds)
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    .0001, .00025, .0005, .001, .0025, .005, .01, .025, .05, .1,
    .25, .5, 1.0, 2.5, 5.0)

_RESERVED_LABELS = ("le",)


def _escape(value: str) -> str:
    return (str(value).replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer():
        return str(int(v))
    return repr(float(v))


class _Instrument:
    """Shared parent/child machinery for labelled instruments.

    An instrument with ``label_names`` is a *family*: ``labels(...)`` binds
    one value per label name and returns (creating on first use) the child
    holding the actual series.  Label-less instruments are their own child.
    """

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 label_names: Sequence[str] = (), *,
                 registry: Optional["MetricsRegistry"] = None):
        for ln in label_names:
            if ln in _RESERVED_LABELS:
                raise ValueError(f"label name {ln!r} is reserved")
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._registry = registry
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], "_Instrument"] = {}
        if not self.label_names:
            self._children[()] = self

    @property
    def spec(self) -> Tuple:
        return (self.kind, self.name, self.help, self.label_names,
                getattr(self, "buckets", None))

    @property
    def _enabled(self) -> bool:
        return self._registry is None or self._registry.enabled

    def labels(self, *values, **kv) -> "_Instrument":
        if kv:
            if values:
                raise ValueError("pass label values positionally OR by name")
            try:
                values = tuple(kv[n] for n in self.label_names)
            except KeyError as e:
                raise ValueError(
                    f"{self.name} labels are {self.label_names}") from e
        values = tuple(str(v) for v in values)
        if len(values) != len(self.label_names):
            raise ValueError(
                f"{self.name} needs {len(self.label_names)} label values "
                f"{self.label_names}, got {values}")
        with self._lock:
            child = self._children.get(values)
            if child is None:
                child = self._make_child()
                self._children[values] = child
            return child

    def _make_child(self) -> "_Instrument":
        raise NotImplementedError

    def _series(self) -> List[Tuple[Tuple[str, ...], "_Instrument"]]:
        with self._lock:
            return sorted(self._children.items())

    def _label_str(self, values: Tuple[str, ...],
                   extra: str = "") -> str:
        parts = [f'{n}="{_escape(v)}"'
                 for n, v in zip(self.label_names, values)]
        if extra:
            parts.append(extra)
        return "{" + ",".join(parts) + "}" if parts else ""

    def render(self) -> List[str]:
        raise NotImplementedError


class Counter(_Instrument):
    """Monotonically increasing count (events ingested, requests served)."""

    kind = "counter"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._value = 0.0

    def _make_child(self) -> "Counter":
        c = Counter(self.name, self.help, registry=self._registry)
        return c

    def inc(self, amount: float = 1.0) -> None:
        if not self._enabled:
            return
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} counter"]
        for values, child in self._series():
            lines.append(f"{self.name}{self._label_str(values)} "
                         f"{_fmt(child.value)}")
        return lines


class Gauge(_Instrument):
    """Instantaneous value; settable, or computed by a callback at scrape
    time (``set_function``) for values derived from live state."""

    kind = "gauge"

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._value = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def _make_child(self) -> "Gauge":
        return Gauge(self.name, self.help, registry=self._registry)

    def set(self, value: float) -> None:
        if not self._enabled:
            return
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        if not self._enabled:
            return
        with self._lock:
            self._value += amount

    def set_function(self, fn: Callable[[], float]) -> None:
        """Compute the value at scrape time (e.g. snapshot age, uptime).
        The callback runs OUTSIDE instrument/registry locks, so it may take
        its own locks (``ServiceState.lock``) without deadlock risk."""
        self._fn = fn

    @property
    def value(self) -> float:
        fn = self._fn
        if fn is not None:
            return float(fn())
        with self._lock:
            return self._value

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} gauge"]
        for values, child in self._series():
            lines.append(f"{self.name}{self._label_str(values)} "
                         f"{_fmt(child.value)}")
        return lines


class Histogram(_Instrument):
    """Fixed-bucket histogram with quantile estimation from bucket counts.

    ``buckets`` are ascending upper bounds; an implicit +Inf bucket catches
    the overflow.  ``quantile(q)`` linearly interpolates inside the
    containing bucket, clamped to the observed min/max, so the estimate is
    never further from the true sample quantile than the containing
    bucket's width.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 label_names: Sequence[str] = (), *,
                 buckets: Sequence[float] = DEFAULT_BUCKETS,
                 registry: Optional["MetricsRegistry"] = None):
        bkts = tuple(float(b) for b in buckets)
        if not bkts or list(bkts) != sorted(set(bkts)):
            raise ValueError(f"buckets must be ascending and unique: {bkts}")
        self.buckets = bkts
        super().__init__(name, help, label_names, registry=registry)
        self._counts = [0] * (len(bkts) + 1)
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def _make_child(self) -> "Histogram":
        return Histogram(self.name, self.help, buckets=self.buckets,
                         registry=self._registry)

    def observe(self, value: float) -> None:
        if not self._enabled:
            return
        value = float(value)
        i = bisect.bisect_left(self.buckets, value)
        with self._lock:
            self._counts[i] += 1
            self._sum += value
            self._min = min(self._min, value)
            self._max = max(self._max, value)

    # -- reads -------------------------------------------------------------

    @property
    def count(self) -> int:
        with self._lock:
            return sum(self._counts)

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]) from bucket counts; NaN when
        empty.  Within the containing bucket the mass is assumed uniform."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        with self._lock:
            counts = list(self._counts)
            total = sum(counts)
            lo_obs, hi_obs = self._min, self._max
        if total == 0:
            return float("nan")
        target = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if cum + c >= target and c > 0:
                lo = self.buckets[i - 1] if i > 0 else min(lo_obs, self.buckets[0])
                hi = self.buckets[i] if i < len(self.buckets) else hi_obs
                lo = max(lo, lo_obs) if i == 0 else lo
                frac = (target - cum) / c
                est = lo + (hi - lo) * frac
                return float(min(max(est, lo_obs), hi_obs))
            cum += c
        return float(hi_obs)

    def summary(self) -> Dict[str, float]:
        """p50/p90/p99 + count/sum — the shape BENCH JSON artifacts embed."""
        return {"count": self.count, "sum": self.sum,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}

    def render(self) -> List[str]:
        lines = [f"# HELP {self.name} {self.help}",
                 f"# TYPE {self.name} histogram"]
        for values, child in self._series():
            with child._lock:
                counts = list(child._counts)
                total = sum(counts)
                s = child._sum
            cum = 0
            for bound, c in zip(list(self.buckets) + [math.inf],
                                counts):
                cum += c
                le = self._label_str(values, f'le="{_fmt(bound)}"')
                lines.append(f"{self.name}_bucket{le} {cum}")
            lines.append(f"{self.name}_sum{self._label_str(values)} "
                         f"{repr(float(s))}")
            lines.append(f"{self.name}_count{self._label_str(values)} "
                         f"{total}")
        return lines


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Thread-safe name -> instrument map with idempotent registration."""

    def __init__(self, enabled: bool = True):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Instrument] = {}
        self.enabled = enabled

    def set_enabled(self, enabled: bool) -> None:
        """Globally arm/disarm every instrument in this registry (records
        become one-boolean-check no-ops).  The fig18 gate measures exactly
        this toggle's cost."""
        self.enabled = bool(enabled)

    # -- registration ------------------------------------------------------

    def _register(self, kind: str, name: str, help: str,
                  label_names: Sequence[str],
                  buckets: Optional[Sequence[float]]) -> _Instrument:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                kw = {"buckets": tuple(float(b) for b in buckets)} \
                    if kind == "histogram" else {}
                want = (kind, name, help, tuple(label_names),
                        kw.get("buckets"))
                if existing.spec != want:
                    raise ValueError(
                        f"metric {name!r} already registered with spec "
                        f"{existing.spec}, conflicting re-registration "
                        f"{want}")
                return existing
            cls = _KINDS[kind]
            kw = {"buckets": buckets} if (kind == "histogram"
                                          and buckets is not None) else {}
            inst = cls(name, help, label_names, registry=self, **kw)
            self._metrics[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._register("counter", name, help, labels, None)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._register("gauge", name, help, labels, None)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (), *,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._register("histogram", name, help, labels, buckets)

    def get(self, name: str) -> Optional[_Instrument]:
        with self._lock:
            return self._metrics.get(name)

    def unregister(self, name: str) -> None:
        """Drop one instrument (tests re-registering with new specs)."""
        with self._lock:
            self._metrics.pop(name, None)

    # -- export ------------------------------------------------------------

    def _snapshot(self) -> List[_Instrument]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def render_prometheus(self) -> str:
        """Text exposition format (the ``GET /v1/metrics`` body).  Renders
        from a snapshot of the instrument list, never under the registry
        lock, so scrapes proceed during registration and callbacks may
        take their own locks."""
        lines: List[str] = []
        for inst in self._snapshot():
            lines.extend(inst.render())
        return "\n".join(lines) + "\n"

    def collect(self) -> Dict[str, Dict]:
        """Plain-dict view: {name: {kind, help, series: [{labels, ...}]}}."""
        out: Dict[str, Dict] = {}
        for inst in self._snapshot():
            series = []
            for values, child in inst._series():
                labels = dict(zip(inst.label_names, values))
                if inst.kind == "histogram":
                    series.append({"labels": labels, **child.summary()})
                else:
                    series.append({"labels": labels, "value": child.value})
            out[inst.name] = {"kind": inst.kind, "help": inst.help,
                              "series": series}
        return out

    def render_json(self) -> str:
        """``repro.serde`` schema-stamped JSON export of :meth:`collect`."""
        return serde.dumps({"kind": "metrics", "metrics": self.collect()})


#: the process-global default registry every layer records into
REGISTRY = MetricsRegistry()


def parse_prometheus(text: str) -> Dict[str, Dict[Tuple[Tuple[str, str], ...], float]]:
    """Parse exposition text back to ``{series_name: {labels: value}}``.

    Labels are sorted ``(name, value)`` tuples (hashable keys).  Used by
    the fig18 gate, the CI service smoke, and the scrape tests to assert
    that served metrics match ground truth.
    """
    out: Dict[str, Dict[Tuple[Tuple[str, str], ...], float]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        name_part, _, value_part = line.rpartition(" ")
        if "{" in name_part:
            name, _, rest = name_part.partition("{")
            raw = rest.rstrip("}")
            labels = []
            for item in filter(None, _split_labels(raw)):
                k, _, v = item.partition("=")
                labels.append((k, v.strip('"').replace(r'\"', '"')
                               .replace(r"\n", "\n").replace(r"\\", "\\")))
            key = tuple(sorted(labels))
        else:
            name, key = name_part, ()
        value = math.inf if value_part == "+Inf" else float(value_part)
        out.setdefault(name, {})[key] = value
    return out


def _split_labels(raw: str) -> List[str]:
    """Split ``a="x",b="y,z"`` on commas outside quotes."""
    parts, buf, in_q, prev = [], [], False, ""
    for ch in raw:
        if ch == '"' and prev != "\\":
            in_q = not in_q
        if ch == "," and not in_q:
            parts.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
        prev = ch
    if buf:
        parts.append("".join(buf))
    return parts
