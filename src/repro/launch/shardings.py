"""Parameter/activation/cache PartitionSpec rules for the production mesh.

Name-based rules map every leaf of the model pytree to a PartitionSpec:
tensor-parallel over ``model`` (heads / ff / vocab / experts / d_inner),
batch over the data axes, ZeRO over data for optimizer moments.  Every rule
is divisibility-guarded: a dim that doesn't divide by its mesh axis falls
back to replicated (never a compile error).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# leaf-name -> UNSTACKED dim index sharded over "model" (leaves under
# "blocks" carry a leading block-stack dim; index is offset by 1 there).
# Megatron convention: column-parallel in-projections shard dim 1 (output
# features); row-parallel out-projections shard dim 0 (input features).
_NAME_RULES = {
    # attention / dense mlp
    "wq": 1, "wk": 1, "wv": 1, "wo": 0,
    "bq": 0, "bk": 0, "bv": 0,
    "w_gate": 1, "w_up": 1, "w_down": 0,
    "embed": 0,                        # vocab-sharded embedding (V, d)
    "lm_head": 1,
    "vision_proj": 1,
    # mamba1 / mamba2: d_inner (or ssm-heads) sharded
    "wx": 1, "wz": 1, "wdt": 1,
    "w_dt": 0, "w_b": 0, "w_c": 0,
    "dt_w": 1, "dt_b": 0,
    "out_proj": 0,
    "A_log": 0,                        # mamba1 (di, N) / mamba2 (nh,)
    "D": 0,
    "gate_norm": 0,
    "conv_w": 1,                       # (K, C) depthwise conv, channel-sharded
    "conv_b": 0,
}


def _leaf_name(path) -> str:
    last = path[-1]
    return getattr(last, "key", str(last))


def _in_moe(path) -> bool:
    return any(getattr(k, "key", None) == "moe" for k in path)


def _stacked(path) -> bool:
    return any(getattr(k, "key", None) == "blocks" for k in path)


def spec_for_param(path, shape: Tuple[int, ...], mesh: Mesh,
                   model_axis: str = "model") -> P:
    rank = len(shape)
    spec = [None] * rank
    name = _leaf_name(path)
    base = 1 if _stacked(path) else 0

    def try_set(d: int, axis: str):
        if d < rank and shape[d] % mesh.shape[axis] == 0 \
                and shape[d] >= mesh.shape[axis]:
            spec[d] = axis

    if _in_moe(path) and name in ("w_gate", "w_up", "w_down"):
        try_set(base + 0, model_axis)      # shard the EXPERT dim (EP)
        return P(*spec)
    dim = _NAME_RULES.get(name)
    if dim is not None:
        try_set(base + dim, model_axis)
    return P(*spec)


def param_specs(params_shapes: PyTree, mesh: Mesh,
                mode: str = "tp") -> PyTree:
    """PartitionSpec pytree for a params (shape) pytree.

    mode="tp":   Megatron tensor parallel over the model axis (default).
    mode="fsdp": weights ZeRO-3-sharded over the model axis on their first
                 divisible dim; batch additionally shards over model.
                 (REFUTED for gemma3-1b in §Perf: the partitioner resolves
                 the contracting-dim/batch axis conflict by replicating
                 compute — kept for the record.)
    mode="dp":   pure data parallel: weights REPLICATED, batch over
                 data+model, optimizer moments ZeRO-sharded (small-model
                 regime: a 1B model's 2 GB of bf16 weights replicate
                 cheaply and the only collective is the grad all-reduce —
                 §Perf hillclimb B iteration 2).
    """
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    out = []
    for path, leaf in leaves:
        if mode == "fsdp":
            out.append(zero_spec(P(), tuple(leaf.shape), mesh, ("model",)))
        elif mode == "dp":
            out.append(P(*([None] * len(leaf.shape))))
        else:
            out.append(spec_for_param(path, tuple(leaf.shape), mesh))
    return jax.tree_util.tree_unflatten(treedef, out)


def zero_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh,
              data_axes: Sequence[str]) -> P:
    """ZeRO: additionally shard the first replicated dim over the data axes
    (applied to optimizer moments; optionally to params for full FSDP)."""
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (cur, dim) in enumerate(zip(parts, shape)):
        if cur is None and dim % dsize == 0 and dim >= dsize:
            parts[i] = tuple(data_axes)
            break
    return P(*parts)


def zero3_param_specs(params_shapes: PyTree, mesh: Mesh,
                      data_axes: Sequence[str]) -> PyTree:
    """ZeRO-3: TP specs PLUS data-axis sharding of each leaf's first free
    dim — params live fully sharded; XLA all-gathers each block's weights
    at use inside the layer scan (MaxText-style fsdp)."""
    base = param_specs(params_shapes, mesh, mode="tp")
    leaves, td = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = jax.tree.leaves(base)
    out = [zero_spec(s, tuple(l.shape), mesh, data_axes)
           for (p, l), s in zip(leaves, specs)]
    return jax.tree_util.tree_unflatten(td, out)


def state_specs(state_shapes: PyTree, mesh: Mesh,
                data_axes: Sequence[str] = ("data",),
                zero: bool = True, mode: str = "tp",
                zero3: bool = False) -> PyTree:
    """Specs for a TrainState(params, opt(step, mu, nu)) shape pytree."""
    from repro.train.train_step import TrainState
    from repro.train.optimizer import AdamWState

    if zero3:
        pspecs = zero3_param_specs(state_shapes.params, mesh, data_axes)
    else:
        pspecs = param_specs(state_shapes.params, mesh, mode=mode)

    def moment_specs(shapes):
        leaves, td = jax.tree_util.tree_flatten_with_path(shapes)
        base = jax.tree.leaves(param_specs(shapes, mesh, mode=mode))
        out = []
        for (path, leaf), sp in zip(leaves, base):
            out.append(zero_spec(sp, tuple(leaf.shape), mesh, data_axes)
                       if zero else sp)
        return jax.tree_util.tree_unflatten(td, out)

    opt = AdamWState(step=P(), mu=moment_specs(state_shapes.opt.mu),
                     nu=moment_specs(state_shapes.opt.nu))
    return TrainState(params=pspecs, opt=opt)


def cache_specs(cache_shapes: PyTree, mesh: Mesh, batch: int,
                data_axes: Sequence[str] = ("data",),
                model_axis: str = "model") -> PyTree:
    """KV/SSM cache specs.  Layout (maybe-stacked over blocks):
    k/v: (L?, B, Hkv, S, hd);  conv: (L?, B, K, C);  h(m1): (L?, B, di, N);
    h(m2): (L?, B, nh, hd, N).  Batch over data when divisible (long_500k has
    B=1 -> replicated), heads/channels over model when divisible."""
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    msize = mesh.shape[model_axis]
    dp = tuple(data_axes)

    def one(path, leaf):
        shape = leaf.shape
        rank = len(shape)
        name = jax.tree_util.keystr(path)
        stacked = rank >= 1 and "blocks" in name
        base = 1 if stacked else 0
        spec = [None] * rank
        if shape[base] % dsize == 0 and shape[base] >= dsize:
            spec[base] = dp

        def fits(d):
            return d < rank and shape[d] % msize == 0 and shape[d] >= msize

        if re.search(r"\[.(k|v).\]$", name) or rank - base == 4:
            # KV cache (B, Hkv, S, hd): heads over model when divisible;
            # otherwise shard the SEQUENCE dim (MHA archs like qwen kv=20,
            # GQA kv=8 on a 16-way model axis) — attention softmax/psum
            # partitions cleanly over kv-seq, and the cache is the dominant
            # decode buffer (17TB for qwen decode_32k unsharded).
            if fits(base + 1):
                spec[base + 1] = model_axis
            elif fits(base + 2):
                spec[base + 2] = model_axis
        elif re.search(r"\bconv\]?$", name):
            if shape[-1] % msize == 0:
                spec[-1] = model_axis
        elif re.search(r"\bh\]?$", name):
            if fits(base + 1):
                spec[base + 1] = model_axis
            elif fits(base + 2):
                spec[base + 2] = model_axis
        return P(*spec)

    leaves, td = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(td, [one(p, l) for p, l in leaves])


def batch_specs(batch_shapes: PyTree, mesh: Mesh,
                data_axes: Sequence[str] = ("data",)) -> PyTree:
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))

    def one(leaf):
        if leaf.shape and leaf.shape[0] % dsize == 0 and leaf.shape[0] >= dsize:
            return P(tuple(data_axes), *([None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(one, batch_shapes)


def to_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
