"""Production mesh construction, with DGRO-optimized device ordering.

``make_production_mesh`` builds the assignment's meshes:
  * single-pod: (16, 16) over ("data", "model") — 256 chips;
  * multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips.

**DGRO integration (the paper's technique as a first-class feature).**  The
axes that cross hosts/pods (``pod`` and the host-level fraction of ``data``)
run their ring-reduce collectives and the gossip membership plane over DCN,
where the hop order is software-chosen.  ``dgro_host_order`` optimizes that
order: given a host-to-host latency matrix (measured via Alg. 3's gossip
sampling in production; modeled here), it applies the paper's §V selection
(rho -> random vs nearest ring; DQN ordering available via
``repro.core.qlearning`` for small fleets) and returns the host permutation
that minimizes ring diameter.  ``make_production_mesh(dgro_order=True)``
permutes the devices of the DCN-facing axes accordingly, leaving the
intra-pod ICI order untouched (fixed torus — DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

import jax

from repro.compat import make_mesh, mesh_from_devices
from repro.core.construction import nearest_ring, random_ring
from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.core.selection import (clustering_ratio, measure_latency_stats,
                                  select_ring_kind)


def dgro_host_order(latency: np.ndarray, seed: int = 0,
                    eps: float = 0.3) -> Tuple[np.ndarray, dict]:
    """DGRO ring order for ``n`` hosts given a latency matrix.

    Applies the paper's adaptive selection: measure rho on a probe (random)
    ring; if the latency field is informative (rho high) use the nearest
    ring, otherwise keep the random ring.  Returns (order, report)."""
    n = latency.shape[0]
    rng = np.random.default_rng(seed)
    probe = random_ring(rng, n)
    adj = adjacency_from_rings(latency, [probe])
    stats = measure_latency_stats(latency, adj, seed=seed)
    rho = clustering_ratio(stats)
    kind = select_ring_kind(rho, eps)
    candidates = {"random": probe}
    if kind in ("nearest", "keep"):
        candidates["nearest"] = nearest_ring(latency, start=0)
    best_kind, best_order, best_diam = None, None, float("inf")
    for k, order in candidates.items():
        d = diameter_scipy(adjacency_from_rings(latency, [order]))
        if d < best_diam:
            best_kind, best_order, best_diam = k, order, d
    report = {
        "rho": rho, "selected": best_kind, "diameter": best_diam,
        "random_diameter": diameter_scipy(adjacency_from_rings(latency, [probe])),
    }
    return best_order, report


def model_dcn_latency(n_hosts: int, n_pods: int = 1, seed: int = 0) -> np.ndarray:
    """Synthetic DCN host latency model: intra-pod ~10us, cross-pod ~80us,
    plus per-host jitter — the stand-in for Alg. 3 measurements on CPU."""
    rng = np.random.default_rng(seed)
    pod_of = np.arange(n_hosts) // max(1, n_hosts // n_pods)
    base = np.where(pod_of[:, None] == pod_of[None, :], 10.0, 80.0)
    jitter = rng.gamma(2.0, 1.5, size=(n_hosts, n_hosts))
    lat = np.triu(base + jitter, 1)
    lat = lat + lat.T
    np.fill_diagonal(lat, 0.0)
    return lat.astype(np.float32)


def make_eval_mesh(n: Optional[int] = None, axis: str = "batch"):
    """1D mesh over the local devices for sharded bulk evaluation.

    The batch-evaluation counterpart of ``make_production_mesh``: candidate
    scoring has no model axis, so ``batcheval.diameters_sharded`` /
    ``apsp_rowshard`` just want every chip on one named axis.  ``n`` caps
    the device count (tests pin it under
    ``--xla_force_host_platform_device_count``)."""
    devices = jax.devices()
    k = min(n or len(devices), len(devices))
    return make_mesh((k,), (axis,), devices=devices[:k])


def make_production_mesh(*, multi_pod: bool = False, dgro_order: bool = False,
                         latency: Optional[np.ndarray] = None,
                         chips_per_host: int = 4):
    """The assignment's production mesh (optionally DGRO-ordered).

    With ``dgro_order``, hosts (groups of ``chips_per_host`` consecutive
    devices) are permuted along the leading (DCN-facing) axes by the DGRO
    ring; the trailing ``model`` axis stays in hardware order (ICI torus).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if not dgro_order:
        return make_mesh(shape, axes)

    devices = np.asarray(jax.devices())
    n = int(np.prod(shape))
    assert len(devices) >= n, (len(devices), n)
    devices = devices[:n]
    # hosts along the DCN-facing axes: leading dims except the model axis
    n_model = shape[-1]
    n_dcn = n // n_model                       # pod*data groups
    n_hosts = max(1, n_dcn // max(1, chips_per_host // 1))
    hosts = n_dcn                              # treat each data-group as a host
    lat = latency if latency is not None else model_dcn_latency(
        hosts, n_pods=shape[0] if multi_pod else 1)
    order, report = dgro_host_order(lat)
    grid = devices.reshape(n_dcn, n_model)
    grid = grid[order]                         # DGRO permutation of DCN axis
    dev = grid.reshape(shape)
    mesh = mesh_from_devices(dev, axes)
    mesh.dgro_report = report                  # type: ignore[attr-defined]
    return mesh
