import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape x
# mesh) cell against the production mesh, print memory/cost analyses, and
# derive the roofline terms (EXPERIMENTS.md §Dry-run / §Roofline).
#
# The two lines above MUST precede any jax import (including `from repro...`):
# jax locks the device count at first backend initialization.  Run:
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
#         --mesh both --out results/dryrun
#
# Each cell writes one JSON (incrementally — the sweep is resumable).

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, ArchConfig, ShapeConfig, get_arch, \
    shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (batch_specs, cache_specs, param_specs,
                                    state_specs, to_shardings)
from repro.models import model as Mdl
from repro.models.sharding import default_rules, use_rules
from repro.roofline.analysis import (Roofline, active_param_count, model_flops,
                                     roofline_from)
from repro.roofline.hlo_walk import walk
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import TrainConfig, TrainState, train_step

PARAM_DTYPE = jnp.bfloat16
# bf16 moments for the 400B arch: fp32 moments do not fit a single v5e pod
# (DESIGN.md §8 / EXPERIMENTS.md §Dry-run notes)
BF16_MOMENT_ARCHS = {"llama4-maverick-400b-a17b"}
# ZeRO-3 (params sharded over model x data, gathered at use): 400B bf16
# params are 800 GB — 16-way TP alone leaves 50 GB/device resident
ZERO3_ARCHS = {"llama4-maverick-400b-a17b"}


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    has_vision = cfg.frontend == "vision"
    n_text = s - cfg.n_patches if has_vision else s
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sd((b, n_text), jnp.int32),
                 "labels": sd((b, n_text), jnp.int32)}
        if has_vision:
            specs["vision_embeds"] = sd((b, cfg.n_patches, cfg.d_model),
                                        PARAM_DTYPE)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sd((b, n_text), jnp.int32)}
        if has_vision:
            specs["vision_embeds"] = sd((b, cfg.n_patches, cfg.d_model),
                                        PARAM_DTYPE)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"tokens": sd((b, 1), jnp.int32), "pos": sd((), jnp.int32)}


def state_shapes(cfg: ArchConfig, moment_dtype) -> TrainState:
    def mk():
        params = Mdl.init_params(cfg, jax.random.PRNGKey(0), PARAM_DTYPE)
        return TrainState(params=params,
                          opt=adamw_init(params, moment_dtype))
    return jax.eval_shape(mk)


def cache_shapes(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: Mdl.init_caches(cfg, batch, max_len, PARAM_DTYPE))


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------

def make_step(cfg: ArchConfig, shape: ShapeConfig, mesh, data_axes,
              grad_shardings=None, microbatches: int | None = None,
              sharding_mode: str = "tp", ce_chunk: int = 0):
    if sharding_mode in ("fsdp", "dp"):
        # data_axes already includes "model" here (batch spans it)
        from repro.models.sharding import fsdp_rules
        rules = fsdp_rules(data_axes=tuple(a for a in data_axes
                                           if a != "model"), mesh=mesh)
    else:
        rules = default_rules(data_axes=tuple(data_axes), mesh=mesh)
    if microbatches is None:
        # cap ~16k tokens per device per microbatch: bounds the fp32
        # logits/CE working set (vocab/16-sharded) to a few GB at 262k vocab
        dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
        tokens_per_dev = shape.global_batch * shape.seq_len // dsize
        local_batch = max(1, shape.global_batch // dsize)
        microbatches = max(1, min(tokens_per_dev // 16384, local_batch))
        while local_batch % microbatches:
            microbatches -= 1
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-4, weight_decay=0.1),
                     remat=True, microbatches=microbatches,
                     ce_chunk=ce_chunk)

    if shape.kind == "train":
        def step(state, batch):
            with use_rules(rules):
                return train_step(cfg, tc, state, batch, mesh=mesh,
                                  data_axes=tuple(data_axes),
                                  grad_shardings=grad_shardings)
        return step

    if shape.kind == "prefill":
        def step(params, caches, batch):
            with use_rules(rules):
                logits, new_caches, _aux = Mdl.forward(
                    cfg, params, batch["tokens"], mode="prefill",
                    caches=caches, vision_embeds=batch.get("vision_embeds"),
                    mesh=mesh, data_axes=tuple(data_axes))
            return logits, new_caches
        return step

    def step(params, caches, batch):   # decode / serve_step
        with use_rules(rules):
            logits, new_caches = Mdl.forward(
                cfg, params, batch["tokens"], mode="decode", caches=caches,
                pos=batch["pos"], mesh=mesh, data_axes=tuple(data_axes))
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_caches
    return step


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str,
             dgro_order: bool = False, sharding_mode: str = "tp",
             cache_dtype: str = "bf16",
             microbatches: int | None = None,
             hlo_path: str | None = None,
             pod_compress: bool = False,
             ce_chunk: int = 0) -> Dict[str, Any]:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "timestamp": time.time(), "sharding_mode": sharding_mode,
        "cache_dtype": cache_dtype, "_hlo_path": hlo_path,
    }
    if not ok:
        record.update(status="skipped", reason=why)
        return record
    if sharding_mode in ("fsdp", "dp") and cfg.n_experts:
        record.update(status="error",
                      error="fsdp mode not wired for shard_map EP archs")
        return record

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi, dgro_order=dgro_order)
    data_axes = ("pod", "data") if multi else ("data",)
    batch_axes = (data_axes + ("model",)
                  if sharding_mode in ("fsdp", "dp") else data_axes)
    n_chips = int(np.prod(list(mesh.shape.values())))
    moment_dtype = (jnp.bfloat16 if arch in BF16_MOMENT_ARCHS
                    else jnp.float32)
    c_dtype = jnp.float8_e4m3fn if cache_dtype == "fp8" else PARAM_DTYPE

    t0 = time.time()
    specs_in = input_specs(cfg, shape)
    b_specs = batch_specs(specs_in, mesh, batch_axes)
    b_shard = to_shardings(b_specs, mesh)

    if shape.kind == "train":
        st_shapes = state_shapes(cfg, moment_dtype)
        # ZeRO axes: in dp/fsdp regimes the moments shard over data+model
        zero_axes = batch_axes if sharding_mode in ("dp", "fsdp") else data_axes
        st_specs = state_specs(st_shapes, mesh, zero_axes, zero=True,
                               mode=sharding_mode,
                               zero3=arch in ZERO3_ARCHS)
        st_shard = to_shardings(st_specs, mesh)
        # ZeRO-2: gradients take the MOMENT sharding (model x data) — the
        # partitioner then emits reduce-scatter for the grad reduction and
        # the fp32 accumulator is fully sharded (a model-sharded-only 27B
        # fp32 accumulator alone is 6.75 GB/device)
        if pod_compress and multi:
            from repro.train.pod_compress import pod_compressed_train_step
            # inside the manual-pod body only auto axes exist: ZeRO over
            # data, grads pinned to the moment shardings, same adaptive
            # microbatching as the baseline
            st_specs = state_specs(st_shapes, mesh, ("data",), zero=True,
                                   mode=sharding_mode)
            st_shard = to_shardings(st_specs, mesh)
            dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
            tokens_per_dev = shape.global_batch * shape.seq_len // dsize
            local_batch = max(1, shape.global_batch // dsize)
            mb = max(1, min(tokens_per_dev // 16384, local_batch))
            while local_batch % mb:
                mb -= 1
            if microbatches is not None:
                mb = microbatches
            tc = TrainConfig(optimizer=AdamWConfig(lr=3e-4, weight_decay=0.1),
                             remat=True, microbatches=mb)
            # bare-PartitionSpec constraints under an ambient mesh: the
            # NamedSharding form crashes XLA inside the partial-manual
            # region at 512 devices (see §Perf C)
            inner = pod_compressed_train_step(
                cfg, tc, mesh, st_shapes, specs_in, pod_axis="pod",
                inner_data_axes=("data",),
                grad_shardings=None)  # XLA check-fails with constraints
                                      # in partial-manual at 512 dev
            rules = default_rules(data_axes=("data",), mesh=mesh)

            def step(state, batch):
                with use_rules(rules):
                    return inner(state, batch)
            record["pod_compress"] = True
        else:
            step = make_step(cfg, shape, mesh, batch_axes,
                             grad_shardings=st_shard.opt.mu,
                             sharding_mode=sharding_mode,
                             microbatches=microbatches,
                             ce_chunk=ce_chunk)
        fn = jax.jit(step, in_shardings=(st_shard, b_shard),
                     donate_argnums=(0,))
        if record.pop("_ambient_mesh", False):
            with jax.set_mesh(mesh):
                lowered = fn.lower(st_shapes, specs_in)
        else:
            lowered = fn.lower(st_shapes, specs_in)
        n_tokens = shape.global_batch * shape.seq_len
        params_shapes = st_shapes.params
    else:
        step = make_step(cfg, shape, mesh, batch_axes,
                         sharding_mode=sharding_mode)
        params_sh = jax.eval_shape(
            lambda: Mdl.init_params(cfg, jax.random.PRNGKey(0), PARAM_DTYPE))
        if arch in ZERO3_ARCHS:
            from repro.launch.shardings import zero3_param_specs
            p_specs = zero3_param_specs(params_sh, mesh, data_axes)
        else:
            p_specs = param_specs(params_sh, mesh, mode=sharding_mode)
        p_shard = to_shardings(p_specs, mesh)
        c_shapes = jax.eval_shape(
            lambda: Mdl.init_caches(cfg, shape.global_batch, shape.seq_len,
                                    c_dtype))
        c_specs = cache_specs(c_shapes, mesh, shape.global_batch, batch_axes)
        c_shard = to_shardings(c_specs, mesh)
        fn = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard),
                     donate_argnums=(1,))
        lowered = fn.lower(params_sh, c_shapes, specs_in)
        n_tokens = shape.global_batch * (shape.seq_len
                                         if shape.kind == "prefill" else 1)
        params_shapes = params_sh

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    roof = roofline_from(cost, hlo)           # XLA cost_analysis (no trips)
    wk = walk(hlo)                            # trip-count-aware walk

    # archive the compiled HLO so any later analysis can re-derive terms
    import gzip
    hlo_path = record.get("_hlo_path")
    if hlo_path:
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
        record["hlo_gz"] = hlo_path
    record.pop("_hlo_path", None)

    n_active = active_param_count(cfg, params_shapes)
    n_total = sum(int(l.size) for l in jax.tree.leaves(params_shapes))
    mf = model_flops(cfg, n_tokens, n_active)
    if shape.kind != "train":
        mf /= 3.0               # forward only: 2ND

    from repro.roofline.analysis import HBM_BW, ICI_BW, PEAK_FLOPS
    wk_compute = wk.dot_flops / PEAK_FLOPS
    wk_memory = wk.naive_bytes / HBM_BW
    wk_coll = wk.collective_bytes / ICI_BW
    dominant = max((("compute", wk_compute), ("memory", wk_memory),
                    ("collective", wk_coll)), key=lambda kv: kv[1])[0]

    hbm_per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    record.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            "hbm_per_device_bytes": hbm_per_dev,
            "fits_16gb": bool(hbm_per_dev < 16e9),
        },
        # PRIMARY: trip-count-aware HLO walk (lax.scan bodies multiplied)
        roofline={
            "flops": wk.dot_flops,
            "hbm_bytes": wk.naive_bytes,
            "collective_bytes": wk.collective_bytes,
            "compute_s": wk_compute,
            "memory_s": wk_memory,
            "collective_s": wk_coll,
            "dominant": dominant,
            "by_op": wk.collective_by_op,
            "n_while": wk.n_while,
            "max_trip": wk.max_trip,
        },
        # reference: XLA cost_analysis (counts loop bodies once)
        roofline_xla_once=roof.to_dict(),
        model_flops_global=mf,
        hlo_flops_global=wk.dot_flops * n_chips,
        useful_flops_ratio=(mf / (wk.dot_flops * n_chips)
                            if wk.dot_flops else None),
        n_params_total=n_total,
        n_params_active=n_active,
        moment_dtype=str(np.dtype("float32") if moment_dtype == jnp.float32
                         else "bfloat16"),
    )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--dgro-order", action="store_true",
                    help="DGRO-optimized device order for the DCN axes")
    ap.add_argument("--force", action="store_true", help="re-run existing cells")
    ap.add_argument("--sharding", default="tp", choices=["tp", "fsdp", "dp"],
                    help="parallelism regime (fsdp: §Perf hillclimb)")
    ap.add_argument("--cache-dtype", default="bf16", choices=["bf16", "fp8"],
                    help="KV-cache dtype (fp8: §Perf hillclimb)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--ce-chunk", type=int, default=0,
                    help="chunked cross-entropy block size (0=dense)")
    ap.add_argument("--pod-compress", action="store_true",
                    help="int8 ring gradient reduce over the pod axis "
                         "(§Perf hillclimb; multi mesh only)")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                tag = f"{arch}__{shape}__{mesh_kind}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[run] {tag}", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind, args.dgro_order,
                                   sharding_mode=args.sharding,
                                   cache_dtype=args.cache_dtype,
                                   microbatches=args.microbatches,
                                   pod_compress=args.pod_compress,
                                   ce_chunk=args.ce_chunk,
                                   hlo_path=os.path.join(
                                       args.out, tag + ".hlo.gz"))
                except Exception as e:  # noqa: BLE001 - sweep must continue
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                    print(f"  ERROR: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                if rec.get("status") == "ok":
                    r = rec["roofline"]
                    print(f"  ok chips={rec['n_chips']} compile={rec['compile_s']}s "
                          f"hbm/dev={rec['memory']['hbm_per_device_bytes']/1e9:.2f}GB "
                          f"terms(c/m/coll)={r['compute_s']:.4f}/"
                          f"{r['memory_s']:.4f}/{r['collective_s']:.4f}s "
                          f"dom={r['dominant']}", flush=True)
    print(f"done; {failures} failures")
    return 0 if failures == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
