"""Batched serving loop: continuous prefill + decode with a sharded KV cache.

Requests arrive with different prompt lengths; the loop packs up to
``--batch`` requests, prefills them together (left-padded), then decodes
tokens until every request reaches its target length.  On the production
mesh this is the decode_32k / long_500k cell from the dry-run; on CPU the
smoke config serves for real:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --smoke \
        --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import model as Mdl


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(0)
    params = Mdl.init_params(cfg, key)
    b = args.requests

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, size=(b, args.prompt_len))

    prefill = jax.jit(lambda p, c, t: Mdl.forward(cfg, p, t, mode="prefill",
                                                  caches=c))
    decode = jax.jit(lambda p, c, t, pos: Mdl.forward(
        cfg, p, t, mode="decode", caches=c, pos=pos))

    caches = Mdl.init_caches(cfg, b, max_len=args.max_len)
    t0 = time.time()
    logits, caches, _ = prefill(params, caches, jnp.asarray(prompts))
    t_prefill = time.time() - t0

    def sample(lg, k):
        if args.temperature <= 0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / args.temperature).astype(jnp.int32)

    out = [sample(logits, key)]
    t0 = time.time()
    for i in range(args.max_new - 1):
        pos = jnp.int32(args.prompt_len + i)
        key, sub = jax.random.split(key)
        logits, caches = decode(params, caches, out[-1][:, None], pos)
        out.append(sample(logits, sub))
    t_decode = time.time() - t0
    tokens = np.stack([np.asarray(o) for o in out], axis=1)
    print(f"[serve] arch={cfg.name} batch={b} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"[serve] prefill {t_prefill*1e3:.1f}ms "
          f"({b*args.prompt_len/max(t_prefill,1e-9):.0f} tok/s), decode "
          f"{t_decode*1e3:.1f}ms ({b*(args.max_new-1)/max(t_decode,1e-9):.0f} tok/s)")
    print(f"[serve] first request continuation: {tokens[0][:16].tolist()}")
    return tokens


if __name__ == "__main__":
    main()
