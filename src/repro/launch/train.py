"""Production training launcher.

Wires every subsystem together: DGRO-ordered mesh -> sharded TrainState ->
deterministic data pipeline -> pjit train_step (remat + microbatching +
ZeRO) -> async checkpointing -> membership/straggler hooks.

CPU-runnable smoke mode (reduced config, 1 device):

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --smoke \
        --steps 20 --batch 8 --seq 64

On a real fleet the same entrypoint runs the FULL config against the
production mesh (the dry-run proves every cell compiles; see
repro.launch.dryrun).  Latency-hiding flags for TPU are set in LIBTPU_FLAGS
below (documented, inert on CPU).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

# XLA/libtpu flags we run with in production (latency-hiding scheduler +
# async collectives); harmless no-ops on CPU.
TPU_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_steps, restore
from repro.configs import SHAPES, get_arch
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import batch_specs, state_specs, to_shardings
from repro.membership.elastic import HostState, update_ewma
from repro.models.sharding import default_rules, use_rules
from repro.train.optimizer import AdamWConfig, warmup_cosine
from repro.train.train_step import TrainConfig, TrainState, init_state, train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none", help="production mesh (needs devices)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"[train] arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab}")

    if args.mesh == "none":
        mesh = None
        data_axes = ("data",)
        rules = None
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multi",
                                    dgro_order=True)
        data_axes = ("pod", "data") if args.mesh == "multi" else ("data",)
        rules = default_rules(data_axes=data_axes, mesh=mesh)
        if hasattr(mesh, "dgro_report"):
            print(f"[mesh] DGRO order: {mesh.dgro_report}")

    tc = TrainConfig(
        optimizer=AdamWConfig(
            lr=args.lr, weight_decay=0.1,
            schedule=warmup_cosine(args.lr, warmup=max(args.steps // 20, 5),
                                   total=args.steps)),
        remat=not args.smoke,
        microbatches=args.microbatches,
    )
    state = init_state(cfg, jax.random.PRNGKey(0))
    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and latest_steps(args.ckpt_dir):
        state, start_step = restore(args.ckpt_dir, state)
        print(f"[ckpt] resumed from step {start_step}")

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch,
                                  mean_doc_len=args.seq / 2))

    def step_fn(s, b):
        if rules is None:
            return train_step(cfg, tc, s, b, mesh=mesh, data_axes=data_axes)
        with use_rules(rules):
            return train_step(cfg, tc, s, b, mesh=mesh, data_axes=data_axes)

    if mesh is not None:
        st_shapes = jax.eval_shape(lambda: state)
        st_shard = to_shardings(state_specs(st_shapes, mesh, data_axes), mesh)
        b_shapes = jax.eval_shape(
            lambda: {k: jnp.asarray(v) for k, v in data.batch(0).items()})
        b_shard = to_shardings(batch_specs(b_shapes, mesh, data_axes), mesh)
        jit_step = jax.jit(step_fn, in_shardings=(st_shard, b_shard),
                           donate_argnums=(0,))
        state = jax.device_put(state, st_shard)
    else:
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

    # membership/straggler bookkeeping (per-host heartbeat EWMA; this
    # process is host 0 — multi-host launch feeds real heartbeats)
    host = HostState(host_id=0)

    t_start = time.time()
    losses = []
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, metrics = jit_step(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        update_ewma(host, dt * 1e3)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} ce {float(metrics['ce']):8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} {dt*1e3:7.1f}ms")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, state)
    if ckpt:
        ckpt.save_async(args.steps, state)
        ckpt.wait()
        print(f"[ckpt] final checkpoint at {ckpt.last_committed}")
    wall = time.time() - t_start
    n_tok = args.steps * args.batch * args.seq
    print(f"[done] {args.steps} steps, {wall:.1f}s, "
          f"{n_tok / wall:.0f} tok/s, loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return losses


if __name__ == "__main__":
    main()
