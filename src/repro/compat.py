"""jax API-version compatibility shims.

The codebase targets the modern jax surface (top-level ``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType``,
``check_vma=``); older pins (e.g. 0.4.x, where shard_map lives in
``jax.experimental`` and meshes have no axis types) lack parts of it.  All
imports of these symbols go through this module so the rest of the tree can
be written against one API regardless of the installed jax.
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax
from jax.sharding import AbstractMesh, Mesh

__all__ = ["shard_map", "make_mesh", "mesh_from_devices", "abstract_mesh",
           "auto_axis_types", "axis_size", "named_sharding"]

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax 0.4.x/0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map_impl).parameters
_MAKE_MESH = getattr(jax, "make_mesh", None)       # absent before jax 0.4.35
_MAKE_MESH_AXIS_TYPES = (
    _MAKE_MESH is not None
    and "axis_types" in inspect.signature(_MAKE_MESH).parameters)

AxisType = getattr(jax.sharding, "AxisType", None)


def auto_axis_types(n_axes: int):
    """``(AxisType.Auto,) * n`` on jax versions that have axis types."""
    if AxisType is None:
        return None
    return (AxisType.Auto,) * n_axes


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` with the replication-check kwarg name normalized
    (``check_vma`` on modern jax, ``check_rep`` on 0.4.x)."""
    kw = {}
    if check_vma is not None:
        kw["check_vma" if _HAS_CHECK_VMA else "check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where supported; on jax
    versions predating make_mesh, a plain device-grid Mesh."""
    shape, names = tuple(axis_shapes), tuple(axis_names)
    if _MAKE_MESH is None:
        import numpy as np

        devs = np.asarray(devices if devices is not None else jax.devices())
        return Mesh(devs[:int(np.prod(shape))].reshape(shape), names)
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _MAKE_MESH_AXIS_TYPES:
        kw["axis_types"] = auto_axis_types(len(names))
    return _MAKE_MESH(shape, names, **kw)


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, from inside shard_map.

    ``jax.lax.axis_size`` on modern jax; on 0.4.x ``jax.core.axis_frame``
    resolves the name in the ambient axis env (returning the size int).
    """
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis_name))
    frame = jax.core.axis_frame(axis_name)
    return int(getattr(frame, "size", frame))


def named_sharding(mesh: Mesh, *spec):
    """``NamedSharding(mesh, PartitionSpec(*spec))`` — placing a host batch
    explicitly before a shard_map call avoids the implicit broadcast-then-
    reshard transfer some jax versions emit for unsharded inputs."""
    from jax.sharding import NamedSharding, PartitionSpec

    return NamedSharding(mesh, PartitionSpec(*spec))


def abstract_mesh(axis_shapes: Sequence[int],
                  axis_names: Sequence[str]) -> AbstractMesh:
    """``AbstractMesh(sizes, names)`` (modern) vs ``AbstractMesh(pairs)``
    (0.4.x)."""
    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def mesh_from_devices(device_array, axis_names: Sequence[str]) -> Mesh:
    """``Mesh(devices, axes)`` with Auto axis types where supported."""
    try:
        return Mesh(device_array, axis_names,
                    axis_types=auto_axis_types(len(tuple(axis_names))))
    except TypeError:
        return Mesh(device_array, axis_names)
