import os
import sys

# tests must see exactly ONE device (the dry-run sets its own 512-device env
# in a separate process); make the src/ tree importable regardless of cwd.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
