import os
import sys

# tests must see exactly ONE device (the dry-run sets its own 512-device env
# in a separate process); make the src/ tree importable regardless of cwd.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def subproc_env(**extra):
    """Minimal env for re-exec'd jax subprocesses, forwarding the parent's
    platform pins (JAX_PLATFORMS=cpu etc.) so jax does not probe for
    accelerator hardware and hang in CI containers."""
    keep = {k: v for k, v in os.environ.items()
            if k.startswith(("JAX_", "XLA_"))}
    return {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", **keep, **extra}


def _install_hypothesis_fallback() -> None:
    """Minimal stand-in for ``hypothesis`` so the suite runs without it.

    Only what this suite uses is implemented: ``@settings(max_examples=...,
    deadline=...)``, ``@given(st.integers(a, b), st.floats(a, b))``.  Each
    property test is executed for ``max_examples`` deterministic pseudo-random
    examples (seeded by the test's qualname) plus the strategy endpoints.
    When the real hypothesis is installed (see requirements.txt / CI) it is
    used instead and this shim never activates.
    """
    import functools
    import inspect
    import random
    import types

    st_mod = types.ModuleType("hypothesis.strategies")

    def integers(min_value, max_value):
        return (min_value, max_value,
                lambda rnd: rnd.randint(min_value, max_value))

    def floats(min_value, max_value):
        return (min_value, max_value,
                lambda rnd: rnd.uniform(min_value, max_value))

    st_mod.integers = integers
    st_mod.floats = floats

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 20)
                rnd = random.Random(fn.__qualname__)
                # endpoints first (cheap shrink-less "edge cases"), then draws
                examples = [tuple(s[0] for s in strategies),
                            tuple(s[1] for s in strategies)]
                examples += [tuple(s[2](rnd) for s in strategies)
                             for _ in range(max(0, n - 2))]
                for ex in examples[:n]:
                    fn(*args, *ex, **kwargs)
            # hide the generated params from pytest's fixture resolution
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.strategies = st_mod
    hyp.given = given
    hyp.settings = settings
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:  # pragma: no cover - prefer the real library when available
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()
