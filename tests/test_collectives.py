"""int8 ring all-reduce + error feedback (subprocess: needs 8 devices)."""
import subprocess
import sys

from conftest import subproc_env

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.train.collectives import _quantize



@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.floats(0.01, 1000.0))
def test_quantize_error_bound(seed, scale):
    """Property: |x - dequant(quant(x))| <= max|x|/254 elementwise."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(0, 1, 64) * scale).astype(np.float32))
    q, s = _quantize(x)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * float(s))
    assert err.max() <= float(jnp.max(jnp.abs(x))) / 254.0 + 1e-6


def test_ring_allreduce_8dev():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.train.collectives import ring_allreduce, compressed_grad_allreduce

mesh = make_mesh((8,), ("data",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 1003)) * 3.0
fn = shard_map(lambda xl: ring_allreduce(xl[0], "data")[None], mesh=mesh,
               in_specs=P("data", None), out_specs=P("data", None),
               check_vma=False)
got = np.asarray(fn(x))
want = np.asarray(jnp.sum(x, 0))
rel = np.abs(got[0] - want).max() / np.abs(want).max()
assert rel < 0.05, rel
assert np.array_equal(got, np.broadcast_to(got[0], got.shape)), "ranks differ"

# error feedback: mean of (grads + err) over steps converges to true mean
def df(xl):
    g = {"w": xl[0]}
    mean, err = compressed_grad_allreduce(g, "data")
    return mean["w"][None], err["w"][None]
fn2 = shard_map(df, mesh=mesh, in_specs=P("data", None),
                out_specs=(P("data", None), P("data", None)), check_vma=False)
mean, err = fn2(x)
true = np.asarray(jnp.mean(x, 0))
assert np.abs(np.asarray(mean)[0] - true).max() / np.abs(true).max() < 0.05
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=subproc_env(),
                         cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]
