"""repro.overlay: the Overlay type, the builder registry, the legacy shims."""
import subprocess
import sys

from conftest import subproc_env

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import overlay
from repro.core.diameter import INF, diameter, diameter_scipy, is_edge
from repro.core.ga import GAConfig
from repro.core.topology import DISTRIBUTIONS, make_latency

N = 24

# configs that keep every builder cheap enough for a 4-distribution sweep
FAST_CFG = {
    "ga": GAConfig(k_rings=2, population=16, budget=64, seed=0),
    "parallel": overlay.ParallelConfig(m=4, extra_random=1),
}


def _build(name, w, seed=0):
    return overlay.build(name, w, FAST_CFG.get(name), seed=seed)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_unknown_builder_error_lists_registered_names():
    w = make_latency("uniform", 8, seed=0)
    with pytest.raises(ValueError) as exc:
        overlay.build("does-not-exist", w)
    msg = str(exc.value)
    for name in overlay.builders():
        assert name in msg, (name, msg)


def test_expected_builders_registered():
    assert {"dgro", "chord", "rapid", "perigee", "ga", "nearest", "random",
            "parallel"} <= set(overlay.builders())


@pytest.mark.parametrize("dist", sorted(DISTRIBUTIONS))
@pytest.mark.parametrize("name", sorted(
    ["dgro", "chord", "rapid", "perigee", "ga", "nearest", "random",
     "parallel"]))
def test_every_builder_connected_and_diameter_matches_fresh(name, dist):
    """Acceptance: every registered builder x all four latency distributions
    returns a connected overlay whose (lazily cached) diameter matches a
    fresh ``core.diameter`` computation on its adjacency."""
    w = make_latency(dist, N, seed=3)
    ov = _build(name, w, seed=1)
    assert ov.policy == name
    assert ov.n == N and ov.num_rings >= 1
    assert ov.is_connected(), (name, dist)
    cached = ov.diameter()
    fresh = float(diameter(jnp.asarray(ov.adjacency)))
    assert cached == pytest.approx(fresh, rel=1e-4), (name, dist)
    # and against the host-side scipy oracle
    assert cached == pytest.approx(diameter_scipy(ov.adjacency), rel=1e-4)


def test_builder_determinism_and_config_overrides():
    w = make_latency("bitnode", 40, seed=2)
    a = overlay.build("chord", w, rng=np.random.default_rng(9))
    b = overlay.build("chord", w, rng=np.random.default_rng(9))
    assert a.equals(b)
    c = overlay.build("chord", w, rng=np.random.default_rng(10))
    assert not np.array_equal(a.adjacency, c.adjacency)
    # field overrides build the default config
    ov = overlay.build("rapid", w, k=3, seed=0)
    assert ov.num_rings == 3
    with pytest.raises(ValueError):
        overlay.build("rapid", w, overlay.RapidConfig(k=3), k=3)
    with pytest.raises(TypeError):
        overlay.build("rapid", w, overlay.ChordConfig())


def test_register_rejects_duplicates_and_accepts_new():
    with pytest.raises(ValueError):
        overlay.register("chord")(lambda w, cfg, rng: None)

    @overlay.register("_test_line")
    def _line(w, cfg, rng):
        n = w.shape[0]
        return overlay.Overlay.from_rings(w, [np.arange(n)])

    try:
        ov = overlay.build("_test_line", make_latency("uniform", 8, seed=0))
        assert ov.policy == "_test_line" and ov.num_rings == 1
    finally:
        overlay.registry._REGISTRY.pop("_test_line")


# ---------------------------------------------------------------------------
# the Overlay type
# ---------------------------------------------------------------------------

def test_overlay_validates_inputs():
    w = make_latency("uniform", 8, seed=0)
    with pytest.raises(ValueError):
        overlay.Overlay.from_rings(w, [np.arange(7)])       # short ring
    with pytest.raises(ValueError):
        overlay.Overlay(w, (), np.array([[0, 9]]))          # edge out of range
    with pytest.raises(ValueError):
        overlay.Overlay(np.zeros((3, 4), np.float32))       # non-square w


def test_pytree_flatten_unflatten_roundtrip():
    w = make_latency("fabric", N, seed=0)
    ov = _build("perigee", w)
    leaves, treedef = jax.tree_util.tree_flatten(ov)
    assert all(isinstance(x, np.ndarray) for x in leaves)
    ov2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(ov2, overlay.Overlay)
    assert ov.equals(ov2)
    # identity tree_map round-trips and recomputes the same diameter
    ov3 = jax.tree_util.tree_map(lambda x: x, ov)
    assert ov3.diameter() == pytest.approx(ov.diameter())
    # overlays nest inside other pytrees
    flat, td = jax.tree_util.tree_flatten({"a": ov, "b": [ov]})
    rt = jax.tree_util.tree_unflatten(td, flat)
    assert rt["a"].equals(ov) and rt["b"][0].equals(ov)


def test_json_roundtrip_preserves_everything():
    w = make_latency("bitnode", N, seed=1)
    for name in ("chord", "dgro"):
        ov = _build(name, w)
        rt = overlay.Overlay.from_json(ov.to_json())
        assert rt.policy == ov.policy
        assert rt.equals(ov)                   # w, rings, extras, adjacency
        assert rt.diameter() == pytest.approx(ov.diameter(), rel=1e-5)


def test_replace_rings_validates_count_and_swaps():
    w = make_latency("gaussian", N, seed=4)
    ov = _build("rapid", w)
    rng = np.random.default_rng(99)
    swapped = ov.replace_rings([rng.permutation(N)
                                for _ in range(ov.num_rings)])
    assert swapped.num_rings == ov.num_rings
    assert not np.array_equal(swapped.adjacency, ov.adjacency)
    with pytest.raises(ValueError):
        ov.replace_rings([rng.permutation(N)] * (ov.num_rings + 1))
    with pytest.raises(ValueError):
        ov.replace_rings([np.arange(N - 1)] * ov.num_rings)  # not a perm
    # chord keeps its fingers (extra edges) across a ring swap
    ch = _build("chord", w)
    sw = ch.replace_rings([rng.permutation(N)])
    assert len(sw.extra_edges) == len(ch.extra_edges)


def test_add_ring_only_improves_diameter():
    w = make_latency("fabric", N, seed=5)
    ov = _build("nearest", w)
    rng = np.random.default_rng(1)
    grown = ov.add_ring(rng.permutation(N))
    assert grown.num_rings == ov.num_rings + 1
    assert grown.diameter() <= ov.diameter() + 1e-6
    assert ov.num_rings == 1                   # original untouched (immutable)


def test_subset_drops_dead_nodes_and_stays_consistent():
    w = make_latency("uniform", N, seed=6)
    ov = _build("rapid", w)
    alive = np.ones(N, bool)
    alive[[1, 7, 13]] = False
    sub = ov.subset(alive)
    assert sub.n == N - 3 and sub.num_rings == ov.num_rings
    idx = np.flatnonzero(alive)
    assert np.array_equal(sub.w, w[np.ix_(idx, idx)])
    assert sub.is_connected()                  # rings re-stitch the survivors
    # index-array form agrees with the mask form
    assert sub.equals(ov.subset(idx))
    with pytest.raises(ValueError):
        ov.subset(np.zeros(N, bool))


def test_dataclasses_replace_rederives_adjacency():
    """``adjacency`` is a derived (init=False) field: the idiomatic frozen
    update ``dataclasses.replace(ov, rings=...)`` must re-derive it instead
    of carrying the old topology along."""
    import dataclasses

    w = make_latency("uniform", N, seed=3)
    ov = _build("rapid", w)
    rng = np.random.default_rng(42)
    new_rings = tuple(rng.permutation(N) for _ in range(ov.num_rings))
    rep = dataclasses.replace(ov, rings=new_rings)
    assert not np.array_equal(rep.adjacency, ov.adjacency)
    assert rep.equals(ov.replace_rings(new_rings))


def test_from_adjacency_with_rings_keeps_rings_swappable():
    """Edges covered by the passed rings must NOT be recorded as extra
    edges — otherwise replace_rings silently keeps the old rings' topology."""
    w = make_latency("gaussian", N, seed=11)
    base = _build("chord", w)
    ov = overlay.Overlay.from_adjacency(w, base.adjacency, rings=base.rings)
    assert np.array_equal(ov.adjacency, base.adjacency)
    # recovered extras = the finger edges only (as an undirected set; the
    # builder's raw list may contain duplicate/reversed entries)
    fingers = {tuple(sorted(e)) for e in base.extra_edges.tolist()}
    assert {tuple(e) for e in ov.extra_edges.tolist()} == fingers
    rng = np.random.default_rng(123)
    swapped = ov.replace_rings([rng.permutation(N)])
    old_ring_edges = {tuple(sorted(e))
                      for e in np.stack([base.rings[0],
                                         np.roll(base.rings[0], -1)], axis=1)}
    extra_set = {tuple(sorted(e)) for e in swapped.extra_edges.tolist()}
    assert not (old_ring_edges & extra_set)


def test_from_adjacency_roundtrip_and_mismatch_rejected():
    w = make_latency("gaussian", N, seed=7)
    ov = _build("perigee", w)
    rt = overlay.Overlay.from_adjacency(w, ov.adjacency)
    assert np.array_equal(rt.adjacency, ov.adjacency)
    bad = ov.adjacency.copy()
    mask = np.asarray(is_edge(bad))
    bad[mask] = bad[mask] * 2.0                # weights disagree with w
    with pytest.raises(ValueError):
        overlay.Overlay.from_adjacency(w, bad)
    # fold_weights keeps the legacy tolerance: deviating edge weights are
    # folded into the stored w and the adjacency reproduces exactly
    folded = overlay.Overlay.from_adjacency(w, bad, fold_weights=True)
    assert np.array_equal(folded.adjacency, bad)
    assert np.array_equal(folded.w[~mask], np.asarray(w)[~mask])


def test_adapt_with_folded_weights_tolerates_custom_edge_weights():
    """Adjacencies whose edge weights deviate from w (e.g. after
    IncrementalDistances.add_edge(weight=...)) adapt fine when folded into
    an Overlay via fold_weights=True — the path the removed adapt_overlay
    shim used to provide."""
    from repro.core import selection
    from repro.core.diameter import adjacency_from_rings

    w = make_latency("uniform", 16, seed=0)
    adj = adjacency_from_rings(w, [np.random.default_rng(0).permutation(16)])
    adj[0, 5] = adj[5, 0] = 0.25               # cheaper than w[0, 5]
    ov = overlay.Overlay.from_adjacency(w, adj, fold_weights=True)
    new_ov, kind, rho = selection.adapt(ov, seed=0)
    assert new_ov.adjacency[0, 5] == np.float32(0.25)  # custom weight survives
    assert kind in ("nearest", "random", "keep")


def test_to_tuple_matches_legacy_layout():
    w = make_latency("uniform", N, seed=8)
    ov = _build("chord", w)
    adj, rings = ov.to_tuple()
    assert np.array_equal(adj, ov.adjacency)
    assert len(rings) == ov.num_rings
    assert float(adj[~np.asarray(is_edge(adj))].max()) == float(INF)


def test_degree_stats_and_edge_list():
    w = make_latency("uniform", N, seed=9)
    ov = _build("random", w)
    stats = ov.degree_stats()
    assert 2 <= stats["min"] <= stats["mean"] <= stats["max"]
    edges = ov.edge_list()
    assert (edges[:, 0] < edges[:, 1]).all()
    assert 2 * len(edges) == int(ov.degrees().sum())


# ---------------------------------------------------------------------------
# legacy shims (satellite: tuple facades removed, hard error with pointer)
# ---------------------------------------------------------------------------

def test_legacy_shims_are_removed_with_pointer():
    """Run the CI checker in a fresh interpreter: every tuple shim is gone
    and raises AttributeError naming the overlay API replacement."""
    out = subprocess.run(
        [sys.executable, "tools/check_deprecation.py"], capture_output=True,
        text=True, env=subproc_env(), cwd=".", timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "all legacy shims removed" in out.stdout


def test_removed_shims_raise_attributeerror_inline():
    """Direct access (not just the subprocess checker) fails with directions."""
    from repro.core import protocols, qlearning, selection

    for module, name in [(protocols, "chord"),
                         (protocols, "with_replaced_rings"),
                         (selection, "adapt_overlay"),
                         (qlearning, "dgro_topology")]:
        with pytest.raises(AttributeError, match="removed.*overlay"):
            getattr(module, name)
    # unknown names still produce the stock message, not the removal hint
    with pytest.raises(AttributeError, match="has no attribute"):
        protocols.definitely_not_a_protocol
