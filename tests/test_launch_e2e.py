"""End-to-end launcher tests: train CLI, serve CLI, elastic restore."""
import subprocess
import sys

from conftest import subproc_env

ENV = subproc_env()


def test_train_cli_smoke(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "musicgen-large",
         "--smoke", "--steps", "6", "--batch", "4", "--seq", "32",
         "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"],
        capture_output=True, text=True, env=ENV, cwd=".", timeout=600)
    assert "[done]" in out.stdout, out.stderr[-2000:]
    assert "loss" in out.stdout
    # resume from the checkpoint it wrote
    out2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "musicgen-large",
         "--smoke", "--steps", "8", "--batch", "4", "--seq", "32",
         "--ckpt-dir", str(tmp_path), "--resume"],
        capture_output=True, text=True, env=ENV, cwd=".", timeout=600)
    assert "resumed from step 6" in out2.stdout, out2.stdout[-2000:]


def test_serve_cli_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-1b",
         "--smoke", "--requests", "2", "--prompt-len", "8", "--max-new", "4"],
        capture_output=True, text=True, env=ENV, cwd=".", timeout=600)
    assert "[serve]" in out.stdout, out.stderr[-2000:]


def test_elastic_restore_to_different_mesh():
    """Checkpoint written single-device restores onto a 4-way mesh with new
    shardings (the elastic-restart path)."""
    code = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpoint import save, restore

tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4),
        "b": jnp.ones((4,), jnp.bfloat16)}
with tempfile.TemporaryDirectory() as d:
    save(d, 7, tree)
    from repro.compat import make_mesh
    mesh = make_mesh((4,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None)),
                 "b": NamedSharding(mesh, P())}
    got, step = restore(d, tree, shardings=shardings)
    assert step == 7
    assert got["w"].sharding.spec == P("data", None), got["w"].sharding
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=ENV, cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]
