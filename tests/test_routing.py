"""repro.routing: batched greedy router, workloads, summaries, probes."""
import os
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import overlay, routing
from repro.core.diameter import INF, adjacency_from_edges, ring_edges
from repro.core.topology import DISTRIBUTIONS, N_FABRIC_SITES, make_latency
from repro.dynamics import POLICIES as DYN_POLICIES
from repro.dynamics import ChurnEngine
from repro.dynamics.scenarios import poisson_churn
from repro.obs import REGISTRY, parse_prometheus

N = 16

# overrides that keep every builder cheap enough for a 4-distribution sweep
# (dgro-dqn skips training: the construction-only vmapped rollout is what
# routing exercises, and train_epoch's fused scan is compile-heavy)
FAST_CFG = {
    "ga": dict(k_rings=2, population=8, budget=32),
    "parallel": dict(m=2, extra_random=0),
    "dgro-dqn": dict(k=2, epochs=0, n_starts=2),
}


def _build(name, w, seed=0):
    return overlay.build(name, w, seed=seed, **FAST_CFG.get(name, {}))


def _chord_fabric(n, seed=0, dist="bitnode"):
    ov = overlay.build("chord", make_latency(dist, n, seed=seed), seed=seed)
    return (np.asarray(ov.adjacency, np.float32),
            np.asarray(ov.distances(), np.float32), np.asarray(ov.rings[0]))


# ---------------------------------------------------------------------------
# properties over every registered builder
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", sorted(DISTRIBUTIONS))
def test_every_builder_routes_every_pair(dist):
    """Acceptance: all registered builders x all latency distributions —
    greedy routing succeeds on the connected overlay under BOTH policies
    and stretch is >= 1; the latency policy descends an exact potential,
    so it follows a shortest path (stretch == 1)."""
    w = make_latency(dist, N, seed=3)
    pairs = routing.sample_pairs(N, 64, "uniform", seed=5)
    for name in sorted(overlay.builders()):
        if overlay.get_builder(name).kind != "flat":
            continue        # hier builders route via repro.hier.routing
        ov = _build(name, w, seed=1)
        assert ov.is_connected(), (name, dist)
        for policy in routing.POLICIES:
            res = routing.route_overlay(ov, pairs, policy=policy)
            assert res.success.all(), (name, dist, policy)
            assert not res.failed.any(), (name, dist, policy)
            assert np.all(res.stretch >= 1 - 1e-4), (name, dist, policy)
            if policy == "latency":
                assert np.all(res.stretch <= 1 + 1e-3), (name, dist)


def test_batched_router_matches_host_reference():
    """The device scan and the numpy per-pair loop agree bit-for-bit on
    hops, latency, and outcome flags at a fixed seed (the fig19 parity
    gate, at test scale)."""
    adj, dist, ring = _chord_fabric(24)
    pairs = routing.sample_pairs(24, 128, "uniform", seed=1)
    for policy in routing.POLICIES:
        dev = routing.route_pairs(adj, dist, pairs, policy=policy, ring=ring)
        host = routing.route_pairs_host(adj, dist, pairs, policy=policy,
                                        ring=ring)
        for field in ("hops", "latency", "success", "failed"):
            assert np.array_equal(getattr(dev, field),
                                  getattr(host, field)), (policy, field)


# ---------------------------------------------------------------------------
# termination
# ---------------------------------------------------------------------------

def test_masked_termination_respects_hop_budget():
    """On a pure ring, the antipodal pair needs exactly n/2 hops: one more
    budget delivers it, one less freezes at the budget (exhausted, never
    beyond), and the host reference agrees."""
    n = 16
    perm = np.arange(n)
    adj = adjacency_from_edges(make_latency("uniform", n, seed=0),
                               ring_edges(perm))
    pairs = np.array([[0, 8]])
    full = routing.route_pairs(adj, None, pairs, policy="ring", ring=perm,
                               hop_budget=8)
    assert full.success.all() and full.hops[0] == 8
    cut = routing.route_pairs(adj, None, pairs, policy="ring", ring=perm,
                              hop_budget=7)
    assert not cut.success[0] and not cut.failed[0]
    assert cut.hops[0] == 7 and cut.outcome(0) == "exhausted"
    keys = routing.ring_distance_keys(perm, pairs[:, 1])
    path, _, hops, outcome = routing.route_single_host(
        adj, keys[0], 0, 8, policy="ring", hop_budget=7)
    assert outcome == "exhausted" and hops == 7 and len(path) == 8


def test_disconnected_cross_pairs_dead_end():
    """Cross-component pairs dead-end immediately (INF potential on every
    neighbour) and don't count against success_rate, which only charges
    the router for reachable pairs."""
    from repro.core.batcheval import batched_apsp

    n = 12
    w = make_latency("uniform", n, seed=2)
    edges = list(ring_edges(np.arange(6))) + list(ring_edges(np.arange(6, n)))
    adj = np.asarray(adjacency_from_edges(w, edges), np.float32)
    dist = np.asarray(batched_apsp(jnp.asarray(adj)[None])[0], np.float32)
    assert dist[0, 9] >= float(INF) / 2          # really partitioned
    res = routing.route_pairs(adj, dist, np.array([[0, 9], [1, 4]]),
                              policy="latency")
    assert not res.success[0] and res.failed[0]
    assert res.outcome(0) == "dead_end" and res.hops[0] == 0
    assert np.isnan(res.stretch[0])
    assert res.success[1]
    s = routing.summarize(res, builder="two-rings", workload="uniform",
                          policy="latency", n=n, hop_budget=n)
    assert s.success_rate == 1.0                 # 1 delivered / 1 reachable


# ---------------------------------------------------------------------------
# workload sampling
# ---------------------------------------------------------------------------

def test_sample_pairs_deterministic_distinct_in_range():
    for kind in routing.WORKLOADS:
        a = routing.sample_pairs(40, 200, kind, seed=7)
        assert np.array_equal(a, routing.sample_pairs(40, 200, kind, seed=7))
        assert not np.array_equal(a, routing.sample_pairs(40, 200, kind,
                                                          seed=8))
        assert a.shape == (200, 2)
        assert (a[:, 0] != a[:, 1]).all(), kind
        assert a.min() >= 0 and a.max() < 40
    with pytest.raises(ValueError, match="unknown workload"):
        routing.sample_pairs(40, 10, "nope")
    with pytest.raises(ValueError, match=">= 2 nodes"):
        routing.sample_pairs(1, 10)


def test_hotspot_concentrates_and_regional_localizes():
    hot = routing.sample_pairs(64, 600, "hotspot", seed=0)
    _, counts = np.unique(hot[:, 1], return_counts=True)
    assert np.sort(counts)[-4:].sum() / 600 >= 0.6   # frac=0.8 on 4 hotspots
    same_site = lambda p: float(  # noqa: E731
        ((p[:, 0] % N_FABRIC_SITES) == (p[:, 1] % N_FABRIC_SITES)).mean())
    reg = same_site(routing.sample_pairs(64, 600, "regional", seed=0))
    uni = same_site(routing.sample_pairs(64, 600, "uniform", seed=0))
    assert reg >= 0.6 and reg > uni + 0.2            # locality=0.8 vs 1/sites


# ---------------------------------------------------------------------------
# summaries + observability instruments
# ---------------------------------------------------------------------------

def test_routing_summary_serde_roundtrip():
    adj, dist, ring = _chord_fabric(12)
    res = routing.route_pairs(adj, dist,
                              routing.sample_pairs(12, 32, "hotspot", seed=1),
                              policy="latency", ring=ring)
    s = routing.summarize(res, builder="chord", workload="hotspot",
                          policy="latency", n=12, hop_budget=12)
    assert s.success_rate == 1.0 and s.stretch_mean >= 1 - 1e-4
    assert routing.RoutingSummary.from_json(s.to_json()) == s
    with pytest.raises(ValueError, match="routing_summary"):
        routing.RoutingSummary.from_json(
            s.to_json().replace("routing_summary", "other_kind"))


def test_route_instruments_land_in_the_scrape():
    """record_route / record_route_batch bump the SAME process-global
    instruments the service scrape serves (absolute-delta asserted, since
    other tests may have recorded already)."""
    def scrape():
        return parse_prometheus(REGISTRY.render_prometheus())

    adj, dist, ring = _chord_fabric(12)
    res = routing.route_pairs(adj, dist,
                              routing.sample_pairs(12, 16, "uniform", seed=2),
                              policy="ring", ring=ring)
    assert res.success.all()
    before = scrape()
    routing.record_route_batch("ring", res)
    routing.record_route("latency", "unreachable")
    after = scrape()
    delivered = (("outcome", "delivered"), ("policy", "ring"))
    unreachable = (("outcome", "unreachable"), ("policy", "latency"))
    reqs0 = before.get("repro_route_requests_total", {})
    reqs1 = after["repro_route_requests_total"]
    assert reqs1[delivered] - reqs0.get(delivered, 0) == res.n_pairs
    assert reqs1[unreachable] - reqs0.get(unreachable, 0) == 1
    hops0 = before.get("repro_route_hops_count", {}).get((), 0)
    assert after["repro_route_hops_count"][()] - hops0 == res.n_pairs


# ---------------------------------------------------------------------------
# rollout reward shaping stays opt-in
# ---------------------------------------------------------------------------

def test_rollout_stretch_weight_zero_is_bit_identical():
    from repro.core import rollout
    from repro.core.embedding import init_qparams

    n, k, n_envs = 8, 2, 2
    params = init_qparams(jax.random.PRNGKey(0), 8, 16)
    ws = jnp.asarray(np.stack([make_latency("uniform", n, seed=i)
                               for i in range(n_envs)]), jnp.float32)
    plan = rollout.make_plan(np.random.default_rng(0), n_envs, k, n)
    args = (params, ws, jnp.asarray(plan.starts), jnp.asarray(plan.eps_u),
            jnp.asarray(plan.choice_u), 0.3, 0.1)
    base = rollout.rollout_episodes(*args, k_rings=k, n_rounds=2)
    zero = rollout.rollout_episodes(*args, k_rings=k, n_rounds=2,
                                    stretch_weight=0.0)
    shaped = rollout.rollout_episodes(*args, k_rings=k, n_rounds=2,
                                      stretch_weight=0.5)
    for a, b in zip(base, zero):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(base[1]), np.asarray(shaped[1]))


# ---------------------------------------------------------------------------
# churn-engine routing probe
# ---------------------------------------------------------------------------

def test_churn_probe_stretch_stays_finite_under_poisson():
    trace = poisson_churn(n0=16, dist="uniform", seed=0, horizon=10_000.0,
                          join_rate=1e-3, leave_rate=1e-3, min_live=8)
    eng = ChurnEngine(trace, DYN_POLICIES["dgro"](), seed=1,
                      detect_failures=True, route_probe=2, route_pairs=16)
    res = eng.run()
    probed = [s.stretch for s in res.samples if np.isfinite(s.stretch)]
    assert probed, "probe recorded no finite stretch samples"
    assert all(v >= 1 - 1e-4 for v in probed)
    assert np.isfinite(res.mean_stretch) and res.mean_stretch >= 1 - 1e-4
    # probe off (the default): the column stays NaN and so does the mean
    res_off = ChurnEngine(trace, DYN_POLICIES["dgro"](), seed=1,
                          detect_failures=True).run()
    assert all(not np.isfinite(s.stretch) for s in res_off.samples)
    assert not np.isfinite(res_off.mean_stretch)


# ---------------------------------------------------------------------------
# integration seams: registry message, service response, benchmark gate
# ---------------------------------------------------------------------------

def test_unknown_builder_message_is_sorted_and_comma_joined():
    with pytest.raises(ValueError) as exc:
        overlay.build("does-not-exist", make_latency("uniform", 8, seed=0))
    assert ", ".join(sorted(overlay.builders())) in str(exc.value)


def test_service_route_response_carries_routing_fields():
    from repro.dynamics import Trace
    from repro.service.state import ServiceState

    world = Trace(n0=12, capacity=24, dist="bitnode", seed=3, events=[],
                  name="routing-test-world")
    state = ServiceState.fresh(world, policy="dgro", seed=0)
    r = state.route(0, 7)
    assert r["reachable"] and r["bound"] == "exact"
    assert r["path"] is not None and r["hops"] == len(r["path"]) - 1
    assert r["stretch"] == pytest.approx(1.0, rel=1e-3)   # exact matrix
    assert r["hop_bounds"] == ["exact"] * r["hops"]


def test_fig19_gate_is_registered_in_the_harness():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, root)
    try:
        from benchmarks.run import GATES
    finally:
        sys.path.remove(root)
    gate = GATES["fig19-routing"]
    assert gate.hard and gate.key == "passes_gate"
    assert gate.bench_file == "BENCH_fig19_routing.json"
