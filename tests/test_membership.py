"""Membership plane: dissemination ~ diameter, SWIM detection, elastic."""
import numpy as np
import pytest

from repro.core.construction import nearest_ring, random_ring
from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.core.topology import make_latency
from repro.membership.elastic import HostState, detect_stragglers, plan_rescale
from repro.membership.gossip import (SwimConfig, disseminate,
                                     simulate_failure_detection)


def _overlays(n=60, seed=1):
    w = make_latency("bitnode", n, seed=seed)
    rng = np.random.default_rng(0)
    low = adjacency_from_rings(w, [nearest_ring(w, 0), random_ring(rng, n)])
    high = adjacency_from_rings(w, [random_ring(rng, n)])
    return w, low, high


def test_dissemination_latency_tracks_diameter():
    """The paper's core premise: lower-diameter overlays disseminate faster.
    Checked in expectation over sources."""
    w, low, high = _overlays()
    d_low, d_high = diameter_scipy(low), diameter_scipy(high)
    assert d_low < d_high
    t_low = np.mean([disseminate(low, w, s, seed=s)[0] for s in range(8)])
    t_high = np.mean([disseminate(high, w, s, seed=s)[0] for s in range(8)])
    assert t_low < t_high * 1.05, (t_low, t_high)


def test_dissemination_reaches_everyone():
    w, low, _ = _overlays(n=40)
    t, recv = disseminate(low, w, 0, coverage=1.0)
    assert np.isfinite(recv).all()
    assert t == pytest.approx(np.max(recv))


def test_failure_detection_ordering():
    w, low, _ = _overlays(n=40)
    det = simulate_failure_detection(low, w, failed=5, cfg=SwimConfig())
    assert 0 < det.t_first_suspect < det.t_confirmed < det.t_all_know


def test_straggler_detection():
    hosts = [HostState(i, ewma_ms=1.0) for i in range(10)]
    hosts[4].ewma_ms = 100.0
    assert detect_stragglers(hosts, factor=3.0) == [4]


def test_plan_rescale_excludes_dead_and_stragglers():
    w = make_latency("fabric", 16, seed=2)
    hosts = [HostState(i) for i in range(16)]
    hosts[3].alive = False
    hosts[7].ewma_ms = 1000.0
    plan = plan_rescale(w, hosts, model_hosts=2, old_world=16)
    assert 3 not in plan.hosts and 7 not in plan.hosts
    pods, data, model = plan.mesh_shape
    assert pods * data * model == len(plan.hosts)
    assert model == 2
    assert plan.expected_step_time_factor >= 1.0
    # shard remap covers every old shard
    assert set(plan.shard_remap) == set(range(16))
