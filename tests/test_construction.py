"""Ring constructors: validity, determinism, jax/host agreement."""
import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import topology
from repro.core.construction import (default_num_rings, greedy_ring, k_rings,
                                     nearest_ring, nearest_ring_jax,
                                     random_ring)


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 40), st.integers(0, 10_000))
def test_nearest_ring_is_permutation_and_matches_jax(n, seed):
    w = topology.make_latency("uniform", n, seed=seed)
    start = seed % n
    host = nearest_ring(w, start)
    assert sorted(host) == list(range(n))
    dev = np.asarray(nearest_ring_jax(jnp.asarray(w), jnp.int32(start)))
    assert np.array_equal(host, dev)


def test_greedy_ring_respects_score():
    w = topology.make_latency("gaussian", 12, seed=0)
    # score = -w  => nearest neighbour
    perm = greedy_ring(w, lambda w_, vis, cur, p: -w_[cur], start=3)
    assert np.array_equal(perm, nearest_ring(w, 3))


def test_k_rings_mixed():
    w = topology.make_latency("uniform", 16, seed=1)
    rng = np.random.default_rng(0)
    rings = k_rings(w, 4, kind="mixed:2", rng=rng)
    assert len(rings) == 4
    for r in rings:
        assert sorted(r) == list(range(16))


def test_default_num_rings():
    assert default_num_rings(2) == 1
    assert default_num_rings(256) == 8
    assert default_num_rings(1000) == 10
