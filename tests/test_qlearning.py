"""DQN components: embedding forward, TD update, end-to-end improvement."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core.construction import random_ring
from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.core.embedding import init_qparams, q_values
from repro.core.qlearning import (DQNConfig, ReplayBuffer, construct_ring_dqn,
                                  train_dqn)
from repro.core.topology import make_latency


def test_q_values_shape_finite():
    params = init_qparams(jax.random.PRNGKey(0), p=8, h=16)
    w = jnp.asarray(make_latency("uniform", 10, seed=0))
    adj = jnp.zeros((10, 10))
    q = q_values(params, w, adj, jnp.int32(0))
    assert q.shape == (10,)
    assert bool(jnp.all(jnp.isfinite(q)))
    # embedding must depend on the partial topology
    adj2 = adj.at[0, 3].set(1.0).at[3, 0].set(1.0)
    q2 = q_values(params, w, adj2, jnp.int32(0))
    assert float(jnp.max(jnp.abs(q - q2))) > 0


def test_replay_buffer_wraps():
    buf = ReplayBuffer(capacity=8, n=5)
    w = np.zeros((5, 5), np.float32)
    a = np.zeros((5, 5), np.uint8)
    for i in range(11):
        buf.push(w, a, 0, 1, float(i), a, 1, np.zeros(5, np.uint8), False)
    assert buf.size == 8
    rng = np.random.default_rng(0)
    batch = buf.sample(rng, 4)
    assert batch[0].shape == (4, 5, 5)


def test_dqn_training_improves_over_random():
    cfg = DQNConfig(n=12, k_rings=2, epochs=30, eps_decay=15, batch_size=16,
                    buffer_capacity=4000, seed=1)
    params, log = train_dqn(cfg, eval_every=10)
    w = make_latency("uniform", 12, seed=777)
    rng = np.random.default_rng(0)
    _, d_dqn = construct_ring_dqn(params, cfg, w, rng)
    d_rand = np.mean([
        diameter_scipy(adjacency_from_rings(
            w, [random_ring(np.random.default_rng(s), 12) for _ in range(2)]))
        for s in range(5)])
    # trained greedy construction should at least match the random mean
    assert d_dqn <= d_rand * 1.15, (d_dqn, d_rand)
    # learning signal exists: test diameter not increasing overall
    assert min(log.test_diam) <= log.test_diam[0] + 1e-6
