"""DQN components: embedding forward, replay buffer, rollout parity,
end-to-end improvement."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import rollout
from repro.core.construction import random_ring
from repro.core.diameter import (INF, adjacency_from_rings, diameter,
                                 diameter_scipy, largest_cc_diameter,
                                 relax_edge_update)
from repro.core.embedding import init_qparams, q_values, q_values_batch
from repro.core.qlearning import (DQNConfig, ReplayBuffer, _run_episode,
                                  construct_ring_dqn, dgro_overlay, train_dqn)
from repro.core.topology import make_latency


def test_q_values_shape_finite():
    params = init_qparams(jax.random.PRNGKey(0), p=8, h=16)
    w = jnp.asarray(make_latency("uniform", 10, seed=0))
    adj = jnp.zeros((10, 10))
    q = q_values(params, w, adj, jnp.int32(0))
    assert q.shape == (10,)
    assert bool(jnp.all(jnp.isfinite(q)))
    # embedding must depend on the partial topology
    adj2 = adj.at[0, 3].set(1.0).at[3, 0].set(1.0)
    q2 = q_values(params, w, adj2, jnp.int32(0))
    assert float(jnp.max(jnp.abs(q - q2))) > 0


def test_q_values_batch_n_rounds_static():
    """Regression: q_values_batch used to break when n_rounds was passed
    (the vmap in_axes tuple had no axis spec for it)."""
    params = init_qparams(jax.random.PRNGKey(0), p=8, h=16)
    ws = jnp.asarray(np.stack([make_latency("uniform", 9, seed=i)
                               for i in range(3)]), jnp.float32)
    adjs = jnp.zeros((3, 9, 9))
    adjs = adjs.at[:, 0, 4].set(1.0).at[:, 4, 0].set(1.0)
    vs = jnp.asarray([0, 1, 2], jnp.int32)
    for n_rounds in (1, 3):
        got = q_values_batch(params, ws, adjs, vs, n_rounds=n_rounds)
        assert got.shape == (3, 9)
        want = jnp.stack([q_values(params, ws[i], adjs[i], vs[i], n_rounds)
                          for i in range(3)])
        assert np.allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # the kwarg must actually change the embedding depth
    q1 = q_values_batch(params, ws, adjs, vs, n_rounds=1)
    q3 = q_values_batch(params, ws, adjs, vs, n_rounds=3)
    assert float(jnp.max(jnp.abs(q1 - q3))) > 0


def test_replay_buffer_wraps():
    buf = ReplayBuffer(capacity=8, n=5)
    w = np.zeros((5, 5), np.float32)
    a = np.zeros((5, 5), np.uint8)
    for i in range(11):
        buf.push(w, a, 0, 1, float(i), a, 1, np.zeros(5, np.uint8), False)
    assert buf.size == 8
    rng = np.random.default_rng(0)
    batch = buf.sample(rng, 4)
    assert batch[0].shape == (4, 5, 5)


def test_replay_buffer_graph_table_dedup_and_prune():
    """Transitions store graph ids, not (N, N) copies: one epoch = one
    table entry, and graphs fall out of the table once the ring buffer
    overwrites their last transition."""
    buf = ReplayBuffer(capacity=6, n=4)
    w0 = make_latency("uniform", 4, seed=0)
    w1 = make_latency("uniform", 4, seed=1)
    a = np.zeros((4, 4), np.uint8)
    for _ in range(3):        # an "epoch" worth of pushes on one graph
        buf.push(w0, a, 0, 1, 0.0, a, 1, np.zeros(4, np.uint8), False)
    assert buf.n_graphs == 1
    gid1 = buf.register_graph(w1)
    for _ in range(6):        # overwrites every w0 transition
        buf.push(gid1, a, 0, 1, 0.0, a, 1, np.zeros(4, np.uint8), False)
    buf.register_graph(make_latency("uniform", 4, seed=2))  # triggers prune
    assert 0 not in buf.graphs          # w0 is dead
    assert gid1 in buf.graphs           # w1 transitions are live
    batch = buf.sample(np.random.default_rng(0), 3)
    assert batch[0].shape == (3, 4, 4)
    assert np.allclose(batch[0], w1.astype(np.float32))


@settings(max_examples=8, deadline=None)
@given(st.integers(6, 14), st.integers(0, 10_000))
def test_incremental_relax_rewards_match_full_apsp(n, seed):
    """Property (satellite of the rollout engine): rewards computed from
    O(N^2) incremental relaxation equal full-APSP rewards on random
    edge-insert sequences — the substitution the engine makes."""
    rng = np.random.default_rng(seed)
    w = make_latency("uniform", n, seed=seed % 97)
    dist = np.full((n, n), float(INF), np.float32)
    np.fill_diagonal(dist, 0.0)
    dist = jnp.asarray(dist)
    adj_w = np.full((n, n), float(INF), np.float32)
    np.fill_diagonal(adj_w, 0.0)
    prev_inc = prev_full = 0.0
    for _ in range(2 * n):
        u, v = (int(x) for x in rng.choice(n, size=2, replace=False))
        wuv = np.float32(w[u, v])
        adj_w[u, v] = adj_w[v, u] = min(adj_w[u, v], float(wuv))
        dist = relax_edge_update(dist, u, v, wuv)
        d_inc = float(largest_cc_diameter(dist))
        d_full = float(diameter(jnp.asarray(adj_w)))
        scale = max(1.0, d_full)
        assert abs(d_inc - d_full) <= 1e-3 * scale, (d_inc, d_full)
        r_inc, r_full = prev_inc - d_inc, prev_full - d_full
        assert abs(r_inc - r_full) <= 2e-3 * scale, (r_inc, r_full)
        prev_inc, prev_full = d_inc, d_full
    # final state cross-check against the scipy oracle
    assert d_inc == pytest.approx(diameter_scipy(adj_w), rel=1e-3)


def test_host_device_rollout_trajectory_parity():
    """Acceptance: device-vs-host rollouts produce identical rings and
    matching rewards at fixed seeds (eps-greedy randomness exercised)."""
    cfg = DQNConfig(n=9, k_rings=2)
    params = init_qparams(jax.random.PRNGKey(1), cfg.p, cfg.h)
    w = make_latency("uniform", 9, seed=5)
    plan = rollout.make_plan(np.random.default_rng(3), 1, cfg.k_rings, cfg.n)
    _, _, d_h, _, perms_h, rw_h = _run_episode(
        params, cfg, w, 0.4, plan, 0, buffer=None, train=False)
    actions, rw_d, d_d = rollout.rollout_episodes(
        params, jnp.asarray(w, jnp.float32)[None], jnp.asarray(plan.starts),
        jnp.asarray(plan.eps_u), jnp.asarray(plan.choice_u), 0.4, cfg.alpha,
        k_rings=cfg.k_rings, n_rounds=cfg.n_rounds)
    perms_d = rollout.perms_from_actions(plan.starts, np.asarray(actions),
                                         cfg.k_rings, cfg.n)[0]
    assert all(np.array_equal(a, b) for a, b in zip(perms_h, perms_d))
    assert np.allclose(rw_h, np.asarray(rw_d)[:, 0], atol=1e-4)
    assert abs(d_h - float(np.asarray(d_d)[0])) <= 1e-3 * max(1.0, d_h)


def test_construct_ring_dqn_mode_parity():
    """The public facade consumes its rng identically in both modes."""
    cfg = DQNConfig(n=10, k_rings=2)
    params = init_qparams(jax.random.PRNGKey(0), cfg.p, cfg.h)
    w = make_latency("gaussian", 10, seed=2)
    perms_h, d_h = construct_ring_dqn(
        params, dataclasses.replace(cfg, rollout="host"), w,
        np.random.default_rng(11))
    perms_d, d_d = construct_ring_dqn(params, cfg, w,
                                      np.random.default_rng(11))
    assert all(np.array_equal(a, b) for a, b in zip(perms_h, perms_d))
    assert abs(d_h - d_d) <= 1e-3 * max(1.0, d_h)


def test_dgro_overlay_batched_matches_host():
    """dgro_overlay's n_starts constructions collapse into one vmapped
    rollout call; the winner must match the sequential host loop."""
    cfg = DQNConfig(n=8, k_rings=2)
    params = init_qparams(jax.random.PRNGKey(4), cfg.p, cfg.h)
    w = make_latency("uniform", 8, seed=9)
    ov_d = dgro_overlay(params, cfg, w, n_starts=4, seed=13)
    ov_h = dgro_overlay(params, dataclasses.replace(cfg, rollout="host"), w,
                        n_starts=4, seed=13)
    assert all(np.array_equal(a, b) for a, b in zip(ov_d.rings, ov_h.rings))
    assert ov_d.diameter() == ov_h.diameter()


def test_dqn_training_improves_over_random():
    cfg = DQNConfig(n=12, k_rings=2, epochs=30, eps_decay=15, batch_size=16,
                    buffer_capacity=4000, seed=1)
    params, log = train_dqn(cfg, eval_every=10)
    w = make_latency("uniform", 12, seed=777)
    rng = np.random.default_rng(0)
    _, d_dqn = construct_ring_dqn(params, cfg, w, rng)
    d_rand = np.mean([
        diameter_scipy(adjacency_from_rings(
            w, [random_ring(np.random.default_rng(s), 12) for _ in range(2)]))
        for s in range(5)])
    # trained greedy construction should at least match the random mean
    assert d_dqn <= d_rand * 1.15, (d_dqn, d_rand)
    # learning signal exists: test diameter not increasing overall
    assert min(log.test_diam) <= log.test_diam[0] + 1e-6


def test_train_dqn_host_mode_smoke():
    """The host debug path stays alive: it trains, logs and constructs."""
    cfg = DQNConfig(n=8, k_rings=1, epochs=4, eps_decay=2, batch_size=8,
                    buffer_capacity=200, seed=3, rollout="host")
    params, log = train_dqn(cfg, eval_every=2, eval_graphs=2)
    assert len(log.epochs) >= 2
    assert all(np.isfinite(log.test_diam))
    _, d = construct_ring_dqn(params, cfg, make_latency("uniform", 8, seed=1),
                              np.random.default_rng(0))
    assert np.isfinite(d) and d > 0
