"""Roofline derivation: HLO collective parser + term arithmetic."""
import pytest

from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS, Collective,
                                     parse_collectives, roofline_from)

HLO = """
ENTRY %main {
  %ar = f32[16,4096]{1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true
  %ag = bf16[32,1024]{1,0} all-gather(%y), channel_id=2, replica_groups=[4,8]<=[32], dimensions={1}
  %rs = f32[8,128]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[2,4]<=[8], dimensions={0}
  %cp = bf16[64]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1},{1,2}}
  %a2a = f32[16,16]{1,0} all-to-all(%v), channel_id=5, replica_groups={{0,1,2,3}}, dimensions={0}
  %ags = (bf16[8,8]{1,0}, bf16[8,64]{1,0}) all-gather-start(%u), channel_id=6, replica_groups=[1,8]<=[8], dimensions={1}
  %dot = f32[128,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_parse_collectives_ops_and_groups():
    colls = parse_collectives(HLO)
    by_op = {}
    for c in colls:
        by_op.setdefault(c.op, []).append(c)
    assert len(by_op["all-reduce"]) == 1
    ar = by_op["all-reduce"][0]
    assert ar.group_size == 16
    assert ar.result_bytes == 16 * 4096 * 4
    assert ar.transfer_bytes == pytest.approx(2 * ar.result_bytes * 15 / 16)

    ag = by_op["all-gather"][0]
    assert ag.group_size == 8
    assert ag.result_bytes == 32 * 1024 * 2
    assert ag.transfer_bytes == pytest.approx(ag.result_bytes * 7 / 8)

    rs = by_op["reduce-scatter"][0]
    assert rs.group_size == 4
    assert rs.transfer_bytes == pytest.approx(8 * 128 * 4 * 3)

    cp = by_op["collective-permute"][0]
    assert cp.transfer_bytes == 64 * 2

    a2a = by_op["all-to-all"][0]
    assert a2a.group_size == 4          # brace-style replica_groups

    # async start op: tuple result, max shape = gathered output
    starts = [c for c in colls if c.op == "all-gather"]
    assert len(starts) == 2
    assert starts[1].result_bytes == 8 * 64 * 2

    # the dot must NOT be picked up
    assert all(c.op != "dot" for c in colls)


def test_roofline_terms_and_dominant():
    cost = {"flops": PEAK_FLOPS * 0.5, "bytes accessed": HBM_BW * 2.0}
    roof = roofline_from(cost, HLO)
    assert roof.compute_s == pytest.approx(0.5)
    assert roof.memory_s == pytest.approx(2.0)
    assert roof.dominant == "memory"
    assert roof.collective_s == pytest.approx(roof.collective_bytes / ICI_BW)
    assert roof.n_collectives == 6


def test_active_param_count_moe():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.models import model as Mdl
    from repro.roofline.analysis import active_param_count

    cfg = get_arch("moonshot-v1-16b-a3b").smoke()
    shapes = jax.eval_shape(
        lambda: Mdl.init_params(cfg, jax.random.PRNGKey(0), jnp.float32))
    total = sum(int(l.size) for l in jax.tree.leaves(shapes))
    active = active_param_count(cfg, shapes)
    assert active < total
    # top-2 of 8 experts: expert share should shrink ~4x
    assert active > total * 0.2


def test_hlo_walk_counts_loop_trips():
    """The trip-aware walk must count a lax.scan body trip_count times —
    XLA's own cost_analysis counts it once (the bug the walk fixes)."""
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo_walk import walk

    w = jnp.zeros((64, 64), jnp.float32)
    x = jnp.zeros((64,), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return w @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    def unrolled(x, w):
        for _ in range(7):
            x = w @ x
        return x

    f_scan = walk(jax.jit(scanned).lower(x, w).compile().as_text())
    f_unr = walk(jax.jit(unrolled).lower(x, w).compile().as_text())
    truth = 7 * 2 * 64 * 64
    assert f_scan.dot_flops == truth
    assert f_unr.dot_flops == truth
    assert f_scan.n_while == 1 and f_scan.max_trip == 7


def test_hlo_walk_nested_loops():
    import jax
    import jax.numpy as jnp
    from repro.roofline.hlo_walk import walk

    w = jnp.zeros((32, 32), jnp.float32)
    x = jnp.zeros((32,), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            def inner(d, _):
                return w @ d, None
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    f = walk(jax.jit(nested).lower(x, w).compile().as_text())
    assert f.dot_flops == 5 * 3 * 2 * 32 * 32, f.dot_flops
