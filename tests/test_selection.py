"""Algorithm 3 + rho-based adaptive ring selection (§V)."""
import numpy as np

from repro import overlay
from repro.core.diameter import diameter_scipy
from repro.core.selection import (adapt, clustering_ratio,
                                  measure_latency_stats, select_ring_kind)
from repro.core.topology import make_latency


def test_chord_rho_high_perigee_rho_low():
    """Paper: Chord's random ring has rho ~ 1; Perigee's nearest-neighbour
    overlay has rho ~ 0."""
    w = make_latency("bitnode", 80, seed=0)
    rng = np.random.default_rng(0)
    chord_ov = overlay.build("chord", w, rng=rng)
    peri_ov = overlay.build("perigee", w, rng=rng)
    rho_c = clustering_ratio(
        measure_latency_stats(w, chord_ov.adjacency, seed=0))
    rho_p = clustering_ratio(
        measure_latency_stats(w, peri_ov.adjacency, seed=0))
    assert rho_c > 0.6, rho_c
    assert rho_p < 0.4, rho_p
    assert select_ring_kind(rho_c) == "nearest"
    assert select_ring_kind(rho_p) == "random"


def test_gossip_aggregation_converges_to_mean():
    w = make_latency("uniform", 40, seed=1)
    ov = overlay.build("rapid", w, seed=0)
    s_few = measure_latency_stats(w, ov.adjacency, gossip_rounds=60, seed=0)
    # direct averages (no gossip) as ground truth via many rounds
    assert s_few.l_global > s_few.l_min
    assert s_few.l_local > 0


def test_adapt_improves_chord():
    """Adding the selected ring must not hurt, and usually helps, the
    diameter (paper Figs. 5/11/15)."""
    w = make_latency("fabric", 60, seed=2)
    ov = overlay.build("chord", w, seed=0)
    d0 = diameter_scipy(ov.adjacency)
    new_ov, kind, rho = adapt(ov, seed=0)
    d1 = diameter_scipy(new_ov.adjacency)
    assert kind in ("nearest", "random", "keep")
    assert d1 <= d0 + 1e-9, (d0, d1)
    if kind != "keep":       # the winning ring is appended, never in place
        assert new_ov.num_rings == ov.num_rings + 1


def test_measure_latency_stats_small_networks():
    """Regression: the global sample is clamped to the n-1 available peers.
    The default k at n=2 (k=2 > 1 peer) and an explicit k_samples > n-1
    used to raise ``ValueError: Cannot take a larger sample than
    population when replace is False``."""
    w2 = make_latency("uniform", 2, seed=0)
    adj2 = overlay.Overlay.from_rings(w2, [np.arange(2)]).adjacency
    s = measure_latency_stats(w2, adj2, seed=0)            # default k = 2
    assert np.isfinite([s.l_local, s.l_global, s.l_min]).all()
    assert s.l_global == s.l_min                           # only one peer

    w5 = make_latency("gaussian", 5, seed=1)
    adj5 = overlay.Overlay.from_rings(w5, [np.arange(5)]).adjacency
    s = measure_latency_stats(w5, adj5, k_samples=8, seed=0)   # 8 > n-1 = 4
    assert np.isfinite([s.l_local, s.l_global, s.l_min]).all()
    assert s.l_global >= s.l_min
    # n=1 degenerates to zero stats instead of sampling an empty pool
    s1 = measure_latency_stats(np.zeros((1, 1), np.float32),
                               np.zeros((1, 1), np.float32))
    assert (s1.l_local, s1.l_global, s1.l_min) == (0.0, 0.0, 0.0)


def test_adapt_small_network_does_not_crash():
    """DGRO self-repair on a network churned down to n=2 must not raise."""
    w = make_latency("uniform", 2, seed=3)
    ov = overlay.Overlay.from_rings(w, [np.arange(2)], policy="dgro")
    new_ov, kind, rho = adapt(ov, seed=0)
    assert kind in ("nearest", "random", "keep")
    assert new_ov.n == 2


def test_adapt_deterministic_and_streams_decorrelated():
    """Fixed seed -> identical result (the measurement and candidate rngs
    are spawned children of the seed, not the seed itself)."""
    w = make_latency("fabric", 40, seed=5)
    ov = overlay.build("chord", w, seed=1)
    a1, kind1, rho1 = adapt(ov, seed=7)
    a2, kind2, rho2 = adapt(ov, seed=7)
    assert kind1 == kind2 and rho1 == rho2
    assert a1.equals(a2)
    # the candidate rng is NOT default_rng(seed): a random ring drawn from
    # the raw seed must differ from the ring adapt actually added
    if kind1 == "random":
        raw = np.random.default_rng(7).permutation(40)
        assert not np.array_equal(a1.rings[-1], raw)
