"""Algorithm 3 + rho-based adaptive ring selection (§V)."""
import numpy as np

from repro import overlay
from repro.core.diameter import diameter_scipy
from repro.core.selection import (adapt, clustering_ratio,
                                  measure_latency_stats, select_ring_kind)
from repro.core.topology import make_latency


def test_chord_rho_high_perigee_rho_low():
    """Paper: Chord's random ring has rho ~ 1; Perigee's nearest-neighbour
    overlay has rho ~ 0."""
    w = make_latency("bitnode", 80, seed=0)
    rng = np.random.default_rng(0)
    chord_ov = overlay.build("chord", w, rng=rng)
    peri_ov = overlay.build("perigee", w, rng=rng)
    rho_c = clustering_ratio(
        measure_latency_stats(w, chord_ov.adjacency, seed=0))
    rho_p = clustering_ratio(
        measure_latency_stats(w, peri_ov.adjacency, seed=0))
    assert rho_c > 0.6, rho_c
    assert rho_p < 0.4, rho_p
    assert select_ring_kind(rho_c) == "nearest"
    assert select_ring_kind(rho_p) == "random"


def test_gossip_aggregation_converges_to_mean():
    w = make_latency("uniform", 40, seed=1)
    ov = overlay.build("rapid", w, seed=0)
    s_few = measure_latency_stats(w, ov.adjacency, gossip_rounds=60, seed=0)
    # direct averages (no gossip) as ground truth via many rounds
    assert s_few.l_global > s_few.l_min
    assert s_few.l_local > 0


def test_adapt_improves_chord():
    """Adding the selected ring must not hurt, and usually helps, the
    diameter (paper Figs. 5/11/15)."""
    w = make_latency("fabric", 60, seed=2)
    ov = overlay.build("chord", w, seed=0)
    d0 = diameter_scipy(ov.adjacency)
    new_ov, kind, rho = adapt(ov, seed=0)
    d1 = diameter_scipy(new_ov.adjacency)
    assert kind in ("nearest", "random", "keep")
    assert d1 <= d0 + 1e-9, (d0, d1)
    if kind != "keep":       # the winning ring is appended, never in place
        assert new_ov.num_rings == ov.num_rings + 1
