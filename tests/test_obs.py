"""Observability stack: registry semantics, quantile math, concurrency,
structured logging, and the live /v1/metrics scrape.

Covers the PR's satellites explicitly:

* duplicate registration — identical spec returns the SAME instrument,
  conflicting type/help/labels/buckets raise at registration time;
* histogram quantile estimates stay within the containing bucket's width of
  ``np.quantile`` over the same samples (property test);
* concurrent counter increments from N threads sum exactly (no lost
  updates);
* ``GET /v1/metrics`` answers while a re-optimization cycle is in flight,
  and scraped counters match the workload exactly;
* durations come from the monotonic clock — a wall-clock step cannot
  corrupt ``uptime_s``.
"""
from __future__ import annotations

import logging
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import serde
from repro.dynamics.scenarios import Event, Trace, poisson_churn
from repro.obs import (REGISTRY, Histogram, MetricsRegistry, TimedRLock,
                       current_span, jit_span, parse_prometheus, span)
from repro.obs.logsetup import KVFormatter, configure, get_logger, kv
from repro.service import (Reoptimizer, ServiceClient, ServiceServer,
                           ServiceState)

N0 = 20


def _world(n0=N0, dist="bitnode", seed=3) -> Trace:
    return Trace(n0=n0, capacity=2 * n0, dist=dist, seed=seed,
                 events=[], name="obs-world")


def _events(n0=N0, seed=3, events=20):
    tr = poisson_churn(n0=n0, dist="bitnode", seed=seed, horizon=30_000.0,
                       join_rate=events / 2 / 30_000.0,
                       leave_rate=events / 2 / 30_000.0)
    return sorted(tr.events, key=lambda e: e.time)[:events]


# ---------------------------------------------------------------------------
# registration semantics (satellite)
# ---------------------------------------------------------------------------

def test_same_spec_registration_returns_existing_instrument():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "things", labels=("k",))
    b = reg.counter("x_total", "things", labels=("k",))
    assert a is b
    h1 = reg.histogram("h_seconds", "hh", buckets=(1.0, 2.0))
    h2 = reg.histogram("h_seconds", "hh", buckets=(1.0, 2.0))
    assert h1 is h2


def test_conflicting_registration_raises_at_registration_time():
    reg = MetricsRegistry()
    reg.counter("x_total", "things", labels=("k",))
    with pytest.raises(ValueError):
        reg.gauge("x_total", "things")           # different kind
    with pytest.raises(ValueError):
        reg.counter("x_total", "other help", labels=("k",))
    with pytest.raises(ValueError):
        reg.counter("x_total", "things")         # different labels
    reg.histogram("h_seconds", "hh", buckets=(1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("h_seconds", "hh", buckets=(1.0, 2.0, 3.0))


def test_bad_histogram_buckets_rejected():
    with pytest.raises(ValueError):
        Histogram("h", buckets=())
    with pytest.raises(ValueError):
        Histogram("h", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", buckets=(1.0, 1.0))


# ---------------------------------------------------------------------------
# quantile math vs numpy (property test, satellite)
# ---------------------------------------------------------------------------

def _bucket_tolerance(buckets, samples, value):
    """The histogram's resolution at ``value``: the containing bucket's
    width (clamp slack past the last bound)."""
    bounds = list(buckets)
    if value > bounds[-1]:
        return float(np.max(samples)) - bounds[-1] + 1e-9
    hi = next(b for b in bounds if value <= b)
    lo = max([float(np.min(samples))] + [b for b in bounds if b < hi])
    return max(hi - lo, 0.0) + 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 300), st.floats(0.0, 1.0))
def test_histogram_quantile_within_bucket_of_numpy(seed, q):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 400))
    samples = rng.gamma(2.0, 0.05, size=n)       # spans several buckets
    h = Histogram("q_seconds")
    for s in samples:
        h.observe(float(s))
    est = h.quantile(q)
    true = float(np.quantile(samples, q, method="inverted_cdf"))
    assert float(np.min(samples)) <= est <= float(np.max(samples))
    assert abs(est - true) <= _bucket_tolerance(h.buckets, samples, true)


def test_histogram_summary_and_empty_quantile():
    h = Histogram("s_seconds")
    assert np.isnan(h.quantile(0.5))
    for v in (0.003, 0.004, 0.2):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 3 and s["sum"] == pytest.approx(0.207)
    with pytest.raises(ValueError):
        h.quantile(1.5)


# ---------------------------------------------------------------------------
# thread-safety: concurrent increments sum exactly (satellite)
# ---------------------------------------------------------------------------

def test_concurrent_counter_increments_sum_exactly():
    reg = MetricsRegistry()
    ctr = reg.counter("hits_total", "hits")
    lab = reg.counter("lhits_total", "labelled hits", labels=("who",))
    hist = reg.histogram("obs_seconds", "observations")
    n_threads, per_thread = 8, 2_000

    def work(i):
        child = lab.labels(who=f"t{i % 2}")
        for _ in range(per_thread):
            ctr.inc()
            child.inc()
            hist.observe(0.01)

    threads = [threading.Thread(target=work, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = n_threads * per_thread
    assert ctr.value == total
    assert (lab.labels(who="t0").value + lab.labels(who="t1").value) == total
    assert hist.count == total


# ---------------------------------------------------------------------------
# spans + jit-aware timing
# ---------------------------------------------------------------------------

def test_span_nesting_and_counts():
    reg = MetricsRegistry()
    assert current_span() is None
    with span("outer", registry=reg):
        assert current_span() == "outer"
        with span("inner", registry=reg):
            assert current_span() == "inner"
        assert current_span() == "outer"
    assert current_span() is None
    h = reg.get("repro_span_seconds")
    assert h.labels(span="outer").count == 1
    assert h.labels(span="inner").count == 1


def test_jit_span_splits_compile_from_execute():
    reg = MetricsRegistry()
    for _ in range(3):
        with jit_span("fn.a", key=(4, 4), registry=reg):
            pass
    with jit_span("fn.a", key=(8, 8), registry=reg):  # retrace: new key
        pass
    comp = reg.get("repro_jit_compile_seconds").labels(fn="fn.a")
    execd = reg.get("repro_jit_execute_seconds").labels(fn="fn.a")
    assert comp.count == 2          # one first-call per distinct key
    assert execd.count == 2


def test_disabled_registry_records_nothing():
    reg = MetricsRegistry()
    ctr = reg.counter("c_total", "c")
    hist = reg.histogram("h_seconds", "h")
    reg.set_enabled(False)
    ctr.inc()
    hist.observe(1.0)
    with span("quiet", registry=reg):
        pass
    reg.set_enabled(True)
    assert ctr.value == 0 and hist.count == 0
    assert reg.get("repro_span_seconds") is None or \
        reg.get("repro_span_seconds").labels(span="quiet").count == 0


def test_timed_rlock_reentrant_and_records_waits():
    reg = MetricsRegistry()
    lock = TimedRLock(registry=reg, name="w_seconds", help="w")
    with lock:
        with lock:                  # re-entrant acquire must not deadlock
            pass
    hist = reg.get("w_seconds")
    assert hist.count == 1          # only the top-level acquire is observed

    entered = threading.Event()
    release = threading.Event()

    def holder():
        with lock:
            entered.set()
            release.wait(5.0)

    t = threading.Thread(target=holder)
    t.start()
    assert entered.wait(5.0)
    t2_done = threading.Event()

    def waiter():
        with lock:
            t2_done.set()

    t2 = threading.Thread(target=waiter)
    t2.start()
    time.sleep(0.05)
    release.set()
    t.join()
    assert t2_done.wait(5.0)
    t2.join()
    assert hist.count == 3
    assert hist.quantile(1.0) >= 0.01   # the contended acquire waited


# ---------------------------------------------------------------------------
# exposition: render -> parse roundtrip, JSON export
# ---------------------------------------------------------------------------

def test_prometheus_render_parse_roundtrip():
    reg = MetricsRegistry()
    reg.counter("ev_total", "events", labels=("kind",)).labels(
        kind="join").inc(3)
    reg.gauge("temp", "temperature").set(4.5)
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    text = reg.render_prometheus()
    parsed = parse_prometheus(text)
    assert parsed["ev_total"][(("kind", "join"),)] == 3.0
    assert parsed["temp"][()] == 4.5
    assert parsed["lat_seconds_bucket"][(("le", "0.1"),)] == 1.0
    assert parsed["lat_seconds_bucket"][(("le", "1"),)] == 2.0     # cumulative
    assert parsed["lat_seconds_bucket"][(("le", "+Inf"),)] == 2.0
    assert parsed["lat_seconds_count"][()] == 2.0


def test_gauge_callback_read_at_scrape_time():
    reg = MetricsRegistry()
    box = {"v": 1.0}
    reg.gauge("live", "live value").set_function(lambda: box["v"])
    assert parse_prometheus(reg.render_prometheus())["live"][()] == 1.0
    box["v"] = 7.0
    assert parse_prometheus(reg.render_prometheus())["live"][()] == 7.0


def test_render_json_is_schema_stamped():
    reg = MetricsRegistry()
    reg.counter("c_total", "c").inc()
    doc = serde.loads(reg.render_json(), what="metrics json")
    assert doc["schema"] == serde.SCHEMA_VERSION
    m = doc["metrics"]["c_total"]
    assert m["kind"] == "counter"
    assert m["series"][0]["value"] == 1.0


# ---------------------------------------------------------------------------
# structured logging
# ---------------------------------------------------------------------------

def test_kv_formatting_quotes_and_types():
    line = kv("reopt.cycle", outcome="swapped", n=3, ratio=0.25,
              ok=True, msg='has space and "quote"')
    assert line.startswith("event=reopt.cycle ")
    assert "outcome=swapped" in line and "n=3" in line
    assert "ratio=0.25" in line and "ok=true" in line
    assert 'msg="has space and \\"quote\\""' in line


def test_log_level_env_and_formatter(monkeypatch):
    monkeypatch.setenv("REPRO_LOG_LEVEL", "debug")
    configure(force=True)
    log = get_logger("test")
    assert log.name == "repro.test"
    assert logging.getLogger("repro").level == logging.DEBUG
    rec = logging.LogRecord("repro.test", logging.INFO, __file__, 1,
                            kv("unit.test", n=1), None, None)
    out = KVFormatter().format(rec)
    assert "level=info" in out and "logger=repro.test" in out
    assert "event=unit.test n=1" in out
    monkeypatch.delenv("REPRO_LOG_LEVEL")
    configure(force=True)           # restore the library default


# ---------------------------------------------------------------------------
# monotonic clock discipline (satellite)
# ---------------------------------------------------------------------------

def test_uptime_survives_wall_clock_step(monkeypatch):
    state = ServiceState.fresh(_world(), policy="rapid", seed=0)
    u0 = state.uptime_s
    # a wall-clock step (NTP, suspend) must not corrupt uptime
    monkeypatch.setattr(time, "time", lambda: 0.0)
    u1 = state.uptime_s
    assert 0.0 <= u0 <= u1 < 60.0
    assert state.stats()["uptime_s"] < 60.0


# ---------------------------------------------------------------------------
# the live scrape: /v1/metrics under load (satellite)
# ---------------------------------------------------------------------------

def test_metrics_endpoint_scrape_under_inflight_reopt():
    state = ServiceState.fresh(_world(), policy="dgro", seed=0)
    server = ServiceServer(state, reopt_enabled=False).start()
    try:
        c = ServiceClient(server.url)
        c.wait_ready(timeout=30)
        before = c.metrics()

        evs = _events(events=20)
        res = c.post_events(evs)
        assert res["accepted"] == len(evs)
        c.stats()

        reopt = Reoptimizer(state, every=2**31, eps=0.49, seed=0)
        worker = threading.Thread(target=reopt.step, kwargs={"force": True})
        worker.start()
        scrapes = 0
        while worker.is_alive():    # scrape WHILE the cycle is in flight
            after = c.metrics()
            scrapes += 1
        worker.join()
        assert scrapes > 0, "reopt finished before any scrape landed"
        after = c.metrics()

        def delta(series, **labels):
            key = tuple(sorted(labels.items()))
            return (after.get(series, {}).get(key, 0.0)
                    - before.get(series, {}).get(key, 0.0))

        assert delta("repro_service_events_ingested_total") == len(evs)
        assert delta("repro_http_requests_total", method="POST",
                     endpoint="events", status="200") == 1
        # gauges read live state: version/staleness/live-count exported
        st_now = c.stats()
        assert after["repro_service_overlay_version"][()] == st_now["version"]
        assert after["repro_service_n_live"][()] == st_now["n_live"]
        assert (after["repro_service_stale_entries"][()]
                == st_now["pending_deletions"])
        # the reopt cycle left spans + an outcome counter behind
        outcomes = after.get("repro_reopt_cycles_total", {})
        assert sum(outcomes.values()) >= sum(
            before.get("repro_reopt_cycles_total", {}).values()) + 1
        # JSON flavour of the same endpoint is schema-stamped
        doc = serde.loads(_metrics_json(c), what="metrics json")
        assert doc["schema"] == serde.SCHEMA_VERSION
    finally:
        server.stop(final_snapshot=False)


def _metrics_json(c: ServiceClient) -> str:
    import urllib.request
    with urllib.request.urlopen(f"{c.base_url}/v1/metrics?format=json",
                                timeout=30) as resp:
        return resp.read().decode()


def test_http_request_latency_histogram_counts_requests():
    state = ServiceState.fresh(_world(), policy="rapid", seed=0)
    server = ServiceServer(state, reopt_enabled=False).start()
    try:
        c = ServiceClient(server.url)
        c.wait_ready(timeout=30)
        before = c.metrics()
        for _ in range(5):
            c.stats()
        after = c.metrics()
        key = (("endpoint", "stats"),)
        d = (after["repro_http_request_seconds_count"][key]
             - before.get("repro_http_request_seconds_count", {}).get(key, 0))
        assert d == 5
    finally:
        server.stop(final_snapshot=False)
