"""DGRO device-order integration (launch.mesh) — numpy-level tests plus a
subprocess mesh-construction check."""
import subprocess
import sys

from conftest import subproc_env

import numpy as np

from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.launch.mesh import dgro_host_order, model_dcn_latency



def test_model_dcn_latency_structure():
    lat = model_dcn_latency(32, n_pods=2, seed=0)
    assert lat.shape == (32, 32)
    assert np.allclose(lat, lat.T)
    assert np.allclose(np.diag(lat), 0)
    # cross-pod latencies dominate intra-pod
    intra = lat[:16, :16][np.triu_indices(16, 1)]
    cross = lat[:16, 16:]
    assert cross.mean() > 2 * intra.mean()


def test_dgro_host_order_improves_ring():
    lat = model_dcn_latency(32, n_pods=2, seed=1)
    order, report = dgro_host_order(lat)
    assert sorted(order) == list(range(32))
    d_dgro = diameter_scipy(adjacency_from_rings(lat, [np.asarray(order)]))
    assert d_dgro == report["diameter"]
    assert report["diameter"] <= report["random_diameter"] + 1e-9


def test_make_production_mesh_shapes():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}, m2.shape
m3 = make_production_mesh(multi_pod=True, dgro_order=True)
assert dict(m3.shape) == {"pod": 2, "data": 16, "model": 16}
assert hasattr(m3, "dgro_report")
# DGRO order must be a permutation of the same device set
d_base = {d.id for d in m2.devices.flat}
d_dgro = {d.id for d in m3.devices.flat}
assert d_base == d_dgro
print("OK", m3.dgro_report["selected"], round(m3.dgro_report["diameter"], 1))
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=subproc_env(),
                         cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]
