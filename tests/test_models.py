"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import model as Mdl


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_forward_and_train_step(name):
    cfg = ARCHS[name].smoke()
    params = Mdl.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    vis = None
    if cfg.frontend == "vision":
        vis = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.n_patches, cfg.d_model))
    logits, aux = Mdl.forward(cfg, params, toks, mode="train",
                              vision_embeds=vis)
    exp_s = S + (cfg.n_patches if vis is not None else 0)
    assert logits.shape == (B, exp_s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one train step: loss finite, params move
    from repro.train.train_step import TrainConfig, init_state, train_step
    tc = TrainConfig(remat=False, microbatches=1)
    state = init_state(cfg, jax.random.PRNGKey(3))
    batch = {"tokens": toks, "labels": toks}
    if vis is not None:
        batch["vision_embeds"] = vis
    new_state, metrics = train_step(cfg, tc, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(new_state.params),
                        jax.tree.leaves(state.params)))
    assert moved


@pytest.mark.parametrize("name", ["qwen1.5-4b", "gemma3-1b",
                                  "falcon-mamba-7b", "zamba2-7b",
                                  "moonshot-v1-16b-a3b"])
def test_prefill_decode_matches_train_forward(name):
    cfg = dataclasses.replace(ARCHS[name].smoke(), capacity_factor=16.0)
    params = Mdl.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 3), 0, cfg.vocab)
    full, _ = Mdl.forward(cfg, params, toks, mode="train")
    caches = Mdl.init_caches(cfg, B, max_len=64)
    lp, caches, _ = Mdl.forward(cfg, params, toks[:, :S], mode="prefill",
                                caches=caches)
    errs = [float(jnp.max(jnp.abs(lp - Mdl.forward(
        cfg, params, toks[:, :S], mode="train")[0][:, -1])))]
    for t in range(S, S + 3):
        ld, caches = Mdl.forward(cfg, params, toks[:, t:t + 1], mode="decode",
                                 caches=caches, pos=jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(ld - full[:, t]))))
    assert max(errs) < 2e-3, errs


def test_gemma_pattern_scan_vs_unrolled():
    """Pattern-period scan (blocks of 6) must equal a naive unrolled stack:
    verified indirectly — remainder layers get the correct per-position kind."""
    cfg = ARCHS["gemma3-1b"].smoke()   # 12 layers, period 6 -> 2 blocks
    assert Mdl.pattern_period(cfg) == 6
    kinds = [Mdl.layer_kind(cfg, j) for j in range(6)]
    assert [k["window"] is None for k in kinds] == [False] * 5 + [True]


def test_moe_drop_rate_reasonable():
    """With untrained (roughly uniform) routing, capacity 1.25 should drop
    only a few percent of tokens."""
    from repro.models.moe import init_moe, moe_apply, _capacity
    cfg = ARCHS["moonshot-v1-16b-a3b"].smoke()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64, cfg.d_model))
    y, probs = moe_apply(p, x, cfg)
    # tokens that got zero output = fully dropped (both experts over capacity)
    zero_rows = float(jnp.mean(jnp.all(y == 0, axis=-1)))
    assert zero_rows < 0.2


def test_fp8_kv_cache_decode_close():
    """fp8 KV cache (serving memory optimization, §Perf): decode logits stay
    close to the bf16-cache path."""
    cfg = ARCHS["granite-8b"].smoke()
    params = Mdl.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0, cfg.vocab)
    ref_caches = Mdl.init_caches(cfg, B, 64, jnp.float32)
    f8_caches = Mdl.init_caches(cfg, B, 64, jnp.float8_e4m3fn)
    lr, ref_caches, _ = Mdl.forward(cfg, params, toks[:, :S], mode="prefill",
                                    caches=ref_caches)
    l8, f8_caches, _ = Mdl.forward(cfg, params, toks[:, :S], mode="prefill",
                                   caches=f8_caches)
    errs = [float(jnp.max(jnp.abs(lr - l8)))]
    for t in range(S, S + 2):
        dr, ref_caches = Mdl.forward(cfg, params, toks[:, t:t + 1],
                                     mode="decode", caches=ref_caches,
                                     pos=jnp.int32(t))
        d8, f8_caches = Mdl.forward(cfg, params, toks[:, t:t + 1],
                                    mode="decode", caches=f8_caches,
                                    pos=jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(dr - d8))))
    # fp8 e4m3 carries ~2 significant digits; logits of a random-init smoke
    # model are O(1)
    assert max(errs) < 0.7, errs
    assert float(jnp.mean(jnp.abs(dr - d8))) < 0.1
