"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels.minplus.ops import minplus
from repro.kernels.minplus.ref import minplus_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


# --- minplus ---------------------------------------------------------------

@pytest.mark.parametrize("shape", [(128, 128, 128), (64, 100, 36),
                                   (256, 128, 384), (13, 17, 29), (1, 1, 1)])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_minplus_shapes(shape, dtype):
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = jnp.asarray(rng.uniform(0, 10, (m, k)).astype(dtype))
    b = jnp.asarray(rng.uniform(0, 10, (k, n)).astype(dtype))
    got = minplus(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(minplus_ref(a.astype(jnp.float32),
                                                      b.astype(jnp.float32))),
                               rtol=1e-6, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 80), st.integers(2, 80), st.integers(0, 10**6))
def test_minplus_property(m, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.uniform(0, 100, (m, n)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 100, (n, m)).astype(np.float32))
    got = np.asarray(minplus(a, b, interpret=True))
    want = np.asarray(minplus_ref(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-4)


def test_minplus_batched_kernel_matches_ref():
    """Batched Pallas kernel (grid over batch axis, interpret mode on CPU)
    vs the vmapped jnp oracle."""
    from repro.kernels.minplus.ops import minplus_batched
    from repro.kernels.minplus.ref import minplus_batched_ref
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.uniform(0, 10, (3, 20, 33)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 10, (3, 33, 17)).astype(np.float32))
    got = minplus_batched(a, b, block=16, force_kernel=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(minplus_batched_ref(a, b)),
                               rtol=1e-6, atol=1e-5)


def test_batched_apsp_kernel_path_matches_scipy():
    """use_kernel=True routes the batched APSP through kernels.minplus
    (oracle on CPU, Pallas grid-over-batch on TPU)."""
    from repro.core.batcheval import adjacency_batch_from_rings, diameters
    from repro.core.construction import random_ring
    from repro.core.diameter import diameter_scipy
    from repro.core.topology import make_latency
    rng = np.random.default_rng(4)
    w = make_latency("uniform", 24, seed=8)
    genomes = np.stack([[random_ring(rng, 24)] for _ in range(4)])
    batch = adjacency_batch_from_rings(w, genomes)
    got = diameters(batch, use_kernel=True)
    for i in range(4):
        assert float(got[i]) == pytest.approx(diameter_scipy(batch[i]),
                                              rel=1e-5)


def test_minplus_apsp_integration():
    """The kernel plugged into the APSP loop gives scipy's diameter."""
    from repro.core.diameter import apsp, diameter_scipy, adjacency_from_rings
    from repro.core.topology import make_latency
    from repro.core.construction import random_ring
    w = make_latency("uniform", 40, seed=7)
    adj = adjacency_from_rings(w, [random_ring(np.random.default_rng(0), 40)])
    d_kernel = np.asarray(apsp(jnp.asarray(adj), use_kernel=True))
    assert float(d_kernel.max()) == pytest.approx(diameter_scipy(adj), rel=1e-5)


@pytest.mark.parametrize("shape", [(100, 36, 20), (37, 53, 29), (5, 130, 7)])
def test_minplus_adaptive_block_bit_identical(shape):
    """Regression for the pad-to-128 waste: with the default (adaptive)
    block the padded kernel output must be BIT-identical to the jnp oracle
    for non-multiple shapes — min over the INF-padded candidates is exact,
    so any deviation means the padding leaked into the reduction."""
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    a = jnp.asarray(rng.uniform(0, 10, (m, k)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 10, (k, n)).astype(np.float32))
    got = np.asarray(minplus(a, b, interpret=True))
    assert np.array_equal(got, np.asarray(minplus_ref(a, b))), shape


def test_minplus_batched_adaptive_block_bit_identical():
    from repro.kernels.minplus.ops import minplus_batched
    from repro.kernels.minplus.ref import minplus_batched_ref
    rng = np.random.default_rng(11)
    a = jnp.asarray(rng.uniform(0, 10, (2, 45, 70)).astype(np.float32))
    b = jnp.asarray(rng.uniform(0, 10, (2, 70, 31)).astype(np.float32))
    got = np.asarray(minplus_batched(a, b, force_kernel=True))
    assert np.array_equal(got, np.asarray(minplus_batched_ref(a, b)))


def test_adaptive_block_sizes():
    """The auto block covers small operands without padding to 128 and
    the auto tile splits non-multiple N into balanced multiple-of-8 tiles."""
    from repro.kernels.minplus.ops import _auto_block, default_tile
    assert _auto_block(20, 33) == 40       # ceil(33 -> /8) is 40, not 128
    assert _auto_block(7, 5) == 8
    assert _auto_block(300, 40) == 128     # large dims still cap at 128
    assert default_tile(256) == 256
    assert default_tile(300) == 152        # 2 tiles of 152, not 2 of 256
    assert default_tile(1024) == 256


# --- tiled (blocked) Floyd-Warshall APSP ------------------------------------

def _ring_adj(n, seed, k_rings=2):
    from repro.core.construction import random_ring
    from repro.core.diameter import adjacency_from_rings
    from repro.core.topology import make_latency
    rng = np.random.default_rng(seed)
    w = make_latency("uniform", n, seed=seed)
    return adjacency_from_rings(w, [random_ring(rng, n)
                                    for _ in range(k_rings)])


@pytest.mark.parametrize("n,tile", [(24, 8), (37, 16), (64, 16)])
def test_apsp_tiled_kernel_bitwise_matches_ref(n, tile):
    """Pallas blocked FW (interpret on CPU) vs the jnp twin: the two run
    the same blocked schedule over the same candidates, so the float32
    results must be bit-identical — non-multiple N exercises the INF pad."""
    from repro.kernels.minplus.ops import apsp_tiled
    adj = jnp.asarray(_ring_adj(n, seed=n))
    ref = np.asarray(apsp_tiled(adj, tile=tile))
    ker = np.asarray(apsp_tiled(adj, tile=tile, force_kernel=True,
                                interpret=True))
    assert np.array_equal(ref, ker), (n, tile)
    sym = np.asarray(apsp_tiled(adj, tile=tile, symmetric=True))
    assert np.array_equal(ref, sym), (n, tile)


def test_apsp_tiled_matches_scipy():
    from scipy.sparse.csgraph import shortest_path
    from repro.core.diameter import INF, is_edge
    from repro.kernels.minplus.ops import apsp_tiled
    adj = _ring_adj(30, seed=5)
    got = np.asarray(apsp_tiled(jnp.asarray(adj), tile=8))
    graph = np.where(np.asarray(is_edge(adj)), adj, 0.0)
    want = shortest_path(graph, method="D", directed=False)
    np.testing.assert_allclose(np.where(got >= INF / 2, np.inf, got), want,
                               rtol=1e-5)


# --- flash attention --------------------------------------------------------

CASES = [
    dict(b=1, hq=2, hkv=2, tq=128, tk=128, d=128, causal=True, window=None),
    dict(b=2, hq=4, hkv=2, tq=256, tk=256, d=64, causal=True, window=None),
    dict(b=1, hq=4, hkv=1, tq=200, tk=200, d=80, causal=True, window=96),
    dict(b=1, hq=2, hkv=2, tq=128, tk=384, d=128, causal=False, window=None),
    dict(b=1, hq=8, hkv=2, tq=64, tk=64, d=32, causal=True, window=32),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_flash_attention_sweep(case, dtype, tol):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (case["b"], case["hq"], case["tq"],
                                      case["d"]))).astype(dtype)
    k = jnp.asarray(rng.normal(0, 1, (case["b"], case["hkv"], case["tk"],
                                      case["d"]))).astype(dtype)
    v = jnp.asarray(rng.normal(0, 1, (case["b"], case["hkv"], case["tk"],
                                      case["d"]))).astype(dtype)
    got = flash_attention(q, k, v, causal=case["causal"],
                          window=case["window"], interpret=True)
    want = attention_ref(q, k, v, causal=case["causal"], window=case["window"])
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, (case, dtype, err)


def test_chunked_attention_matches_ref():
    from repro.models.layers import _chunked_attention
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (2, 4, 4096, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (2, 2, 4096, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (2, 2, 4096, 32)).astype(np.float32))
    for w in (None, 512):
        got = _chunked_attention(q, k, v, window=w)
        want = attention_ref(q, k, v, causal=True, window=w)
        assert float(jnp.max(jnp.abs(got - want))) < 2e-5


# --- fused rmsnorm -----------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 64), (3, 7, 96), (256, 1152), (1, 8)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-6), (jnp.bfloat16, 2e-2)])
def test_rmsnorm_kernel(shape, dtype, tol):
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 2, shape)).astype(dtype)
    s = jnp.asarray(rng.normal(0, 0.1, shape[-1:])).astype(dtype)
    got = rmsnorm(x, s, interpret=True)
    want = rmsnorm_ref(x, s)
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    assert err < tol, (shape, dtype, err)


def test_rmsnorm_matches_model_layer():
    from repro.kernels.rmsnorm.ops import rmsnorm
    from repro.models.layers import rms_norm
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 1, (5, 128)).astype(np.float32))
    s = jnp.asarray(rng.normal(0, 0.1, (128,)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(rmsnorm(x, s, interpret=True)),
                               np.asarray(rms_norm(x, s)), rtol=1e-5, atol=1e-6)
