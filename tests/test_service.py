"""Tests for the live control plane (repro.service) and schema versioning.

Covers the tentpole's core invariants:

* serde: every serialized payload is schema-stamped, legacy payloads load,
  future payloads are rejected loudly;
* the live ingest path (``ChurnEngine.process``) applies the same event
  stream as the replay path (``run``) to the same final state;
* bounded staleness: every distance served while deletions are pending is a
  LOWER bound on the exact distance;
* crash recovery: a death between the re-optimization swap and the snapshot
  commit restores to the pre-swap overlay — both in-process (crash hook)
  and as a real daemon subprocess (``REPRO_SERVICE_CRASH_AFTER_SWAP``).
"""
from __future__ import annotations

import json
import subprocess
import sys
import threading

import numpy as np
import pytest

from conftest import subproc_env
from repro import overlay, serde
from repro.core.diameter import INF
from repro.core.topology import make_latency
from repro.dynamics.engine import ChurnEngine, DGROPolicy
from repro.dynamics.scenarios import (Event, Trace, churn_with_drift,
                                      merge_traces, poisson_churn)
from repro.service import (Reoptimizer, ServiceClient, ServiceError,
                           ServiceServer, ServiceState, latest_snapshot,
                           list_snapshots, write_snapshot)

N0 = 24


def _world(n0=N0, capacity=None, dist="bitnode", seed=3) -> Trace:
    return Trace(n0=n0, capacity=capacity or 2 * n0, dist=dist, seed=seed,
                 events=[], name="test-world")


def _trace(n0=N0, seed=3, events=30) -> Trace:
    tr = poisson_churn(n0=n0, dist="bitnode", seed=seed, horizon=30_000.0,
                       join_rate=events / 2 / 30_000.0,
                       leave_rate=events / 2 / 30_000.0)
    return Trace(n0=tr.n0, capacity=tr.capacity, dist=tr.dist, seed=tr.seed,
                 events=sorted(tr.events, key=lambda e: e.time)[:events],
                 name=tr.name)


# ---------------------------------------------------------------------------
# serde: schema stamping (satellite)
# ---------------------------------------------------------------------------

def test_serde_stamps_and_roundtrips():
    s = serde.dumps({"x": 1})
    d = json.loads(s)
    assert d["schema"] == serde.SCHEMA_VERSION
    assert serde.loads(s, what="t")["x"] == 1


def test_serde_accepts_legacy_payload_without_schema():
    assert serde.loads('{"x": 2}', what="t")["x"] == 2


def test_serde_rejects_future_and_malformed_schema():
    future = json.dumps({"schema": serde.MAX_SCHEMA + 1})
    with pytest.raises(serde.SchemaError, match="only understands"):
        serde.loads(future, what="t")
    with pytest.raises(serde.SchemaError):
        serde.loads('{"schema": "banana"}', what="t")
    with pytest.raises(serde.SchemaError, match="JSON object"):
        serde.loads("[1, 2]", what="t")


def test_overlay_and_trace_json_carry_schema():
    w = make_latency("uniform", 12, seed=0)
    ov = overlay.build("chord", w, rng=np.random.default_rng(0))
    assert json.loads(ov.to_json())["schema"] == serde.SCHEMA_VERSION
    rt = overlay.Overlay.from_json(ov.to_json())
    assert np.array_equal(rt.adjacency, ov.adjacency)

    tr = _trace(events=6)
    assert json.loads(tr.to_json())["schema"] == serde.SCHEMA_VERSION
    rt2 = Trace.from_json(tr.to_json())
    assert rt2.events == tr.events

    future = dict(json.loads(tr.to_json()), schema=serde.MAX_SCHEMA + 1)
    with pytest.raises(serde.SchemaError):
        Trace.from_json(json.dumps(future))


def test_merged_churn_drift_scenario():
    tr = churn_with_drift(n0=16, seed=1, drift_steps=4)
    kinds = {e.kind for e in tr.events}
    assert "latency_drift" in kinds and {"join", "leave"} & kinds
    times = [e.time for e in tr.events]
    assert times == sorted(times)
    with pytest.raises(ValueError, match="latency world"):
        merge_traces(poisson_churn(n0=16, seed=1),
                     poisson_churn(n0=16, seed=2))


# ---------------------------------------------------------------------------
# live ingest path == replay path
# ---------------------------------------------------------------------------

def test_engine_process_matches_run_replay():
    tr = _trace(events=24)
    replayed = ChurnEngine(tr, DGROPolicy(), seed=5)
    replayed.run(record=False)

    live_world = Trace(n0=tr.n0, capacity=tr.capacity, dist=tr.dist,
                       seed=tr.seed, events=[], name=tr.name)
    live = ChurnEngine(live_world, DGROPolicy(), seed=5)
    for e in sorted(tr.events, key=lambda t: t.time):
        live.process(e)
    live.flush()

    assert np.array_equal(live.alive, replayed.alive)
    assert np.allclose(live.inc.adj, replayed.inc.adj)
    assert live.events_processed == replayed.events_processed
    assert np.isclose(live.inc.diameter(exact=True),
                      replayed.inc.diameter(exact=True))


def test_engine_process_rejects_time_travel():
    eng = ChurnEngine(_world(), DGROPolicy(), seed=0)
    eng.process(Event(time=100.0, kind="leave", node=0))
    with pytest.raises(ValueError, match="clock"):
        eng.process(Event(time=50.0, kind="leave", node=1))


# ---------------------------------------------------------------------------
# service state: queries + staleness bound
# ---------------------------------------------------------------------------

def test_state_ingest_and_query_surface():
    state = ServiceState.fresh(_world(), policy="dgro", seed=0)
    tr = _trace(events=16)
    res = state.ingest(sorted(tr.events, key=lambda e: e.time))
    assert res["accepted"] == 16 and res["applied"] >= 16

    st = state.stats()
    assert st["events_ingested"] == 16
    assert st["distances_are"] in ("exact", "lower-bound")

    adj = state.adjacency()
    assert adj["n_live"] == st["n_live"] == len(adj["nodes"])
    src, dst = adj["nodes"][0], adj["nodes"][-1]
    r = state.route(src, dst)
    assert r["reachable"] and r["distance"] > 0
    if r["path"] is not None:
        assert r["path"][0] == src and r["path"][-1] == dst
    with pytest.raises(ValueError, match="not a live node"):
        dead = next(u for u in range(state.engine.inc.capacity)
                    if u not in set(adj["nodes"]))
        state.route(src, dead)


def test_served_distances_are_lower_bounds_while_stale():
    """The bounded-staleness contract: between deletion-triggered rebuilds
    every served distance is <= the exact live distance."""
    state = ServiceState.fresh(_world(n0=20), policy="dgro",
                               rebuild_threshold=64, seed=0)
    inc = state.engine.inc
    live0 = list(inc.live_ids())
    # leave a third of the fleet without ever hitting the rebuild threshold
    t = 0.0
    for u in live0[::3]:
        t += 10.0
        state.ingest([Event(time=t, kind="leave", node=int(u))])
    assert inc.pending_deletions > 0
    assert state.stats()["distances_are"] == "lower-bound"
    assert state.diameter()["exact"] is False

    live = inc.live_ids()
    served = inc.distances[np.ix_(live, live)].copy()
    served_routes = {(int(a), int(b)): state.route(int(a), int(b))
                     for a in live[:4] for b in live[-4:] if a != b}
    inc.refresh()                      # ground truth: exact recompute
    exact = inc.distances[np.ix_(live, live)]
    assert (served <= exact + 1e-4).all(), "stale distance overestimated"
    for (a, b), r in served_routes.items():
        assert r["bound"] == "lower"
        truth = float(inc.distances[a, b])
        if r["distance"] is not None and truth < float(INF) / 2:
            assert r["distance"] <= truth + 1e-4
    assert state.stats()["distances_are"] == "exact"


# ---------------------------------------------------------------------------
# snapshots + crash recovery (satellite)
# ---------------------------------------------------------------------------

def test_snapshot_protocol_ignores_uncommitted(tmp_path):
    d = str(tmp_path)
    write_snapshot(d, 1, {"kind": "t", "x": 1})
    write_snapshot(d, 2, {"kind": "t", "x": 2})
    # a torn write: directory exists, no COMMITTED marker
    (tmp_path / "snap-000005").mkdir()
    (tmp_path / "snap-000005" / "state.json").write_text("{}")
    assert list_snapshots(d) == [1, 2]
    seq, payload = latest_snapshot(d)
    assert seq == 2 and payload["x"] == 2


def test_snapshot_restore_roundtrip(tmp_path):
    state = ServiceState.fresh(_world(), policy="dgro",
                               snapshot_dir=str(tmp_path), seed=0)
    tr = _trace(events=12)
    state.ingest(sorted(tr.events, key=lambda e: e.time))
    state.write_snapshot(reason="test")
    _, payload = latest_snapshot(str(tmp_path))
    assert payload["schema"] == serde.SCHEMA_VERSION

    restored = ServiceState.restore(str(tmp_path))
    assert restored.events_ingested == state.events_ingested
    assert np.isclose(restored.diameter(exact=True)["diameter"],
                      payload["diameter"])
    assert restored.stats()["n_live"] == state.stats()["n_live"]
    # the restored engine keeps ingesting from the restored clock
    restored.ingest([Event(time=state.engine.clock + 1.0, kind="leave",
                           node=int(restored.engine.inc.live_ids()[0]))])


class _Boom(RuntimeError):
    pass


def test_crash_between_swap_and_snapshot_restores_preswap(tmp_path):
    """Kill the service inside the torn-state window: the buffer swap
    landed in memory but the snapshot never committed.  Restore must serve
    the consistent PRE-swap overlay."""
    state = ServiceState.fresh(_world(n0=20, dist="gaussian"),
                               policy="rapid", snapshot_dir=str(tmp_path),
                               seed=0)
    state.write_snapshot(reason="baseline")
    pre_seq, pre = latest_snapshot(str(tmp_path))
    pre_version = state.version

    def boom():
        raise _Boom()

    reopt = Reoptimizer(state, every=2**31, eps=0.49, seed=0,
                        crash_hook=boom)
    crashed = False
    for _ in range(5):
        try:
            reopt.step(force=True)     # "keep" rounds never reach the hook
        except _Boom:
            crashed = True
            break
    assert crashed, "re-optimizer never swapped; cannot exercise the window"
    assert state.version == pre_version + 1          # swap landed in memory

    seq, payload = latest_snapshot(str(tmp_path))
    assert seq == pre_seq, "snapshot leaked out of the crash window"
    assert payload["version"] == pre_version

    restored = ServiceState.restore(str(tmp_path))
    assert restored.version == pre_version
    assert np.isclose(restored.diameter(exact=True)["diameter"],
                      pre["diameter"])


def test_reopt_commit_swaps_atomically_and_improves():
    state = ServiceState.fresh(_world(n0=20, dist="gaussian"),
                               policy="rapid", seed=0)
    d0 = state.diameter(exact=True)["diameter"]
    reopt = Reoptimizer(state, every=2**31, eps=0.49, seed=0)
    swapped = None
    for _ in range(5):
        swapped = reopt.step(force=True)
        if swapped:
            break
    assert swapped and swapped["edges_added"] > 0
    assert state.version >= 1
    d1 = state.diameter(exact=True)["diameter"]
    assert d1 <= d0 + 1e-5             # added edges only relax distances


# ---------------------------------------------------------------------------
# HTTP surface
# ---------------------------------------------------------------------------

def test_http_server_endpoints_and_versioning():
    state = ServiceState.fresh(_world(), policy="dgro", seed=0)
    server = ServiceServer(state, reopt_enabled=False).start()
    try:
        c = ServiceClient(server.url)
        h = c.wait_ready(timeout=30)
        assert h["api_versions"] == ["v1"]
        tr = _trace(events=10)
        res = c.post_events(sorted(tr.events, key=lambda e: e.time))
        assert res["accepted"] == 10
        assert c.stats()["events_ingested"] == 10
        nodes = c.adjacency()["nodes"]
        assert c.route(nodes[0], nodes[-1])["reachable"]

        with pytest.raises(ServiceError) as ei:
            c._request("GET", "/v9/stats")
        assert ei.value.status == 404 and "v1" in str(ei.value)
        with pytest.raises(ServiceError) as ei:
            c.route(-1, 10**6)
        assert ei.value.status == 400
        # replaying an old timestamp conflicts (409), state is unharmed
        with pytest.raises(ServiceError) as ei:
            c.post_events([Event(time=0.0, kind="leave", node=nodes[0])])
        assert ei.value.status == 409
        assert c.stats()["events_ingested"] == 10
    finally:
        server.stop(final_snapshot=False)


def test_http_queries_survive_inflight_reopt():
    state = ServiceState.fresh(_world(n0=20, dist="gaussian"),
                               policy="rapid", seed=0)
    server = ServiceServer(state, reopt_enabled=False).start()
    try:
        c = ServiceClient(server.url)
        c.wait_ready(timeout=30)
        reopt = Reoptimizer(state, every=2**31, eps=0.49, seed=0)
        worker = threading.Thread(target=reopt.step, kwargs={"force": True})
        worker.start()
        answered = 0
        while worker.is_alive():
            assert c.stats()["n_live"] == 20
            answered += 1
        worker.join()
        assert answered > 0, "reopt finished before any query landed"
        assert c.health()["status"] == "ok"
    finally:
        server.stop(final_snapshot=False)


# ---------------------------------------------------------------------------
# the real daemon: env-injected crash + restart (subprocess)
# ---------------------------------------------------------------------------

def test_daemon_crash_env_and_restart_consistency(tmp_path):
    snapdir = str(tmp_path)
    base_cmd = [sys.executable, "-m", "repro.service", "--n0", "20",
                "--dist", "gaussian", "--policy", "rapid", "--port", "0",
                "--snapshot-dir", snapdir, "--reopt-eps", "0.49",
                "--reopt-every", "1000000", "--snapshot-every", "1000000"]

    def boot(extra_env):
        proc = subprocess.Popen(base_cmd, stdout=subprocess.PIPE, text=True,
                                env=subproc_env(**extra_env), cwd=".")
        line = proc.stdout.readline().strip()
        assert line.startswith("SERVING "), line
        port = dict(kv.split("=") for kv in line.split()[1:])["port"]
        client = ServiceClient(f"http://127.0.0.1:{port}")
        client.wait_ready(timeout=60)
        return proc, client

    # phase 1: seed a committed snapshot, then crash inside the window
    proc, client = boot({"REPRO_SERVICE_CRASH_AFTER_SWAP": "1"})
    try:
        client.snapshot()
        pre_seq, pre = latest_snapshot(snapdir)
        client.reoptimize()
        rc = proc.wait(timeout=120)    # os._exit(17) after the swap
        assert rc == 17, f"daemon exited {rc}, expected the injected crash"
    finally:
        if proc.poll() is None:
            proc.kill()
            pytest.fail("daemon did not crash on the injected window")
    seq, payload = latest_snapshot(snapdir)
    assert seq == pre_seq and payload["version"] == pre["version"]

    # phase 2: restart against the same snapshot dir; ServiceState.open
    # restores and must serve exactly the committed pre-crash overlay
    proc, client = boot({})
    try:
        d = client.diameter(exact=True)
        assert np.isclose(d["diameter"], pre["diameter"]), (
            d["diameter"], pre["diameter"])
        assert client.stats()["version"] == pre["version"]
        client.shutdown()
        assert proc.wait(timeout=60) == 0
    finally:
        if proc.poll() is None:
            proc.kill()
