"""Training substrate: optimizer, loss, microbatching, data, checkpoint."""
import os
import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   global_norm, warmup_cosine)
from repro.train.train_step import (TrainConfig, cross_entropy, init_state,
                                    train_step)


def test_adamw_matches_reference_scalar():
    """Single-scalar AdamW against a hand-rolled reference."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, clip_norm=None)
    p = {"w": jnp.asarray(2.0)}
    st_ = adamw_init(p)
    g = {"w": jnp.asarray(0.5)}
    newp, st_, _ = adamw_update(cfg, g, st_, p)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = 2.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    assert float(newp["w"]) == pytest.approx(want, rel=1e-5)


def test_warmup_cosine_shape():
    sched = warmup_cosine(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(0))) == pytest.approx(0.0)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, -1, 2, -1]])
    loss, n = cross_entropy(logits, labels)
    assert float(n) == 2.0
    assert float(loss) == pytest.approx(np.log(8.0), rel=1e-5)


def test_cross_entropy_matches_take_along_axis():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(0, 2, (2, 6, 32)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 32, (2, 6)).astype(np.int32))
    loss, _ = cross_entropy(logits, labels)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = float(jnp.mean(lse - gold))
    assert float(loss) == pytest.approx(want, rel=1e-5)


def test_microbatch_equivalence():
    """grad accumulation over 2 microbatches == single big batch (same data)."""
    cfg = get_arch("granite-8b").smoke()
    state = init_state(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    s1, m1 = train_step(cfg, TrainConfig(remat=False, microbatches=1),
                        state, batch)
    s2, m2 = train_step(cfg, TrainConfig(remat=False, microbatches=2),
                        state, batch)
    # microbatching averages CE over microbatches - same value for equal sizes
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_remat_equivalence():
    cfg = get_arch("granite-8b").smoke()
    state = init_state(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    _, m1 = train_step(cfg, TrainConfig(remat=False), state, batch)
    _, m2 = train_step(cfg, TrainConfig(remat=True), state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)


def test_loss_decreases_end_to_end():
    cfg = get_arch("musicgen-large").smoke()
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3), remat=False)
    state = init_state(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8))
    step = jax.jit(lambda s, b: train_step(cfg, tc, s, b))
    losses = []
    for i in range(6):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


# --- data pipeline ----------------------------------------------------------

def test_data_determinism_and_masking():
    cfg = DataConfig(vocab=128, seq_len=64, global_batch=4, seed=7)
    a = SyntheticLM(cfg).batch(3)
    b = SyntheticLM(cfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].max() < 128 and a["tokens"].min() >= 0
    # labels masked exactly at EOS positions
    np.testing.assert_array_equal(a["labels"] == -1, a["tokens"] == 0)


def test_data_host_sharding_disjoint():
    full = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=8,
                                  n_hosts=1, host_id=0)).batch(0)
    h0 = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=8,
                                n_hosts=2, host_id=0)).batch(0)
    h1 = SyntheticLM(DataConfig(vocab=64, seq_len=32, global_batch=8,
                                n_hosts=2, host_id=1)).batch(0)
    np.testing.assert_array_equal(np.concatenate([h0["tokens"], h1["tokens"]]),
                                  full["tokens"])


# --- checkpoint --------------------------------------------------------------

def test_checkpoint_roundtrip_and_atomicity():
    from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_steps,
                                             restore, save)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        # torn checkpoint: tmp dir without COMMITTED must be ignored
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        ck = AsyncCheckpointer(d, keep=2)
        for s in (2, 3, 4):
            ck.save_async(s, tree)
        ck.wait()
        assert latest_steps(d) == [3, 4]          # gc kept 2
        got, step = restore(d, tree)
        assert step == 4
        for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        # shape mismatch raises
        bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones((4,))}}
        with pytest.raises(ValueError):
            restore(d, bad)
