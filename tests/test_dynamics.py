"""dynamics: incremental APSP parity, engine determinism, scenarios."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.diameter import (INF, adjacency_from_edges,
                                 adjacency_from_rings, is_edge, ring_edges)
from repro.core.topology import make_latency
from repro.dynamics import (ChurnEngine, DGROPolicy, Event, IncrementalDistances,
                            POLICIES, SCENARIOS, Trace)
from repro.dynamics import incremental as incr
from repro.membership.elastic import plan_rescale_from_engine


def _scipy_dists(adj: np.ndarray) -> np.ndarray:
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import dijkstra

    a = np.asarray(adj, np.float64)
    return dijkstra(csr_matrix(np.where(is_edge(a), a, 0.0)), directed=False)


def _fresh_state(n_live: int, capacity: int, seed: int, dist="uniform"):
    w = make_latency(dist, capacity, seed=seed)
    rng = np.random.default_rng(seed)
    alive = np.zeros(capacity, bool)
    alive[:n_live] = True
    adj = adjacency_from_edges(w, ring_edges(rng.permutation(n_live)))
    return w, adj, alive


def _random_ops(inc: IncrementalDistances, rng, n_ops: int):
    """Yield a random churn op applied to ``inc``, one at a time."""
    for _ in range(n_ops):
        r = rng.random()
        live = inc.live_ids()
        if r < 0.55 or inc.n_live < 6:
            u, v = rng.choice(live, size=2, replace=False)
            inc.add_edge(int(u), int(v))
        elif r < 0.8 and (~inc.alive).any():
            u = int(np.flatnonzero(~inc.alive)[0])
            nbrs = rng.choice(live, size=min(3, len(live)), replace=False)
            inc.join(u, [int(x) for x in nbrs])
        else:
            inc.leave(int(rng.choice(live)))
        yield


def _assert_live_parity(inc: IncrementalDistances, tag=""):
    live = inc.live_ids()
    want = _scipy_dists(inc.adj[np.ix_(live, live)])
    got = np.asarray(inc.live_distances(), np.float64)
    reach = np.isfinite(want)
    assert (got < float(INF) / 2).tolist() == reach.tolist(), tag
    assert np.allclose(got[reach], want[reach], rtol=1e-4, atol=1e-3), tag


# ---------------------------------------------------------------------------
# incremental maintenance
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(10, 22), st.integers(0, 10_000))
def test_incremental_exact_after_every_event(n, seed):
    """Acceptance criterion: with every deletion triggering the tombstone
    rebuild (threshold=1), the maintained distances match a from-scratch
    Dijkstra rebuild after EVERY event of a random churn trace."""
    w, adj, alive = _fresh_state(n, n + 4, seed)
    inc = IncrementalDistances(w, adj, alive, rebuild_threshold=1)
    rng = np.random.default_rng(seed + 1)
    _assert_live_parity(inc, "init")
    for i, _ in enumerate(_random_ops(inc, rng, 30)):
        _assert_live_parity(inc, f"op{i}")
    assert inc.stats["rebuilds"] >= 1          # the tombstone path ran


def test_stale_distances_are_lower_bounds_until_refresh():
    """With a large rebuild threshold, post-leave distances may be stale but
    only ever UNDER-estimate (paths through tombstoned nodes); refresh()
    restores exactness."""
    w, adj, alive = _fresh_state(16, 16, seed=3)
    inc = IncrementalDistances(w, adj, alive, rebuild_threshold=100)
    rng = np.random.default_rng(4)
    for u in rng.choice(16, size=4, replace=False):
        inc.leave(int(u))
    assert inc.pending_deletions == 4 and inc.stats["rebuilds"] == 0
    live = inc.live_ids()
    want = _scipy_dists(inc.adj[np.ix_(live, live)])
    got = np.asarray(inc.live_distances(), np.float64)
    reach = np.isfinite(want)
    assert (got[reach] <= want[reach] + 1e-3).all()
    inc.refresh()
    assert inc.pending_deletions == 0
    _assert_live_parity(inc, "post-refresh")


def test_set_latency_increase_against_current_edge_weight():
    """A latency increase must be judged against the CURRENT edge weight
    (add_edge may have set it below w); otherwise the update is misread as
    a decrease and distances go permanently stale."""
    w, adj, alive = _fresh_state(10, 10, seed=1)
    inc = IncrementalDistances(w, adj, alive, rebuild_threshold=1)
    u, v = int(inc.live_ids()[0]), int(inc.live_ids()[5])
    inc.add_edge(u, v, weight=0.5)
    _assert_live_parity(inc, "after cheap edge")
    mid = 0.5 + float(w[u, v] - 0.5) / 2     # above 0.5, below w[u, v]
    inc.set_latency(u, v, mid)               # an INCREASE of the edge weight
    inc.refresh()
    _assert_live_parity(inc, "after increase + refresh")
    inc.set_latency(u, v, 0.25)              # and a genuine decrease relaxes
    _assert_live_parity(inc, "after decrease")


def test_full_mode_and_incremental_agree():
    w, adj, alive = _fresh_state(14, 18, seed=9)
    a = IncrementalDistances(w, adj, alive, mode="incremental",
                             rebuild_threshold=3)
    b = IncrementalDistances(w, adj, alive, mode="full")
    rng_a, rng_b = (np.random.default_rng(11) for _ in range(2))
    list(_random_ops(a, rng_a, 25))
    list(_random_ops(b, rng_b, 25))
    a.refresh()
    assert np.array_equal(a.alive, b.alive)
    assert np.allclose(a.live_distances(), b.live_distances(),
                       rtol=1e-4, atol=1e-3)


def test_batched_relax_matches_sequential():
    """(B,) replicas advanced in one device call == per-replica loop."""
    import jax.numpy as jnp

    b, n = 5, 12
    w = make_latency("gaussian", n, seed=0)
    rng = np.random.default_rng(2)
    dists, us, vs = [], [], []
    for i in range(b):
        ring = rng.permutation(n)
        adj = adjacency_from_rings(w, [ring])
        dists.append(_scipy_dists(adj))
        u, v = rng.choice(n, size=2, replace=False)
        us.append(int(u)), vs.append(int(v))
    dists = np.where(np.isfinite(dists), dists, float(INF)).astype(np.float32)
    ws = w[us, vs].astype(np.float32)
    got = incr.relax_edges_batched(jnp.asarray(dists), jnp.asarray(us),
                                   jnp.asarray(vs), jnp.asarray(ws))
    for i in range(b):
        want = incr.relax_edge(jnp.asarray(dists[i]), us[i], vs[i], ws[i])
        assert np.allclose(got[i], want, rtol=1e-5), i
    # the scanned stream applies T steps in one call
    t_steps = 3
    us_t = np.stack([np.roll(us, k) for k in range(t_steps)])
    vs_t = np.stack([np.roll(vs, k) for k in range(t_steps)])
    ws_t = w[us_t, vs_t].astype(np.float32)
    stream = incr.relax_edge_stream_batched(
        jnp.asarray(dists), jnp.asarray(us_t), jnp.asarray(vs_t),
        jnp.asarray(ws_t))
    ref = jnp.asarray(dists)
    for k in range(t_steps):
        ref = incr.relax_edges_batched(ref, jnp.asarray(us_t[k]),
                                       jnp.asarray(vs_t[k]),
                                       jnp.asarray(ws_t[k]))
    assert np.allclose(stream, ref, rtol=1e-5)


# ---------------------------------------------------------------------------
# traces + scenarios
# ---------------------------------------------------------------------------

def test_trace_json_roundtrip_and_determinism():
    for name, make in SCENARIOS.items():
        t1, t2 = make(n0=20, seed=5), make(n0=20, seed=5)
        assert t1.events == t2.events, name          # generator determinism
        rt = Trace.from_json(t1.to_json())
        assert rt.events == t1.events and rt.n0 == t1.n0
        assert (rt.capacity, rt.dist, rt.seed) == (
            t1.capacity, t1.dist, t1.seed)
        from repro.dynamics.scenarios import EVENT_KINDS
        assert all(e.kind in EVENT_KINDS for e in t1.events), name


def test_event_kind_validated():
    with pytest.raises(ValueError):
        Event(time=0.0, kind="reboot")


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def test_engine_survives_full_drain_then_rejoin():
    """A trace may empty the fleet entirely; the next joiner re-seeds the
    rings instead of crashing the splice."""
    events = [Event(time=1.0, kind="leave", node=0),
              Event(time=2.0, kind="leave", node=1),
              Event(time=3.0, kind="join", node=2),
              Event(time=4.0, kind="join", node=3)]
    trace = Trace(n0=2, capacity=4, dist="uniform", seed=0,
                  events=events, name="drain")
    for pname, P in POLICIES.items():
        eng = ChurnEngine(trace, P(), seed=0)
        eng.run()
        assert eng.inc.n_live == 2, pname


def test_engine_run_is_single_use():
    trace = SCENARIOS["flash_crowd"](n0=12, seed=0)
    eng = ChurnEngine(trace, POLICIES["rapid"](), seed=0)
    eng.run()
    with pytest.raises(RuntimeError):
        eng.run()


def test_engine_deterministic_replay():
    trace = SCENARIOS["poisson_churn"](n0=18, seed=2)
    runs = [ChurnEngine(trace, DGROPolicy(), seed=7,
                        detect_failures=True).run() for _ in range(2)]
    assert runs[0].samples == runs[1].samples
    assert runs[0].final_diameter == runs[1].final_diameter


@pytest.mark.parametrize("policy", list(POLICIES))
def test_engine_scenarios_stay_connected(policy):
    for name in ("flash_crowd", "regional_failure", "straggler_storm"):
        trace = SCENARIOS[name](n0=18, seed=1)
        res = ChurnEngine(trace, POLICIES[policy](), seed=3,
                          detect_failures=True).run()
        assert np.isfinite(res.final_diameter), (name, policy)
        assert res.final_diameter < float(INF) / 2, (name, policy)
        assert all(s.diameter < float(INF) / 2 for s in res.samples), name


def test_engine_distances_exact_after_trace():
    """End-to-end acceptance: replaying a scenario through the engine, the
    incrementally-maintained diameter equals a from-scratch rebuild."""
    from repro.core.diameter import diameter_scipy

    trace = SCENARIOS["poisson_churn"](n0=16, seed=6)
    eng = ChurnEngine(trace, POLICIES["rapid"](), seed=1)
    res = eng.run()
    live = eng.live_ids()
    want = diameter_scipy(eng.inc.adj[np.ix_(live, live)])
    assert res.final_diameter == pytest.approx(want, rel=1e-4)


def test_regional_failure_kills_site_and_dgro_recovers():
    trace = SCENARIOS["regional_failure"](n0=34, seed=4)
    victims = {e.node for e in trace.events}
    eng = ChurnEngine(trace, DGROPolicy(adapt_every=1), seed=2,
                      detect_failures=True)
    res = eng.run()
    assert not eng.alive[list(victims)].any()
    assert eng.inc.n_live == 34 - len(victims)
    assert np.isfinite(res.final_diameter)


def test_plan_rescale_from_engine_excludes_dead_and_stragglers():
    events = [Event(time=1_000.0, kind="fail", node=5),
              Event(time=3_000.0, kind="straggler", node=11, factor=25.0)]
    trace = Trace(n0=24, capacity=24, dist="fabric", seed=3,
                  events=events, name="rescale")
    eng = ChurnEngine(trace, DGROPolicy(), seed=0, detect_failures=True)
    eng.run()
    plan = plan_rescale_from_engine(eng, model_hosts=2, old_world=24)
    assert 5 not in plan.hosts and 11 not in plan.hosts
    pods, data, model = plan.mesh_shape
    assert pods * data * model == len(plan.hosts) and model == 2


def test_dgro_self_repair_survives_shrinking_below_sample_size():
    """Regression: a network churning down to a handful of nodes used to
    crash DGRO's Algorithm-3 self-repair inside measure_latency_stats
    (global sample of k > n-1 without replacement).  A pure-leave trace
    shrinking 12 -> 4 with adapt_every=1 must replay to completion."""
    events = [Event(time=1_000.0 * (i + 1), kind="leave", node=i)
              for i in range(8)]
    trace = Trace(n0=12, capacity=12, dist="uniform", seed=5,
                  events=events, name="shrink")
    eng = ChurnEngine(trace, DGROPolicy(adapt_every=1), seed=1)
    res = eng.run()
    assert eng.inc.n_live == 4
    assert np.isfinite(res.final_diameter)


# ---------------------------------------------------------------------------
# input validation (satellite)
# ---------------------------------------------------------------------------

def test_adjacency_from_rings_rejects_non_permutations():
    from repro.core.diameter import adjacency_from_edges

    w = make_latency("uniform", 8, seed=0)
    with pytest.raises(ValueError):
        adjacency_from_rings(w, [np.array([0, 1, 2])])          # too short
    with pytest.raises(ValueError):
        adjacency_from_rings(w, [np.array([0, 1, 2, 3, 4, 5, 6, 6])])  # dup
    with pytest.raises(ValueError):
        adjacency_from_edges(w, [(0, 9)])                       # out of range
    with pytest.raises(ValueError):
        adjacency_from_edges(w, [(-1, 2)])
    # valid inputs still pass
    adjacency_from_rings(w, [np.arange(8)])
    adjacency_from_edges(w, [(0, 7)])
