"""Batched topology evaluation: (B, N, N) diameters vs the scipy oracle and
the unbatched JAX path; vectorized adjacency builders; padded batches."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import batcheval, topology
from repro.core.construction import random_ring
from repro.core.diameter import (INF, adjacency_from_edges,
                                 adjacency_from_rings, diameter,
                                 diameter_scipy, ring_edges)


def _genome_batch(rng, b, n, k=2):
    return np.stack([[rng.permutation(n) for _ in range(k)]
                     for _ in range(b)])


# --- graph assembly ---------------------------------------------------------

@pytest.mark.parametrize("k", [1, 3])
def test_adjacency_batch_matches_scalar_builder(k):
    rng = np.random.default_rng(0)
    w = topology.make_latency("gaussian", 30, seed=1)
    genomes = _genome_batch(rng, 8, 30, k)
    batch = batcheval.adjacency_batch_from_rings(w, genomes)
    for i in range(8):
        ref = adjacency_from_rings(w, list(genomes[i]))
        np.testing.assert_array_equal(batch[i], ref)


def test_adjacency_from_edges_matches_old_loop():
    """Regression: the np.minimum.at scatter must reproduce the per-edge
    Python loop it replaced, bit for bit — including duplicate and
    self-referential edges resolving to the min weight."""
    rng = np.random.default_rng(2)
    n = 25
    w = topology.make_latency("fabric", n, seed=3)
    edges = rng.integers(0, n, size=(120, 2)).tolist()
    edges += edges[:13]                      # duplicates on purpose

    def old_loop(w, edges):
        d = np.full((n, n), float(INF), dtype=np.float32)
        np.fill_diagonal(d, 0.0)
        for u, v in edges:
            d[u, v] = min(d[u, v], w[u, v])
            d[v, u] = min(d[v, u], w[v, u])
        return d

    got = adjacency_from_edges(w, edges)
    np.testing.assert_array_equal(got, old_loop(w, edges))


def test_rings_to_edges_shapes_and_content():
    perm = np.array([2, 0, 1])
    edges = batcheval.rings_to_edges(perm[None])
    assert edges.shape == (1, 3, 2)
    np.testing.assert_array_equal(edges[0], ring_edges(perm))


# --- batched diameters vs oracles ------------------------------------------

@pytest.mark.parametrize("dist", ["uniform", "gaussian", "fabric", "bitnode"])
def test_batched_matches_scipy_elementwise(dist):
    rng = np.random.default_rng(4)
    n, b = 26, 12
    w = topology.make_latency(dist, n, seed=5)
    genomes = _genome_batch(rng, b, n)
    batch = batcheval.adjacency_batch_from_rings(w, genomes)
    got = batcheval.diameters(batch)
    for i in range(b):
        assert got[i] == pytest.approx(diameter_scipy(batch[i]), rel=1e-5)


def test_batched_matches_unbatched_jax():
    rng = np.random.default_rng(6)
    n, b = 20, 6
    w = topology.make_latency("uniform", n, seed=7)
    batch = batcheval.adjacency_batch_from_rings(w, _genome_batch(rng, b, n))
    got = batcheval.diameters(batch)
    for i in range(b):
        assert got[i] == pytest.approx(
            float(diameter(jnp.asarray(batch[i]))), rel=1e-5)


def test_methods_agree():
    """Floyd-Warshall and min-plus squaring are interchangeable."""
    rng = np.random.default_rng(8)
    n = 22
    w = topology.make_latency("gaussian", n, seed=9)
    batch = batcheval.adjacency_batch_from_rings(w, _genome_batch(rng, 5, n))
    d_fw = batcheval.diameters(batch, method="fw")
    d_sq = batcheval.diameters(batch, method="squaring")
    d_asym = batcheval.diameters(batch, method="fw", symmetric=False)
    np.testing.assert_allclose(d_fw, d_sq, rtol=1e-5)
    np.testing.assert_allclose(d_fw, d_asym, rtol=1e-5)


def test_disconnected_uses_largest_component():
    """§IV-C: disconnected overlays score by the largest component, batched
    exactly like the scipy oracle."""
    w = topology.make_latency("uniform", 12, seed=0)
    # ring over 0..6 + edge 7-8; nodes 9..11 isolated
    e1 = np.concatenate([ring_edges(np.arange(7)), [[7, 8]]], axis=0)
    # two components of different sizes: ring over 0..3, ring over 4..11
    e2 = np.concatenate([ring_edges(np.arange(4)),
                         ring_edges(np.arange(4, 12))], axis=0)
    blocks = [adjacency_from_edges(w, e1), adjacency_from_edges(w, e2)]
    batch = np.stack(blocks)
    got = batcheval.diameters(batch)
    for i, adj in enumerate(blocks):
        want = diameter_scipy(adj)
        assert want < float(INF) / 2
        assert got[i] == pytest.approx(want, rel=1e-5), i


def test_chunked_path_matches_direct():
    rng = np.random.default_rng(10)
    n, b = 18, 23
    w = topology.make_latency("uniform", n, seed=11)
    batch = batcheval.adjacency_batch_from_rings(w, _genome_batch(rng, b, n))
    direct = batcheval.diameters(batch)
    chunked = batcheval.diameters(batch, chunk=4)   # 23 -> 6 chunks, padded
    np.testing.assert_allclose(direct, chunked, rtol=1e-6)


def test_padded_blocks_score_like_their_own_graphs():
    rng = np.random.default_rng(12)
    w = topology.make_latency("gaussian", 40, seed=13)
    sizes = (5, 11, 24, 40)
    blocks = [adjacency_from_rings(w[:m, :m], [rng.permutation(m)])
              for m in sizes]
    got = batcheval.diameters(batcheval.pad_adjacency_blocks(blocks))
    for i, blk in enumerate(blocks):
        assert got[i] == pytest.approx(diameter_scipy(blk), rel=1e-5), sizes[i]


def test_overlay_with_rings_only_improves():
    rng = np.random.default_rng(14)
    n = 24
    w = topology.make_latency("fabric", n, seed=15)
    base = adjacency_from_rings(w, [random_ring(rng, n)])
    rings = np.stack([random_ring(rng, n) for _ in range(6)])[:, None, :]
    overlays = batcheval.overlay_with_rings(base, w, rings)
    d_base = diameter_scipy(base)
    got = batcheval.diameters(overlays)
    assert np.all(got <= d_base + 1e-3)
    for i in range(6):
        assert np.all(overlays[i] <= base + 1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(6, 24), st.integers(0, 10_000))
def test_batched_diameter_property(n, seed):
    """Property: for random K-ring batches, the batched engine equals the
    scipy oracle on every element (spot-checked) and is permutation-stable
    across the batch axis."""
    rng = np.random.default_rng(seed)
    w = topology.make_latency("uniform", n, seed=seed % 97)
    batch = batcheval.adjacency_batch_from_rings(
        w, _genome_batch(rng, 5, n, k=1))
    got = batcheval.diameters(batch)
    i = seed % 5
    assert got[i] == pytest.approx(diameter_scipy(batch[i]), rel=1e-4)
    perm = rng.permutation(5)
    np.testing.assert_allclose(batcheval.diameters(batch[perm]), got[perm],
                               rtol=1e-6)


# --- consumers --------------------------------------------------------------

def test_evolve_generations_and_history():
    from repro.core.ga import GAConfig, evolve
    w = topology.make_latency("uniform", 16, seed=16)
    cfg = GAConfig(k_rings=2, population=10, budget=50, seed=0)
    res = evolve(w, cfg)
    assert res.evaluations == 50
    assert res.generations == 4          # 10 init + 4 * 10 children
    assert len(res.history) == 5
    assert res.history == sorted(res.history, reverse=True)  # monotone best
    assert res.best_diameter == pytest.approx(res.history[-1])
    for ring in res.best:
        assert sorted(ring) == list(range(16))


def test_score_candidate_rings_matches_scipy():
    from repro.core.selection import score_candidate_rings
    rng = np.random.default_rng(17)
    n = 20
    w = topology.make_latency("gaussian", n, seed=18)
    base = adjacency_from_rings(w, [random_ring(rng, n)])
    rings = [random_ring(rng, n) for _ in range(4)]
    got = score_candidate_rings(w, base, rings)
    for i, ring in enumerate(rings):
        want = diameter_scipy(np.minimum(
            base, adjacency_from_rings(w, [ring])))
        assert got[i] == pytest.approx(want, rel=1e-5), i


def test_score_partition_blocks_matches_scipy():
    from repro.core.parallel import parallel_ring_scored, partition_nodes
    from repro.core.construction import nearest_ring
    w = topology.make_latency("gaussian", 48, seed=19)
    perm, scores = parallel_ring_scored(w, 5, seed=0, score_blocks=True)
    assert sorted(perm) == list(range(48))
    assert scores.shape == (5,)
    rng = np.random.default_rng(0)
    parts = partition_nodes(48, 5, rng)
    for i, nodes in enumerate(parts):
        sub_w = w[np.ix_(nodes, nodes)]
        start = int(rng.integers(len(nodes)))
        seg = nodes[nearest_ring(sub_w, start=start)]
        sw = w[np.ix_(seg, seg)]
        want = diameter_scipy(adjacency_from_rings(sw, [np.arange(len(seg))]))
        assert scores[i] == pytest.approx(want, rel=1e-5), i
