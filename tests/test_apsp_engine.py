"""The tiled/streamed APSP engine: correctness, memory model, precision
contracts, scoped options, sharded parity, and the observability surface."""
import os
import subprocess
import sys

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from conftest import subproc_env
from repro.core import batcheval
from repro.core.construction import random_ring
from repro.core.diameter import INF, adjacency_from_rings, is_edge
from repro.core.topology import make_latency
from repro.kernels.minplus.ops import apsp_tiled


def _scipy_apsp(adj):
    from scipy.sparse.csgraph import shortest_path
    graph = np.where(np.asarray(is_edge(adj)), np.asarray(adj), 0.0)
    return shortest_path(graph, method="D", directed=True)


def _ring_batch(n, b, seed, k_rings=2, dist="uniform"):
    rng = np.random.default_rng(seed)
    w = make_latency(dist, n, seed=seed)
    genomes = np.stack([[random_ring(rng, n) for _ in range(k_rings)]
                        for _ in range(b)])
    return w, genomes, batcheval.adjacency_batch_from_rings(w, genomes)


# --- tiled APSP vs scipy (property) -----------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(6, 70), st.integers(0, 10**6))
def test_tiled_apsp_property_vs_scipy(n, seed):
    """Random sizes (mostly NOT tile multiples), random symmetric rings."""
    rng = np.random.default_rng(seed)
    w = make_latency("uniform", n, seed=seed % 997)
    adj = adjacency_from_rings(w, [random_ring(rng, n)])
    got = np.asarray(apsp_tiled(jnp.asarray(adj), tile=16))
    want = _scipy_apsp(adj)
    np.testing.assert_allclose(np.where(got >= INF / 2, np.inf, got), want,
                               rtol=1e-5)


def test_tiled_apsp_disconnected_components():
    """Two components: cross-component entries must stay >= INF/2 and the
    intra-component distances must match scipy exactly."""
    rng = np.random.default_rng(0)
    n1, n2 = 14, 9
    w1 = make_latency("uniform", n1, seed=1)
    w2 = make_latency("uniform", n2, seed=2)
    a1 = adjacency_from_rings(w1, [random_ring(rng, n1)])
    a2 = adjacency_from_rings(w2, [random_ring(rng, n2)])
    adj = np.full((n1 + n2, n1 + n2), float(INF), np.float32)
    adj[:n1, :n1] = a1
    adj[n1:, n1:] = a2
    np.fill_diagonal(adj, 0.0)
    got = np.asarray(apsp_tiled(jnp.asarray(adj), tile=8))
    assert np.all(got[:n1, n1:] >= INF / 2) and np.all(got[n1:, :n1] >= INF / 2)
    np.testing.assert_allclose(got[:n1, :n1], _scipy_apsp(a1), rtol=1e-5)
    np.testing.assert_allclose(got[n1:, n1:], _scipy_apsp(a2), rtol=1e-5)


def test_tiled_apsp_asymmetric_latency():
    """Directed (asymmetric) weights through the general (non-symmetric)
    panel path, vs directed scipy."""
    rng = np.random.default_rng(3)
    n = 23
    adj = np.full((n, n), float(INF), np.float32)
    order = rng.permutation(n)
    for i in range(n):                     # a directed ring + random chords
        adj[order[i], order[(i + 1) % n]] = rng.uniform(1, 10)
    for _ in range(3 * n):
        i, j = rng.integers(0, n, 2)
        if i != j:
            adj[i, j] = rng.uniform(1, 10)
    np.fill_diagonal(adj, 0.0)
    got = np.asarray(apsp_tiled(jnp.asarray(adj), tile=8))
    np.testing.assert_allclose(np.where(got >= INF / 2, np.inf, got),
                               _scipy_apsp(adj), rtol=1e-5)


# --- streaming facade -------------------------------------------------------

def test_streamed_bit_identical_to_direct():
    """Chunked streaming (including the padded trailing partial chunk) must
    return the same BITS as one direct batched_diameter over the stack."""
    _, _, adjs = _ring_batch(24, 23, seed=4)
    ref = np.asarray(batcheval.batched_diameter(jnp.asarray(adjs)))
    for chunk in (4, 7, 23, 64):
        got = batcheval.diameters(adjs, chunk=chunk)
        assert np.array_equal(got, ref), chunk


def test_ring_block_source_matches_dense_assembly():
    w, genomes, adjs = _ring_batch(20, 9, seed=5)
    dense = batcheval.diameters(adjs, chunk=4)
    src = batcheval.RingBlockSource(w, genomes)
    assert len(src) == 9 and src.n == 20
    streamed = batcheval.diameters(src, chunk=4)
    assert np.array_equal(streamed, dense)
    assert np.array_equal(
        batcheval.diameters_of_rings(w, genomes, chunk=4), dense)


def test_apsp_matrices_streams_full_distances():
    _, _, adjs = _ring_batch(16, 6, seed=6)
    direct = np.asarray(batcheval.batched_apsp(jnp.asarray(adjs)))
    got = batcheval.apsp_matrices(adjs, chunk=2)
    assert got.dtype == np.float32
    assert np.array_equal(got, direct)


def test_tiled_method_through_facade():
    _, _, adjs = _ring_batch(40, 5, seed=7)
    ref = batcheval.diameters(adjs)
    got = batcheval.diameters(adjs, method="tiled", tile=16)
    np.testing.assert_allclose(got, ref, rtol=1e-5)
    assert batcheval.last_eval_report()["method"] == "tiled"


# --- precision contracts ----------------------------------------------------

def test_bfloat16_error_bound_and_report():
    # gaussian: continuous weights, so bf16 rounding shows a REAL error
    # (integer-valued worlds sum exactly in bf16 and would test nothing)
    _, _, adjs = _ring_batch(32, 12, seed=8, dist="gaussian")
    ref = batcheval.diameters(adjs)
    got = batcheval.diameters(adjs, dtype="bfloat16")
    rel = np.max(np.abs(got - ref) / np.maximum(ref, 1e-9))
    assert 0 < rel < 0.05, rel        # bf16 has ~3 decimal digits
    rep = batcheval.last_eval_report()
    assert rep["dtype"] == "bfloat16" and not rep["fallback"]
    assert 0 < rep["quant_rel_err"] < 0.05


def test_int16_quantized_error_bound():
    _, _, adjs = _ring_batch(32, 12, seed=9, dist="gaussian")
    ref = batcheval.diameters(adjs)
    got = batcheval.diameters(adjs, dtype="int16")
    rel = np.max(np.abs(got - ref) / np.maximum(ref, 1e-9))
    assert rel < 1e-3, rel            # 16-bit grid: per-hop err <= scale/2
    q, scale = batcheval.quantize_latency(adjs)
    assert scale > 0
    # sentinel and diagonal pass through bit-exact
    assert np.array_equal(np.asarray(is_edge(q)), np.asarray(is_edge(adjs)))
    assert np.all(q[~np.asarray(is_edge(adjs))]
                  == adjs[~np.asarray(is_edge(adjs))])


def test_exact_fallback_fires_and_is_bit_exact():
    _, _, adjs = _ring_batch(24, 8, seed=10, dist="gaussian")
    ref = batcheval.diameters(adjs)
    got = batcheval.diameters(adjs, dtype="bfloat16", exact_rtol=0.0)
    rep = batcheval.last_eval_report()
    assert rep["fallback"], rep
    assert np.array_equal(got, ref)   # the rerun is the exact f32 path


def test_incremental_rebuild_pinned_float32():
    """dynamics.incremental rebuilds its base distances in f32 even under
    an ambient reduced-precision eval_options scope."""
    from repro.dynamics.incremental import IncrementalDistances
    rng = np.random.default_rng(11)
    w = make_latency("uniform", 16, seed=11)
    adj = adjacency_from_rings(w, [random_ring(rng, 16)])
    with batcheval.eval_options(dtype="bfloat16"):
        inc = IncrementalDistances(w, adj, np.ones(16, bool))
    dist = np.asarray(inc.distances)
    assert dist.dtype == np.float32
    np.testing.assert_allclose(np.where(dist >= INF / 2, np.inf, dist),
                               _scipy_apsp(adj), rtol=1e-5)


# --- options & memory model -------------------------------------------------

def test_eval_options_resolution_and_nesting():
    _, _, adjs = _ring_batch(20, 4, seed=12)
    with batcheval.eval_options(method="squaring"):
        batcheval.diameters(adjs)
        assert batcheval.last_eval_report()["method"] == "squaring"
        with batcheval.eval_options(method="tiled"):
            batcheval.diameters(adjs)
            assert batcheval.last_eval_report()["method"] == "tiled"
            # explicit kwarg beats the innermost context
            batcheval.diameters(adjs, method="fw")
            assert batcheval.last_eval_report()["method"] == "fw"
        batcheval.diameters(adjs)
        assert batcheval.last_eval_report()["method"] == "squaring"
    with pytest.raises(ValueError):
        with batcheval.eval_options(method="dijkstra"):
            pass
    with pytest.raises(ValueError):
        with batcheval.eval_options(typo=1):
            pass


def test_default_chunk_per_method():
    n = 64
    # fw: 8 N^2 slabs per item -> 256MiB / (4*64*64*8) = 2048
    assert batcheval.default_chunk(n, "fw") == 2048
    # CPU-oracle squaring: N^3 temporary per item -> 256
    assert batcheval.default_chunk(n, "squaring") == 256
    # tiled: fixed panels shared across the chunk, one N^2 per item
    assert batcheval.default_chunk(n, "tiled") > 2048
    # bf16 halves the per-item cost
    assert (batcheval.default_chunk(n, "fw", dtype="bfloat16")
            == 2 * batcheval.default_chunk(n, "fw"))
    # a single matrix always fits
    assert batcheval.default_chunk(4096, "fw") == 1
    # tighter explicit budget -> smaller chunk, never 0
    assert batcheval.default_chunk(n, "fw", budget_bytes=1) == 1


def test_mem_budget_env_override(monkeypatch):
    base = batcheval.default_chunk(64, "fw")
    monkeypatch.setenv("REPRO_APSP_MEM_BYTES", str(1 << 20))
    small = batcheval.default_chunk(64, "fw")
    assert small < base and small == (1 << 20) // (4 * 64 * 64 * 8)
    # the facade picks it up end to end
    _, _, adjs = _ring_batch(64, 12, seed=13)
    ref = batcheval.diameters(adjs, chunk=12)
    got = batcheval.diameters(adjs)
    rep = batcheval.last_eval_report()
    assert rep["chunk"] == small and rep["device_calls"] > 1
    assert np.array_equal(got, ref)


def test_workingset_model_orders():
    ws_fw = batcheval.workingset_bytes(4, 256, "fw")
    ws_sq = batcheval.workingset_bytes(4, 256, "squaring")
    ws_tiled = batcheval.workingset_bytes(4, 256, "tiled")
    assert ws_sq > ws_fw > 0            # N^3 temporary dominates
    assert ws_tiled < ws_fw             # the point of the blocked engine
    assert (batcheval.workingset_bytes(4, 256, "fw", dtype="bfloat16")
            == ws_fw // 2)


# --- observability ----------------------------------------------------------

def test_apsp_metrics_and_report():
    from repro.obs import REGISTRY, parse_prometheus
    _, _, adjs = _ring_batch(24, 10, seed=14)
    batcheval.diameters(adjs, chunk=3)
    scraped = parse_prometheus(REGISTRY.render_prometheus())
    counts = scraped["repro_apsp_seconds_count"]
    assert sum(counts.values()) >= 1, counts
    assert any(dict(k).get("phase") in ("compile", "execute")
               for k in counts), counts
    assert scraped["repro_apsp_workingset_bytes"][()] > 0
    rep = batcheval.last_eval_report()
    assert rep["b"] == 10 and rep["chunk"] == 3 and rep["device_calls"] == 4
    assert rep["workingset_bytes"] == batcheval.workingset_bytes(
        3, 24, rep["method"])


def test_jit_phase_transitions():
    from repro.obs import jit_phase
    assert jit_phase("test.phase.unique", key=(1,)) == "compile"
    assert jit_phase("test.phase.unique", key=(1,)) == "execute"
    assert jit_phase("test.phase.unique", key=(2,)) == "compile"


# --- consumers --------------------------------------------------------------

def test_parallel_scoring_accepts_eval_opts():
    from repro.core.parallel import parallel_ring_scored
    w = make_latency("uniform", 24, seed=15)
    ring, blocks = parallel_ring_scored(w, 4, seed=0, score_blocks=True)
    ring2, blocks2 = parallel_ring_scored(w, 4, seed=0, score_blocks=True,
                                          eval_opts={"method": "squaring"})
    assert np.array_equal(ring, ring2)
    np.testing.assert_allclose(blocks, blocks2, rtol=1e-5)


def test_reoptimizer_scoped_eval_opts():
    from repro.dynamics.scenarios import Trace
    from repro.service.reoptimizer import Reoptimizer
    from repro.service.state import ServiceState
    world = Trace(n0=12, capacity=16, dist="uniform", seed=0, events=[],
                  name="apsp-engine-test")
    state = ServiceState.fresh(world, policy="dgro", seed=0)
    r = Reoptimizer(state, eval_opts={"dtype": "bfloat16"})
    r.step(force=True)              # must run end to end under the scope
    assert r.last_error is None, r.last_error


# --- sharded ----------------------------------------------------------------

def test_sharded_single_device_degrades_to_streaming():
    _, _, adjs = _ring_batch(20, 6, seed=16)
    ref = batcheval.diameters(adjs)
    got = batcheval.diameters_sharded(adjs)
    assert np.array_equal(got, ref)


def test_sharded_and_rowshard_multi_device():
    """8 forced host devices: batch-sharded diameters and the row-sharded
    single-matrix APSP both match the streaming engine exactly."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
import jax.numpy as jnp
from repro.core import batcheval
from repro.core.construction import random_ring
from repro.core.diameter import adjacency_from_rings
from repro.core.topology import make_latency
from repro.launch.mesh import make_eval_mesh

rng = np.random.default_rng(0)
w = make_latency("uniform", 30, seed=1)
genomes = np.stack([[random_ring(rng, 30)] for _ in range(13)])
adjs = batcheval.adjacency_batch_from_rings(w, genomes)
ref = batcheval.diameters(adjs)

got8 = batcheval.diameters_sharded(adjs)        # default mesh: all 8
assert np.array_equal(got8, ref), (got8, ref)
assert batcheval.last_eval_report()["devices"] == 8

mesh4 = make_eval_mesh(4)
got4 = batcheval.diameters_sharded(adjs, mesh=mesh4)
assert np.array_equal(got4, ref), (got4, ref)

adj = adjs[0]
want = np.asarray(batcheval.batched_apsp(jnp.asarray(adj)[None])[0])
rows = np.asarray(batcheval.apsp_rowshard(adj))   # 30 pads to 32 over 8
assert rows.shape == (30, 30) and np.array_equal(rows, want)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=subproc_env(), cwd=".", timeout=600)
    assert "OK" in out.stdout, out.stderr[-2000:]
