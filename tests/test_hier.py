"""Tests for ``repro.hier``: the hierarchical overlay stack.

Covers the topology protocol (both implementations), schema-2 serde next
to byte-identical schema-1 flat payloads, small-N exactness of the
hierarchical bounds against materialized exact APSP, the
:class:`~repro.hier.HierChurnEngine` under cluster split/merge and
correlated regional failure, trace JSON round-trips with the ``peer``
field, and the hierarchical service integration (fresh -> ingest ->
route -> snapshot -> restore).  A slow-marked N=10^5 smoke exercises the
lazy-latency scale path (excluded from tier-1 by the ``slow`` marker).
"""
import json

import numpy as np
import pytest

from repro import overlay
from repro.core.topology import make_latency
from repro.dynamics.scenarios import (Event, Trace, cluster_split_merge,
                                      regional_failure)
from repro.hier import (DenseLatency, HierChurnEngine, HierConfig,
                        HierarchicalOverlay, build_hier, synthetic_geo)
from repro.overlay import Overlay, Topology, from_topology_json

N = 96


def _hier(n=N, seed=0, dist="bitnode", **cfg):
    w = make_latency(dist, n, seed=seed + 2)
    return w, build_hier(DenseLatency(w),
                         HierConfig(**cfg) if cfg else None, seed=seed)


# ---------------------------------------------------------------------------
# topology protocol + registry
# ---------------------------------------------------------------------------

def test_both_implementations_satisfy_topology_protocol():
    w, hov = _hier()
    flat = overlay.build("dgro", w, seed=1)
    assert isinstance(flat, Topology)
    assert isinstance(hov, Topology)
    assert overlay.get_builder("dgro").kind == "flat"
    assert overlay.get_builder("dgro-hier").kind == "hier"
    assert "dgro-hier" in overlay.builders()


def test_registry_builds_hier_from_dense_matrix():
    w = make_latency("uniform", N, seed=4)
    hov = overlay.build("dgro-hier", w, seed=1)
    assert isinstance(hov, HierarchicalOverlay)
    assert hov.n == N and hov.n_clusters >= 2
    e = hov.edge_list()
    assert e.ndim == 2 and e.shape[1] == 2
    assert np.all(e[:, 0] < e[:, 1])                   # unique, u < v
    assert np.array_equal(e, np.unique(np.sort(e, axis=1), axis=0))


# ---------------------------------------------------------------------------
# bound validity at small N (exact APSP oracle via materialize)
# ---------------------------------------------------------------------------

def test_hier_bounds_match_materialized_exact_apsp():
    _, hov = _hier()
    mat = hov.materialize()
    apsp = np.asarray(mat.distances(), np.float64)
    rng = np.random.default_rng(7)
    us = rng.integers(0, N, size=128)
    vs = rng.integers(0, N, size=128)
    served, stamp = hov.distance_bound_pairs(us, vs)
    assert stamp == "exact"
    # heads are the only gateways, so the three-leg composition IS the
    # exact APSP of the hier edge set (float32 round-off only)
    np.testing.assert_allclose(served, apsp[us, vs], rtol=1e-4, atol=1e-3)
    d, ds = hov.diameter_bound("exact")
    assert ds == "exact"
    assert d == pytest.approx(float(mat.diameter()), rel=1e-4)
    ub, us_ = hov.diameter_bound("ecc")
    assert us_ == "upper"
    assert ub >= d - 1e-3                              # never an underestimate
    with pytest.raises(ValueError):
        hov.diameter_bound("nope")


def test_hier_diameter_within_1_5x_flat_dgro():
    n = 256
    w = make_latency("bitnode", n, seed=2)
    flat_d = float(overlay.build("dgro", w, seed=0).diameter())
    hov = build_hier(DenseLatency(w), HierConfig(k_local=12), seed=0)
    hd, stamp = hov.diameter_bound("exact")
    assert stamp == "exact"
    assert hd <= 1.5 * flat_d


def test_subset_survives_head_death():
    _, hov = _hier()
    alive = np.ones(N, bool)
    alive[int(hov.heads[0])] = False                   # kill a gateway
    alive[:5] = False
    sub = hov.subset(alive)
    assert sub.n == int(alive.sum())
    assert isinstance(sub, HierarchicalOverlay)
    mat = sub.materialize()
    d, ds = sub.diameter_bound("exact")
    assert ds == "exact"
    assert d == pytest.approx(float(mat.diameter()), rel=1e-4)


# ---------------------------------------------------------------------------
# serde: schema 2 next to byte-identical schema 1
# ---------------------------------------------------------------------------

def test_hier_serde_schema2_round_trip():
    _, hov = _hier()
    s = hov.to_json()
    assert json.loads(s)["schema"] == 2
    rt = HierarchicalOverlay.from_json(s)
    assert rt.equals(hov)
    assert rt.to_json() == s                           # byte-identical
    # the flat loader refuses schema 2; the protocol dispatcher accepts it
    with pytest.raises(ValueError):
        Overlay.from_json(s)
    via = from_topology_json(s)
    assert isinstance(via, HierarchicalOverlay) and via.equals(hov)


def test_flat_serde_stays_schema1_byte_identical():
    w = make_latency("uniform", 48, seed=3)
    ov = overlay.build("dgro", w, seed=1)
    s = ov.to_json()
    assert json.loads(s).get("schema", 1) == 1
    rt = Overlay.from_json(s)
    assert rt.to_json() == s
    assert float(rt.diameter()) == float(ov.diameter())
    via = from_topology_json(s)
    assert isinstance(via, Overlay)
    assert via.to_json() == s


def test_trace_json_round_trips_cluster_events():
    trace = cluster_split_merge(n0=48, seed=5)
    rt = Trace.from_json(trace.to_json())
    assert rt.to_json() == trace.to_json()
    kinds = [e.kind for e in rt.events]
    assert "cluster_split" in kinds and "cluster_merge" in kinds
    merge = next(e for e in rt.events if e.kind == "cluster_merge")
    assert merge.peer >= 0 and merge.peer != merge.node
    # node-level events stay byte-identical to the pre-cluster format:
    # no "peer" key in their serialized form
    node_ev = Event(time=1.0, kind="join", node=3)
    assert "peer" not in node_ev.to_dict()


# ---------------------------------------------------------------------------
# HierChurnEngine
# ---------------------------------------------------------------------------

def test_engine_cluster_split_and_merge():
    trace = cluster_split_merge(n0=N, seed=3)
    eng = HierChurnEngine(trace, seed=0)
    res = eng.run()
    assert res.policy == "dgro-hier"
    assert eng.reorg_stats["splits"] >= 1
    assert eng.reorg_stats["merges"] >= 1
    assert np.isfinite(res.final_diameter) and res.final_diameter > 0
    assert eng.events_processed == len(trace.events)
    with pytest.raises(RuntimeError):
        eng.run()                                      # one-shot replay


def test_engine_regional_failure_diameter_is_valid_lower_bound():
    trace = regional_failure(n0=51, seed=2)
    eng = HierChurnEngine(trace, seed=0)
    for e in sorted(trace.events, key=lambda e: e.time):
        eng.process(e)
    d_maint = eng.diameter()                 # maintained (exact-or-lower)
    d_exact = eng.diameter(exact=True)       # refreshes every level first
    assert d_maint <= d_exact + 1e-3
    assert np.isfinite(d_exact) and d_exact > 0
    # against a from-scratch APSP oracle over the engine's served edges
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import dijkstra
    edges, wts = eng.weighted_edges()
    cap = eng.capacity
    m = np.zeros((cap, cap))
    m[edges[:, 0], edges[:, 1]] = wts
    m[edges[:, 1], edges[:, 0]] = wts
    live = eng.live_ids()
    full = dijkstra(csr_matrix(m), directed=False, indices=live)[:, live]
    assert d_exact == pytest.approx(float(full[np.isfinite(full)].max()),
                                    rel=1e-4)


def test_engine_per_node_bounds_and_routing_after_churn():
    trace = cluster_split_merge(n0=N, seed=1)
    eng = HierChurnEngine(trace, seed=0)
    for e in sorted(trace.events, key=lambda e: e.time):
        eng.process(e)
    eng.refresh()
    live = eng.live_ids()
    src, dst = int(live[0]), int(live[-1])
    d, stamp = eng.distance_bound(src, dst)
    assert stamp == "exact" and np.isfinite(d)
    path, lat, levels, outcome = eng.route(src, dst)
    assert outcome == "delivered"
    assert path[0] == src and path[-1] == dst
    assert lat == pytest.approx(d, rel=1e-4)           # latency-potential walk
    assert levels["local"] + levels["head"] == len(path) - 1


def test_engine_rejects_stale_events():
    trace = cluster_split_merge(n0=48, seed=4)
    eng = HierChurnEngine(trace, seed=0)
    eng.process(Event(time=10.0, kind="join", node=48))
    with pytest.raises(ValueError):
        eng.process(Event(time=5.0, kind="leave", node=0))


# ---------------------------------------------------------------------------
# service integration
# ---------------------------------------------------------------------------

def test_service_hier_fresh_ingest_route_snapshot_restore(tmp_path):
    from repro.service.state import ServiceState

    world = Trace(n0=64, capacity=80, dist="bitnode", seed=3, events=[],
                  name="svc-hier")
    st = ServiceState.fresh(world, policy="dgro-hier",
                            snapshot_dir=str(tmp_path))
    stats = st.stats()
    assert stats["policy"] == "dgro-hier"
    assert stats["clusters"] >= 2
    res = st.ingest([
        Event(time=1.0, kind="join", node=64),
        Event(time=2.0, kind="leave", node=1),
        Event(time=3.0, kind="cluster_split", node=0),
        Event(time=4.0, kind="cluster_merge", node=1, peer=2),
    ])
    assert res["applied"] == 4, res
    live = np.asarray(st.adjacency()["nodes"])
    r = st.route(int(live[0]), int(live[-1]))
    assert r["reachable"] and r["hops"] >= 1
    assert r["hops_by_level"]["local"] + r["hops_by_level"]["head"] == r["hops"]
    assert r["stretch"] >= 1 - 1e-5

    path = st.write_snapshot()
    assert path is not None
    raw = json.loads(open(f"{path}/state.json").read())
    assert raw["schema"] == 2
    assert raw["kind"] == "service_snapshot_hier"

    d0 = st.diameter(exact=True)["diameter"]
    rt = ServiceState.restore(str(tmp_path))
    assert rt.stats()["clusters"] == st.stats()["clusters"]
    assert rt.stats()["n_live"] == st.stats()["n_live"]
    assert rt.diameter(exact=True)["diameter"] == pytest.approx(d0, rel=1e-5)
    a0 = st.adjacency()
    a1 = rt.adjacency()
    assert a0["nodes"] == a1["nodes"]
    assert sorted(map(tuple, a0["edges"])) == sorted(map(tuple, a1["edges"]))


def test_hier_gauges_track_engine_state():
    from repro.obs import HIER_CLUSTERS

    # a prior ServiceState in this process may have left a (now-dead)
    # scrape callback bound; drop it so the engine's direct .set() shows
    HIER_CLUSTERS.set_function(None)
    trace = cluster_split_merge(n0=48, seed=6)
    eng = HierChurnEngine(trace, seed=0)
    assert HIER_CLUSTERS.value == eng.n_clusters > 0


# ---------------------------------------------------------------------------
# scale smoke (slow: excluded from tier-1 by the marker)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_hier_scale_smoke_100k():
    n = 100_000
    lat = synthetic_geo(n, seed=0)
    hov = build_hier(lat, seed=0)
    assert hov.n == n and hov.n_clusters >= 2
    d, stamp = hov.diameter_bound("ecc")
    assert stamp == "upper" and np.isfinite(d) and d > 0
    trace = Trace(n0=n, capacity=n + 8, dist="bitnode", seed=0, events=[],
                  name="scale-smoke")
    eng = HierChurnEngine(trace, lat=synthetic_geo(n + 8, seed=0), seed=0)
    t = 0.0
    for i in range(10):
        t += 1.0
        eng.process(Event(time=t, kind="join", node=n + i % 8)
                    if i % 2 else Event(time=t, kind="leave", node=i))
    assert eng.events_processed == 10
