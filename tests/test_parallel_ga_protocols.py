"""Parallel construction (Alg. 4), GA baseline, protocol overlays."""
import subprocess
import sys

from conftest import subproc_env

import numpy as np
import pytest

from repro import overlay
from repro.core.diameter import diameter_scipy
from repro.core.ga import GAConfig, evolve, ga_search, random_search
from repro.core.parallel import parallel_overlay, parallel_ring, partition_nodes
from repro.core.topology import make_latency



def test_partition_nodes_cover_all():
    rng = np.random.default_rng(0)
    parts = partition_nodes(100, 7, rng)
    allnodes = np.concatenate(parts)
    assert sorted(allnodes) == list(range(100))


@pytest.mark.parametrize("m", [1, 2, 4, 8])
def test_parallel_ring_valid_and_reasonable(m):
    w = make_latency("gaussian", 64, seed=3)
    perm = parallel_ring(w, m, seed=0)
    assert sorted(perm) == list(range(64))
    ov, _ = parallel_overlay(w, m, seed=0)
    assert np.array_equal(ov.rings[0], perm)        # same Alg. 4 build
    d = ov.diameter()
    assert np.isfinite(d) and 0 < d < 1e8
    assert d == pytest.approx(diameter_scipy(ov.adjacency), rel=1e-4)


def test_parallel_ring_shmap_matches_host():
    """shard_map partition build == host build (run with 8 fake devices)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core.topology import make_latency
from repro.core.parallel import parallel_ring, parallel_ring_shmap
w = make_latency("gaussian", 64, seed=3)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("partitions",))
p_host = parallel_ring(w, 8, seed=0)
p_shm = parallel_ring_shmap(w, mesh, seed=0)
assert sorted(p_shm) == list(range(64))
from repro.overlay import Overlay
dh = Overlay.from_rings(w, [p_host]).diameter()
ds = Overlay.from_rings(w, [p_shm]).diameter()
assert abs(dh - ds) < 1e-6, (dh, ds)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=subproc_env(),
                         cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_ga_beats_random_same_budget():
    w = make_latency("uniform", 24, seed=5)
    _, d_ga, evals = ga_search(w, GAConfig(k_rings=2, budget=400, seed=0))
    _, d_rs = random_search(w, 2, 400, seed=0)
    assert evals == 400
    assert d_ga <= d_rs, (d_ga, d_rs)


def test_evolve_result_to_overlay():
    w = make_latency("uniform", 20, seed=5)
    res = evolve(w, GAConfig(k_rings=2, budget=120, population=20, seed=0))
    ov = res.to_overlay(w)
    assert ov.policy == "ga" and ov.num_rings == 2
    # the seeded diameter cache must agree with an independent oracle over
    # the rebuilt adjacency (catches wrong rings or stale best_diameter)
    assert ov.diameter() == pytest.approx(diameter_scipy(ov.adjacency),
                                          rel=1e-4)
    assert ov.diameter() == pytest.approx(res.best_diameter, rel=1e-4)


@pytest.mark.parametrize("builder", ["chord", "rapid", "perigee"])
def test_protocol_builders_deterministic(builder):
    """Same latency matrix + same rng seed -> bit-identical overlay."""
    w = make_latency("bitnode", 40, seed=2)
    ov1 = overlay.build(builder, w, rng=np.random.default_rng(9))
    ov2 = overlay.build(builder, w, rng=np.random.default_rng(9))
    assert ov1.equals(ov2)
    # a different seed produces a different overlay (sanity: rng is used)
    ov3 = overlay.build(builder, w, rng=np.random.default_rng(10))
    assert not np.array_equal(ov1.adjacency, ov3.adjacency)


def test_protocol_overlays_connected_and_bounded_degree():
    w = make_latency("uniform", 50, seed=6)
    rng = np.random.default_rng(0)
    for name in ("chord", "rapid", "perigee"):
        ov = overlay.build(name, w, rng=rng)
        assert ov.is_connected(), name
        assert np.isfinite(diameter_scipy(ov.adjacency)), name
        deg = ov.degrees()
        assert deg.max() <= 4 * np.ceil(np.log2(50)) + 4, (name, deg.max())
