"""Parallel construction (Alg. 4), GA baseline, protocol overlays."""
import subprocess
import sys

from conftest import subproc_env

import numpy as np
import pytest

from repro import overlay
from repro.core.diameter import diameter_scipy
from repro.core.ga import GAConfig, evolve, ga_search, random_search
from repro.core.parallel import (SegmentDQNConfig, parallel_overlay,
                                 parallel_ring, parallel_ring_host,
                                 parallel_ring_scored, parallel_rings,
                                 partition_nodes, score_partition_blocks,
                                 stitch_segments)
from repro.core.topology import make_latency



def test_partition_nodes_cover_all():
    rng = np.random.default_rng(0)
    parts = partition_nodes(100, 7, rng)
    allnodes = np.concatenate(parts)
    assert sorted(allnodes) == list(range(100))


@pytest.mark.parametrize("m", [1, 2, 4, 8])
def test_parallel_ring_valid_and_reasonable(m):
    w = make_latency("gaussian", 64, seed=3)
    perm = parallel_ring(w, m, seed=0)
    assert sorted(perm) == list(range(64))
    ov, _ = parallel_overlay(w, m, seed=0)
    assert np.array_equal(ov.rings[0], perm)        # same Alg. 4 build
    d = ov.diameter()
    assert np.isfinite(d) and 0 < d < 1e8
    assert d == pytest.approx(diameter_scipy(ov.adjacency), rel=1e-4)


@pytest.mark.parametrize("m", [1, 3, 5, 8, 17, 63, 64, 80])
def test_parallel_ring_any_m_and_host_parity(m):
    """Any 1 <= M (non-divisible N, M = N, even M > N) builds a valid ring,
    and the device-batched engine matches the host reference loop exactly
    (both consume the same PartitionPlan randomness)."""
    w = make_latency("gaussian", 64, seed=3)
    perm = parallel_ring(w, m, seed=0)
    assert sorted(perm) == list(range(64)), m
    assert np.array_equal(perm, parallel_ring_host(w, m, seed=0)), m


def test_parallel_ring_rejects_m_zero():
    w = make_latency("uniform", 8, seed=0)
    with pytest.raises(ValueError):
        parallel_ring(w, 0, seed=0)


def test_parallel_rings_batch_matches_single_builds():
    """B builds fused into one device call == B independent single builds."""
    w = make_latency("bitnode", 30, seed=7)
    seeds = [3, 11, 42]
    rings = parallel_rings(w, 4, seeds)
    for s, ring in zip(seeds, rings):
        assert np.array_equal(ring, parallel_ring(w, 4, seed=s)), s


def test_scored_stitch_never_worse_than_naive():
    """The naive merge is always a candidate, so the scored stitch can only
    improve the built ring's own diameter."""
    w = make_latency("gaussian", 64, seed=3)
    from repro.overlay import Overlay
    for m in (4, 8, 16):
        d_naive = Overlay.from_rings(
            w, [parallel_ring(w, m, seed=0, stitch="naive")]).diameter()
        d_scored = Overlay.from_rings(
            w, [parallel_ring(w, m, seed=0, stitch="scored")]).diameter()
        assert d_scored <= d_naive + 1e-6, (m, d_naive, d_scored)


def test_stitch_candidates_preserve_segment_edges():
    with pytest.raises(ValueError):
        stitch_segments(np.zeros((4, 4)), [np.array([], np.intp)])
    with pytest.raises(ValueError):
        stitch_segments(np.zeros((4, 4)), [np.arange(4)], stitch="bogus")
    # a single segment has nothing to refine: identity merge on both paths
    w = make_latency("uniform", 8, seed=0)
    seg = [np.arange(8)]
    assert np.array_equal(stitch_segments(w, seg, "naive"),
                          stitch_segments(w, seg, "scored"))


def test_score_partition_blocks_nan_for_empty_partitions():
    """M > N: per-requested-partition scores, NaN marking empty blocks."""
    w = make_latency("uniform", 5, seed=1)
    ring, scores = parallel_ring_scored(w, 8, seed=1, score_blocks=True)
    assert sorted(ring) == list(range(5))
    assert scores.shape == (8,)
    assert np.isfinite(scores[:5]).all()      # 5 singleton blocks, diameter 0
    assert np.isnan(scores[5:]).all()         # 3 empty partitions
    # direct call with an explicitly empty segment in the middle
    got = score_partition_blocks(w, [np.array([0, 1]),
                                     np.array([], np.intp),
                                     np.array([2, 3, 4])])
    assert np.isfinite(got[0]) and np.isnan(got[1]) and np.isfinite(got[2])


def test_parallel_dqn_constructor_uneven_partitions():
    """constructor="dqn" rides the vectorized rollout engine with partitions
    as the env batch; n=13, m=3 exercises unequal (5,4,4) padded sizes."""
    w = make_latency("uniform", 13, seed=1)
    rings = parallel_rings(w, 3, [0, 1], constructor="dqn",
                           dqn=SegmentDQNConfig(epochs=2, n_envs=2))
    for ring in rings:
        assert sorted(ring) == list(range(13))
    # tiny blocks (p_max <= 2) short-circuit to the nearest constructor
    w6 = make_latency("uniform", 6, seed=0)
    assert np.array_equal(parallel_ring(w6, 3, seed=0, constructor="dqn"),
                          parallel_ring(w6, 3, seed=0, constructor="nearest"))


def test_parallel_builder_constructor_and_stitch_knobs():
    w = make_latency("uniform", 20, seed=4)
    ov = overlay.build("parallel", w,
                       overlay.ParallelConfig(m=3, stitch="naive"), seed=2)
    assert ov.policy == "parallel" and ov.num_rings == 1
    ov2 = overlay.build("parallel", w,
                        overlay.ParallelConfig(m=3, stitch="scored"), seed=2)
    assert diameter_scipy(ov2.adjacency) <= diameter_scipy(ov.adjacency) + 1e-6


def test_parallel_ring_shmap_matches_host():
    """shard_map partition build == host build bit-for-bit on an M>1 mesh
    (8 fake devices), including the padded paths: non-divisible N (64, 30)
    and M > N (6 nodes over 8 partitions)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core.topology import make_latency
from repro.core.parallel import parallel_ring_host, parallel_ring_shmap
from repro.compat import make_mesh
mesh = make_mesh((8,), ("partitions",))
for n in (64, 30, 6):
    w = make_latency("gaussian", n, seed=3)
    p_shm = parallel_ring_shmap(w, mesh, seed=0)
    p_host = parallel_ring_host(w, 8, seed=0)
    assert sorted(p_shm) == list(range(n)), n
    assert np.array_equal(p_shm, p_host), (n, p_shm, p_host)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=subproc_env(),
                         cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_ga_beats_random_same_budget():
    w = make_latency("uniform", 24, seed=5)
    _, d_ga, evals = ga_search(w, GAConfig(k_rings=2, budget=400, seed=0))
    _, d_rs = random_search(w, 2, 400, seed=0)
    assert evals == 400
    assert d_ga <= d_rs, (d_ga, d_rs)


def test_evolve_result_to_overlay():
    w = make_latency("uniform", 20, seed=5)
    res = evolve(w, GAConfig(k_rings=2, budget=120, population=20, seed=0))
    ov = res.to_overlay(w)
    assert ov.policy == "ga" and ov.num_rings == 2
    # the seeded diameter cache must agree with an independent oracle over
    # the rebuilt adjacency (catches wrong rings or stale best_diameter)
    assert ov.diameter() == pytest.approx(diameter_scipy(ov.adjacency),
                                          rel=1e-4)
    assert ov.diameter() == pytest.approx(res.best_diameter, rel=1e-4)


@pytest.mark.parametrize("builder", ["chord", "rapid", "perigee"])
def test_protocol_builders_deterministic(builder):
    """Same latency matrix + same rng seed -> bit-identical overlay."""
    w = make_latency("bitnode", 40, seed=2)
    ov1 = overlay.build(builder, w, rng=np.random.default_rng(9))
    ov2 = overlay.build(builder, w, rng=np.random.default_rng(9))
    assert ov1.equals(ov2)
    # a different seed produces a different overlay (sanity: rng is used)
    ov3 = overlay.build(builder, w, rng=np.random.default_rng(10))
    assert not np.array_equal(ov1.adjacency, ov3.adjacency)


def test_protocol_overlays_connected_and_bounded_degree():
    w = make_latency("uniform", 50, seed=6)
    rng = np.random.default_rng(0)
    for name in ("chord", "rapid", "perigee"):
        ov = overlay.build(name, w, rng=rng)
        assert ov.is_connected(), name
        assert np.isfinite(diameter_scipy(ov.adjacency)), name
        deg = ov.degrees()
        assert deg.max() <= 4 * np.ceil(np.log2(50)) + 4, (name, deg.max())
