"""Parallel construction (Alg. 4), GA baseline, protocol overlays."""
import subprocess
import sys

from conftest import subproc_env

import numpy as np
import pytest

from repro.core import protocols
from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.core.ga import GAConfig, ga_search, random_search
from repro.core.parallel import parallel_ring, partition_nodes
from repro.core.topology import make_latency



def test_partition_nodes_cover_all():
    rng = np.random.default_rng(0)
    parts = partition_nodes(100, 7, rng)
    allnodes = np.concatenate(parts)
    assert sorted(allnodes) == list(range(100))


@pytest.mark.parametrize("m", [1, 2, 4, 8])
def test_parallel_ring_valid_and_reasonable(m):
    w = make_latency("gaussian", 64, seed=3)
    perm = parallel_ring(w, m, seed=0)
    assert sorted(perm) == list(range(64))
    d = diameter_scipy(adjacency_from_rings(w, [perm]))
    assert np.isfinite(d) and d > 0


def test_parallel_ring_shmap_matches_host():
    """shard_map partition build == host build (run with 8 fake devices)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro.core.topology import make_latency
from repro.core.parallel import parallel_ring, parallel_ring_shmap
w = make_latency("gaussian", 64, seed=3)
from repro.compat import make_mesh
mesh = make_mesh((8,), ("partitions",))
p_host = parallel_ring(w, 8, seed=0)
p_shm = parallel_ring_shmap(w, mesh, seed=0)
assert sorted(p_shm) == list(range(64))
from repro.core.diameter import adjacency_from_rings, diameter_scipy
dh = diameter_scipy(adjacency_from_rings(w, [p_host]))
ds = diameter_scipy(adjacency_from_rings(w, [p_shm]))
assert abs(dh - ds) < 1e-6, (dh, ds)
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=subproc_env(),
                         cwd=".", timeout=300)
    assert "OK" in out.stdout, out.stderr[-2000:]


def test_ga_beats_random_same_budget():
    w = make_latency("uniform", 24, seed=5)
    _, d_ga, evals = ga_search(w, GAConfig(k_rings=2, budget=400, seed=0))
    _, d_rs = random_search(w, 2, 400, seed=0)
    assert evals == 400
    assert d_ga <= d_rs, (d_ga, d_rs)


@pytest.mark.parametrize("builder", ["chord", "rapid", "perigee"])
def test_protocol_builders_deterministic(builder):
    """Same latency matrix + same rng seed -> bit-identical overlay."""
    w = make_latency("bitnode", 40, seed=2)
    build = getattr(protocols, builder)
    adj1, rings1 = build(w, np.random.default_rng(9))
    adj2, rings2 = build(w, np.random.default_rng(9))
    assert np.array_equal(adj1, adj2)
    assert len(rings1) == len(rings2)
    assert all(np.array_equal(a, b) for a, b in zip(rings1, rings2))
    # a different seed produces a different overlay (sanity: rng is used)
    adj3, _ = build(w, np.random.default_rng(10))
    assert not np.array_equal(adj1, adj3)


def test_protocol_overlays_connected_and_bounded_degree():
    w = make_latency("uniform", 50, seed=6)
    rng = np.random.default_rng(0)
    for name, (adj, rings) in {
        "chord": protocols.chord(w, rng),
        "rapid": protocols.rapid(w, rng),
        "perigee": protocols.perigee(w, rng),
    }.items():
        d = diameter_scipy(adj)
        assert np.isfinite(d), name
        deg = protocols.node_degrees(adj)
        assert deg.max() <= 4 * np.ceil(np.log2(50)) + 4, (name, deg.max())
