"""repro.core.rollout: the device-resident vectorized episode engine."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import rollout
from repro.core.embedding import init_qparams
from repro.core.qlearning import DQNConfig, _run_episode
from repro.core.topology import make_latency
from repro.train.optimizer import adamw_init


def _params(seed=0, p=8, h=16):
    return init_qparams(jax.random.PRNGKey(seed), p, h)


def test_make_plan_shapes_and_determinism():
    plan = rollout.make_plan(np.random.default_rng(5), n_envs=3, k_rings=2,
                             n=7, updates_per_step=2, batch_size=4)
    assert plan.starts.shape == (3, 2)
    assert plan.eps_u.shape == (14, 3) and plan.choice_u.shape == (14, 3)
    assert plan.sample_u.shape == (14, 2, 4)
    assert plan.starts.min() >= 0 and plan.starts.max() < 7
    again = rollout.make_plan(np.random.default_rng(5), 3, 2, 7, 2, 4)
    assert np.array_equal(plan.eps_u, again.eps_u)
    # no training -> empty sampling block
    lean = rollout.make_plan(np.random.default_rng(5), 3, 2, 7)
    assert lean.sample_u.shape == (14, 0, 0)


def test_rollout_output_shapes_and_valid_rings():
    n, k, n_envs = 8, 2, 4
    cfg = DQNConfig(n=n, k_rings=k, p=8, h=16)
    params = _params()
    ws = np.stack([make_latency("uniform", n, seed=i) for i in range(n_envs)])
    plan = rollout.make_plan(np.random.default_rng(0), n_envs, k, n)
    actions, rewards, d = rollout.rollout_episodes(
        params, jnp.asarray(ws, jnp.float32), jnp.asarray(plan.starts),
        jnp.asarray(plan.eps_u), jnp.asarray(plan.choice_u), 0.3, cfg.alpha,
        k_rings=k, n_rounds=2)
    assert actions.shape == (k * n, n_envs)
    assert rewards.shape == (k * n, n_envs)
    assert d.shape == (n_envs,)
    assert bool(jnp.all(jnp.isfinite(rewards)))
    assert bool(jnp.all(d > 0))
    # every episode's rings are permutations of range(n)
    for perms in rollout.perms_from_actions(plan.starts, np.asarray(actions),
                                            k, n):
        for perm in perms:
            assert np.array_equal(np.sort(perm), np.arange(n))


def test_multi_env_parity_with_host_loop():
    """E vmapped environments match E sequential host episodes consuming
    the same plan columns — different graphs per env."""
    n, k, n_envs = 8, 2, 3
    cfg = DQNConfig(n=n, k_rings=k, p=8, h=16, n_rounds=2)
    params = _params(seed=2)
    ws = np.stack([make_latency("gaussian", n, seed=10 + i)
                   for i in range(n_envs)])
    plan = rollout.make_plan(np.random.default_rng(8), n_envs, k, n)
    actions, rewards, d = rollout.rollout_episodes(
        params, jnp.asarray(ws, jnp.float32), jnp.asarray(plan.starts),
        jnp.asarray(plan.eps_u), jnp.asarray(plan.choice_u), 0.5, cfg.alpha,
        k_rings=k, n_rounds=cfg.n_rounds)
    perms_dev = rollout.perms_from_actions(plan.starts, np.asarray(actions),
                                           k, n)
    for e in range(n_envs):
        _, _, d_h, _, perms_h, rw_h = _run_episode(
            params, cfg, ws[e], 0.5, plan, e, buffer=None, train=False)
        assert all(np.array_equal(a, b)
                   for a, b in zip(perms_h, perms_dev[e])), e
        assert np.allclose(rw_h, np.asarray(rewards)[:, e], atol=1e-4)
        assert abs(d_h - float(np.asarray(d)[e])) <= 1e-3 * max(1.0, d_h)


def test_graph_slots_reuse_is_safe():
    """A graph-table slot is only reused after every transition referencing
    its previous occupant has been overwritten in the ring buffer."""
    for cap, n_envs, k, n in [(20000, 1, 2, 14), (500, 4, 2, 8),
                              (64, 2, 1, 6), (7, 3, 2, 5)]:
        slots = rollout.graph_slots(cap, n_envs, k, n)
        pushes_per_epoch = n_envs * k * (n - 1)
        epochs_to_reuse = slots // n_envs
        assert (epochs_to_reuse - 1) * pushes_per_epoch >= cap, \
            (cap, n_envs, k, n, slots)


def test_train_epoch_buffer_invariants_and_updates():
    n, k, n_envs, cap, batch = 8, 2, 2, 64, 8
    cfg = DQNConfig(n=n, k_rings=k, p=8, h=16, n_rounds=2)
    params = _params(seed=1)
    opt_state = adamw_init(params)
    slots = rollout.graph_slots(cap, n_envs, k, n)
    buf = rollout.init_buffer(cap, n, slots)
    ws = np.stack([make_latency("uniform", n, seed=20 + i)
                   for i in range(n_envs)])
    plan = rollout.make_plan(np.random.default_rng(1), n_envs, k, n,
                             updates_per_step=1, batch_size=batch)
    gids = jnp.asarray(np.arange(n_envs), jnp.int32)
    params2, opt2, buf2, d, losses, actions, rewards = rollout.train_epoch(
        params, opt_state, buf, jnp.asarray(ws, jnp.float32), gids,
        jnp.asarray(plan.starts), jnp.asarray(plan.eps_u),
        jnp.asarray(plan.choice_u), jnp.asarray(plan.sample_u),
        0.8, 0.99, 5e-4, 0.1, k_rings=k, n_rounds=2, batch_size=batch,
        updates_per_step=1)
    # closing steps are not pushed: k*(n-1) transitions per env
    assert int(buf2.size) == n_envs * k * (n - 1)
    assert int(buf2.ptr) == int(buf2.size) % cap
    # the epoch graphs landed in their table slots, transitions point at them
    assert np.allclose(np.asarray(buf2.table[:n_envs]),
                       ws.astype(np.float32))
    live_widx = np.asarray(buf2.widx)[:int(buf2.size)]
    assert set(live_widx.tolist()) <= set(range(n_envs))
    # pushed done flags are all False (mirrors the host loop)
    assert not np.asarray(buf2.done)[:int(buf2.size)].any()
    # TD updates kicked in once the buffer filled: early NaN, late finite
    l = np.asarray(losses)
    assert np.isnan(l[0])
    assert np.isfinite(l[-1])
    # and the params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    # stored rewards for the first env's first steps match the scan output
    stored_r = np.asarray(buf2.reward)[:int(buf2.size)]
    assert np.isfinite(stored_r).all()


def test_device_buffer_wraps_capacity():
    n, k, n_envs, cap = 6, 2, 2, 10     # pushes/epoch = 2*2*5 = 20 > cap
    params = _params(seed=0)
    opt_state = adamw_init(params)
    slots = rollout.graph_slots(cap, n_envs, k, n)
    buf = rollout.init_buffer(cap, n, slots)
    ws = np.stack([make_latency("uniform", n, seed=i) for i in range(n_envs)])
    plan = rollout.make_plan(np.random.default_rng(2), n_envs, k, n,
                             updates_per_step=1, batch_size=4)
    _, _, buf2, *_ = rollout.train_epoch(
        params, opt_state, buf, jnp.asarray(ws, jnp.float32),
        jnp.asarray(np.arange(n_envs), jnp.int32), jnp.asarray(plan.starts),
        jnp.asarray(plan.eps_u), jnp.asarray(plan.choice_u),
        jnp.asarray(plan.sample_u), 1.0, 0.99, 5e-4, 0.1,
        k_rings=k, n_rounds=1, batch_size=4, updates_per_step=1)
    assert int(buf2.size) == cap
    assert 0 <= int(buf2.ptr) < cap


def test_rollout_sizes_none_equals_full_sizes():
    """The padded-env path with sizes == N must be bit-identical to the
    default path (the parallel engine relies on this degenerate case)."""
    n, k, n_envs = 8, 2, 3
    params = _params(seed=1)
    ws = jnp.asarray(np.stack([make_latency("uniform", n, seed=i)
                               for i in range(n_envs)]), jnp.float32)
    plan = rollout.make_plan(np.random.default_rng(3), n_envs, k, n)
    args = (jnp.asarray(plan.starts), jnp.asarray(plan.eps_u),
            jnp.asarray(plan.choice_u))
    a1, r1, d1 = rollout.rollout_episodes(params, ws, *args, 0.4, 0.1,
                                          k_rings=k, n_rounds=2)
    a2, r2, d2 = rollout.rollout_episodes(
        params, ws, *args, 0.4, 0.1, k_rings=k, n_rounds=2,
        sizes=jnp.full((n_envs,), n, jnp.int32))
    assert np.array_equal(np.asarray(a1), np.asarray(a2))
    assert np.array_equal(np.asarray(r1), np.asarray(r2))
    assert np.array_equal(np.asarray(d1), np.asarray(d2))


def test_rollout_sizes_padded_envs_build_real_subrings():
    """Envs with sizes[e] < N (padded blocks, the parallel engine's batch
    layout) must build rings that are permutations of their real nodes
    only, with zero reward and frozen state on the idle steps."""
    n, k, n_envs = 8, 2, 3
    sizes = np.array([8, 5, 3], np.int32)
    params = _params(seed=4)
    ws = np.stack([make_latency("gaussian", n, seed=20 + i)
                   for i in range(n_envs)])
    ws[1, 5:, :] = ws[1, :, 5:] = 0.0       # pad region (masked anyway)
    ws[2, 3:, :] = ws[2, :, 3:] = 0.0
    plan = rollout.make_plan(np.random.default_rng(6), n_envs, k, n)
    starts = (plan.starts % sizes[:, None]).astype(np.int32)
    actions, rewards, d = rollout.rollout_episodes(
        params, jnp.asarray(ws, jnp.float32), jnp.asarray(starts),
        jnp.asarray(plan.eps_u), jnp.asarray(plan.choice_u), 0.5, 0.1,
        k_rings=k, n_rounds=2, sizes=jnp.asarray(sizes))
    actions = np.asarray(actions)
    rewards = np.asarray(rewards)
    for e, s in enumerate(sizes):
        for ring_i in range(k):
            base = ring_i * n
            perm = [int(starts[e, ring_i])] + \
                list(actions[base:base + s - 1, e])
            assert sorted(perm) == list(range(s)), (e, ring_i, perm)
            # idle steps past the per-env closing edge earn nothing
            assert np.all(rewards[base + s:base + n, e] == 0.0), (e, ring_i)
    assert np.asarray(d).shape == (n_envs,)
    assert np.isfinite(np.asarray(d)).all()
