"""Sharding-rule unit tests (AbstractMesh — no devices needed) + a mini
multi-device dry-run integration test (subprocess, 8 fake devices)."""
import subprocess
import sys

from conftest import subproc_env

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.configs import ARCHS, get_arch
from repro.launch.shardings import (batch_specs, cache_specs, param_specs,
                                    spec_for_param, state_specs, zero_spec)
from repro.models import model as Mdl



MESH = abstract_mesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divisible_everywhere(arch):
    """Every sharded dim must divide by its mesh axis; big matrices must
    actually BE sharded (vocab/ff/heads/experts over model)."""
    cfg = get_arch(arch)
    shapes = jax.eval_shape(
        lambda: Mdl.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
    specs = param_specs(shapes, MESH)
    sl, _ = jax.tree_util.tree_flatten_with_path(specs)
    hl, _ = jax.tree_util.tree_flatten_with_path(shapes)
    n_big_unsharded = 0
    for (path, spec), (_, leaf) in zip(sl, hl):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is not None:
                size = MESH.shape[ax] if isinstance(ax, str) else \
                    int(np.prod([MESH.shape[a] for a in ax]))
                assert dim % size == 0, (arch, jax.tree_util.keystr(path))
        name = jax.tree_util.keystr(path)
        if (leaf.size > 4e6 and all(a is None for a in tuple(spec))
                and "router" not in name):   # router is replicated by design
            n_big_unsharded += 1
    assert n_big_unsharded == 0, f"{arch}: {n_big_unsharded} big leaves unsharded"


def test_zero_spec_adds_data_axis():
    spec = zero_spec(P("model", None), (262144, 1152), MESH, ("data",))
    assert tuple(spec) in (("model", "data"), ("model", ("data",)))
    # non-divisible dim stays replicated
    spec = zero_spec(P("model", None), (262144, 7), MESH, ("data",))
    assert tuple(spec) == ("model", None)


def test_cache_and_batch_specs():
    cfg = get_arch("gemma3-1b")
    caches = jax.eval_shape(lambda: Mdl.init_caches(cfg, 128, 1024, jnp.bfloat16))
    specs = cache_specs(caches, MESH, 128, ("data",))
    kspec = specs["blocks"]["pos5"]["k"]
    assert tuple(kspec)[1] in ("data", ("data",))  # batch dim (after stack)
    # gemma3-1b has kv=1 head (not divisible by 16) -> falls back to
    # sequence-dim sharding of the cache
    assert tuple(kspec)[2] is None and tuple(kspec)[3] == "model"
    b = batch_specs({"tokens": jax.ShapeDtypeStruct((128, 64), jnp.int32)},
                    MESH, ("data",))
    assert tuple(b["tokens"]) in ((("data",), None), ("data", None))
    # batch=1 (long_500k): replicated
    b1 = batch_specs({"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)},
                     MESH, ("data",))
    assert tuple(b1["tokens"]) == (None, None)


def test_mini_dryrun_8dev():
    """Smoke config lower+compile on a (2, 4) mesh with collectives."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import model as Mdl
from repro.models.sharding import default_rules, use_rules
from repro.launch.shardings import batch_specs, state_specs, to_shardings
from repro.roofline.analysis import parse_collectives, roofline_from
from repro.train.train_step import TrainConfig, TrainState, train_step
from repro.train.optimizer import adamw_init

from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_arch("moonshot-v1-16b-a3b").smoke()
tc = TrainConfig(remat=True, microbatches=1)
rules = default_rules(data_axes=("data",), mesh=mesh)

def step(state, batch):
    with use_rules(rules):
        return train_step(cfg, tc, state, batch, mesh=mesh,
                          data_axes=("data",))

st = jax.eval_shape(lambda: TrainState(
    params=Mdl.init_params(cfg, jax.random.PRNGKey(0), jnp.float32),
    opt=adamw_init(Mdl.init_params(cfg, jax.random.PRNGKey(0), jnp.float32))))
st_sh = to_shardings(state_specs(st, mesh, ("data",)), mesh)
batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
         "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
b_sh = to_shardings(batch_specs(batch, mesh, ("data",)), mesh)
lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                  donate_argnums=(0,)).lower(st, batch)
compiled = lowered.compile()
cost = compiled.cost_analysis()
roof = roofline_from(cost, compiled.as_text())
assert roof.flops > 0
assert roof.n_collectives > 0, "SPMD must emit collectives"
print("OK", int(roof.flops), roof.n_collectives)
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=subproc_env(),
                         cwd=".", timeout=600)
    assert "OK" in out.stdout, out.stderr[-3000:]
