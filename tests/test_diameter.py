"""Diameter/APSP: JAX min-plus vs scipy oracle vs networkx; invariants."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import topology
from repro.core.construction import nearest_ring, random_ring
from repro.core.diameter import (INF, adjacency_from_rings, apsp, diameter,
                                 diameter_scipy, ring_edges)


def _ring_adj(n=20, k=2, seed=0, dist="uniform"):
    w = topology.make_latency(dist, n, seed=seed)
    rng = np.random.default_rng(seed)
    rings = [random_ring(rng, n) for _ in range(k)]
    return w, adjacency_from_rings(w, rings)


@pytest.mark.parametrize("dist", ["uniform", "gaussian", "fabric", "bitnode"])
@pytest.mark.parametrize("n", [8, 21, 50])
def test_jax_matches_scipy(dist, n):
    w, adj = _ring_adj(n=n, seed=n, dist=dist)
    assert float(diameter(jnp.asarray(adj))) == pytest.approx(
        diameter_scipy(adj), rel=1e-5)


def test_matches_networkx():
    import networkx as nx
    w, adj = _ring_adj(n=24, seed=3)
    g = nx.Graph()
    for i in range(24):
        for j in range(i + 1, 24):
            if adj[i, j] < float(INF) / 2:
                g.add_edge(i, j, weight=float(adj[i, j]))
    want = nx.diameter(g, weight="weight")  # eccentricity-based
    lengths = dict(nx.all_pairs_dijkstra_path_length(g, weight="weight"))
    want = max(max(d.values()) for d in lengths.values())
    assert float(diameter(jnp.asarray(adj))) == pytest.approx(want, rel=1e-5)


def test_disconnected_uses_largest_component():
    w = topology.make_latency("uniform", 10, seed=0)
    # component A: ring over 0..5; component B: edge 6-7; 8, 9 isolated
    edges = list(ring_edges(np.arange(6))) + [(6, 7)]
    from repro.core.diameter import adjacency_from_edges
    adj = adjacency_from_edges(w, edges)
    d = float(diameter(jnp.asarray(adj)))
    assert d < float(INF) / 2
    assert d == pytest.approx(diameter_scipy(adj), rel=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(6, 24), st.integers(0, 10_000))
def test_apsp_properties(n, seed):
    """APSP output: zero diagonal, symmetric, triangle inequality, and
    monotone non-increasing under edge addition."""
    w, adj = _ring_adj(n=n, seed=seed, k=1)
    d = np.asarray(apsp(jnp.asarray(adj)))
    assert np.allclose(np.diag(d), 0.0)
    assert np.allclose(d, d.T, atol=1e-3)
    # triangle inequality on finite entries
    fin = d < float(INF) / 2
    for _ in range(20):
        i, j, k = np.random.default_rng(seed).integers(0, n, 3)
        if fin[i, j] and fin[j, k] and fin[i, k]:
            assert d[i, k] <= d[i, j] + d[j, k] + 1e-3
    # adding a ring can only reduce the diameter
    rng = np.random.default_rng(seed + 1)
    adj2 = adjacency_from_rings(w, [random_ring(rng, n)])
    both = np.minimum(adj, adj2)
    assert float(diameter(jnp.asarray(both))) <= float(
        diameter(jnp.asarray(adj))) + 1e-3


def test_nearest_ring_not_worse_than_random_on_clustered():
    """On geographically clustered latencies the nearest ring usually has a
    smaller total weight; the diameter claim is what the paper's selection
    exploits (either may win — just check both produce valid diameters)."""
    w = topology.make_latency("fabric", 40, seed=1)
    rng = np.random.default_rng(0)
    d_near = diameter_scipy(adjacency_from_rings(w, [nearest_ring(w, 0)]))
    d_rand = diameter_scipy(adjacency_from_rings(w, [random_ring(rng, 40)]))
    assert d_near > 0 and d_rand > 0
