"""End-to-end behaviour tests for the paper's system (DGRO pipeline)."""
import numpy as np
import pytest

from repro.core.construction import default_num_rings, k_rings, random_ring
from repro.core.diameter import adjacency_from_rings, diameter_scipy
from repro.core.selection import (clustering_ratio, measure_latency_stats,
                                  select_ring_kind)
from repro.core.topology import make_latency


def dgro_pipeline(w, seed=0):
    """End-to-end DGRO (heuristic path): probe -> rho -> ring choice."""
    n = w.shape[0]
    k = max(2, default_num_rings(n) // 2)
    rng = np.random.default_rng(seed)
    probe = adjacency_from_rings(w, k_rings(w, k, "random", rng))
    rho = clustering_ratio(measure_latency_stats(w, probe, seed=seed))
    kind = select_ring_kind(rho)
    m = k if kind == "random" else (0 if kind == "nearest" else k // 2)
    rings = k_rings(w, k, f"mixed:{m}", rng)
    return diameter_scipy(adjacency_from_rings(w, rings)), rho, kind


@pytest.mark.parametrize("dist", ["uniform", "gaussian", "fabric", "bitnode"])
def test_dgro_pipeline_end_to_end(dist):
    """The full selection pipeline produces a connected overlay whose
    diameter is no worse than an all-random K-ring baseline (in expectation
    the paper shows large gains; here we assert not-worse + validity)."""
    w = make_latency(dist, 80, seed=3)
    d_dgro, rho, kind = dgro_pipeline(w)
    rng = np.random.default_rng(99)
    k = max(2, default_num_rings(80) // 2)
    d_rand = np.median([
        diameter_scipy(adjacency_from_rings(
            w, [random_ring(np.random.default_rng(s), 80) for _ in range(k)]))
        for s in range(5)])
    assert np.isfinite(d_dgro) and d_dgro > 0
    assert 0.0 <= rho <= 1.5
    assert d_dgro <= d_rand * 1.25, (dist, d_dgro, d_rand, rho, kind)


def test_dgro_improves_realistic_latency():
    """On geographically clustered (fabric) latencies the paper's selection
    must find a strictly better-than-random configuration."""
    w = make_latency("fabric", 100, seed=1)
    d_dgro, rho, kind = dgro_pipeline(w)
    rng = np.random.default_rng(5)
    k = max(2, default_num_rings(100) // 2)
    d_rand = np.median([
        diameter_scipy(adjacency_from_rings(
            w, [random_ring(np.random.default_rng(s), 100) for _ in range(k)]))
        for s in range(5)])
    assert d_dgro < d_rand, (d_dgro, d_rand, rho, kind)
