"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict, Iterable

from repro.obs.metrics import LATENCY_BUCKETS_S, Histogram


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0
    t["us"] = t["s"] * 1e6


def latency_histogram(name: str = "bench_latency_seconds") -> Histogram:
    """Standalone (unregistered) histogram with the service's latency
    buckets — BENCH JSON artifacts and the live ``/v1/metrics`` endpoint
    summarize through the exact same bucket/quantile implementation."""
    return Histogram(name, "benchmark-local latency samples",
                     buckets=LATENCY_BUCKETS_S)


def latency_summary(samples_s: Iterable[float]) -> Dict[str, float]:
    """count/sum/p50/p90/p99 of per-call latencies (seconds) via
    :class:`repro.obs.metrics.Histogram` — the shape BENCH JSON embeds."""
    h = latency_histogram()
    for s in samples_s:
        h.observe(float(s))
    return h.summary()
