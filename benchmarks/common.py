"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import time
from contextlib import contextmanager


def emit(name: str, us_per_call: float, derived: str = ""):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


@contextmanager
def timed():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0
    t["us"] = t["s"] * 1e6
