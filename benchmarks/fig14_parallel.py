"""Paper Figs. 14/18: parallel DGRO construction — diameter vs partitions.

The N nodes are strided into M partitions; each partition orders its slice
concurrently (nearest-neighbour constructor) and segments are stitched
(Alg. 4).  Reports diameter for M = 1..max and validates the paper's claim
that partitioned construction matches the sequential build's diameter while
cutting sequential steps by ~Mx.  Also cross-checks the shard_map
implementation against the host implementation (M=8).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import batcheval
from repro.core.parallel import parallel_overlay
from repro.core.topology import make_latency
from repro.overlay import Overlay


def run(dist: str = "uniform", n: int = 256,
        partitions=(1, 2, 4, 8, 16, 32), seed: int = 0, k_rings: int = 3):
    """Paper setup: the K-ring topology keeps (K-1) random rings fixed and
    builds ONE ring with the partitioned constructor; the claim is that the
    topology diameter stays flat as partitions increase."""
    import numpy as np

    from repro.core.construction import random_ring

    w = make_latency(dist, n, seed=seed)
    rng = np.random.default_rng(seed)
    fixed = [random_ring(rng, n) for _ in range(k_rings - 1)]
    t0 = time.time()
    print("partitions,topology_diameter,parallel_ring_only,max_block_diam,"
          "seq_steps")
    diams = {}
    for m in partitions:
        solo, block_d = parallel_overlay(w, m, seed=seed, score_blocks=True)
        full = Overlay.from_rings(w, fixed + [solo.rings[0]])
        # full K-ring overlay + the built ring alone, one batched call
        d, d_solo = batcheval.diameters(np.stack([
            full.adjacency, solo.adjacency]))
        diams[m] = float(d)
        print(f"{m},{d:.1f},{d_solo:.1f},{block_d.max():.1f},{n // m}")
    wall = time.time() - t0
    base = diams[partitions[0]]
    ratio8 = diams.get(8, base) / base
    ratio_max = max(diams.values()) / base
    print(f"# n={n} dist={dist} K={k_rings}: ratio@8={ratio8:.2f} "
          f"ratio@{partitions[-1]}={ratio_max:.2f}")
    # paper claim: 8-partition comparable on synthetic; degradation stays
    # bounded out to 32 (Figs. 14/18 show the same small gaps)
    return {"name": f"fig14_parallel[{dist}]",
            "us_per_call": wall * 1e6 / len(partitions),
            "derived": f"K-ring diam ratio: {ratio8:.2f}@8 partitions, "
                       f"{ratio_max:.2f}@{partitions[-1]}",
            "holds": ratio8 < 1.35}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="uniform")
    ap.add_argument("--n", type=int, default=256)
    args = ap.parse_args()
    run(args.dist, args.n)
