"""Paper Figs. 14/18: parallel DGRO construction — throughput + diameter vs M.

Part A — the throughput gate.  The device-batched engine
(``parallel_rings``: all B*M padded partition blocks of B ring builds
gathered and constructed in ONE jit'd call) is timed against the
pre-batched host loop (``parallel_ring_host``: a Python ``for`` of numpy
nearest-neighbour builds per partition).  The acceptance gate is >= 5x
per-ring construction throughput at N=256, M=8 on CPU (best-of-N min-time,
jit warmed outside the timed runs — the CI-sized box has bimodal timing).

Part B — the diameter-parity gate + M sweep.  The paper's claim 3: parallel
construction scales to 32 partitions "while maintaining the same diameter
compared to the centralized version".  We build the paper's full ring
budget (K = ceil(log2 N) rings, §IV-B) entirely with the partitioned
constructor — scored stitch: segment rotations/reflections ranked in one
batched diameter call — for M in {1..32} and compare against M=1 (the
centralized builder).  The gate: mean topology diameter over seeds at M=8
within 5% of M=1, on uniform AND bitnode (clustered) latencies.

Results go to ``BENCH_fig14_parallel.json`` (archived by CI next to the
fig09/fig16 artifacts).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.construction import default_num_rings
from repro.core.parallel import parallel_ring_host, parallel_rings
from repro.core.topology import make_latency
from repro.overlay import Overlay


def _best(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_speedup(gate_n: int, gate_m: int, batch: int, repeats: int,
                   seed: int) -> dict:
    """Part A: per-ring build time, batched engine (B rings, one device
    call) vs the host per-partition loop."""
    w = make_latency("uniform", gate_n, seed=seed)
    seeds = list(range(batch))
    parallel_rings(w, gate_m, seeds)             # warm the fused jit
    parallel_ring_host(w, gate_m, seed=seed)     # warm numpy caches
    t_batched = _best(lambda: parallel_rings(w, gate_m, seeds), repeats) / batch
    t_host = _best(lambda: parallel_ring_host(w, gate_m, seed=seed), repeats)
    return {
        "n": gate_n, "m": gate_m, "batch": batch,
        "us_per_ring_batched": t_batched * 1e6,
        "us_per_ring_host": t_host * 1e6,
        "speedup": t_host / t_batched,
    }


def _topology_diameter(w: np.ndarray, m: int, k: int, seed: int,
                       stitch: str) -> float:
    """Full K-ring topology with every ring built by the M-partition
    engine — one fused device call for all K*M partition segments."""
    rings = parallel_rings(w, m, [seed * 1000 + r for r in range(k)],
                           stitch=stitch)
    return Overlay.from_rings(w, rings).diameter()


def run(n: int = 256, partitions=(1, 2, 4, 8, 16, 32), seeds=(0, 1, 2),
        dists=("uniform", "bitnode"), gate_n: int = 256, gate_m: int = 8,
        gate_batch: int = 32, repeats: int = 5, stitch: str = "scored",
        out_json: str = "BENCH_fig14_parallel.json"):
    t0 = time.time()
    results: dict = {"sweeps": [], "stitch_gain": []}

    # ---- part A: construction throughput gate (always N=256, M=8) -------
    results["gate_speedup"] = _bench_speedup(gate_n, gate_m, gate_batch,
                                             repeats, seed=0)
    speedup = results["gate_speedup"]["speedup"]
    print("engine,n,m,us_per_ring")
    print(f"host-loop,{gate_n},{gate_m},"
          f"{results['gate_speedup']['us_per_ring_host']:.0f}")
    print(f"batched[B={gate_batch}],{gate_n},{gate_m},"
          f"{results['gate_speedup']['us_per_ring_batched']:.0f}")
    print(f"# batched speedup {speedup:.1f}x (gate >= 5x)")

    # ---- part B: diameter parity vs the centralized builder -------------
    k = default_num_rings(n)
    gate_ms = {1, 8} | set(partitions)
    print("dist,seed,partitions,topology_diameter")
    diams: dict = {d: {m: [] for m in sorted(gate_ms)} for d in dists}
    for dist in dists:
        for seed in seeds:
            w = make_latency(dist, n, seed=seed)
            for m in sorted(gate_ms):
                d = _topology_diameter(w, m, k, seed, stitch)
                diams[dist][m].append(d)
                results["sweeps"].append(
                    {"dist": dist, "seed": seed, "m": m, "k_rings": k,
                     "diameter": d})
                print(f"{dist},{seed},{m},{d:.1f}")

    ratios = {}
    for dist in dists:
        base = float(np.mean(diams[dist][1]))
        ratios[dist] = float(np.mean(diams[dist][8])) / base
        worst = max(float(np.mean(diams[dist][m])) / base
                    for m in diams[dist])
        results.setdefault("gate_parity", {})[dist] = {
            "k_rings": k, "n": n, "seeds": list(seeds),
            "mean_diameter_m1": base,
            "mean_diameter_m8": float(np.mean(diams[dist][8])),
            "ratio_at_8": ratios[dist], "worst_ratio": worst,
        }
        print(f"# {dist}: ratio@8={ratios[dist]:.3f} (gate <= 1.05), "
              f"worst over sweep {worst:.2f}")

    # ---- stitch refinement win (informational; only meaningful when the
    # sweep itself ran with the scored stitch) ----------------------------
    if stitch == "scored":
        for dist in dists:
            w = make_latency(dist, n, seed=seeds[0])
            for m in sorted({8, max(partitions)}):
                d_naive = _topology_diameter(w, m, k, seeds[0], "naive")
                d_scored = diams[dist][m][0]      # seeds[0]'s scored build
                results["stitch_gain"].append(
                    {"dist": dist, "m": m, "naive": d_naive,
                     "scored": d_scored})
                print(f"# stitch {dist} m={m}: naive={d_naive:.1f} "
                      f"scored={d_scored:.1f}")

    wall = time.time() - t0
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    parity_ok = all(r <= 1.05 for r in ratios.values())
    ratio_str = " ".join(f"{d}={r:.2f}" for d, r in ratios.items())
    n_rows = 2 + len(results["sweeps"])
    return {"name": "fig14_parallel",
            "us_per_call": wall * 1e6 / n_rows,
            "derived": f"construction {speedup:.1f}x vs host loop at "
                       f"N={gate_n}/M={gate_m}; diam ratio@8 {ratio_str}",
            "passes_gate": speedup >= 5.0 and parity_ok}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--seeds", type=int, nargs="+", default=[0, 1, 2])
    ap.add_argument("--partitions", type=int, nargs="+",
                    default=[1, 2, 4, 8, 16, 32])
    ap.add_argument("--stitch", default="scored")
    args = ap.parse_args()
    print(run(n=args.n, partitions=tuple(args.partitions),
              seeds=tuple(args.seeds), stitch=args.stitch))
