"""Churn maintenance throughput + dynamic diameter trajectories (fig. 16).

Part A — the gate.  A deterministic stream of churn ops (edge inserts,
joins, leaves) over an N-node overlay is applied to
``dynamics.IncrementalDistances`` two ways:

  * ``incremental`` — O(N^2) relaxations, tombstones, threshold rebuilds;
  * ``full``        — a from-scratch batched APSP (``core.batcheval``)
                      after every event: exactly what the static stack did.

The acceptance gate is >= 5x churn-events/sec for incremental over full at
N=128 (enforced by ``benchmarks.run`` via ``passes_gate``).  A third row
reports the batched-replica path (``relax_edge_stream_batched``: B scenario
replicas advanced in one device call).

Part B — end-to-end trajectories.  Every scenario in
``dynamics.scenarios.SCENARIOS`` is replayed against DGRO / Chord / RAPID /
Perigee policies; we report mean/peak/final overlay diameter and live-node
counts.  Results are also written to ``BENCH_fig16_churn.json`` so CI can
archive the perf trajectory across PRs.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax.numpy as jnp

from repro.core.diameter import adjacency_from_edges, ring_edges
from repro.dynamics import POLICIES, SCENARIOS, ChurnEngine, IncrementalDistances
from repro.dynamics.incremental import relax_edge_stream_batched
from repro.core.topology import make_latency


def _initial_state(w: np.ndarray, n_live: int, seed: int):
    """Overlay of two random rings over the first ``n_live`` slots."""
    cap = w.shape[0]
    rng = np.random.default_rng(seed)
    alive = np.zeros(cap, bool)
    alive[:n_live] = True
    edges = np.concatenate([ring_edges(rng.permutation(n_live))
                            for _ in range(2)])
    return adjacency_from_edges(w, edges), alive


def _make_ops(n_live: int, capacity: int, n_ops: int, seed: int):
    """Deterministic churn op stream with its own membership bookkeeping."""
    rng = np.random.default_rng(seed)
    live = list(range(n_live))
    dead = list(range(n_live, capacity))
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.70 or len(live) < 8:
            u, v = rng.choice(live, size=2, replace=False)
            ops.append(("add", int(u), int(v)))
        elif r < 0.85 and dead:
            u = dead.pop(0)
            nbrs = [int(x) for x in rng.choice(live, size=3, replace=False)]
            ops.append(("join", u, tuple(nbrs)))
            live.append(u)
        else:
            u = live.pop(int(rng.integers(len(live))))
            dead.append(u)
            ops.append(("leave", u, ()))
    return ops


def _apply_ops(inc: IncrementalDistances, ops) -> None:
    for op in ops:
        if op[0] == "add":
            inc.add_edge(op[1], op[2])
        elif op[0] == "join":
            inc.join(op[1], list(op[2]))
        else:
            inc.leave(op[1])
    np.asarray(inc.distances)      # block until device work is done


def _bench_mode(w, adj, alive, ops, mode: str, threshold: int,
                repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        inc = IncrementalDistances(w, adj, alive, mode=mode,
                                   rebuild_threshold=threshold)
        t0 = time.perf_counter()
        _apply_ops(inc, ops)
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_batched_stream(w, adj, alive, b: int, t_steps: int,
                          seed: int) -> float:
    """Events/sec of the one-device-call batched insert stream."""
    rng = np.random.default_rng(seed)
    live = np.flatnonzero(alive)
    dist0 = IncrementalDistances(w, adj, alive).distances
    dists = jnp.asarray(np.repeat(dist0[None], b, axis=0))
    iu = rng.integers(0, len(live), size=(t_steps, b))
    off = rng.integers(1, len(live), size=(t_steps, b))
    us = live[iu]
    vs = live[(iu + off) % len(live)]        # distinct from us by construction
    ws = w[us, vs].astype(np.float32)
    args = (jnp.asarray(us), jnp.asarray(vs), jnp.asarray(ws))
    relax_edge_stream_batched(dists, *args).block_until_ready()   # warm jit
    t0 = time.perf_counter()
    relax_edge_stream_batched(dists, *args).block_until_ready()
    dt = time.perf_counter() - t0
    return (t_steps * b) / dt


def run(n_gate: int = 128, gate_ops: int = 80, gate_threshold: int = 16,
        traj_n0: int = 32, seed: int = 0, batch_replicas: int = 16,
        out_json: str = "BENCH_fig16_churn.json"):
    t0 = time.time()
    results = {"gate": {}, "trajectories": []}

    # ---- part A: maintenance throughput gate at N=n_gate -----------------
    capacity = n_gate + max(8, gate_ops // 5)
    w = make_latency("bitnode", capacity, seed=seed + 7)
    adj, alive = _initial_state(w, n_gate, seed)
    ops = _make_ops(n_gate, capacity, gate_ops, seed + 1)
    # warm both jit paths (compile outside the timed runs)
    _bench_mode(w, adj, alive, ops[:4], "incremental", gate_threshold, 1)
    _bench_mode(w, adj, alive, ops[:2], "full", gate_threshold, 1)

    t_inc = _bench_mode(w, adj, alive, ops, "incremental", gate_threshold)
    t_full = _bench_mode(w, adj, alive, ops, "full", gate_threshold)
    ev_batched = _bench_batched_stream(w, adj, alive, batch_replicas,
                                       max(8, gate_ops // 2), seed + 2)
    speedup = t_full / t_inc
    results["gate"] = {
        "n": n_gate, "ops": gate_ops, "rebuild_threshold": gate_threshold,
        "events_per_s_incremental": gate_ops / t_inc,
        "events_per_s_full": gate_ops / t_full,
        "events_per_s_batched_stream": ev_batched,
        "batch_replicas": batch_replicas,
        "speedup": speedup,
    }
    print("mode,n,events_per_s")
    print(f"full-recompute,{n_gate},{gate_ops / t_full:.0f}")
    print(f"incremental,{n_gate},{gate_ops / t_inc:.0f}")
    print(f"batched-stream[B={batch_replicas}],{n_gate},{ev_batched:.0f}")
    print(f"# incremental speedup {speedup:.1f}x (gate >= 5x)")

    # ---- part B: scenario x policy diameter trajectories -----------------
    print("scenario,policy,events,n_live_end,mean_diam,peak_diam,final_diam,"
          "mean_stretch,rebuilds")
    results["initial_overlays"] = {}
    for sname, make in SCENARIOS.items():
        trace = make(n0=traj_n0, seed=seed + 3)
        if any(e.kind.startswith("cluster_") for e in trace.events):
            # cluster reorg scenarios need the hierarchical engine; the
            # flat-policy trajectory comparison here skips them (fig21
            # exercises them through HierChurnEngine)
            continue
        for pname, P in POLICIES.items():
            eng = ChurnEngine(trace, P(), seed=seed + 4,
                              detect_failures=True, route_probe=4)
            if pname == "dgro":
                # snapshot what the DGRO replay started from (replayable
                # next to the trace JSON via Overlay.from_json)
                results["initial_overlays"][sname] = json.loads(
                    eng.initial_overlay.to_json())
            # exact sampling: trajectories compare true diameters across
            # policies, not the incremental maintenance lower bound
            res = eng.run(sample_exact=True)
            row = {
                "scenario": sname, "policy": pname,
                "events": len(trace.events),
                "n_live_end": res.samples[-1].n_live,
                "mean_diameter": res.mean_diameter,
                "peak_diameter": res.peak_diameter,
                "final_diameter": res.final_diameter,
                "mean_stretch": res.mean_stretch,
                "rebuilds": res.stats["rebuilds"],
            }
            results["trajectories"].append(row)
            print(f"{sname},{pname},{row['events']},{row['n_live_end']},"
                  f"{row['mean_diameter']:.1f},{row['peak_diameter']:.1f},"
                  f"{row['final_diameter']:.1f},{row['mean_stretch']:.2f},"
                  f"{row['rebuilds']}")

    wall = time.time() - t0
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    n_rows = 3 + len(results["trajectories"])
    return {"name": "fig16_churn",
            "us_per_call": wall * 1e6 / n_rows,
            "derived": f"incremental {speedup:.1f}x vs full recompute "
                       f"at N={n_gate}",
            "passes_gate": speedup >= 5.0}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-gate", type=int, default=128)
    ap.add_argument("--gate-ops", type=int, default=80)
    ap.add_argument("--traj-n0", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(run(n_gate=args.n_gate, gate_ops=args.gate_ops,
              traj_n0=args.traj_n0, seed=args.seed))
