"""Greedy routing workloads over every overlay family (fig. 19).

Part A — the gate.  A (P, 2) batch of uniform pairs is greedy-routed over
a Chord overlay two ways:

  * ``device`` — ``routing.route_pairs``: the whole batch in ONE jit'd
    fixed-length ``lax.scan`` with masked termination;
  * ``host``   — ``routing.route_pairs_host``: the per-pair numpy loop
    (the same float32 next-hop rule, and the serving path behind the
    control plane's ``/v1/route``).

Three hard conditions (enforced by ``benchmarks.run`` via ``passes_gate``):
the device router is >= 5x the host loop at N=256, P=1024; hop / latency /
success parity with the host reference is exact at a fixed seed (both
next-hop policies); and greedy success is 1.0 on the connected overlay.
A fourth rides along from ``core.rollout``: ``stretch_weight=0.0`` is
bit-identical to the unshaped episode engine (and 0.5 is not).

Part B — the stretch matrix.  Every builder in {dgro, dgro-dqn, chord,
perigee, kleinberg, papillon} x every workload mix (uniform / hotspot /
regional) x both policies is routed and summarized
(``routing.summarize``); rows land in ``BENCH_fig19_routing.json`` and
every batch is recorded into the shared ``repro_route_*`` instruments.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax

from repro import overlay, routing
from repro.core.topology import make_latency

BUILDERS = ("dgro", "dgro-dqn", "chord", "perigee", "kleinberg", "papillon")

# the matrix measures routing quality, not construction quality: dgro-dqn
# skips training (epochs=0 keeps the Q net at init) because compiling the
# fused train_epoch scan at N=256 takes minutes on CPU, while the
# construction-only vmapped rollout it still exercises compiles in seconds
_BUILD_OVERRIDES = {"dgro-dqn": dict(k=2, epochs=0, n_starts=2)}


def _build(name: str, w: np.ndarray, seed: int) -> overlay.Overlay:
    return overlay.build(name, w, seed=seed, **_BUILD_OVERRIDES.get(name, {}))


def _time_device(adj, dist, pairs, ring, policy: str, budget: int,
                 repeats: int = 3) -> float:
    routing.route_pairs(adj, dist, pairs, policy=policy, ring=ring,
                        hop_budget=budget)            # warm the jit cache
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        routing.route_pairs(adj, dist, pairs, policy=policy, ring=ring,
                            hop_budget=budget)
        best = min(best, time.perf_counter() - t0)
    return best


def _gate(n: int, n_pairs: int, seed: int) -> dict:
    w = make_latency("bitnode", n, seed=seed)
    ov = _build("chord", w, seed)
    adj = np.asarray(ov.adjacency, np.float32)
    dist = np.asarray(ov.distances(), np.float32)
    ring = np.asarray(ov.rings[0])
    pairs = routing.sample_pairs(n, n_pairs, "uniform", seed=seed + 1)
    # ~8x the deepest walk either policy takes on a Chord overlay (O(log N)
    # hops): the masked scan's fixed length prices the device path, and the
    # host loop early-exits regardless, so the comparison stays apples-to-
    # apples while success must still hit 1.0 within the budget
    budget = min(64, n)

    parity = True
    success = {}
    t_host = float("inf")
    for policy in routing.POLICIES:
        dev = routing.route_pairs(adj, dist, pairs, policy=policy,
                                  ring=ring, hop_budget=budget)
        t0 = time.perf_counter()
        host = routing.route_pairs_host(adj, dist, pairs, policy=policy,
                                        ring=ring, hop_budget=budget)
        if policy == "latency":
            t_host = time.perf_counter() - t0
        parity &= (np.array_equal(dev.hops, host.hops)
                   and np.array_equal(dev.latency, host.latency)
                   and np.array_equal(dev.success, host.success))
        success[policy] = float(dev.success.mean())
        routing.record_route_batch(policy, dev)
    t_dev = _time_device(adj, dist, pairs, ring, "latency", budget)
    speedup = t_host / t_dev
    return {
        "n": n, "pairs": n_pairs, "hop_budget": budget,
        "t_device_s": t_dev, "t_host_s": t_host, "speedup": speedup,
        "parity": bool(parity),
        "success_rate_latency": success["latency"],
        "success_rate_ring": success["ring"],
    }


def _rollout_parity(seed: int) -> bool:
    """stretch_weight=0.0 must be bit-identical to the unshaped engine."""
    import jax.numpy as jnp

    from repro.core import rollout
    from repro.core.embedding import init_qparams

    n, k, n_envs = 8, 2, 2
    params = init_qparams(jax.random.PRNGKey(seed), 8, 16)
    ws = jnp.asarray(np.stack([make_latency("uniform", n, seed=seed + i)
                               for i in range(n_envs)]), jnp.float32)
    plan = rollout.make_plan(np.random.default_rng(seed), n_envs, k, n)
    args = (params, ws, jnp.asarray(plan.starts), jnp.asarray(plan.eps_u),
            jnp.asarray(plan.choice_u), 0.3, 0.1)
    base = rollout.rollout_episodes(*args, k_rings=k, n_rounds=2)
    zero = rollout.rollout_episodes(*args, k_rings=k, n_rounds=2,
                                    stretch_weight=0.0)
    shaped = rollout.rollout_episodes(*args, k_rings=k, n_rounds=2,
                                      stretch_weight=0.5)
    identical = all(np.array_equal(np.asarray(a), np.asarray(b))
                    for a, b in zip(base, zero))
    differs = not np.array_equal(np.asarray(base[1]), np.asarray(shaped[1]))
    return identical and differs


def run(n_gate: int = 256, gate_pairs: int = 1024, matrix_n: int = 256,
        matrix_pairs: int = 256, seed: int = 0,
        out_json: str = "BENCH_fig19_routing.json"):
    t0 = time.time()
    results = {"gate": {}, "matrix": []}

    # ---- part A: device-vs-host gate at N=n_gate, P=gate_pairs -----------
    results["gate"] = _gate(n_gate, gate_pairs, seed)
    results["gate"]["rollout_parity"] = _rollout_parity(seed)
    g = results["gate"]
    print(f"# router device {g['t_device_s'] * 1e3:.1f}ms vs host "
          f"{g['t_host_s'] * 1e3:.1f}ms at N={n_gate}, P={gate_pairs} "
          f"-> {g['speedup']:.1f}x (gate >= 5x); parity={g['parity']}; "
          f"success latency={g['success_rate_latency']:.3f} "
          f"ring={g['success_rate_ring']:.3f}; "
          f"rollout stretch_weight parity={g['rollout_parity']}")

    # ---- part B: builder x workload x policy stretch matrix --------------
    w = make_latency("bitnode", matrix_n, seed=seed + 2)
    print("builder,workload,policy,success,hops_mean,stretch_mean,"
          "stretch_p99")
    for builder in BUILDERS:
        ov = _build(builder, w, seed)
        adj = np.asarray(ov.adjacency, np.float32)
        dist = np.asarray(ov.distances(), np.float32)
        ring = np.asarray(ov.rings[0])
        for workload in routing.WORKLOADS:
            pairs = routing.sample_pairs(matrix_n, matrix_pairs, workload,
                                         seed=seed + 3)
            for policy in routing.POLICIES:
                res = routing.route_pairs(adj, dist, pairs, policy=policy,
                                          ring=ring, hop_budget=matrix_n)
                routing.record_route_batch(policy, res)
                s = routing.summarize(res, builder=builder,
                                      workload=workload, policy=policy,
                                      n=matrix_n, hop_budget=matrix_n)
                results["matrix"].append(s.to_dict())
                print(f"{builder},{workload},{policy},"
                      f"{s.success_rate:.3f},{s.hops_mean:.2f},"
                      f"{s.stretch_mean:.3f},{s.stretch_p99:.3f}")

    wall = time.time() - t0
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    passes = (g["speedup"] >= 5.0 and g["parity"]
              and g["success_rate_latency"] == 1.0
              and g["success_rate_ring"] == 1.0 and g["rollout_parity"])
    n_rows = 1 + len(results["matrix"])
    return {"name": "fig19-routing",
            "us_per_call": wall * 1e6 / n_rows,
            "derived": f"device router {g['speedup']:.1f}x vs host at "
                       f"N={n_gate}, P={gate_pairs}; "
                       f"{len(results['matrix'])} matrix cells",
            "passes_gate": passes}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-gate", type=int, default=256)
    ap.add_argument("--gate-pairs", type=int, default=1024)
    ap.add_argument("--matrix-n", type=int, default=256)
    ap.add_argument("--matrix-pairs", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(run(n_gate=args.n_gate, gate_pairs=args.gate_pairs,
              matrix_n=args.matrix_n, matrix_pairs=args.matrix_pairs,
              seed=args.seed))
