"""Paper Fig. 10: DGRO vs genetic algorithm vs random (diameter + time).

Diameters are normalized by the random-K-ring result (paper's normalization).
DGRO builds n_starts topologies and keeps the best (paper: 10 starts) — with
``--rollout device`` (default) all n_starts constructions run as ONE vmapped
batched rollout call through ``repro.core.rollout``; the GA searches
``--ga-budget`` topologies (paper: 1e5).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import overlay
from repro.core.ga import GAConfig, ga_search, random_search
from repro.core.qlearning import DQNConfig, dgro_overlay, train_dqn
from repro.core.topology import make_latency


def run(n: int = 14, epochs: int = 50, ga_budget: int = 1000,
        k_rings: int = 2, n_graphs: int = 3, n_starts: int = 5, seed: int = 0,
        rollout: str = "device"):
    cfg = DQNConfig(n=n, k_rings=k_rings, epochs=epochs,
                    eps_decay=max(epochs // 2, 1), seed=seed, rollout=rollout)
    t0 = time.time()
    params, _ = train_dqn(cfg, eval_every=epochs)
    train_s = time.time() - t0

    rows = []
    for g in range(n_graphs):
        w = make_latency("uniform", n, seed=500 + g)
        rng = np.random.default_rng(g)
        d_rand = overlay.build("random", w,
                               overlay.RandomRingsConfig(k=k_rings),
                               rng=rng).diameter()
        t0 = time.time()
        d_dgro = dgro_overlay(params, cfg, w, n_starts=n_starts,
                              seed=g).diameter()
        t_dgro = time.time() - t0
        t0 = time.time()
        _, d_ga, evals = ga_search(w, GAConfig(k_rings=k_rings,
                                               budget=ga_budget, seed=g))
        t_ga = time.time() - t0
        rows.append((d_dgro / d_rand, d_ga / d_rand, t_dgro, t_ga))
        print(f"graph {g}: rand={d_rand:.1f} dgro={d_dgro:.1f} "
              f"({t_dgro:.1f}s) ga={d_ga:.1f} ({t_ga:.1f}s, {evals} evals)")

    dgro_norm = float(np.mean([r[0] for r in rows]))
    ga_norm = float(np.mean([r[1] for r in rows]))
    t_dgro = float(np.mean([r[2] for r in rows]))
    t_ga = float(np.mean([r[3] for r in rows]))
    print(f"# normalized: dgro={dgro_norm:.3f} ga={ga_norm:.3f} "
          f"(train {train_s:.0f}s, infer {t_dgro:.1f}s vs ga {t_ga:.1f}s, "
          f"rollout={rollout})")
    return {"name": "fig10_dgro_vs_ga",
            "us_per_call": t_dgro * 1e6,
            "derived": f"norm-diam dgro={dgro_norm:.2f} ga={ga_norm:.2f}",
            "dgro_not_worse": dgro_norm <= ga_norm * 1.15}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=14)
    ap.add_argument("--epochs", type=int, default=50)
    ap.add_argument("--ga-budget", type=int, default=1000)
    ap.add_argument("--rollout", default="device", choices=["device", "host"])
    args = ap.parse_args()
    run(args.n, args.epochs, args.ga_budget, rollout=args.rollout)
