"""Paper Figs. 5/6/7 + 11/15: DGRO's adaptive ring selection reduces the
diameter of Chord, RAPID and Perigee.

For each protocol and network size we build the stock overlay through the
``repro.overlay`` registry, measure rho (Alg. 3) and apply the selected ring
swap (``Overlay.replace_rings`` / the builder's ``ring="nearest"`` knob);
report the stock vs DGRO diameter.  ``--dist`` picks the latency
distribution (uniform / gaussian = Fig. 11; fabric / bitnode = Fig. 15).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro import overlay
from repro.core.construction import nearest_ring
from repro.core.diameter import diameter_scipy
from repro.core.selection import (clustering_ratio, measure_latency_stats,
                                  select_ring_kind)
from repro.core.topology import make_latency


def _chord_overlays(w, rng):
    stock = overlay.build("chord", w, overlay.ChordConfig(ring="random"),
                          rng=rng)
    swapped = overlay.build("chord", w, overlay.ChordConfig(ring="nearest"),
                            rng=rng)
    return stock, swapped


def _rapid_overlays(w, rng):
    stock = overlay.build("rapid", w, rng=rng)
    new_first = nearest_ring(w, start=int(rng.integers(w.shape[0])))
    swapped = stock.replace_rings([new_first] + list(stock.rings[1:]))
    return stock, swapped


def _perigee_overlays(w, rng):
    stock = overlay.build("perigee", w, overlay.PerigeeConfig(ring="nearest"),
                          rng=rng)
    swapped = overlay.build("perigee", w, overlay.PerigeeConfig(ring="random"),
                            rng=rng)
    return stock, swapped


BUILDERS = {"chord": _chord_overlays, "rapid": _rapid_overlays,
            "perigee": _perigee_overlays}


def run(dist: str = "uniform", sizes=(50, 100, 200), seed: int = 0):
    t0 = time.time()
    rows = []
    print("protocol,n,rho,selected,stock_diam,dgro_diam,improvement")
    for proto, build in BUILDERS.items():
        for n in sizes:
            w = make_latency(dist, n, seed=seed + n)
            rng = np.random.default_rng(seed)
            stock, swapped = build(w, rng)
            stats = measure_latency_stats(w, stock.adjacency, seed=seed)
            rho = clustering_ratio(stats)
            kind = select_ring_kind(rho)
            d_stock = diameter_scipy(stock.adjacency)
            d_swap = diameter_scipy(swapped.adjacency)
            # DGRO keeps the better per its selection; "keep" -> stock
            d_dgro = d_swap if kind != "keep" else min(d_stock, d_swap)
            imp = (d_stock - d_dgro) / d_stock
            rows.append((proto, n, rho, d_stock, d_dgro, imp))
            print(f"{proto},{n},{rho:.2f},{kind},{d_stock:.1f},{d_dgro:.1f},"
                  f"{imp * 100:.0f}%")
    mean_imp = float(np.mean([r[5] for r in rows]))
    wall = time.time() - t0
    print(f"# dist={dist} mean improvement={mean_imp * 100:.0f}%")
    return {"name": f"fig11_ring_selection[{dist}]",
            "us_per_call": wall * 1e6 / len(rows),
            "derived": f"mean diam reduction {mean_imp * 100:.0f}%",
            "improves": mean_imp > 0.0}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dist", default="uniform",
                    choices=["uniform", "gaussian", "fabric", "bitnode"])
    ap.add_argument("--sizes", type=int, nargs="+", default=[50, 100, 200])
    args = ap.parse_args()
    run(args.dist, tuple(args.sizes))
