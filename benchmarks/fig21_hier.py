"""Hierarchical overlay scale + correctness gates (fig. 21).

Part A — the scale gate.  Builds a two-level hierarchical overlay over
N = 10^5 synthetic-geography nodes (lazy ``LatencyModel`` — the dense
(N, N) float32 matrix would be 40 GB), then boots a
:class:`repro.hier.HierChurnEngine` over the same fleet and streams
>= 200 churn events through it (joins, leaves, plus one cluster split
and one merge to exercise the reorg path).  The gate is that construct
+ maintain completes on CPU within the CI wall-clock budget and the
maintained diameter bound stays finite.

Part B — bound validity at small N, where the hierarchy can be
materialized into a dense global :class:`repro.overlay.Overlay` and
checked against exact APSP:

  * ``diameter_bound("exact")`` equals the materialized exact diameter
    and is <= 1.5x the flat ``"dgro"`` builder's exact diameter;
  * ``diameter_bound("ecc")`` is stamped ``"upper"`` and never
    underestimates;
  * served inter-cluster ``distance_bound_pairs`` values are provable
    lower bounds on (in fact equal to) the materialized exact APSP.

Part C — flat parity.  The topology-protocol refactor must leave the
flat path bit-identical: ``Overlay.to_json`` stays schema-1, round-trips
byte-for-byte, and preserves the exact diameter.

Results land in ``BENCH_fig21_hier.json``; ``benchmarks.run`` enforces
``passes_gate`` (the AND of all three parts).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.topology import make_latency
from repro.dynamics.scenarios import Event, poisson_churn
from repro.hier import DenseLatency, HierChurnEngine, build_hier, synthetic_geo
from repro.overlay import Overlay, build


def _scale_gate(n_large: int, events: int, budget_s: float, seed: int) -> dict:
    """Part A: N=n_large construct + >=200-event maintain, on CPU."""
    t0 = time.perf_counter()
    horizon = 30_000.0
    # 1.4x rate margin: the Poisson draw must not undershoot the >=200
    # events the gate demands (std at 280 expected is ~17)
    rate = 1.4 * events / 2 / horizon
    trace = poisson_churn(n0=n_large, dist="bitnode", seed=seed,
                          horizon=horizon, join_rate=rate, leave_rate=rate)
    # two reorg events on top of the node churn: split cluster 0, merge 1+2
    tmax = max((e.time for e in trace.events), default=0.0)
    trace.events.append(Event(time=tmax + 1.0, kind="cluster_split", node=0))
    trace.events.append(Event(time=tmax + 2.0, kind="cluster_merge",
                              node=1, peer=2))
    lat = synthetic_geo(trace.capacity, seed=seed + 1)

    t = time.perf_counter()
    hov = build_hier(lat, seed=seed)
    build_s = time.perf_counter() - t
    diam_ub, ub_stamp = hov.diameter_bound("ecc")

    t = time.perf_counter()
    eng = HierChurnEngine(trace, lat=lat, seed=seed)
    init_s = time.perf_counter() - t
    t = time.perf_counter()
    for e in sorted(trace.events, key=lambda e: e.time):
        eng.process(e)
    maintain_s = time.perf_counter() - t
    t = time.perf_counter()
    diam_maint = eng.diameter()
    diam_s = time.perf_counter() - t
    elapsed = time.perf_counter() - t0

    applied = eng.events_processed
    out = {
        "n": n_large, "capacity": trace.capacity,
        "clusters_built": hov.n_clusters,
        "clusters_end": eng.n_clusters,
        "events_applied": applied,
        "build_s": build_s, "engine_init_s": init_s,
        "maintain_s": maintain_s, "events_per_s": applied / maintain_s,
        "diameter_bound": diam_ub, "diameter_bound_stamp": ub_stamp,
        "diameter_maintained": diam_maint, "diameter_s": diam_s,
        "reorg": dict(eng.reorg_stats),
        "elapsed_s": elapsed, "budget_s": budget_s,
        "passes": bool(applied >= 200 and elapsed <= budget_s
                       and np.isfinite(diam_maint) and diam_maint > 0
                       and ub_stamp == "upper"),
    }
    print(f"scale: N={n_large} build {build_s:.1f}s "
          f"({hov.n_clusters} clusters), engine init {init_s:.1f}s, "
          f"{applied} events in {maintain_s:.1f}s "
          f"({out['events_per_s']:.1f} ev/s), "
          f"maintained diameter {diam_maint:.1f} "
          f"(total {elapsed:.0f}s / budget {budget_s:.0f}s)")
    return out


def _bound_gate(n_small: int, seed: int) -> dict:
    """Part B: hier bounds vs exact APSP + flat DGRO at N<=512."""
    w = make_latency("bitnode", n_small, seed=seed + 2)
    flat = build("dgro", w, seed=seed)
    flat_d = float(flat.diameter())

    # every cross path pays two gateway legs, so the head eccentricities
    # bound the hier/flat gap; at small N (where the dense matrix fits
    # anyway) the extra degree of 12 local rings is affordable and keeps
    # the ratio comfortably under the 1.5x gate across seeds
    from repro.hier import HierConfig
    hov = build_hier(DenseLatency(w), HierConfig(k_local=12), seed=seed)
    hd, hd_stamp = hov.diameter_bound("exact")
    ub, ub_stamp = hov.diameter_bound("ecc")
    mat = hov.materialize()
    exact_d = float(mat.diameter())
    tol = 1e-4 * max(1.0, exact_d)

    # every sampled inter-cluster served distance vs the exact APSP of the
    # materialized hier topology: must be a provable lower bound (heads are
    # the only gateways, so the three-leg composition is in fact exact)
    rng = np.random.default_rng(seed + 3)
    us = rng.integers(0, n_small, size=512)
    vs = rng.integers(0, n_small, size=512)
    inter = hov.assignment[us] != hov.assignment[vs]
    us, vs = us[inter], vs[inter]
    served, served_stamp = hov.distance_bound_pairs(us, vs)
    apsp = np.asarray(mat.distances(), np.float64)[us, vs]
    lower_ok = bool(np.all(served >= apsp - tol))
    max_abs_gap = float(np.max(np.abs(served - apsp))) if us.size else 0.0

    out = {
        "n": n_small, "clusters": hov.n_clusters,
        "flat_dgro_diameter": flat_d,
        "hier_diameter_exact": float(hd), "exact_stamp": hd_stamp,
        "hier_diameter_ecc": float(ub), "ecc_stamp": ub_stamp,
        "materialized_diameter": exact_d,
        "ratio_vs_flat": float(hd) / flat_d,
        "inter_cluster_pairs": int(us.size),
        "served_stamp": served_stamp,
        "max_abs_gap_vs_apsp": max_abs_gap,
        "passes": bool(
            hd_stamp == "exact" and abs(hd - exact_d) <= tol
            and ub_stamp == "upper" and ub >= exact_d - tol
            and hd <= 1.5 * flat_d + tol
            and us.size > 0 and lower_ok),
    }
    print(f"bounds: N={n_small} hier exact {hd:.1f} "
          f"(materialized {exact_d:.1f}, ecc upper {ub:.1f}), "
          f"flat dgro {flat_d:.1f} -> ratio {out['ratio_vs_flat']:.2f}x "
          f"(gate <= 1.5x); {us.size} inter-cluster pairs, "
          f"max |served - apsp| = {max_abs_gap:.2e}")
    return out


def _flat_parity(n: int, seed: int) -> dict:
    """Part C: the flat serde path is byte-identical and stays schema 1."""
    w = make_latency("uniform", n, seed=seed + 4)
    ov = build("dgro", w, seed=seed)
    s = ov.to_json()
    schema = json.loads(s).get("schema", 1)
    rt = Overlay.from_json(s)
    identical = rt.to_json() == s
    diam_eq = float(rt.diameter()) == float(ov.diameter())
    out = {
        "n": n, "schema": schema, "round_trip_identical": identical,
        "diameter_equal": diam_eq,
        "passes": bool(schema == 1 and identical and diam_eq),
    }
    print(f"flat parity: N={n} schema={schema} "
          f"byte-identical={identical} diameter-equal={diam_eq}")
    return out


def run(n_large: int = 100_000, events: int = 200, budget_s: float = 900.0,
        n_small: int = 384, seed: int = 0,
        out_json: str = "BENCH_fig21_hier.json"):
    t0 = time.time()
    results = {
        "scale": _scale_gate(n_large, events, budget_s, seed),
        "bound": _bound_gate(n_small, seed),
        "flat_parity": _flat_parity(max(32, n_small // 2), seed),
    }
    wall = time.time() - t0
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    passes = all(results[k]["passes"] for k in ("scale", "bound",
                                                "flat_parity"))
    sc, bd = results["scale"], results["bound"]
    return {"name": "fig21_hier",
            "us_per_call": wall * 1e6 / max(1, sc["events_applied"]),
            "derived": (f"N={n_large} maintain {sc['events_per_s']:.0f} ev/s"
                        f"; hier/flat diameter {bd['ratio_vs_flat']:.2f}x"),
            "passes_gate": passes}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-large", type=int, default=100_000)
    ap.add_argument("--events", type=int, default=200)
    ap.add_argument("--budget-s", type=float, default=900.0)
    ap.add_argument("--n-small", type=int, default=384)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(run(n_large=args.n_large, events=args.events,
              budget_s=args.budget_s, n_small=args.n_small, seed=args.seed))
