"""Paper Figs. 12/16: ablation — M random rings of K total (RAPID hybrid).

For M = 0..K we build K-ring overlays with M random + (K-M) nearest rings
and report the diameter per latency distribution.  Reproduces the paper's
observation that no single M wins across distributions/sizes — the
motivation for DGRO's adaptive selection.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import batcheval
from repro.core.construction import default_num_rings, k_rings
from repro.core.topology import make_latency


def run(dists=("uniform", "gaussian"), sizes=(50, 100, 200), seed: int = 0):
    t0 = time.time()
    print("dist,n,k,m_random,diameter")
    best_m = {}
    count = 0
    for dist in dists:
        for n in sizes:
            w = make_latency(dist, n, seed=seed + n)
            k = max(2, default_num_rings(n) // 2)
            rng = np.random.default_rng(seed)
            # all K+1 mixes scored as ONE batched device call
            mixes = [k_rings(w, k, kind=f"mixed:{m}", rng=rng)
                     for m in range(k + 1)]
            diams = batcheval.diameters_of_rings(
                w, np.stack([np.stack(r) for r in mixes]))
            for m, d in enumerate(diams):
                print(f"{dist},{n},{k},{m},{d:.1f}")
                count += 1
            best_m[(dist, n)] = int(np.argmin(diams))
    uniq = sorted(set(best_m.values()))
    wall = time.time() - t0
    print(f"# best M per (dist, n): {best_m} — unique bests: {uniq}")
    return {"name": "fig12_ring_ablation",
            "us_per_call": wall * 1e6 / max(count, 1),
            "derived": f"best-M varies across settings: {len(uniq) > 1}",
            "no_single_winner": len(uniq) > 1}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[50, 100, 200])
    ap.add_argument("--dists", nargs="+", default=["uniform", "gaussian"])
    args = ap.parse_args()
    run(tuple(args.dists), tuple(args.sizes))
