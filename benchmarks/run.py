"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (reduced CPU-scale defaults;
each figure module has CLI flags for the full-scale sweeps).

Gates are FIRST-CLASS: every figure declares in ``GATES`` whether it is
informational or carries a hard pass/fail condition, which boolean key in
its result dict the harness enforces, and which ``BENCH_*.json`` metric
records the latest measured value.  ``--list`` prints the registry with the
latest values without running anything.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--list]
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import io
import json
import os
import sys
import traceback
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Gate:
    """A figure's declared pass/fail contract.

    ``key`` names the boolean in the figure's result dict the harness
    enforces; ``None`` marks a purely informational figure (its soft
    indicators print but never fail the run).  ``bench_file`` /
    ``bench_metric`` (a dotted path) locate the latest measured value in
    the figure's emitted ``BENCH_*.json`` for ``--list``.
    """

    description: str
    key: Optional[str] = None
    bench_file: Optional[str] = None
    bench_metric: Optional[str] = None

    @property
    def hard(self) -> bool:
        return self.key is not None

    def passes(self, res: dict) -> bool:
        if self.key is None:
            return True
        if self.key not in res:
            raise KeyError(
                f"gate declares key {self.key!r} but the figure result "
                f"only has {sorted(res)}")
        return bool(res[self.key])


GATES = {
    "fig09": Gate("device rollout >= 10x host steps/s at N=32, E=8",
                  key="passes_gate", bench_file="BENCH_fig09_dqn.json",
                  bench_metric="rollout_gate.speedup"),
    "fig10": Gate("informational: DGRO norm-diam within 1.15x of GA"),
    "fig11-uniform": Gate("informational: adapt reduces mean diameter"),
    "fig11-gaussian": Gate("informational: adapt reduces mean diameter"),
    "fig15-fabric": Gate("informational: adapt reduces mean diameter"),
    "fig15-bitnode": Gate("informational: adapt reduces mean diameter"),
    "fig12": Gate("informational: best ring count M varies by setting"),
    "fig13": Gate("informational: dgro <= min(random, nearest) per size"),
    "fig17-bitnode": Gate("informational: dgro <= min(random, nearest)"),
    "fig14": Gate("batched construction >= 5x host loop at N=256, M=8 "
                  "and diameter parity <= 1.05",
                  key="passes_gate", bench_file="BENCH_fig14_parallel.json",
                  bench_metric="gate_speedup.speedup"),
    "fig15-batcheval": Gate("batched eval >= 5x scipy at the largest batch",
                            key="passes_gate"),
    "fig16-churn": Gate("incremental maintenance >= 5x full recompute "
                        "at N=128",
                        key="passes_gate", bench_file="BENCH_fig16_churn.json",
                        bench_metric="gate.speedup"),
    "fig17-service": Gate("query p99 stays bounded during in-flight reopt "
                          "and restart diameter == pre-crash snapshot",
                          key="passes_gate",
                          bench_file="BENCH_fig17_service.json",
                          bench_metric="gate.query_p99_ms_during_reopt"),
    "fig18-obs": Gate("instrumented throughput within 5% of disabled path, "
                      "scraped counters exact, histogram p99 within bucket",
                      key="passes_gate", bench_file="BENCH_fig18_obs.json",
                      bench_metric="gate.overhead_pct"),
    "fig19-routing": Gate("vmapped router >= 5x host per-pair loop at "
                          "P=1024, host parity at fixed seed, greedy "
                          "success 1.0",
                          key="passes_gate",
                          bench_file="BENCH_fig19_routing.json",
                          bench_metric="gate.speedup"),
    "fig20-scale": Gate("streamed facade bit-identical to the pre-engine "
                        "direct path at N<=256, tiled FW parity, and peak "
                        "working set < dense (B,N,N)/2 at the largest N",
                        key="passes_gate",
                        bench_file="BENCH_fig20_scale.json",
                        bench_metric="gate.largest_n_diam_per_s"),
    "fig21-hier": Gate("N=1e5 hier construct+maintain (>=200 churn events) "
                       "within CPU budget, hier diameter <= 1.5x flat exact "
                       "at small N, served distances lower-bound exact APSP, "
                       "flat serde byte-identical",
                       key="passes_gate", bench_file="BENCH_fig21_hier.json",
                       bench_metric="scale.events_per_s"),
    "roofline": Gate("informational: kernel roofline table renders"),
}


def _bench_value(gate: Gate) -> str:
    """Latest measured value for --list, from the figure's BENCH json."""
    if gate.bench_file is None:
        return "-"
    if not os.path.exists(gate.bench_file):
        return "(no run yet)"
    try:
        with open(gate.bench_file) as f:
            node = json.load(f)
        for part in (gate.bench_metric or "").split("."):
            node = node[part]
        return f"{node:.2f}" if isinstance(node, float) else str(node)
    except (KeyError, TypeError, ValueError) as e:
        return f"(unreadable: {e!r})"


def list_gates() -> None:
    print(f"{'figure':<16} {'gate':<6} {'latest':<14} condition")
    for name, gate in GATES.items():
        kind = "HARD" if gate.hard else "info"
        print(f"{name:<16} {kind:<6} {_bench_value(gate):<14} "
              f"{gate.description}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="minimal sizes (CI smoke)")
    ap.add_argument("--verbose", action="store_true",
                    help="stream per-figure detail output")
    ap.add_argument("--list", action="store_true",
                    help="print figure -> gate -> latest BENCH value, "
                         "run nothing")
    args = ap.parse_args()

    if args.list:
        list_gates()
        return

    from benchmarks import (fig09_training_curve, fig10_dgro_vs_ga,
                            fig11_ring_selection, fig12_ring_ablation,
                            fig13_kring_compare, fig14_parallel,
                            fig15_batcheval, fig16_churn, fig17_service,
                            fig18_obs, fig19_routing, fig20_scale,
                            fig21_hier, roofline_table)

    fast = args.fast
    jobs = [
        # the >=10x device-vs-host rollout gate always runs at N=32, E=8;
        # --fast only shrinks the training curve
        ("fig09", lambda: fig09_training_curve.run(
            n=10 if fast else 14, epochs=16 if fast else 120,
            bench_n=32, bench_envs=8)),
        ("fig10", lambda: fig10_dgro_vs_ga.run(
            n=10 if fast else 14, epochs=16 if fast else 50,
            ga_budget=200 if fast else 1000)),
        ("fig11-uniform", lambda: fig11_ring_selection.run(
            "uniform", (30, 60) if fast else (50, 100, 200))),
        ("fig11-gaussian", lambda: fig11_ring_selection.run(
            "gaussian", (30, 60) if fast else (50, 100, 200))),
        ("fig15-fabric", lambda: fig11_ring_selection.run(
            "fabric", (30, 60) if fast else (50, 100, 200))),
        ("fig15-bitnode", lambda: fig11_ring_selection.run(
            "bitnode", (30, 60) if fast else (50, 100, 200))),
        ("fig12", lambda: fig12_ring_ablation.run(
            sizes=(30, 60) if fast else (50, 100, 200))),
        ("fig13", lambda: fig13_kring_compare.run(
            "uniform", (30, 60) if fast else (50, 100, 200),
            ga_budget=100 if fast else 300)),
        ("fig17-bitnode", lambda: fig13_kring_compare.run(
            "bitnode", (30, 60) if fast else (50, 100, 200),
            ga_budget=100 if fast else 300)),
        # the >=5x batched-vs-host construction gate always runs at N=256,
        # M=8, and the <=1.05 diameter-parity gate on uniform+bitnode; --fast
        # only shrinks the M sweep and the seed fleet
        ("fig14", lambda: fig14_parallel.run(
            seeds=(0, 1) if fast else (0, 1, 2),
            partitions=(1, 8, 32) if fast else (1, 2, 4, 8, 16, 32))),
        ("fig15-batcheval", lambda: fig15_batcheval.run(
            bs=(1, 8, 64) if fast else (1, 8, 64, 256),
            ns=(32, 64) if fast else (32, 64, 128, 256),
            scipy_cap=16 if fast else 64)),
        # the >=5x incremental-vs-full gate always runs at N=128; --fast
        # only shrinks the op stream and the trajectory fleets
        ("fig16-churn", lambda: fig16_churn.run(
            gate_ops=40 if fast else 80,
            traj_n0=24 if fast else 48)),
        # the service gate always exercises a live daemon + crash/restart;
        # --fast only shrinks the event stream
        ("fig17-service", lambda: fig17_service.run(
            events=60 if fast else 200,
            n0=64 if fast else 128)),
        # the <=5% instrumentation-overhead gate always runs at N=64 over
        # 240 events (smaller runs finish in ~15ms and timer noise swamps
        # the delta); --fast only trims the repeat count (kept even so the
        # A/B order alternation balances run positions)
        ("fig18-obs", lambda: fig18_obs.run(
            repeats=2 if fast else 4)),
        # the >=5x router gate + host parity + success 1.0 always run at
        # N=256, P=1024; --fast only shrinks the stretch matrix
        ("fig19-routing", lambda: fig19_routing.run(
            matrix_n=64 if fast else 256,
            matrix_pairs=128 if fast else 256)),
        # the parity + memory gates always run at N=256, B<=64; --fast
        # shrinks the scaling sweep, full caps the timed candidates at
        # N>=2048 (the honest B=64 N=4096 cell is the module's __main__)
        ("fig20-scale", lambda: fig20_scale.run(
            ns=(64, 128, 256) if fast else (256, 1024, 4096),
            b=16 if fast else 64,
            b_cap=None if fast else 8)),
        # the hier gates always run at N=1e5 (scale) and N<=512 (bound
        # validity vs exact APSP + flat parity); --fast only trims the
        # churn stream toward the >=200-event floor and the small-N size
        ("fig21-hier", lambda: fig21_hier.run(
            events=200 if fast else 300,
            n_small=256 if fast else 384)),
        ("roofline", roofline_table.run),
    ]

    undeclared = [name for name, _ in jobs if name not in GATES]
    assert not undeclared, f"jobs missing a GATES entry: {undeclared}"

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in jobs:
        gate = GATES[name]
        buf = io.StringIO()
        try:
            if args.verbose:
                res = fn()
            else:
                with contextlib.redirect_stdout(buf):
                    res = fn()
            if gate.passes(res):
                print(f"{res['name']},{res['us_per_call']:.1f},{res['derived']}")
            else:
                failures += 1
                print(f"{res['name']},{res['us_per_call']:.1f},"
                      f"GATE FAILED ({gate.description}): {res['derived']}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR {e!r}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
