"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (reduced CPU-scale defaults;
each figure module has CLI flags for the full-scale sweeps).

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import contextlib
import io
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="minimal sizes (CI smoke)")
    ap.add_argument("--verbose", action="store_true",
                    help="stream per-figure detail output")
    args = ap.parse_args()

    from benchmarks import (fig09_training_curve, fig10_dgro_vs_ga,
                            fig11_ring_selection, fig12_ring_ablation,
                            fig13_kring_compare, fig14_parallel,
                            fig15_batcheval, fig16_churn, roofline_table)

    fast = args.fast
    jobs = [
        # the >=10x device-vs-host rollout gate always runs at N=32, E=8;
        # --fast only shrinks the training curve
        ("fig09", lambda: fig09_training_curve.run(
            n=10 if fast else 14, epochs=16 if fast else 120,
            bench_n=32, bench_envs=8)),
        ("fig10", lambda: fig10_dgro_vs_ga.run(
            n=10 if fast else 14, epochs=16 if fast else 50,
            ga_budget=200 if fast else 1000)),
        ("fig11-uniform", lambda: fig11_ring_selection.run(
            "uniform", (30, 60) if fast else (50, 100, 200))),
        ("fig11-gaussian", lambda: fig11_ring_selection.run(
            "gaussian", (30, 60) if fast else (50, 100, 200))),
        ("fig15-fabric", lambda: fig11_ring_selection.run(
            "fabric", (30, 60) if fast else (50, 100, 200))),
        ("fig15-bitnode", lambda: fig11_ring_selection.run(
            "bitnode", (30, 60) if fast else (50, 100, 200))),
        ("fig12", lambda: fig12_ring_ablation.run(
            sizes=(30, 60) if fast else (50, 100, 200))),
        ("fig13", lambda: fig13_kring_compare.run(
            "uniform", (30, 60) if fast else (50, 100, 200),
            ga_budget=100 if fast else 300)),
        ("fig17-bitnode", lambda: fig13_kring_compare.run(
            "bitnode", (30, 60) if fast else (50, 100, 200),
            ga_budget=100 if fast else 300)),
        # the >=5x batched-vs-host construction gate always runs at N=256,
        # M=8, and the <=1.05 diameter-parity gate on uniform+bitnode; --fast
        # only shrinks the M sweep and the seed fleet
        ("fig14", lambda: fig14_parallel.run(
            seeds=(0, 1) if fast else (0, 1, 2),
            partitions=(1, 8, 32) if fast else (1, 2, 4, 8, 16, 32))),
        ("fig15-batcheval", lambda: fig15_batcheval.run(
            bs=(1, 8, 64) if fast else (1, 8, 64, 256),
            ns=(32, 64) if fast else (32, 64, 128, 256),
            scipy_cap=16 if fast else 64)),
        # the >=5x incremental-vs-full gate always runs at N=128; --fast
        # only shrinks the op stream and the trajectory fleets
        ("fig16-churn", lambda: fig16_churn.run(
            gate_ops=40 if fast else 80,
            traj_n0=24 if fast else 48)),
        ("roofline", roofline_table.run),
    ]

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in jobs:
        buf = io.StringIO()
        try:
            if args.verbose:
                res = fn()
            else:
                with contextlib.redirect_stdout(buf):
                    res = fn()
            # hard gates opt in via 'passes_gate' (fig09's >=10x rollout,
            # fig15's and fig16's >=5x throughput claims); soft
            # 'holds'/'improves' stay informational
            if res.get("passes_gate", True):
                print(f"{res['name']},{res['us_per_call']:.1f},{res['derived']}")
            else:
                failures += 1
                print(f"{res['name']},{res['us_per_call']:.1f},"
                      f"GATE FAILED: {res['derived']}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR {e!r}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
