"""Batched topology-evaluation throughput: batcheval vs per-candidate loops.

Sweeps batch size B and graph size N; for each cell, scores B random K-ring
genomes end to end (ring permutations -> overlay adjacency -> diameter),
three ways:

  * ``loop-scipy``  — per-candidate ``adjacency_from_rings`` + host Dijkstra
                      (``diameter_scipy``): exactly the path the GA /
                      selection / parallel consumers used before batcheval;
  * ``loop-jax``    — per-candidate assembly + jit'd ``diameter`` (one
                      device call per candidate);
  * ``batched``     — vectorized ``adjacency_batch_from_rings`` + ONE
                      ``batcheval.diameters`` call over the (B, N, N) stack.

Reports evaluations/second and the batched speedup over the scipy loop.
The acceptance gate for this figure is >= 5x at (B=64, N=64) on CPU; the
returned ``passes_gate`` flag is enforced by ``benchmarks.run`` (a False
gate fails the sweep).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax.numpy as jnp

from repro.core import batcheval
from repro.core.diameter import (adjacency_from_rings, diameter,
                                 diameter_scipy)
from repro.core.topology import make_latency


def _bench(fn, repeats: int = 5) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(bs=(1, 8, 64, 256), ns=(32, 64, 256), k_rings: int = 2,
        seed: int = 0, scipy_cap: int = 64):
    """Returns the harness row; prints one CSV line per (B, N) cell.

    ``scipy_cap`` bounds how many candidates the per-candidate loops
    actually time (extrapolated linearly beyond) so the slow baselines do
    not dominate wall-clock at B=256.
    """
    t0 = time.time()
    rng = np.random.default_rng(seed)
    print("B,N,evals_per_s_loop_scipy,evals_per_s_loop_jax,evals_per_s_batched,"
          "speedup_vs_scipy_loop")
    gate = None
    rows = 0
    for n in ns:
        w = make_latency("uniform", n, seed=seed + n)
        for b in bs:
            genomes = np.stack(
                [[rng.permutation(n) for _ in range(k_rings)]
                 for _ in range(b)])

            def eval_loop_scipy(m):
                return [diameter_scipy(adjacency_from_rings(w, list(genomes[i])))
                        for i in range(m)]

            def eval_loop_jax(m):
                return [float(diameter(jnp.asarray(
                    adjacency_from_rings(w, list(genomes[i])))))
                    for i in range(m)]

            def eval_batched():
                return np.asarray(batcheval.diameters_of_rings(w, genomes))

            m = min(b, scipy_cap)
            t_scipy = _bench(lambda: eval_loop_scipy(m)) * (b / m)
            t_jax = _bench(lambda: eval_loop_jax(m)) * (b / m)
            eval_batched()                                 # warm the jit cache
            t_batch = _bench(eval_batched)

            speedup = t_scipy / t_batch
            if (b, n) == (64, 64):
                gate = speedup
            rows += 1
            print(f"{b},{n},{b / t_scipy:.0f},{b / t_jax:.0f},"
                  f"{b / t_batch:.0f},{speedup:.1f}x")
    wall = time.time() - t0
    derived = (f"B=64 N=64 speedup {gate:.1f}x vs per-candidate scipy loop"
               if gate is not None else "gate cell not swept")
    return {"name": "fig15_batcheval",
            "us_per_call": wall * 1e6 / max(1, rows),
            "derived": derived,
            "passes_gate": gate is None or gate >= 5.0}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--bs", type=int, nargs="+", default=[1, 8, 64, 256])
    ap.add_argument("--ns", type=int, nargs="+", default=[32, 64, 128, 256])
    ap.add_argument("--k-rings", type=int, default=2)
    args = ap.parse_args()
    print(run(tuple(args.bs), tuple(args.ns), args.k_rings))
